"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts

Each artifact gets a sibling ``<name>.meta.json`` describing its argument
and result shapes so the Rust runtime can validate inputs without parsing
HLO. A top-level ``manifest.json`` indexes everything.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points():
    """(name, fn, example_args) for every artifact we ship."""
    m = model
    coords = _spec((m.N_ATOMS, 3))
    vels = _spec((m.N_ATOMS, 3))
    batch = _spec((m.BATCH, m.INPUT_DIM))
    lr = _spec(())
    params = tuple(_spec(shape) for _name, shape in m.PARAM_SHAPES)
    return [
        ("md_step", m.entry_md_step, (coords, vels)),
        ("contact_map", m.entry_contact_map, (coords,)),
        ("ae_train", m.entry_ae_train, params + (batch, lr)),
        ("ae_infer", m.entry_ae_infer, params + (batch,)),
        ("ae_encode", m.entry_ae_encode, params + (batch,)),
        ("sanity", m.entry_sanity, (_spec((2, 2)), _spec((2, 2)))),
    ]


def _shape_meta(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_one(name, fn, args, out_dir):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)

    out_tree = jax.eval_shape(fn, *args)
    meta = {
        "name": name,
        "args": [_shape_meta(a) for a in args],
        "results": [_shape_meta(r) for r in jax.tree_util.tree_leaves(out_tree)],
        "hlo_sha256": hashlib.sha256(text.encode()).hexdigest(),
        "hlo_bytes": len(text),
    }
    with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", nargs="*", default=None, help="subset of entry points"
    )
    ns = ap.parse_args()
    os.makedirs(ns.out_dir, exist_ok=True)

    manifest = {"artifacts": []}
    for name, fn, args in entry_points():
        if ns.only and name not in ns.only:
            continue
        meta = lower_one(name, fn, args, ns.out_dir)
        manifest["artifacts"].append(meta)
        print(f"  lowered {name}: {meta['hlo_bytes']} bytes of HLO text")

    manifest["model"] = {
        "n_atoms": model.N_ATOMS,
        "input_dim": model.INPUT_DIM,
        "hidden_dim": model.HIDDEN_DIM,
        "latent_dim": model.LATENT_DIM,
        "batch": model.BATCH,
        "md_substeps": model.MD_SUBSTEPS,
        "param_order": [name for name, _ in model.PARAM_SHAPES],
    }
    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
