"""Blocked matmul Pallas kernel (the autoencoder's dense-layer hot spot).

TPU adaptation of the CUDA dense layer: instead of WMMA warp tiles we use
MXU-shaped (up to 128x128) VMEM blocks, a k-loop grid dimension that
accumulates into a VMEM scratch-like output block, and BlockSpec index
maps expressing the HBM->VMEM schedule that a CUDA implementation would
express with threadblocks + shared-memory staging.

``matmul`` wraps the kernel in ``jax.custom_vjp`` so that ``jax.grad``
through the autoencoder uses the *same* Pallas kernel for the backward
matmuls (dA = g @ B^T, dB = A^T @ g) rather than falling back to XLA dot.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, preferred: int = 128) -> int:
    """Largest power-of-two block <= preferred that divides ``dim``.

    MXU tiles are 128x128; smaller dims fall back to the dim itself
    (all model dims are powers of two >= 8).
    """
    b = min(dim, preferred)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


#: VMEM budget for one grid cell's resident blocks (A, B and the output
#: accumulator). Real TPU cores have ~16 MiB of VMEM; budgeting half
#: leaves room for double buffering of the HBM->VMEM pipeline.
VMEM_BUDGET_BYTES = 8 * 2**20


def pick_blocks(m: int, k: int, n: int, budget: int = VMEM_BUDGET_BYTES):
    """Choose (bm, bn, bk) minimizing grid steps under the VMEM budget.

    Fewer, larger blocks win twice: on TPU they amortize the HBM<->VMEM
    transfers per MXU pass; under interpret=True they collapse the
    lowered grid while-loop (the perf pass measured 52 ms -> 0.7 ms on
    the autoencoder's (32,4096)@(4096,256) layer by growing bk from 128
    to the full K). Greedy order: maximize bk (kills the accumulator
    loop), then bn, then bm.
    """

    def fits(bm, bn, bk):
        return 4 * (bm * bk + bk * bn + bm * bn) <= budget

    bm, bn, bk = _pick_block(m, 256), 1, 1
    # Largest power-of-two divisor of `dim` that keeps us within budget.
    def grow(dim, cur_fits):
        b = dim
        while b > 1 and not cur_fits(b):
            b //= 2
            while dim % b != 0 and b > 1:
                b //= 2
        return max(b, 1)

    bk = grow(k, lambda b: fits(bm, 1, b))
    bn = grow(n, lambda b: fits(bm, b, bk))
    if not fits(bm, bn, bk):
        bm = grow(m, lambda b: fits(b, bn, bk))
    return bm, bn, bk


def _matmul_kernel(a_ref, b_ref, o_ref):
    """Grid = (M/bm, N/bn, K/bk); k is the innermost (minor) grid dim.

    The output block index map ignores k, so the same VMEM output block
    is revisited across the k loop and serves as the accumulator —
    the Pallas analogue of a shared-memory accumulator tile.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_pallas_raw(a, b, bm=None, bn=None, bk=None):
    """Raw pallas_call wrapper: (M,K) @ (K,N) -> (M,N), fp32 accumulate."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    if bm is None and bn is None and bk is None:
        bm, bn, bk = pick_blocks(m, k, n)
    else:
        bm = bm or _pick_block(m)
        bn = bn or _pick_block(n)
        bk = bk or _pick_block(k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def matmul(a, b):
    """Differentiable blocked matmul; fwd and bwd all run on the L1 kernel."""
    return matmul_pallas_raw(a, b)


def _matmul_fwd(a, b):
    return matmul_pallas_raw(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    da = matmul_pallas_raw(g, b.T)
    db = matmul_pallas_raw(a.T, g)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)
