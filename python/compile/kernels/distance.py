"""Pairwise squared-distance / contact-map Pallas kernel.

This is the featurization hot spot of DeepDriveMD's CVAE pipeline: MD
frames (N_atoms x 3 coordinates) become N x N contact maps consumed by
the autoencoder.

TPU adaptation: a CUDA version assigns one thread per (i, j) pair; here
each grid cell computes a (BM x BN) tile of the distance matrix in VMEM
using the MXU-friendly decomposition

    d2[i, j] = |a_i|^2 + |b_j|^2 - 2 * a_i . b_j

so the dominant term is a (BM x 3) @ (3 x BN) matmul instead of a
scalar loop. The coordinate panel is tiny (3 columns), so both row
panels stay resident in VMEM for the whole tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist2_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]  # (bm, 3) row block of coordinates
    b = b_ref[...]  # (bn, 3) column block of coordinates
    na = jnp.sum(a * a, axis=1, keepdims=True)      # (bm, 1)
    nb = jnp.sum(b * b, axis=1, keepdims=True).T    # (1, bn)
    cross = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    d2 = na + nb - 2.0 * cross
    # Clamp tiny negatives produced by the subtractive formulation.
    o_ref[...] = jnp.maximum(d2, 0.0)


def _pick_block(dim: int, preferred: int = 64) -> int:
    b = min(dim, preferred)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def pairwise_dist2(coords, bm=None, bn=None):
    """(N, 3) coordinates -> (N, N) squared distances, fp32."""
    n, d = coords.shape
    assert d == 3, f"expected (N, 3) coordinates, got {coords.shape}"
    bm = bm or _pick_block(n)
    bn = bn or _pick_block(n)
    grid = (n // bm, n // bn)
    return pl.pallas_call(
        _dist2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 3), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(coords, coords)


@functools.partial(jax.jit, static_argnames=("threshold",))
def contact_map(coords, threshold=1.6):
    """(N, 3) coordinates -> (N, N) contact map in {0.0, 1.0}.

    A pair is "in contact" when its distance is below ``threshold``
    (squared compare — no sqrt on the hot path).
    """
    d2 = pairwise_dist2(coords)
    return (d2 < threshold * threshold).astype(jnp.float32)
