"""L1 Pallas kernels for the DeepDriveMD-style ML/MD compute.

Every kernel here is written for TPU idioms (VMEM tiles, MXU-shaped
matmuls, BlockSpec HBM<->VMEM schedules) but lowered with
``interpret=True`` so the CPU PJRT client can execute the resulting HLO.
See DESIGN.md section "Hardware adaptation".
"""

from .matmul import matmul, matmul_pallas_raw
from .distance import pairwise_dist2, contact_map
from .lj import lj_forces

__all__ = [
    "matmul",
    "matmul_pallas_raw",
    "pairwise_dist2",
    "contact_map",
    "lj_forces",
]
