"""Lennard-Jones force Pallas kernel — the MD "Simulation" task's hot spot.

Forces on a block of particles are accumulated over column blocks of
interaction partners:

    grid = (N/bm, N/bk); for a fixed row block i the kernel is revisited
    for every partner block k and accumulates partial force sums into the
    same (bm, 3) VMEM output block — the Pallas analogue of keeping a
    per-threadblock force accumulator in CUDA shared memory.

The LJ pair force (epsilon = sigma = 1, as in reduced units):

    F_i = sum_j 24 * (2 * r2inv^6 - r2inv^3) * r2inv * (x_i - x_j)

with ``r2inv = 1 / (d2 + softening)`` and the diagonal (i == j) masked.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SOFTENING = 1e-6


def _lj_kernel(a_ref, b_ref, o_ref, *, bm, bk, cutoff2):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    i = pl.program_id(0)
    a = a_ref[...]  # (bm, 3)
    b = b_ref[...]  # (bk, 3)
    # displacement tensor (bm, bk, 3)
    disp = a[:, None, :] - b[None, :, :]
    d2 = jnp.sum(disp * disp, axis=-1)  # (bm, bk)
    # Mask self-interaction: global row ids vs global col ids.
    rows = i * bm + jax.lax.iota(jnp.int32, bm)
    cols = k * bk + jax.lax.iota(jnp.int32, bk)
    self_mask = rows[:, None] == cols[None, :]
    within = d2 < cutoff2
    r2inv = 1.0 / (d2 + SOFTENING)
    r6inv = r2inv * r2inv * r2inv
    mag = 24.0 * (2.0 * r6inv * r6inv - r6inv) * r2inv  # (bm, bk)
    mag = jnp.where(self_mask | ~within, 0.0, mag)
    o_ref[...] += jnp.sum(mag[:, :, None] * disp, axis=1)


def _pick_block(dim: int, preferred: int = 32) -> int:
    b = min(dim, preferred)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "cutoff"))
def lj_forces(coords, bm=None, bk=None, cutoff=3.0):
    """(N, 3) coordinates -> (N, 3) Lennard-Jones forces (reduced units)."""
    n, d = coords.shape
    assert d == 3, f"expected (N, 3) coordinates, got {coords.shape}"
    bm = bm or _pick_block(n)
    bk = bk or _pick_block(n)
    grid = (n // bm, n // bk)
    kernel = functools.partial(
        _lj_kernel, bm=bm, bk=bk, cutoff2=float(cutoff) ** 2
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 3), lambda i, k: (i, 0)),
            pl.BlockSpec((bk, 3), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 3), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 3), jnp.float32),
        interpret=True,
    )(coords, coords)
