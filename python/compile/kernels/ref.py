"""Pure-jnp oracles for every L1 kernel (the correctness contract).

These are deliberately written in the most direct vectorized style, with
no blocking or Pallas constructs, so that a mismatch localizes the bug
to the kernel's tiling/index maps.
"""

import jax.numpy as jnp

SOFTENING = 1e-6


def matmul_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def pairwise_dist2_ref(coords):
    disp = coords[:, None, :] - coords[None, :, :]
    return jnp.sum(disp * disp, axis=-1)


def contact_map_ref(coords, threshold=1.6):
    d2 = pairwise_dist2_ref(coords)
    return (d2 < threshold * threshold).astype(jnp.float32)


def lj_forces_ref(coords, cutoff=3.0):
    n = coords.shape[0]
    disp = coords[:, None, :] - coords[None, :, :]  # (n, n, 3)
    d2 = jnp.sum(disp * disp, axis=-1)
    eye = jnp.eye(n, dtype=bool)
    within = d2 < cutoff * cutoff
    r2inv = 1.0 / (d2 + SOFTENING)
    r6inv = r2inv ** 3
    mag = 24.0 * (2.0 * r6inv * r6inv - r6inv) * r2inv
    mag = jnp.where(eye | ~within, 0.0, mag)
    return jnp.sum(mag[:, :, None] * disp, axis=1)


def lj_energy_ref(coords, cutoff=3.0):
    n = coords.shape[0]
    d2 = pairwise_dist2_ref(coords)
    eye = jnp.eye(n, dtype=bool)
    within = d2 < cutoff * cutoff
    r2inv = 1.0 / (d2 + SOFTENING)
    r6inv = r2inv ** 3
    e = 4.0 * (r6inv * r6inv - r6inv)
    e = jnp.where(eye | ~within, 0.0, e)
    return 0.5 * jnp.sum(e)
