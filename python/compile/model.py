"""L2: the JAX compute graphs behind DeepDriveMD's four task types.

DeepDriveMD (Brace et al., IPDPS 2022) couples MD simulation with a
convolutional variational autoencoder over contact maps. Our reproduction
uses the same pipeline shape with TPU-friendly stand-ins:

  Simulation  -> ``md_step``:       velocity-Verlet Lennard-Jones dynamics
                                    (forces from the L1 ``lj_forces`` kernel)
  Aggregation -> ``frame_features``: contact-map featurization of a frame
                                    (L1 ``pairwise_dist2`` kernel)
  Training    -> ``ae_train_step``: one SGD step of an MLP autoencoder whose
                                    dense layers run on the L1 ``matmul``
                                    kernel fwd AND bwd (custom_vjp)
  Inference   -> ``ae_infer``:      per-sample reconstruction error
                (``ae_encode``)     / latent embedding

Everything here is lowered ONCE by ``aot.py`` to HLO text and executed
from the Rust coordinator via PJRT. Python never runs at workflow time.

Model dimensions (defaults): N_ATOMS=64 atoms -> 64x64 contact map ->
flattened 4096 -> 256 -> LATENT=16 -> 256 -> 4096. All powers of two so
the Pallas block pickers tile exactly.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul, pairwise_dist2, contact_map
from .kernels.lj import lj_forces
from .kernels.ref import SOFTENING

# ---------------------------------------------------------------------------
# Default model geometry
# ---------------------------------------------------------------------------

N_ATOMS = 64
INPUT_DIM = N_ATOMS * N_ATOMS  # flattened contact map
HIDDEN_DIM = 256
LATENT_DIM = 16
BATCH = 32
MD_SUBSTEPS = 10
DT = 1e-3
CONTACT_THRESHOLD = 1.6
LJ_CUTOFF = 3.0

#: Parameter layout, in the exact order the AOT entry points take them.
PARAM_SHAPES = (
    ("w1", (INPUT_DIM, HIDDEN_DIM)),
    ("b1", (HIDDEN_DIM,)),
    ("w2", (HIDDEN_DIM, LATENT_DIM)),
    ("b2", (LATENT_DIM,)),
    ("w3", (LATENT_DIM, HIDDEN_DIM)),
    ("b3", (HIDDEN_DIM,)),
    ("w4", (HIDDEN_DIM, INPUT_DIM)),
    ("b4", (INPUT_DIM,)),
)


def init_params(key):
    """He-initialized autoencoder parameters as a flat tuple of arrays."""
    params = []
    for _name, shape in PARAM_SHAPES:
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            fan_in = shape[0]
            params.append(
                jax.random.normal(sub, shape, jnp.float32)
                * jnp.sqrt(2.0 / fan_in)
            )
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return tuple(params)


# ---------------------------------------------------------------------------
# Autoencoder (Training / Inference task bodies)
# ---------------------------------------------------------------------------


def _dense(x, w, b):
    """Dense layer on the L1 blocked-matmul kernel."""
    return matmul(x, w) + b


def ae_forward(params, x):
    """Full autoencoder forward: returns (reconstruction, latent)."""
    w1, b1, w2, b2, w3, b3, w4, b4 = params
    h = jnp.tanh(_dense(x, w1, b1))
    z = _dense(h, w2, b2)  # latent, linear
    h2 = jnp.tanh(_dense(z, w3, b3))
    recon = _dense(h2, w4, b4)  # linear output (inputs are {0,1} maps)
    return recon, z


def ae_loss(params, x):
    """Mean-squared reconstruction error over the batch."""
    recon, _ = ae_forward(params, x)
    return jnp.mean((recon - x) ** 2)


def ae_train_step(params, x, lr):
    """One SGD step. Returns (new_params..., loss).

    Gradients flow through the Pallas matmul via its custom_vjp, so the
    backward pass also runs on the L1 kernel.
    """
    loss, grads = jax.value_and_grad(ae_loss)(params, x)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return new_params + (loss,)


def ae_infer(params, x):
    """Per-sample reconstruction error — DeepDriveMD's outlier score."""
    recon, _ = ae_forward(params, x)
    return jnp.mean((recon - x) ** 2, axis=1)


def ae_encode(params, x):
    """Latent embedding of a batch (used for novelty analysis)."""
    _, z = ae_forward(params, x)
    return z


# ---------------------------------------------------------------------------
# Molecular dynamics (Simulation task body)
# ---------------------------------------------------------------------------


def lj_energy(coords, cutoff=LJ_CUTOFF):
    """Total LJ potential energy, distances from the L1 distance kernel."""
    n = coords.shape[0]
    d2 = pairwise_dist2(coords)
    eye = jnp.eye(n, dtype=bool)
    within = d2 < cutoff * cutoff
    r2inv = 1.0 / (d2 + SOFTENING)
    r6inv = r2inv ** 3
    e = 4.0 * (r6inv * r6inv - r6inv)
    e = jnp.where(eye | ~within, 0.0, e)
    return 0.5 * jnp.sum(e)


def md_step(coords, vels, substeps=MD_SUBSTEPS, dt=DT):
    """``substeps`` velocity-Verlet LJ steps (mass = 1, reduced units).

    Returns (coords', vels', potential_energy) — one "Simulation" work
    quantum. The Rust Simulation task invokes this repeatedly, saving a
    contact-map frame per call.
    """

    def body(state, _):
        x, v = state
        f = lj_forces(x)
        v_half = v + 0.5 * dt * f
        x_new = x + dt * v_half
        f_new = lj_forces(x_new)
        v_new = v_half + 0.5 * dt * f_new
        return (x_new, v_new), None

    (coords, vels), _ = jax.lax.scan(body, (coords, vels), None, length=substeps)
    return coords, vels, lj_energy(coords)


def frame_features(coords, threshold=CONTACT_THRESHOLD):
    """Aggregation featurization: frame -> flattened contact map row."""
    return contact_map(coords, threshold=threshold).reshape(-1)


# ---------------------------------------------------------------------------
# AOT entry points (flat-argument signatures for the Rust side)
# ---------------------------------------------------------------------------
# The Rust runtime feeds xla::Literal positional arguments; keep these
# flat (no pytrees) and return tuples.


def entry_md_step(coords, vels):
    return md_step(coords, vels)


def entry_contact_map(coords):
    return (frame_features(coords),)


def entry_ae_train(w1, b1, w2, b2, w3, b3, w4, b4, x, lr):
    return ae_train_step((w1, b1, w2, b2, w3, b3, w4, b4), x, lr)


def entry_ae_infer(w1, b1, w2, b2, w3, b3, w4, b4, x):
    return (ae_infer((w1, b1, w2, b2, w3, b3, w4, b4), x),)


def entry_ae_encode(w1, b1, w2, b2, w3, b3, w4, b4, x):
    return (ae_encode((w1, b1, w2, b2, w3, b3, w4, b4), x),)


def entry_sanity(x, y):
    """Tiny smoke computation for runtime integration tests."""
    return (jnp.matmul(x, y) + 2.0,)
