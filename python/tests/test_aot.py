"""AOT pipeline tests: entry-point metadata, manifest consistency, and
HLO-text stability (the exact contract the Rust runtime consumes)."""

import hashlib
import json
import os

import jax
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def entries():
    return aot.entry_points()


def test_every_entry_lowerable_to_hlo_text(entries, tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    for name, fn, args in entries:
        meta = aot.lower_one(name, fn, args, str(out))
        hlo_path = out / f"{name}.hlo.txt"
        assert hlo_path.exists()
        text = hlo_path.read_text()
        # HLO text (not a serialized proto): module header present.
        assert text.lstrip().startswith("HloModule"), name
        assert meta["hlo_bytes"] == len(text)
        assert meta["hlo_sha256"] == hashlib.sha256(text.encode()).hexdigest()
        # ENTRY computation exists and it is a tuple return
        # (return_tuple=True contract relied on by runtime/mod.rs).
        assert "ENTRY" in text, name


def test_metadata_shapes_match_eval_shape(entries, tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts2")
    for name, fn, args in entries:
        meta = aot.lower_one(name, fn, args, str(out))
        sidecar = json.loads((out / f"{name}.meta.json").read_text())
        assert sidecar == meta
        shape_tree = jax.eval_shape(fn, *args)
        leaves = jax.tree_util.tree_leaves(shape_tree)
        assert len(sidecar["results"]) == len(leaves), name
        for rec, leaf in zip(sidecar["results"], leaves):
            assert rec["shape"] == list(leaf.shape), name


def test_manifest_written_by_repo_build():
    """If the repo's artifacts/ exists, its manifest must be coherent."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("run `make artifacts` first")
    manifest = json.load(open(manifest_path))
    names = {a["name"] for a in manifest["artifacts"]}
    assert {"md_step", "contact_map", "ae_train", "ae_infer", "sanity"} <= names
    for a in manifest["artifacts"]:
        hlo = open(os.path.join(art, f"{a['name']}.hlo.txt")).read()
        assert hashlib.sha256(hlo.encode()).hexdigest() == a["hlo_sha256"], a["name"]
    m = manifest["model"]
    assert m["input_dim"] == m["n_atoms"] ** 2
    assert m["param_order"] == [n for n, _ in model.PARAM_SHAPES]


def test_ae_train_entry_argcount_matches_params():
    _, _, args = next(e for e in aot.entry_points() if e[0] == "ae_train")
    # 8 params + batch + lr
    assert len(args) == len(model.PARAM_SHAPES) + 2
