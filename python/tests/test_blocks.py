"""Property tests for the VMEM-budgeted block chooser (the §Perf L1
optimization): blocks must always divide the dims, respect the budget,
and never regress correctness."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import (
    matmul_pallas_raw,
    pick_blocks,
    VMEM_BUDGET_BYTES,
)
from compile.kernels import ref


@given(
    mexp=st.integers(0, 12),
    kexp=st.integers(0, 13),
    nexp=st.integers(0, 12),
)
@settings(max_examples=200, deadline=None)
def test_pick_blocks_divides_and_fits(mexp, kexp, nexp):
    m, k, n = 2**mexp, 2**kexp, 2**nexp
    bm, bn, bk = pick_blocks(m, k, n)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert 4 * (bm * bk + bk * bn + bm * bn) <= VMEM_BUDGET_BYTES or (
        bm == 1 and bn == 1 and bk == 1
    )


@given(
    mexp=st.integers(0, 6),
    kexp=st.integers(0, 8),
    nexp=st.integers(0, 6),
    seed=st.integers(0, 100),
)
@settings(max_examples=15, deadline=None)
def test_budgeted_blocks_match_ref(mexp, kexp, nexp, seed):
    m, k, n = 2**mexp, 2**kexp, 2**nexp
    a = jax.random.normal(jax.random.PRNGKey(seed), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n), jnp.float32)
    got = matmul_pallas_raw(a, b)  # uses pick_blocks
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-3, atol=1e-3)


def test_model_layer_shapes_get_single_grid_cell():
    # The autoencoder's big layers collapse to a loop-free grid.
    bm, bn, bk = pick_blocks(32, 4096, 256)
    assert bk == 4096, "k-loop eliminated for the (32,4096)@(4096,256) layer"
    assert (32 // bm) * (256 // bn) * (4096 // bk) <= 2


def test_huge_dims_still_tile():
    bm, bn, bk = pick_blocks(8192, 8192, 8192)
    assert 4 * (bm * bk + bk * bn + bm * bn) <= VMEM_BUDGET_BYTES
    assert bm >= 1 and bn >= 1 and bk >= 1
