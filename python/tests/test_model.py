"""L2 model correctness: autoencoder training dynamics, MD physics,
entry-point shapes (the contract the Rust runtime relies on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m
from compile.kernels import ref


def key(i=0):
    return jax.random.PRNGKey(i)


@pytest.fixture(scope="module")
def params():
    return m.init_params(key(0))


@pytest.fixture(scope="module")
def batch():
    # Sparse binary contact-map-like batch.
    u = jax.random.uniform(key(1), (m.BATCH, m.INPUT_DIM))
    return (u < 0.15).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Autoencoder
# ---------------------------------------------------------------------------


def test_init_params_shapes(params):
    assert len(params) == len(m.PARAM_SHAPES)
    for p, (_n, shape) in zip(params, m.PARAM_SHAPES):
        assert p.shape == shape
        assert p.dtype == jnp.float32


def test_forward_shapes(params, batch):
    recon, z = m.ae_forward(params, batch)
    assert recon.shape == (m.BATCH, m.INPUT_DIM)
    assert z.shape == (m.BATCH, m.LATENT_DIM)


def test_loss_finite_positive(params, batch):
    loss = m.ae_loss(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0


def test_train_step_decreases_loss(params, batch):
    """A few SGD steps must strictly reduce reconstruction error."""
    p = params
    losses = []
    for _ in range(5):
        out = m.ae_train_step(p, batch, 0.05)
        p, loss = tuple(out[:-1]), float(out[-1])
        losses.append(loss)
    assert losses[-1] < losses[0], f"loss did not improve: {losses}"


def test_train_step_grad_matches_pure_jnp(params, batch):
    """Gradients via the Pallas custom_vjp == gradients of a pure-jnp AE."""

    def pure_forward(params, x):
        w1, b1, w2, b2, w3, b3, w4, b4 = params
        h = jnp.tanh(ref.matmul_ref(x, w1) + b1)
        z = ref.matmul_ref(h, w2) + b2
        h2 = jnp.tanh(ref.matmul_ref(z, w3) + b3)
        return ref.matmul_ref(h2, w4) + b4

    def pure_loss(params, x):
        return jnp.mean((pure_forward(params, x) - x) ** 2)

    g_kernel = jax.grad(m.ae_loss)(params, batch)
    g_pure = jax.grad(pure_loss)(params, batch)
    for gk, gp, (name, _) in zip(g_kernel, g_pure, m.PARAM_SHAPES):
        np.testing.assert_allclose(
            gk, gp, rtol=1e-3, atol=1e-4, err_msg=f"grad mismatch for {name}"
        )


def test_infer_scores_shape_and_outliers(params, batch):
    scores = m.ae_infer(params, batch)
    assert scores.shape == (m.BATCH,)
    assert np.isfinite(np.asarray(scores)).all()
    # A corrupted sample must score worse than the batch it was drawn from.
    trained = params
    for _ in range(30):
        out = m.ae_train_step(trained, batch, 0.05)
        trained = tuple(out[:-1])
    corrupted = batch.at[0].set(1.0 - batch[0])
    s = np.asarray(m.ae_infer(trained, corrupted))
    assert s[0] > np.median(s[1:])


def test_encode_shape(params, batch):
    z = m.ae_encode(params, batch)
    assert z.shape == (m.BATCH, m.LATENT_DIM)


# ---------------------------------------------------------------------------
# Molecular dynamics
# ---------------------------------------------------------------------------


def _lattice(n=m.N_ATOMS, spacing=1.2):
    side = int(np.ceil(n ** (1 / 3)))
    pts = [
        (i * spacing, j * spacing, k * spacing)
        for i in range(side)
        for j in range(side)
        for k in range(side)
    ]
    return jnp.asarray(pts[:n], jnp.float32)


def test_md_step_shapes():
    c0, v0 = _lattice(), jnp.zeros((m.N_ATOMS, 3), jnp.float32)
    c, v, e = m.md_step(c0, v0)
    assert c.shape == (m.N_ATOMS, 3) and v.shape == (m.N_ATOMS, 3)
    assert e.shape == ()


def test_md_energy_conservation():
    """Velocity-Verlet at small dt: total energy drift stays small."""
    c = _lattice()
    v = jax.random.normal(key(2), c.shape, jnp.float32) * 0.05

    def total_energy(c, v):
        return float(m.lj_energy(c)) + 0.5 * float(jnp.sum(v * v))

    e0 = total_energy(c, v)
    for _ in range(10):
        c, v, _pe = m.md_step(c, v, substeps=10, dt=1e-3)
    e1 = total_energy(c, v)
    assert abs(e1 - e0) / max(abs(e0), 1e-6) < 0.05, (e0, e1)


def test_md_momentum_conservation():
    c = _lattice()
    v = jax.random.normal(key(3), c.shape, jnp.float32) * 0.05
    p0 = np.asarray(jnp.sum(v, axis=0))
    for _ in range(5):
        c, v, _ = m.md_step(c, v)
    p1 = np.asarray(jnp.sum(v, axis=0))
    np.testing.assert_allclose(p0, p1, atol=1e-3)


def test_md_moves_particles():
    c = _lattice()
    v = jax.random.normal(key(4), c.shape, jnp.float32) * 0.1
    c2, _, _ = m.md_step(c, v)
    assert float(jnp.max(jnp.abs(c2 - c))) > 0


def test_frame_features_binary_flat():
    feats = m.frame_features(_lattice())
    assert feats.shape == (m.INPUT_DIM,)
    vals = set(np.unique(np.asarray(feats)))
    assert vals <= {0.0, 1.0}


# ---------------------------------------------------------------------------
# Entry points (the AOT contract)
# ---------------------------------------------------------------------------


def test_entry_signatures_match_aot_metadata():
    from compile.aot import entry_points

    for name, fn, args in entry_points():
        out = jax.eval_shape(fn, *args)
        leaves = jax.tree_util.tree_leaves(out)
        assert len(leaves) >= 1, name
        for leaf in leaves:
            assert leaf.dtype == jnp.float32, (name, leaf.dtype)


def test_entry_sanity_value():
    x = jnp.asarray([[1.0, 2.0], [3.0, 4.0]], jnp.float32)
    y = jnp.ones((2, 2), jnp.float32)
    (out,) = m.entry_sanity(x, y)
    np.testing.assert_allclose(
        np.asarray(out), [[5.0, 5.0], [9.0, 9.0]], rtol=1e-6
    )


def test_entry_ae_train_roundtrip_types(params, batch):
    out = m.entry_ae_train(*params, batch, jnp.float32(0.01))
    assert len(out) == len(params) + 1
    for new_p, old_p in zip(out[:-1], params):
        assert new_p.shape == old_p.shape
