"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

This is the CORE correctness signal for the compiled artifacts: the Rust
runtime executes HLO lowered from exactly these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    contact_map,
    lj_forces,
    matmul,
    matmul_pallas_raw,
    pairwise_dist2,
)
from compile.kernels import ref
from compile.kernels.matmul import _pick_block as pick_block_mm
from compile.kernels.distance import _pick_block as pick_block_d


def key(i=0):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n",
    [
        (8, 8, 8),
        (32, 64, 16),
        (32, 4096, 256),
        (256, 16, 256),
        (128, 128, 128),
        (1, 8, 8),  # degenerate row
        (64, 2, 4),  # tiny inner dim
    ],
)
def test_matmul_matches_ref(m, k, n):
    a = jax.random.normal(key(1), (m, k), jnp.float32)
    b = jax.random.normal(key(2), (k, n), jnp.float32)
    got = matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 64), (32, 16, 128)])
def test_matmul_block_shapes(bm, bn, bk):
    """Correctness must be invariant to the BlockSpec tiling choice."""
    a = jax.random.normal(key(3), (32, 128), jnp.float32)
    b = jax.random.normal(key(4), (128, 32), jnp.float32)
    got = matmul_pallas_raw(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_grad_uses_kernel_and_matches_jnp():
    """custom_vjp backward == autodiff of plain jnp.dot."""
    a = jax.random.normal(key(5), (16, 32), jnp.float32)
    b = jax.random.normal(key(6), (32, 8), jnp.float32)

    def f_kernel(a, b):
        return jnp.sum(jnp.sin(matmul(a, b)))

    def f_ref(a, b):
        return jnp.sum(jnp.sin(jnp.dot(a, b)))

    ga_k, gb_k = jax.grad(f_kernel, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga_k, ga_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gb_k, gb_r, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    mexp=st.integers(0, 6),
    kexp=st.integers(0, 7),
    nexp=st.integers(0, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(mexp, kexp, nexp, seed):
    """Property sweep: power-of-two shapes, random data, always == ref."""
    m, k, n = 2**mexp, 2**kexp, 2**nexp
    a = jax.random.normal(key(seed), (m, k), jnp.float32)
    b = jax.random.normal(key(seed + 1), (k, n), jnp.float32)
    np.testing.assert_allclose(
        matmul(a, b), ref.matmul_ref(a, b), rtol=1e-3, atol=1e-3
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
)
def test_matmul_hypothesis_ragged_shapes(m, k, n):
    """Non-power-of-two dims: the block picker must still tile exactly."""
    a = jax.random.normal(key(7), (m, k), jnp.float32)
    b = jax.random.normal(key(8), (k, n), jnp.float32)
    np.testing.assert_allclose(
        matmul(a, b), ref.matmul_ref(a, b), rtol=1e-3, atol=1e-3
    )


def test_pick_block_divides():
    for dim in range(1, 300):
        b = pick_block_mm(dim)
        assert dim % b == 0
        assert 1 <= b <= 128


# ---------------------------------------------------------------------------
# pairwise distance / contact map
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [4, 16, 64, 128])
def test_dist2_matches_ref(n):
    c = jax.random.normal(key(10), (n, 3), jnp.float32) * 3.0
    np.testing.assert_allclose(
        pairwise_dist2(c), ref.pairwise_dist2_ref(c), rtol=1e-4, atol=1e-4
    )


def test_dist2_block_invariance():
    c = jax.random.normal(key(11), (64, 3), jnp.float32)
    base = pairwise_dist2(c)
    for bm, bn in [(8, 8), (16, 64), (64, 16), (32, 32)]:
        np.testing.assert_allclose(
            pairwise_dist2(c, bm=bm, bn=bn), base, rtol=1e-5, atol=1e-5
        )


def test_dist2_properties():
    c = jax.random.normal(key(12), (32, 3), jnp.float32)
    d2 = np.asarray(pairwise_dist2(c))
    assert (d2 >= 0).all(), "squared distances must be non-negative"
    np.testing.assert_allclose(d2, d2.T, atol=1e-5)  # symmetry
    np.testing.assert_allclose(np.diag(d2), 0.0, atol=1e-5)


@pytest.mark.parametrize("threshold", [0.5, 1.6, 8.0])
def test_contact_map_matches_ref(threshold):
    c = jax.random.normal(key(13), (64, 3), jnp.float32) * 2.0
    got = contact_map(c, threshold=threshold)
    want = ref.contact_map_ref(c, threshold=threshold)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_contact_map_is_binary_and_diag_one():
    c = jax.random.normal(key(14), (64, 3), jnp.float32)
    cm = np.asarray(contact_map(c))
    assert set(np.unique(cm)) <= {0.0, 1.0}
    np.testing.assert_array_equal(np.diag(cm), 1.0)  # self-distance 0


@settings(max_examples=15, deadline=None)
@given(nexp=st.integers(1, 7), scale=st.floats(0.1, 10.0), seed=st.integers(0, 1000))
def test_dist2_hypothesis(nexp, scale, seed):
    n = 2**nexp
    c = jax.random.normal(key(seed), (n, 3), jnp.float32) * scale
    np.testing.assert_allclose(
        pairwise_dist2(c), ref.pairwise_dist2_ref(c), rtol=1e-3, atol=1e-3
    )


# ---------------------------------------------------------------------------
# Lennard-Jones forces
# ---------------------------------------------------------------------------


def _lattice(n, spacing=1.2):
    """Cubic lattice coordinates — well-separated, physically sane."""
    side = int(np.ceil(n ** (1 / 3)))
    pts = []
    for i in range(side):
        for j in range(side):
            for kk in range(side):
                pts.append((i * spacing, j * spacing, kk * spacing))
    return jnp.asarray(pts[:n], jnp.float32)


@pytest.mark.parametrize("n", [8, 32, 64])
def test_lj_matches_ref(n):
    c = _lattice(n) + jax.random.normal(key(20), (n, 3), jnp.float32) * 0.05
    np.testing.assert_allclose(
        lj_forces(c), ref.lj_forces_ref(c), rtol=1e-3, atol=1e-3
    )


def test_lj_block_invariance():
    c = _lattice(64) + jax.random.normal(key(21), (64, 3), jnp.float32) * 0.05
    base = lj_forces(c)
    for bm, bk in [(8, 8), (16, 32), (64, 64), (32, 16)]:
        np.testing.assert_allclose(
            lj_forces(c, bm=bm, bk=bk), base, rtol=1e-4, atol=1e-4
        )


def test_lj_newton_third_law():
    """Total force must vanish (momentum conservation)."""
    c = _lattice(27) + jax.random.normal(key(22), (27, 3), jnp.float32) * 0.05
    f = np.asarray(lj_forces(c, cutoff=100.0))
    np.testing.assert_allclose(f.sum(axis=0), 0.0, atol=1e-3)


def test_lj_two_particle_sign():
    """Two particles closer than the LJ minimum (2^(1/6)) repel."""
    c = jnp.asarray([[0.0, 0.0, 0.0], [1.0, 0.0, 0.0]], jnp.float32)
    f = np.asarray(lj_forces(c))
    assert f[0, 0] < 0 and f[1, 0] > 0  # pushed apart
    # beyond the minimum: attraction
    c2 = jnp.asarray([[0.0, 0.0, 0.0], [1.5, 0.0, 0.0]], jnp.float32)
    f2 = np.asarray(lj_forces(c2))
    assert f2[0, 0] > 0 and f2[1, 0] < 0


def test_lj_cutoff_zeroes_far_pairs():
    c = jnp.asarray([[0.0, 0.0, 0.0], [10.0, 0.0, 0.0]], jnp.float32)
    f = np.asarray(lj_forces(c, cutoff=3.0))
    np.testing.assert_allclose(f, 0.0, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(nexp=st.integers(1, 6), seed=st.integers(0, 1000))
def test_lj_hypothesis(nexp, seed):
    n = 2**nexp
    c = _lattice(n) + jax.random.normal(key(seed), (n, 3), jnp.float32) * 0.03
    np.testing.assert_allclose(
        lj_forces(c), ref.lj_forces_ref(c), rtol=1e-3, atol=1e-3
    )
