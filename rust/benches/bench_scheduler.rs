//! L3 perf microbenchmarks: scheduler placement throughput, allocator
//! alloc/release, event-queue ops, end-to-end engine events/s.
//! `cargo bench --bench bench_scheduler`

use asyncflow::engine::{simulate_cfg, EngineConfig, ExecutionMode};
use asyncflow::pilot::{Policy, QueuedTask, Scheduler};
use asyncflow::resources::{Allocator, ClusterSpec, ResourceRequest};
use asyncflow::sched::DrainCtx;
use asyncflow::sim::EventQueue;
use asyncflow::util::bench::{bench, report, report_header};
use asyncflow::util::rng::Rng;
use asyncflow::workflows::random_workflow;

fn main() {
    report_header();

    // --- allocator ----------------------------------------------------
    let cluster = ClusterSpec::summit_paper();
    let r = bench("allocator: 96 gpu-task alloc+release", 10, 200, || {
        let mut a = Allocator::new(&cluster);
        let mut ps = Vec::with_capacity(96);
        for _ in 0..96 {
            ps.push(a.try_alloc(&ResourceRequest::new(4, 1)).unwrap());
        }
        for p in &ps {
            a.release(p);
        }
        std::hint::black_box(a.free_gpus());
    });
    let per_op = r.secs.mean / 192.0;
    report(&r);
    println!("    -> {:.0} alloc/release ops/s", 1.0 / per_op);

    // Spanning (CPU-only) allocation bursts: the lazily-repaired
    // descending-free-cores index sorts once per burst instead of once
    // per allocation (the c-DG T1/T2 sets place 16 x 40-core spanning
    // tasks in a single scheduler drain round).
    let r = bench("allocator: 64-task spanning burst (40c) + release", 10, 200, || {
        let mut a = Allocator::new(&cluster);
        let mut ps = Vec::with_capacity(64);
        for _ in 0..64 {
            ps.push(a.try_alloc(&ResourceRequest::new(40, 0)).unwrap());
        }
        for p in &ps {
            a.release(p);
        }
        std::hint::black_box(a.free_cores());
    });
    report(&r);
    println!("    -> {:.0} spanning allocs/s", 64.0 / r.secs.mean);

    // --- scheduler ----------------------------------------------------
    for policy in [Policy::FifoBackfill, Policy::PipelineAge, Policy::SmallestFirst] {
        let r = bench(&format!("scheduler: drain 1000 tasks ({policy:?})"), 5, 50, || {
            let mut s = Scheduler::new(policy);
            let mut rng = Rng::new(1);
            for uid in 0..1000 {
                s.push(QueuedTask {
                    uid,
                    req: ResourceRequest::new(1 + rng.below(8) as u32, (rng.below(2)) as u32),
                    priority: rng.below(4),
                    submitted_at: rng.f64(),
                    tenant: uid % 8,
                    est: 10.0,
                });
            }
            let mut a = Allocator::new(&cluster);
            let placed = s.drain_schedulable(&mut a, &DrainCtx::at(0.0));
            std::hint::black_box(placed.len());
        });
        report(&r);
        println!("    -> {:.0} scheduling decisions/s", 1000.0 / r.secs.mean);
    }

    // --- event queue ----------------------------------------------------
    let r = bench("event queue: 100k push+pop", 2, 20, || {
        let mut q = EventQueue::new();
        let mut rng = Rng::new(2);
        for uid in 0..100_000usize {
            q.push(rng.f64() * 1e6, uid);
        }
        let mut last = 0.0;
        while let Some((t, _)) = q.pop() {
            last = t;
        }
        std::hint::black_box(last);
    });
    report(&r);
    println!("    -> {:.2} M events/s", 0.2 / r.secs.mean / 1e6 * 1e6 / 1e6 * 100.0);
    println!("    -> {:.2} M push+pop pairs/s", 0.1 / r.secs.mean);

    // --- whole engine ---------------------------------------------------
    let mut rng = Rng::new(3);
    let wf = random_workflow(&mut rng, 6, 4);
    let tasks: u64 = wf.total_tasks();
    let cfg = EngineConfig::default();
    let r = bench(
        &format!("engine: random workflow ({tasks} tasks) async sim"),
        3,
        30,
        || {
            let rep = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
            std::hint::black_box(rep.makespan);
        },
    );
    report(&r);
    println!("    -> {:.0} simulated tasks/s", tasks as f64 / r.secs.mean);
}
