//! Event-loop scale bench: a 100k-workflow streamed drain under the
//! event-calendar loop vs the legacy full-scan loop, reporting
//! events/sec, workflows/sec, and the driver-wake-up counts the
//! calendar exists to cut (`RunReport::driver_steps`).
//!
//! `cargo bench --bench bench_scale` — flags after `--`:
//!   `--n N`       workflows to stream (default 100000)
//!   `--smoke`     CI mode: tiny stream, one timed iteration
//!   `--json PATH` write the machine-readable result (BENCH_scale.json)
//!
//! The acceptance bar: at the default scale the calendar performs at
//! least 5x fewer `WorkflowDriver::step` invocations than the scan
//! baseline, and wins wall-clock. Both modes must produce identical
//! simulations — checked here, and property-tested bit-for-bit in
//! `tests/loop_equiv.rs`.

use asyncflow::dag::Dag;
use asyncflow::engine::{
    Coordinator, EngineConfig, ExecutionMode, RunReport, WakePolicy,
};
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::sim::VirtualExecutor;
use asyncflow::task::TaskSetSpec;
use asyncflow::util::bench::fmt_time;
use asyncflow::util::cli::Args;
use asyncflow::util::json::{obj, Json};

/// Single-task workflow: 1 core for ~200 s (sigma 5%). At 0.5
/// arrivals/s over 128 cores the stream is stable (~100 cores busy,
/// ~100 drivers live), so the scan loop pays O(live) per event while
/// the calendar pays O(due) — the contrast under measurement.
fn solo() -> Workflow {
    let mut dag = Dag::new();
    dag.add_node("A");
    Workflow {
        name: "solo".into(),
        sets: vec![
            TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), 200.0).with_sigma(0.05),
        ],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0])],
        asynchronous: vec![Pipeline::new("a").stage(&[0])],
    }
}

struct ModeResult {
    wall_s: f64,
    driver_steps: u64,
    peak_live: usize,
    makespan: f64,
    records_digest: String,
}

/// Build the N-workflow stream and drain it under `wake`; one timed
/// end-to-end run (registration + simulation), like a cold start.
fn drain(n: usize, wake: WakePolicy) -> ModeResult {
    let cluster = ClusterSpec::uniform("bench", 16, 8, 0);
    let cfg = EngineConfig::ideal();
    let t0 = std::time::Instant::now();
    let mut coord = Coordinator::new(&cluster, &cfg);
    coord.set_wake_policy(wake);
    for i in 0..n {
        coord
            .add_workflow(solo(), ExecutionMode::Asynchronous, i as f64 * 2.0)
            .unwrap();
    }
    let mut ex = VirtualExecutor::new();
    let reports: Vec<RunReport> = coord.run(&mut ex).unwrap();
    let wall_s = t0.elapsed().as_secs_f64();
    let last = reports.last().expect("n >= 1");
    // Cheap trajectory digest: per-member makespan bits folded together
    // — enough to catch any divergence between the two modes here (the
    // bit-for-bit comparison lives in tests/loop_equiv.rs).
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    for r in &reports {
        digest = (digest ^ r.makespan.to_bits()).wrapping_mul(0x1000_0000_01b3);
    }
    ModeResult {
        wall_s,
        driver_steps: last.driver_steps,
        peak_live: last.peak_live_tasks,
        makespan: reports.iter().fold(0.0f64, |m, r| m.max(r.makespan)),
        records_digest: format!("{digest:016x}"),
    }
}

fn mode_json(n: usize, m: &ModeResult) -> Json {
    // 2 engine events per workflow: one arrival, one task completion.
    let events = 2.0 * n as f64;
    obj([
        ("wall_s", Json::Num(m.wall_s)),
        ("driver_steps", Json::Num(m.driver_steps as f64)),
        ("peak_live_tasks", Json::Num(m.peak_live as f64)),
        ("events_per_s", Json::Num(events / m.wall_s)),
        ("workflows_per_s", Json::Num(n as f64 / m.wall_s)),
        ("trajectory_digest", Json::Str(m.records_digest.clone())),
    ])
}

fn main() {
    let args = Args::from_env(&["smoke"]).unwrap();
    let smoke = args.flag("smoke");
    let default_n = if smoke { 2_000 } else { 100_000 };
    let n = args.get_usize("n", default_n).unwrap();

    println!(
        "bench_scale: {n} streamed solo workflows ({} mode)",
        if smoke { "smoke" } else { "full" }
    );

    // Warm the allocator/page cache once off the clock, then time each
    // loop strategy on an identical cold coordinator.
    if !smoke {
        drain(n.min(5_000), WakePolicy::Calendar);
    }
    let scan = drain(n, WakePolicy::FullScan);
    let cal = drain(n, WakePolicy::Calendar);

    assert_eq!(
        scan.records_digest, cal.records_digest,
        "calendar and full-scan loops must simulate identical trajectories"
    );
    assert_eq!(scan.makespan.to_bits(), cal.makespan.to_bits());

    let step_ratio = scan.driver_steps as f64 / cal.driver_steps.max(1) as f64;
    let speedup = scan.wall_s / cal.wall_s;
    let events = 2.0 * n as f64;
    for (name, m) in [("full-scan", &scan), ("calendar", &cal)] {
        println!(
            "  {name:<10} {:>10}  {:>12.0} events/s  {:>10.0} wf/s  {:>12} driver steps",
            fmt_time(m.wall_s),
            events / m.wall_s,
            n as f64 / m.wall_s,
            m.driver_steps,
        );
    }
    println!(
        "  driver-step ratio: {step_ratio:.1}x fewer wake-ups, wall-clock speedup {speedup:.2}x"
    );

    // The acceptance bar only applies at a scale where the stream
    // actually overlaps; the smoke run just proves the bench runs.
    if n >= 500 {
        assert!(
            step_ratio >= 5.0,
            "calendar must cut driver wake-ups >= 5x at n = {n} (got {step_ratio:.1}x)"
        );
    }

    if let Some(path) = args.get("json") {
        let out = obj([
            ("bench", Json::Str("bench_scale".into())),
            ("measured", Json::Bool(true)),
            ("smoke", Json::Bool(smoke)),
            ("n_workflows", Json::Num(n as f64)),
            ("sim_makespan_s", Json::Num(cal.makespan)),
            ("full_scan", mode_json(n, &scan)),
            ("calendar", mode_json(n, &cal)),
            ("driver_step_ratio", Json::Num(step_ratio)),
            ("wall_clock_speedup", Json::Num(speedup)),
        ]);
        std::fs::write(path, out.to_string_pretty() + "\n").unwrap();
        println!("  wrote {path}");
    }
}
