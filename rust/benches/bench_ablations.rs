//! Ablations (experiment E10): the design choices DESIGN.md calls out.
//!
//! 1. Scheduler policy (FIFO+backfill vs strict FIFO vs pipeline-age vs
//!    smallest-first) — backfill is what enables TX masking.
//! 2. Execution mode (sequential / paper-async / adaptive).
//! 3. GPU capacity for c-DG2 (96 vs 128 GPUs) — resource-clipped
//!    masking.
//! 4. Overhead sensitivity — when does c-DG1-style asynchronicity flip
//!    negative?
//!
//! `cargo bench --bench bench_ablations`

use asyncflow::ddmd::{ddmd_workflow, DdmdConfig};
use asyncflow::engine::{simulate_cfg, ExecutionMode};
use asyncflow::experiments::paper_engine_config;
use asyncflow::pilot::Policy;
use asyncflow::resources::ClusterSpec;
use asyncflow::util::bench::Table;
use asyncflow::workflows::{cdg1, cdg2};

fn main() {
    let ddmd = ddmd_workflow(&DdmdConfig::paper());
    let summit = ClusterSpec::summit_paper();

    println!("# A1. Scheduler policy (DDMD on Summit, async mode)\n");
    let mut t = Table::new(&["policy", "tSeq", "tAsync", "I", "note"]);
    for (policy, note) in [
        (Policy::FifoBackfill, "default (RP-like)"),
        (Policy::FifoStrict, "no backfill: head-of-line blocking"),
        (Policy::PipelineAge, "old pipelines first: starves stragglers"),
        (Policy::SmallestFirst, "greedy packing"),
    ] {
        let mut cfg = paper_engine_config(42);
        cfg.policy = policy;
        let seq = simulate_cfg(&ddmd, &summit, ExecutionMode::Sequential, &cfg);
        let asy = simulate_cfg(&ddmd, &summit, ExecutionMode::Asynchronous, &cfg);
        t.row(&[
            format!("{policy:?}"),
            format!("{:.0}", seq.makespan),
            format!("{:.0}", asy.makespan),
            format!("{:+.3}", asy.improvement_over(&seq)),
            note.to_string(),
        ]);
    }
    t.print();

    println!("\n# A2. Execution mode across all workflows\n");
    let mut t = Table::new(&["workflow", "sequential", "async", "adaptive"]);
    for (wf, cluster) in [
        (ddmd.clone(), summit.clone()),
        (cdg1(), ClusterSpec::summit_8gpu()),
        (cdg2(), ClusterSpec::summit_8gpu()),
    ] {
        let cfg = paper_engine_config(42);
        let vals: Vec<String> = [
            ExecutionMode::Sequential,
            ExecutionMode::Asynchronous,
            ExecutionMode::Adaptive,
        ]
        .iter()
        .map(|&m| format!("{:.0}", simulate_cfg(&wf, &cluster, m, &cfg).makespan))
        .collect();
        t.row(&[wf.name.clone(), vals[0].clone(), vals[1].clone(), vals[2].clone()]);
    }
    t.print();

    println!("\n# A3. c-DG2 GPU capacity (masking is resource-gated)\n");
    let mut t = Table::new(&["gpus/node", "tSeq", "tAsync", "I"]);
    for gpn in [4, 6, 7, 8, 10] {
        let cluster = ClusterSpec::uniform(format!("summit-{gpn}g"), 16, 168, gpn);
        let cfg = paper_engine_config(42);
        let wf = cdg2();
        let seq = simulate_cfg(&wf, &cluster, ExecutionMode::Sequential, &cfg);
        let asy = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
        t.row(&[
            format!("{gpn} ({})", cluster.total_gpus()),
            format!("{:.0}", seq.makespan),
            format!("{:.0}", asy.makespan),
            format!("{:+.3}", asy.improvement_over(&seq)),
        ]);
    }
    t.print();
    println!("(paper's Table 3 presumes the 112-GPU frontier fits; I flips positive at >= 7 GPUs/node)");

    println!("\n# A4. Overhead sensitivity (c-DG1: small masking gains drown in overheads)\n");
    let mut t = Table::new(&["stage_overhead", "c-DG1 I", "c-DG2 I"]);
    for oh in [0.0, 4.0, 8.0, 16.0, 32.0] {
        let mut cfg = paper_engine_config(42);
        cfg.stage_overhead = oh;
        let cluster = ClusterSpec::summit_8gpu();
        let row: Vec<f64> = [cdg1(), cdg2()]
            .iter()
            .map(|wf| {
                let seq = simulate_cfg(wf, &cluster, ExecutionMode::Sequential, &cfg);
                let asy = simulate_cfg(wf, &cluster, ExecutionMode::Asynchronous, &cfg);
                asy.improvement_over(&seq)
            })
            .collect();
        t.row(&[format!("{oh:.0} s"), format!("{:+.3}", row[0]), format!("{:+.3}", row[1])]);
    }
    t.print();
}
