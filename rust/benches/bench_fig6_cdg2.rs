//! Regenerates Fig. 4 (c-DG2 utilization timelines, experiment E6) and
//! times trace construction. `cargo bench --bench bench_fig6_cdg2`

use asyncflow::experiments::{experiment_workflows, run_figure};
use asyncflow::util::bench::{bench, report, report_header};

fn main() {
    let (wf, cluster) = experiment_workflows().remove(2);
    let art = run_figure("fig6", &wf, &cluster, 42, Some(std::path::Path::new("results")))
        .expect("figure generation");
    println!("{art}");
    println!("CSV written to results/fig6_*.csv\n");
    report_header();
    let r = bench("fig6 generate (2 sims + traces)", 1, 5, || {
        let _ = run_figure("fig6", &wf, &cluster, 42, None).unwrap();
    });
    report(&r);
}
