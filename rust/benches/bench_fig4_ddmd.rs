//! Regenerates Fig. 4 (DDMD utilization timelines, experiment E4) and
//! times trace construction. `cargo bench --bench bench_fig4_ddmd`

use asyncflow::experiments::{experiment_workflows, run_figure};
use asyncflow::util::bench::{bench, report, report_header};

fn main() {
    let (wf, cluster) = experiment_workflows().remove(0);
    let art = run_figure("fig4", &wf, &cluster, 42, Some(std::path::Path::new("results")))
        .expect("figure generation");
    println!("{art}");
    println!("CSV written to results/fig4_*.csv\n");
    report_header();
    let r = bench("fig4 generate (2 sims + traces)", 1, 5, || {
        let _ = run_figure("fig4", &wf, &cluster, 42, None).unwrap();
    });
    report(&r);
}
