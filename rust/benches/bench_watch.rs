//! Watch-pipeline throughput: incremental NDJSON tailing
//! ([`TailParser`]), sliding-window rollups ([`WindowStats`]), and the
//! replay → headline reconstruction, each driven over the same
//! recorded traffic stream.
//!
//! `cargo bench --bench bench_watch` — flags after `--`:
//!   `--n N`       workflows to stream (default 1000)
//!   `--window S`  rollup window in sim-seconds (default 300)
//!   `--smoke`     CI mode: tiny stream, one timed iteration
//!   `--json PATH` write the machine-readable result
//!
//! Every stage is a pure function of the stream, so besides the
//! timings this asserts determinism: the dashboard frame and the
//! headline render must hash identically across iterations.

use std::cell::RefCell;
use std::rc::Rc;

use asyncflow::dag::Dag;
use asyncflow::engine::EngineConfig;
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::obs::tail::TailParser;
use asyncflow::obs::trace::replay;
use asyncflow::obs::watch::{headline, render_frame};
use asyncflow::obs::window::WindowStats;
use asyncflow::obs::{MemSink, ObsEvent};
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::task::TaskSetSpec;
use asyncflow::traffic::{
    run_traffic_resumable_obs, ArrivalProcess, Catalog, TrafficObs, TrafficOutcome,
    TrafficSpec, WorkloadMix,
};
use asyncflow::util::bench::{bench, report, report_header, BenchResult};
use asyncflow::util::cli::Args;
use asyncflow::util::json::{obj, Json};

/// Two-stage chain (4 + 1 tasks): the `bench_obs` workload, so the
/// stream shape matches the emission-overhead bench it rides beside.
fn chain() -> Workflow {
    let mut dag = Dag::new();
    let a = dag.add_node("A");
    let b = dag.add_node("B");
    dag.add_edge(a, b).unwrap();
    Workflow {
        name: "chain".into(),
        sets: vec![
            TaskSetSpec::new("A", 4, ResourceRequest::new(2, 0), 20.0).with_sigma(0.05),
            TaskSetSpec::new("B", 1, ResourceRequest::new(4, 0), 10.0).with_sigma(0.05),
        ],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0]).stage(&[1])],
        asynchronous: vec![Pipeline::new("p").stage(&[0]).stage(&[1])],
    }
}

fn fnv(bytes: &[u8]) -> u64 {
    let mut d = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        d = (d ^ b as u64).wrapping_mul(0x1000_0000_01b3);
    }
    d
}

fn main() {
    let args = Args::from_env(&["smoke"]).unwrap();
    let smoke = args.flag("smoke");
    let default_n = if smoke { 200 } else { 1_000 };
    let n = args.get_usize("n", default_n).unwrap();
    let window = args.get_f64("window", 300.0).unwrap();
    let iters = if smoke { 1 } else { 5 };

    // Record the stream once; the timed stages only consume it.
    let catalog = Catalog::new().insert("chain", chain());
    let cluster = ClusterSpec::uniform("bench", 4, 16, 2);
    let spec = TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 0.5 },
        mix: WorkloadMix::parse("chain").unwrap(),
        duration: 1e9,
        max_workflows: n,
        seed: 1,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: None,
    };
    let sink = Rc::new(RefCell::new(MemSink::new()));
    let obs = TrafficObs { sink: Some(Box::new(Rc::clone(&sink))), profile: None };
    match run_traffic_resumable_obs(&spec, &catalog, &cluster, &EngineConfig::ideal(), obs)
        .unwrap()
    {
        TrafficOutcome::Completed(_) => {}
        TrafficOutcome::Checkpointed(_) => unreachable!("spec has no checkpoint time"),
    }
    let events = sink.borrow().events.clone();
    let text: String = events.iter().map(|e| e.to_ndjson() + "\n").collect();
    println!(
        "bench_watch: {} events / {} KiB over {n} workflows x {iters} iterations ({} mode)",
        events.len(),
        text.len() / 1024,
        if smoke { "smoke" } else { "full" }
    );

    report_header();
    // Stage 1: incremental parse in 64 KiB chunks (the follower's read
    // size), partial trailing lines and all.
    let mut parsed = 0usize;
    let tail = bench("tail: 64 KiB chunked NDJSON parse", 1, iters, || {
        let mut p = TailParser::new();
        let mut out: Vec<ObsEvent> = Vec::with_capacity(events.len());
        for chunk in text.as_bytes().chunks(64 * 1024) {
            p.feed(chunk, &mut out).unwrap();
        }
        p.finish(&mut out).unwrap();
        parsed = out.len();
    });
    report(&tail);
    assert_eq!(parsed, events.len(), "chunked parse must see every event");

    // Stage 2: sliding-window rollups + one frame render.
    let mut frame_digest = None;
    let roll = bench("window: rollups + frame render", 1, iters, || {
        let mut ws = WindowStats::new(window);
        for ev in &events {
            ws.push(ev);
        }
        let d = fnv(render_frame(&ws, "bench", false).as_bytes());
        match frame_digest {
            None => frame_digest = Some(d),
            Some(prev) => assert_eq!(prev, d, "frame must be deterministic"),
        }
    });
    report(&roll);

    // Stage 3: full replay → headline reconstruction.
    let mut head_digest = None;
    let head = bench("headline: replay + reconstruction", 1, iters, || {
        let run = replay(&events).unwrap();
        let d = fnv(headline(&run).render().as_bytes());
        match head_digest {
            None => head_digest = Some(d),
            Some(prev) => assert_eq!(prev, d, "headline must be deterministic"),
        }
    });
    report(&head);

    let per_ev = |r: &BenchResult| r.throughput_per_sec(events.len() as f64);
    println!(
        "  throughput: tail {:.0} ev/s, window {:.0} ev/s, headline {:.0} ev/s",
        per_ev(&tail),
        per_ev(&roll),
        per_ev(&head),
    );

    if let Some(path) = args.get("json") {
        let out = obj([
            ("bench", Json::Str("bench_watch".into())),
            ("measured", Json::Bool(true)),
            ("smoke", Json::Bool(smoke)),
            ("n_workflows", Json::Num(n as f64)),
            ("n_events", Json::Num(events.len() as f64)),
            ("window_s", Json::Num(window)),
            ("tail_mean_s", Json::Num(tail.secs.mean)),
            ("window_mean_s", Json::Num(roll.secs.mean)),
            ("headline_mean_s", Json::Num(head.secs.mean)),
            ("tail_events_per_s", Json::Num(per_ev(&tail))),
            ("window_events_per_s", Json::Num(per_ev(&roll))),
            ("headline_events_per_s", Json::Num(per_ev(&head))),
        ]);
        std::fs::write(path, out.to_string_pretty() + "\n").unwrap();
        println!("  wrote {path}");
    }
}
