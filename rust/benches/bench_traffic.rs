//! Streaming-traffic throughput: how fast the coordinator chews
//! through 1k streamed workflows (arrival sampling + lazy driver
//! materialization + uid recycling + queueing-metric reduction).
//! `cargo bench --bench bench_traffic`

use asyncflow::dag::Dag;
use asyncflow::engine::EngineConfig;
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::task::TaskSetSpec;
use asyncflow::traffic::{run_traffic, ArrivalProcess, Catalog, TrafficSpec, WorkloadMix};
use asyncflow::util::bench::{bench, report, report_header};

/// Small two-stage chain (4 + 1 tasks) — enough structure to exercise
/// dependencies without dominating the run with task-event volume.
fn chain() -> Workflow {
    let mut dag = Dag::new();
    let a = dag.add_node("A");
    let b = dag.add_node("B");
    dag.add_edge(a, b).unwrap();
    Workflow {
        name: "chain".into(),
        sets: vec![
            TaskSetSpec::new("A", 4, ResourceRequest::new(2, 0), 20.0).with_sigma(0.05),
            TaskSetSpec::new("B", 1, ResourceRequest::new(4, 0), 10.0).with_sigma(0.05),
        ],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0]).stage(&[1])],
        asynchronous: vec![Pipeline::new("p").stage(&[0]).stage(&[1])],
    }
}

fn main() {
    report_header();
    let catalog = Catalog::new().insert("chain", chain());
    let cluster = ClusterSpec::uniform("bench", 4, 16, 2);
    let cfg = EngineConfig::ideal();
    let spec = TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 0.5 },
        mix: WorkloadMix::parse("chain").unwrap(),
        duration: 1e9, // the cap, not the window, bounds this run
        max_workflows: 1000,
        seed: 1,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: None,
    };
    let probe = run_traffic(&spec, &catalog, &cluster, &cfg).unwrap();
    let n_wf = probe.workflows.len();
    let n_tasks = probe.total_tasks;
    println!(
        "workload: {n_wf} workflows / {n_tasks} tasks, peak live {} tasks, peak backlog {} tasks\n",
        probe.peak_live_tasks,
        probe.peak_backlog.0
    );

    let r = bench("traffic: 1k streamed workflows (shared pilot)", 1, 10, || {
        let rep = run_traffic(&spec, &catalog, &cluster, &cfg).unwrap();
        std::hint::black_box(rep.makespan);
    });
    report(&r);
    println!(
        "    -> {:.0} workflows/s, {:.0} task events/s simulated",
        n_wf as f64 / r.secs.mean,
        n_tasks as f64 / r.secs.mean
    );
}
