//! Observability overhead: the same streamed-traffic run with no
//! attachments, with an explicit `NullSink`, and with a real
//! `FileSink` writing the NDJSON event stream.
//!
//! `cargo bench --bench bench_obs` — flags after `--`:
//!   `--n N`       workflows to stream (default 1000)
//!   `--smoke`     CI mode: tiny stream, one timed iteration
//!   `--json PATH` write the machine-readable result
//!
//! The acceptance bar: the disabled path (`NullSink`) costs at most 2%
//! over a run with no sink at all — emission sites must vanish behind
//! the single `enabled()` check. All three variants must simulate the
//! identical trajectory (the sink is write-only telemetry).

use asyncflow::dag::Dag;
use asyncflow::engine::EngineConfig;
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::obs::{FileSink, NullSink};
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::task::TaskSetSpec;
use asyncflow::traffic::{
    run_traffic_resumable_obs, ArrivalProcess, Catalog, TrafficObs, TrafficOutcome,
    TrafficReport, TrafficSpec, WorkloadMix,
};
use asyncflow::util::bench::fmt_time;
use asyncflow::util::cli::Args;
use asyncflow::util::json::{obj, Json};

/// Two-stage chain (4 + 1 tasks): enough task volume that the per-event
/// emission sites dominate any fixed setup cost.
fn chain() -> Workflow {
    let mut dag = Dag::new();
    let a = dag.add_node("A");
    let b = dag.add_node("B");
    dag.add_edge(a, b).unwrap();
    Workflow {
        name: "chain".into(),
        sets: vec![
            TaskSetSpec::new("A", 4, ResourceRequest::new(2, 0), 20.0).with_sigma(0.05),
            TaskSetSpec::new("B", 1, ResourceRequest::new(4, 0), 10.0).with_sigma(0.05),
        ],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0]).stage(&[1])],
        asynchronous: vec![Pipeline::new("p").stage(&[0]).stage(&[1])],
    }
}

/// Cheap trajectory digest — any simulation divergence between the
/// variants shows up here (bit-for-bit stream equality is
/// property-tested in `tests/obs_stream.rs`).
fn digest(rep: &TrafficReport) -> u64 {
    let mut d = 0xcbf2_9ce4_8422_2325u64;
    for bits in [
        rep.makespan.to_bits(),
        rep.wait.mean.to_bits(),
        rep.ttx.p95.to_bits(),
        rep.total_tasks as u64,
    ] {
        d = (d ^ bits).wrapping_mul(0x1000_0000_01b3);
    }
    d
}

fn run_once(
    spec: &TrafficSpec,
    catalog: &Catalog,
    cluster: &ClusterSpec,
    cfg: &EngineConfig,
    obs: TrafficObs,
) -> (f64, u64) {
    let t0 = std::time::Instant::now();
    let rep = match run_traffic_resumable_obs(spec, catalog, cluster, cfg, obs).unwrap() {
        TrafficOutcome::Completed(rep) => rep,
        TrafficOutcome::Checkpointed(_) => unreachable!("spec has no checkpoint time"),
    };
    (t0.elapsed().as_secs_f64(), digest(&rep))
}

fn main() {
    let args = Args::from_env(&["smoke"]).unwrap();
    let smoke = args.flag("smoke");
    let default_n = if smoke { 200 } else { 1_000 };
    let n = args.get_usize("n", default_n).unwrap();
    let iters = if smoke { 1 } else { 5 };

    let catalog = Catalog::new().insert("chain", chain());
    let cluster = ClusterSpec::uniform("bench", 4, 16, 2);
    let cfg = EngineConfig::ideal();
    let spec = TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 0.5 },
        mix: WorkloadMix::parse("chain").unwrap(),
        duration: 1e9, // the cap, not the window, bounds this run
        max_workflows: n,
        seed: 1,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: None,
    };
    let stream_path = std::env::temp_dir().join("bench_obs_events.ndjson");

    println!(
        "bench_obs: {n} streamed workflows x {iters} iterations ({} mode)",
        if smoke { "smoke" } else { "full" }
    );

    // Warm-up off the clock, then interleave the variants so drift in
    // machine load hits all three equally; keep each variant's best.
    run_once(&spec, &catalog, &cluster, &cfg, TrafficObs::default());
    let mut best = [f64::INFINITY; 3];
    let mut digests = [0u64; 3];
    for _ in 0..iters {
        let runs: [(f64, u64); 3] = [
            run_once(&spec, &catalog, &cluster, &cfg, TrafficObs::default()),
            run_once(
                &spec,
                &catalog,
                &cluster,
                &cfg,
                TrafficObs { sink: Some(Box::new(NullSink)), profile: None },
            ),
            run_once(
                &spec,
                &catalog,
                &cluster,
                &cfg,
                TrafficObs {
                    sink: Some(Box::new(FileSink::create(&stream_path).unwrap())),
                    profile: None,
                },
            ),
        ];
        for (i, (wall, d)) in runs.into_iter().enumerate() {
            best[i] = best[i].min(wall);
            digests[i] = d;
        }
    }
    assert!(
        digests[0] == digests[1] && digests[0] == digests[2],
        "an attached sink must never change the simulated trajectory"
    );
    let events = std::fs::read_to_string(&stream_path)
        .map(|s| s.lines().count())
        .unwrap_or(0);
    let _ = std::fs::remove_file(&stream_path);

    let null_overhead = best[1] / best[0] - 1.0;
    let file_overhead = best[2] / best[0] - 1.0;
    for (name, wall, overhead) in [
        ("no-obs", best[0], 0.0),
        ("null-sink", best[1], null_overhead),
        ("file-sink", best[2], file_overhead),
    ] {
        println!("  {name:<10} {:>10}  {:>+7.2}%", fmt_time(wall), overhead * 100.0);
    }
    println!("  stream: {events} events/run");

    // The 2% bar needs a baseline large enough that timer noise cannot
    // fake a regression; the smoke run just proves the bench runs.
    if !smoke && best[0] >= 0.05 {
        assert!(
            null_overhead <= 0.02,
            "NullSink must cost <= 2% over no sink (got {:+.2}%)",
            null_overhead * 100.0
        );
    }

    if let Some(path) = args.get("json") {
        let out = obj([
            ("bench", Json::Str("bench_obs".into())),
            ("measured", Json::Bool(true)),
            ("smoke", Json::Bool(smoke)),
            ("n_workflows", Json::Num(n as f64)),
            ("events_per_run", Json::Num(events as f64)),
            ("no_obs_wall_s", Json::Num(best[0])),
            ("null_sink_wall_s", Json::Num(best[1])),
            ("file_sink_wall_s", Json::Num(best[2])),
            ("null_sink_overhead", Json::Num(null_overhead)),
            ("file_sink_overhead", Json::Num(file_overhead)),
        ]);
        std::fs::write(path, out.to_string_pretty() + "\n").unwrap();
        println!("  wrote {path}");
    }
}
