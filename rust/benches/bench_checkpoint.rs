//! Checkpoint/resume cost at streaming scale: snapshot + serialize +
//! parse + restore of a 1k-workflow traffic run preempted mid-stream,
//! and the end-to-end preempt-and-finish path against the
//! uninterrupted baseline. `cargo bench --bench bench_checkpoint`

use asyncflow::dag::Dag;
use asyncflow::engine::EngineConfig;
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::task::TaskSetSpec;
use asyncflow::traffic::{
    run_traffic, run_traffic_resumable, ArrivalProcess, Catalog, TrafficCheckpoint,
    TrafficOutcome, TrafficSpec, WorkloadMix,
};
use asyncflow::util::bench::{bench, report, report_header};
use asyncflow::util::json::{FromJson, Json, ToJson};

/// Small two-stage chain (4 + 1 tasks), same shape as bench_traffic.
fn chain() -> Workflow {
    let mut dag = Dag::new();
    let a = dag.add_node("A");
    let b = dag.add_node("B");
    dag.add_edge(a, b).unwrap();
    Workflow {
        name: "chain".into(),
        sets: vec![
            TaskSetSpec::new("A", 4, ResourceRequest::new(2, 0), 20.0).with_sigma(0.05),
            TaskSetSpec::new("B", 1, ResourceRequest::new(4, 0), 10.0).with_sigma(0.05),
        ],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0]).stage(&[1])],
        asynchronous: vec![Pipeline::new("p").stage(&[0]).stage(&[1])],
    }
}

fn main() {
    report_header();
    let catalog = Catalog::new().insert("chain", chain());
    let cluster = ClusterSpec::uniform("bench", 4, 16, 2);
    let cfg = EngineConfig::ideal();
    let spec = TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 0.5 },
        mix: WorkloadMix::parse("chain").unwrap(),
        duration: 1e9, // the cap, not the window, bounds this run
        max_workflows: 1000,
        seed: 1,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: None,
    };

    // Probe: where is mid-stream, and what does the snapshot carry?
    let baseline = run_traffic(&spec, &catalog, &cluster, &cfg).unwrap();
    let t_ck = baseline.makespan / 2.0;
    let preempted = TrafficSpec { checkpoint_at: Some(t_ck), ..spec.clone() };
    let take_checkpoint = || -> TrafficCheckpoint {
        match run_traffic_resumable(&preempted, &catalog, &cluster, &cfg).unwrap() {
            TrafficOutcome::Checkpointed(ck) => *ck,
            TrafficOutcome::Completed(_) => panic!("mid-makespan checkpoint must fire"),
        }
    };
    let probe = take_checkpoint();
    let wire = probe.to_json().to_string();
    println!(
        "workload: {} workflows total; at t = {:.0} s: {} live / {} finished / {} pending \
         members, {} running + {} queued tasks, {} byte snapshot\n",
        baseline.workflows.len(),
        t_ck,
        probe.sim.drivers.len(),
        probe.sim.finished.len(),
        probe.sim.pending.len(),
        probe.sim.running.len(),
        probe.sim.queue.len(),
        wire.len(),
    );

    let r = bench("checkpoint: run-to-T + snapshot (1k stream)", 1, 10, || {
        let ck = take_checkpoint();
        std::hint::black_box(ck.sim.now);
    });
    report(&r);

    let r = bench("checkpoint: serialize snapshot to JSON", 1, 20, || {
        let s = probe.to_json().to_string();
        std::hint::black_box(s.len());
    });
    report(&r);

    let r = bench("checkpoint: parse + validate snapshot", 1, 20, || {
        let ck = TrafficCheckpoint::from_json(&Json::parse(&wire).unwrap()).unwrap();
        std::hint::black_box(ck.sim.slab_len);
    });
    report(&r);

    let r = bench("resume: restore + drain remaining stream", 1, 10, || {
        let ck = TrafficCheckpoint::from_json(&Json::parse(&wire).unwrap()).unwrap();
        let rep = ck.resume(None).unwrap();
        std::hint::black_box(rep.makespan);
    });
    report(&r);

    // Correctness spot-check alongside the numbers: the resumed report
    // matches the uninterrupted baseline bit for bit.
    let resumed = take_checkpoint().resume(None).unwrap();
    assert_eq!(
        baseline.to_json().to_string(),
        resumed.to_json().to_string(),
        "resume must reproduce the uninterrupted report"
    );
    println!("\nresume == uninterrupted: bit-identical reports (checked)");
}
