//! Regenerates Table 3 (experiments E1–E3) and times the simulation
//! engine on each workflow. `cargo bench --bench bench_table3`

use asyncflow::engine::{simulate_cfg, ExecutionMode};
use asyncflow::experiments::{
    check_shapes, experiment_workflows, paper_engine_config, render_table3, run_table3,
};
use asyncflow::util::bench::{bench, report, report_header};

fn main() {
    println!("# Table 3 reproduction (our values; paper's in parentheses)\n");
    let rows = run_table3(42);
    println!("{}", render_table3(&rows));
    let problems = check_shapes(&rows);
    if problems.is_empty() {
        println!("shape check: OK\n");
    } else {
        println!("shape check FAILED: {problems:?}\n");
        std::process::exit(1);
    }

    println!("# Seed sensitivity (I across 5 seeds)\n");
    for (wf, cluster) in experiment_workflows() {
        let mut is = Vec::new();
        for seed in 0..5 {
            let cfg = paper_engine_config(seed);
            let seq = simulate_cfg(&wf, &cluster, ExecutionMode::Sequential, &cfg);
            let asy = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
            is.push(asy.improvement_over(&seq));
        }
        let mean = is.iter().sum::<f64>() / is.len() as f64;
        let min = is.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = is.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        println!("  {:<16} I = {mean:+.3} (range {min:+.3} .. {max:+.3})", wf.name);
    }

    println!("\n# Engine wall-clock (simulating one full run)\n");
    report_header();
    for (wf, cluster) in experiment_workflows() {
        let cfg = paper_engine_config(42);
        let r = bench(&format!("simulate {} async", wf.name), 2, 10, || {
            let rep = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
            std::hint::black_box(rep.makespan);
        });
        report(&r);
    }
}
