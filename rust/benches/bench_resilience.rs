//! Failure-injection overhead and cadence-sweep cost: a streamed
//! traffic run with a live MTBF fault process + retry pipeline versus
//! the identical fault-free run (the price of the failure lane), the
//! chained periodic-checkpoint runner (snapshot + JSON round-trip +
//! restore every `T` simulated seconds), and a checkpoint-cadence
//! sweep over a Young/Daly-style grid.
//!
//! `cargo bench --bench bench_resilience` — flags after `--`:
//!   `--smoke`  CI mode: tiny stream, one timed iteration
//!   `--n N`    workflows to stream (default 2000)

use asyncflow::dag::Dag;
use asyncflow::engine::EngineConfig;
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::failure::cadence::{cluster_fault_rate, run_chained, sweep_cadence};
use asyncflow::failure::{FailureSpec, RetryPolicy};
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::task::TaskSetSpec;
use asyncflow::traffic::{run_traffic, ArrivalProcess, Catalog, TrafficSpec, WorkloadMix};
use asyncflow::util::bench::{bench, report, report_header};
use asyncflow::util::cli::Args;
use asyncflow::util::json::ToJson;

/// Single-task workflow: 1 core for 30 s, deterministic — small enough
/// that faults regularly catch tasks mid-flight.
fn solo() -> Workflow {
    let mut dag = Dag::new();
    dag.add_node("A");
    Workflow {
        name: "solo".into(),
        sets: vec![TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), 30.0).with_sigma(0.0)],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0])],
        asynchronous: vec![Pipeline::new("a").stage(&[0])],
    }
}

fn main() {
    let args = Args::from_env(&["smoke"]).unwrap();
    let smoke = args.flag("smoke");
    let n = args.get_usize("n", if smoke { 200 } else { 2_000 }).unwrap();
    let iters = if smoke { 1 } else { 10 };

    report_header();
    println!(
        "bench_resilience: {n} streamed solo workflows ({} mode)\n",
        if smoke { "smoke" } else { "full" }
    );

    let catalog = Catalog::new().insert("solo", solo());
    let cluster = ClusterSpec::uniform("bench", 8, 8, 0);
    let cfg = EngineConfig::ideal();
    let failure = FailureSpec {
        retry: RetryPolicy { max_attempts: 0, base: 5.0, factor: 2.0, jitter: 0.25 },
        ..FailureSpec::mtbf(500.0)
    };
    let base_spec = TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 1.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 1e9, // the cap, not the window, bounds this run
        max_workflows: n,
        seed: 1,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: None,
    };
    let faulty_spec = TrafficSpec { failure: Some(failure.clone()), ..base_spec.clone() };

    // Probe once for workload shape + determinism: two fault-injected
    // runs must be bit-identical.
    let probe = run_traffic(&faulty_spec, &catalog, &cluster, &cfg).unwrap();
    let again = run_traffic(&faulty_spec, &catalog, &cluster, &cfg).unwrap();
    assert_eq!(
        probe.to_json().to_string(),
        again.to_json().to_string(),
        "fault-injected runs must be bit-identical per seed"
    );
    let stats = probe.resilience.expect("failure-enabled run reports resilience");
    println!(
        "workload: {} workflows, {} faults injected, {} tasks killed, {} retries\n",
        probe.workflows.len(),
        stats.failures_injected,
        stats.tasks_killed,
        stats.retries_scheduled,
    );

    let clean = bench("traffic: fault-free baseline", 1, iters, || {
        let rep = run_traffic(&base_spec, &catalog, &cluster, &cfg).unwrap();
        std::hint::black_box(rep.makespan);
    });
    report(&clean);

    let faulty = bench("traffic: MTBF faults + retry pipeline", 1, iters, || {
        let rep = run_traffic(&faulty_spec, &catalog, &cluster, &cfg).unwrap();
        std::hint::black_box(rep.makespan);
    });
    report(&faulty);
    println!(
        "    -> failure-lane overhead {:.2}x over the fault-free loop",
        faulty.secs.mean / clean.secs.mean
    );

    // Chained periodic checkpointing: every leg serializes, parses and
    // restores the full simulation. Cadence chosen to take a handful
    // of legs at either scale.
    let every = probe.makespan / 8.0;
    let chained = bench("traffic: chained checkpoints (8 legs)", 0, iters.min(3), || {
        let (rep, legs) = run_chained(&faulty_spec, &catalog, &cluster, &cfg, every).unwrap();
        std::hint::black_box((rep.makespan, legs));
    });
    report(&chained);
    println!(
        "    -> checkpoint-cycle overhead {:.2}x over the straight faulty run",
        chained.secs.mean / faulty.secs.mean
    );

    // Cadence sweep: closed-form expectation + sampled fault walk over
    // a log-ish grid (the `asyncflow resilience --sweep-cadence` core).
    let rate = cluster_fault_rate(&cluster, &failure);
    let grid: Vec<f64> = (0..if smoke { 8 } else { 24 })
        .map(|i| 50.0 * 1.5f64.powi(i))
        .collect();
    let work = probe.makespan;
    let sweep = bench("cadence sweep: expectation + fault walk", 1, iters, || {
        let sw = sweep_cadence(work, rate, 60.0, &grid, 1).unwrap();
        std::hint::black_box(sw.best);
    });
    report(&sweep);
}
