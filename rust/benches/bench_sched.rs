//! Scheduling-subsystem benchmark: saturated 10k-task queue drain
//! under the fifo / fair / backfill disciplines, against the
//! pre-refactor flat-queue walk as the baseline.
//!
//! The scenario is the streaming hot path: a fully-occupied allocation
//! and a deep ready queue, re-drained on every engine state change.
//! The old scheduler walked all 10 000 entries per round (memoizing
//! failed shapes but still touching every task); the shape-bucketed
//! queue screens the 8 distinct shapes and stops. The acceptance bar
//! for the refactor is a >= 5x faster drain round here.
//!
//! `cargo bench --bench bench_sched`

use std::collections::HashSet;

use asyncflow::resources::{Allocator, ClusterSpec, ResourceRequest};
use asyncflow::sched::{DrainCtx, InFlight, Policy, QueuedTask, Scheduler};
use asyncflow::util::bench::{bench, report, report_header};

const QUEUE: usize = 10_000;

/// The 8 distinct task shapes of the queue (c-DG-like mix).
const SHAPES: [(u32, u32); 8] =
    [(1, 0), (4, 0), (16, 0), (40, 0), (4, 1), (16, 1), (8, 2), (1, 1)];

fn queued(uid: usize) -> QueuedTask {
    let (c, g) = SHAPES[uid % SHAPES.len()];
    QueuedTask {
        uid,
        req: ResourceRequest::new(c, g),
        priority: (uid % 4) as u64,
        submitted_at: uid as f64,
        tenant: uid % 16,
        est: 10.0 + (uid % 100) as f64,
    }
}

/// Fill the paper's 16-node allocation completely (one node-sized task
/// per node), returning the running view the backfill policy projects
/// against.
fn saturate(alloc: &mut Allocator) -> Vec<InFlight> {
    let node = ResourceRequest::new(168, 6);
    (0..16)
        .map(|i| {
            alloc.try_alloc(&node).expect("node-sized task fills node");
            InFlight { end: 1000.0 + i as f64, req: node, tenant: i }
        })
        .collect()
}

/// The pre-refactor drain: walk the whole flat queue in FIFO order
/// with a failed-shape memo (verbatim from the old `pilot::scheduler`,
/// minus the placement branch that a saturated round never takes).
fn legacy_drain(queue: &[QueuedTask], alloc: &mut Allocator) -> usize {
    let mut failed_shapes: HashSet<ResourceRequest> = HashSet::new();
    let mut placed = 0;
    for t in queue {
        if failed_shapes.contains(&t.req) {
            continue;
        }
        match alloc.try_alloc(&t.req) {
            Some(_) => placed += 1,
            None => {
                failed_shapes.insert(t.req);
            }
        }
    }
    placed
}

fn main() {
    report_header();
    let cluster = ClusterSpec::summit_paper();

    // --- baseline: flat-queue walk ------------------------------------
    let mut alloc = Allocator::new(&cluster);
    saturate(&mut alloc);
    let flat: Vec<QueuedTask> = (0..QUEUE).map(queued).collect();
    let legacy = bench("legacy flat drain: 10k tasks, saturated", 5, 60, || {
        std::hint::black_box(legacy_drain(&flat, &mut alloc));
    });
    report(&legacy);

    // --- bucketed disciplines -----------------------------------------
    let mut speedup_fifo = 0.0;
    for policy in [Policy::FifoBackfill, Policy::WeightedFair, Policy::Backfill] {
        let mut alloc = Allocator::new(&cluster);
        let running = saturate(&mut alloc);
        let mut s = Scheduler::new(policy);
        for uid in 0..QUEUE {
            s.push(queued(uid));
        }
        let label = format!("bucketed drain: 10k tasks, saturated ({policy:?})");
        let r = bench(&label, 5, 60, || {
            let ctx = DrainCtx { now: 0.0, running: &running };
            let placed = s.drain_schedulable(&mut alloc, &ctx);
            assert!(placed.is_empty(), "saturated round must place nothing");
        });
        report(&r);
        let speedup = legacy.secs.mean / r.secs.mean;
        println!("    -> {speedup:.1}x vs the legacy flat walk");
        if policy == Policy::FifoBackfill {
            speedup_fifo = speedup;
        }
        assert_eq!(s.queue_len(), QUEUE, "no-op rounds must not lose tasks");
    }

    println!(
        "\nheadline: fifo drain round {speedup_fifo:.1}x faster than the \
         pre-refactor O(queue) walk (target >= 5x)"
    );

    // --- non-saturated sanity: drain-to-empty throughput --------------
    let r = bench("bucketed fifo: drain 10k tasks to empty (free pilot)", 3, 20, || {
        let mut alloc = Allocator::new(&cluster);
        let mut s = Scheduler::new(Policy::FifoBackfill);
        for uid in 0..QUEUE {
            s.push(queued(uid));
        }
        let mut done = 0usize;
        let mut live: Vec<asyncflow::resources::Placement> = Vec::new();
        while done < QUEUE {
            let placed = s.drain_schedulable(&mut alloc, &DrainCtx::at(done as f64));
            if placed.is_empty() {
                for p in live.drain(..) {
                    alloc.release(&p);
                }
                continue;
            }
            done += placed.len();
            live.extend(placed.into_iter().map(|p| p.placement));
        }
        std::hint::black_box(done);
    });
    report(&r);
    println!("    -> {:.0} placements/s end to end", QUEUE as f64 / r.secs.mean);
}
