//! L1/L2 perf: PJRT artifact execution latency and throughput
//! (compile-once cache, autoencoder train step, MD step, inference).
//! Requires `make artifacts`. `cargo bench --bench bench_runtime`

use asyncflow::runtime::{Engine, Tensor};
use asyncflow::util::bench::{bench, report, report_header};
use asyncflow::util::rng::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn main() {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping bench_runtime: run `make artifacts` first");
        return;
    }
    let mut eng = Engine::open(artifacts_dir()).expect("engine");
    println!("platform: {}", eng.platform());
    let mut rng = Rng::new(1);

    // Model geometry from the manifest side.
    let n_atoms = 64usize;
    let input_dim = n_atoms * n_atoms;
    let batch = 32usize;

    let coords = Tensor::from_vec(
        (0..n_atoms * 3).map(|_| rng.f64() as f32 * 3.0).collect(),
        &[n_atoms, 3],
    )
    .unwrap();
    let vels = Tensor::zeros(&[n_atoms, 3]);

    // Parameters (He-ish random).
    let dims: [(usize, usize); 4] = [(input_dim, 256), (256, 16), (16, 256), (256, input_dim)];
    let mut params = Vec::new();
    for (i, o) in dims {
        params.push(Tensor::from_vec(
            (0..i * o).map(|_| (rng.normal() * (2.0 / i as f64).sqrt()) as f32).collect(),
            &[i, o],
        )
        .unwrap());
        params.push(Tensor::zeros(&[o]));
    }
    let x = Tensor::from_vec(
        (0..batch * input_dim).map(|_| if rng.f64() < 0.15 { 1.0 } else { 0.0 }).collect(),
        &[batch, input_dim],
    )
    .unwrap();

    report_header();

    // Compile cost (first call) vs cached execution.
    let t0 = std::time::Instant::now();
    eng.ensure_compiled("ae_train").unwrap();
    println!("ae_train compile (cold): {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);

    let mut train_in: Vec<Tensor> = params.clone();
    train_in.push(x.clone());
    train_in.push(Tensor::scalar(0.05));
    let r = bench("ae_train step (batch 32, 4096-256-16 AE)", 3, 20, || {
        let out = eng.execute("ae_train", &train_in).unwrap();
        std::hint::black_box(out[8].data[0]);
    });
    report(&r);
    let flops = 2.0 * batch as f64 * (dims.iter().map(|(i, o)| i * o).sum::<usize>() as f64) * 3.0;
    println!(
        "    -> {:.1} samples/s, ~{:.2} GFLOP/s effective",
        batch as f64 / r.secs.mean,
        flops / r.secs.mean / 1e9
    );

    let mut infer_in: Vec<Tensor> = params.clone();
    infer_in.push(x.clone());
    let r = bench("ae_infer (batch 32)", 3, 30, || {
        let out = eng.execute("ae_infer", &infer_in).unwrap();
        std::hint::black_box(out[0].data[0]);
    });
    report(&r);

    let r = bench("md_step (64 atoms x 10 substeps)", 3, 30, || {
        let out = eng.execute("md_step", &[coords.clone(), vels.clone()]).unwrap();
        std::hint::black_box(out[2].data[0]);
    });
    report(&r);

    let r = bench("contact_map (64 atoms)", 3, 30, || {
        let out = eng.execute("contact_map", &[coords.clone()]).unwrap();
        std::hint::black_box(out[0].data[0]);
    });
    report(&r);

    let (compiles, execs) = (eng.compiles, eng.executions);
    println!("\ncompile cache: {compiles} compiles for {execs} executions");
}
