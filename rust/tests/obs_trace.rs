//! Trace-analyzer reconstruction tests: [`asyncflow::obs::trace::analyze`]
//! over a live event stream must reproduce the run's own
//! [`TrafficReport`] figures **bit for bit** — utilization integrated
//! against the events-only capacity timeline, and the per-workflow
//! wait/TTX distributions — while the overlap sweep stays internally
//! consistent (symmetric matrix, bounded degree of asynchronicity,
//! usage never exceeding offered capacity).

use std::cell::RefCell;
use std::rc::Rc;

use asyncflow::dag::Dag;
use asyncflow::engine::EngineConfig;
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::failure::cadence::run_chained_obs;
use asyncflow::failure::{FailureSpec, RetryPolicy};
use asyncflow::obs::trace::{analyze, parse_stream, TraceAnalysis};
use asyncflow::obs::{MemSink, ObsEvent};
use asyncflow::pilot::ResourcePlan;
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::task::{TaskKind, TaskSetSpec};
use asyncflow::traffic::{
    run_traffic_resumable_obs, ArrivalProcess, Catalog, TrafficObs, TrafficOutcome,
    TrafficReport, TrafficSpec, WorkloadMix,
};
use asyncflow::util::stats::Summary;

/// Two-kind chain: four "simulation" tasks (GPU-bound) feeding one
/// "training" task, so both utilization figures and the cross-kind
/// overlap matrix are non-trivial.
fn chain() -> Workflow {
    let mut dag = Dag::new();
    let a = dag.add_node("sim");
    let b = dag.add_node("train");
    dag.add_edge(a, b).unwrap();
    Workflow {
        name: "chain".into(),
        sets: vec![
            TaskSetSpec::new("sim", 4, ResourceRequest::new(2, 1), 20.0)
                .with_sigma(0.1)
                .with_kind(TaskKind::MdSimulation { chunks: 1 }),
            TaskSetSpec::new("train", 1, ResourceRequest::new(4, 0), 10.0)
                .with_sigma(0.1)
                .with_kind(TaskKind::Training { steps: 1 }),
        ],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0]).stage(&[1])],
        asynchronous: vec![Pipeline::new("p").stage(&[0]).stage(&[1])],
    }
}

/// Single-task workflow: 1 core for `tx` seconds, deterministic.
fn solo(tx: f64) -> Workflow {
    let mut dag = Dag::new();
    dag.add_node("A");
    Workflow {
        name: "solo".into(),
        sets: vec![TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), tx).with_sigma(0.0)],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0])],
        asynchronous: vec![Pipeline::new("a").stage(&[0])],
    }
}

/// Run `spec` to completion with a memory sink attached.
fn run_with_stream(
    spec: &TrafficSpec,
    cat: &Catalog,
    cluster: &ClusterSpec,
) -> (TrafficReport, Vec<ObsEvent>) {
    let sink = Rc::new(RefCell::new(MemSink::new()));
    let obs = TrafficObs { sink: Some(Box::new(Rc::clone(&sink))), profile: None };
    let outcome =
        run_traffic_resumable_obs(spec, cat, cluster, &EngineConfig::ideal(), obs).unwrap();
    let TrafficOutcome::Completed(rep) = outcome else {
        panic!("spec has no checkpoint time, the run must complete")
    };
    let events = sink.borrow().events.clone();
    (*rep, events)
}

fn assert_summary_bits(got: Option<&Summary>, want: &Summary, what: &str) {
    let got = got.unwrap_or_else(|| panic!("{what}: analyzer produced no summary"));
    assert_eq!(got.n, want.n, "{what}: n");
    for (g, w, field) in [
        (got.mean, want.mean, "mean"),
        (got.std, want.std, "std"),
        (got.min, want.min, "min"),
        (got.max, want.max, "max"),
        (got.p50, want.p50, "p50"),
        (got.p95, want.p95, "p95"),
        (got.p99, want.p99, "p99"),
    ] {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: {field}");
    }
}

/// The bit-equality core shared by every scenario below.
fn assert_reconstructs(a: &TraceAnalysis, rep: &TrafficReport, what: &str) {
    assert_eq!(
        a.cpu_utilization.to_bits(),
        rep.cpu_utilization.to_bits(),
        "{what}: cpu utilization"
    );
    assert_eq!(
        a.gpu_utilization.to_bits(),
        rep.gpu_utilization.to_bits(),
        "{what}: gpu utilization"
    );
    assert_summary_bits(a.wait.as_ref(), &rep.wait, &format!("{what}: wait"));
    assert_summary_bits(a.ttx.as_ref(), &rep.ttx, &format!("{what}: ttx"));
    assert_eq!(a.n_workflows, rep.workflows.len(), "{what}: workflow count");
    assert_eq!(a.n_tasks, rep.total_tasks, "{what}: task count");
    let last_finish = rep.workflows.iter().map(|w| w.finish).fold(0.0f64, f64::max);
    assert_eq!(a.makespan.to_bits(), last_finish.to_bits(), "{what}: last finish");
    assert_eq!(
        a.final_capacity,
        rep.capacity.final_capacity(),
        "{what}: final offered capacity"
    );
    assert!(a.capacity_consistent, "{what}: usage must stay within offered capacity");
    assert!(
        (0.0..=1.0).contains(&a.degree_of_asynchronicity),
        "{what}: DOA {} out of range",
        a.degree_of_asynchronicity
    );
    assert!(
        a.multi_active_s <= a.any_active_s + 1e-9,
        "{what}: multi-kind time cannot exceed any-active time"
    );
}

#[test]
fn analyzer_reconstructs_live_traffic_report_bit_for_bit() {
    let cat = Catalog::new().insert("chain", chain());
    let cluster = ClusterSpec::uniform("t", 3, 8, 2);
    let spec = TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 0.5 },
        mix: WorkloadMix::parse("chain").unwrap(),
        duration: 40.0,
        max_workflows: 100_000,
        seed: 7,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: None,
    };
    let (rep, events) = run_with_stream(&spec, &cat, &cluster);
    let a = analyze(&events).unwrap();
    assert_reconstructs(&a, &rep, "chain traffic");

    // Kind decomposition: labels sorted, per-kind task counts exact.
    let n_wf = rep.workflows.len();
    assert_eq!(a.kinds.len(), 2, "two task kinds");
    assert_eq!(a.kinds[0].kind, "simulation");
    assert_eq!(a.kinds[1].kind, "training");
    assert_eq!(a.kinds[0].tasks, 4 * n_wf, "four simulation tasks per workflow");
    assert_eq!(a.kinds[1].tasks, n_wf, "one training task per workflow");

    // Overlap matrix: symmetric, diagonal = the kind's active seconds.
    for i in 0..a.kinds.len() {
        assert_eq!(
            a.overlap[i][i].to_bits(),
            a.kinds[i].active_s.to_bits(),
            "diagonal {i}"
        );
        for j in 0..a.kinds.len() {
            assert_eq!(
                a.overlap[i][j].to_bits(),
                a.overlap[j][i].to_bits(),
                "symmetry {i},{j}"
            );
        }
    }

    // The stream survives its wire format: parse(render) is identity,
    // and the analysis of the parsed stream is bit-identical.
    let text: String = events.iter().map(|e| e.to_ndjson() + "\n").collect();
    let parsed = parse_stream(&text).unwrap();
    assert_eq!(parsed, events, "NDJSON round-trip");
    let b = analyze(&parsed).unwrap();
    assert_eq!(b.cpu_utilization.to_bits(), a.cpu_utilization.to_bits());
    assert_eq!(b.any_active_s.to_bits(), a.any_active_s.to_bits());
    assert_eq!(b.degree_of_asynchronicity.to_bits(), a.degree_of_asynchronicity.to_bits());
}

/// Poisson traffic over a shrinking allocation with MTBF faults and
/// unlimited retries: the reconstruction must hold when records carry
/// retried attempts and the capacity timeline steps downward mid-run.
fn faulty_spec(seed: u64) -> TrafficSpec {
    TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 1.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 30.0,
        max_workflows: 100_000,
        seed,
        plan: Some(ResourcePlan::new().resize(15.0, -1)),
        checkpoint_at: None,
        policy: None,
        failure: Some(FailureSpec {
            retry: RetryPolicy { max_attempts: 0, base: 2.0, factor: 2.0, jitter: 0.25 },
            ..FailureSpec::mtbf(8.0)
        }),
    }
}

#[test]
fn failure_and_elastic_runs_reconstruct_bit_equal() {
    let cat = Catalog::new().insert("solo", solo(4.0));
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let mut total_kills = 0;
    for seed in 1..=3u64 {
        let spec = faulty_spec(seed);
        let (rep, events) = run_with_stream(&spec, &cat, &cluster);
        let a = analyze(&events).unwrap();
        assert_reconstructs(&a, &rep, &format!("faulty seed {seed}"));
        assert_eq!(a.kinds.len(), 1, "seed {seed}: one kind");
        assert_eq!(a.kinds[0].kind, "stress", "seed {seed}");
        assert_eq!(a.kills, a.retries, "seed {seed}: every kill retried (unlimited budget)");
        total_kills += a.kills;
    }
    assert!(total_kills > 0, "mtbf 8 s over 30 s x 3 seeds must kill something");
}

#[test]
fn chained_stream_analysis_matches_the_chained_report() {
    let cat = Catalog::new().insert("solo", solo(4.0));
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let cfg = EngineConfig::ideal();
    let spec = faulty_spec(3);
    let shared = Rc::new(RefCell::new(MemSink::new()));
    let leg = || TrafficObs {
        sink: Some(Box::new(Rc::clone(&shared))),
        profile: None,
    };
    let (rep, legs) = run_chained_obs(&spec, &cat, &cluster, &cfg, 7.0, leg).unwrap();
    assert!(legs >= 2, "a 7 s cadence over a ~30 s run must take several legs, got {legs}");
    // Analyze the raw multi-leg stream, seam markers and all: the
    // replay treats them as annotations, so the reconstruction still
    // matches the (bit-identical-to-uninterrupted) chained report.
    let events = shared.borrow().events.clone();
    let a = analyze(&events).unwrap();
    assert_reconstructs(&a, &rep, "chained run");
    assert_eq!(a.checkpoints, legs, "one seam marker per leg");
}
