//! Bit-identity property tests for the typed event stream (`obs`).
//!
//! The determinism contract under test:
//!
//! 1. **Wake-policy independence** — events hook state transitions,
//!    never loop iterations, so [`WakePolicy::Calendar`] and
//!    [`WakePolicy::FullScan`] (wildly different driver-wake counts)
//!    must render byte-identical NDJSON for the same seed, and reruns
//!    must too.
//! 2. **Resume concatenation** — the stream is derived state, never
//!    snapshotted: the pre-checkpoint prefix (seam marker stripped)
//!    plus the resumed run's stream equals the uninterrupted stream,
//!    even when the resume runs under the *opposite* wake policy.
//! 3. **Failure-lane accounting** — under stochastic faults the stream
//!    stays deterministic and internally consistent: every kill is a
//!    fault victim, schedules a retry, and resubmits.
//! 4. **Chained runs** — one shared sink spans every leg of a
//!    `--checkpoint-every` chain; markers stripped, the stream equals
//!    the uninterrupted run's, and the shared profile's lane counters
//!    equal the event counts.

use std::cell::RefCell;
use std::rc::Rc;

use asyncflow::dag::Dag;
use asyncflow::engine::{Coordinator, EngineConfig, ExecutionMode, RunOutcome, WakePolicy};
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::failure::cadence::run_chained_obs;
use asyncflow::failure::{FailureSpec, RetryPolicy};
use asyncflow::obs::profile::EngineProfile;
use asyncflow::obs::{strip_checkpoint_markers, MemSink, ObsEvent};
use asyncflow::pilot::{AutoscalePolicy, Policy, ResourcePlan};
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::sim::VirtualExecutor;
use asyncflow::task::TaskSetSpec;
use asyncflow::traffic::{
    run_traffic_resumable_obs, ArrivalProcess, Catalog, TrafficObs, TrafficOutcome,
    TrafficReport, TrafficSpec, WorkloadMix,
};
use asyncflow::util::rng::Rng;
use asyncflow::workflows::random_workflow;

/// Build the seed's scenario from scratch (the `tests/loop_equiv.rs`
/// matrix): random workflows, arrivals, scheduling policy, and — for
/// most seeds — an elastic plan with an optional autoscaler, so the
/// resize/autoscale event lanes are load-bearing too.
fn coordinator_for(seed: u64, wake: WakePolicy) -> Coordinator {
    let mut rng = Rng::new(seed);
    let policy = [Policy::FifoBackfill, Policy::WeightedFair, Policy::Backfill]
        [rng.below(3) as usize];
    let cfg = EngineConfig { policy, seed: seed ^ 0x5eed, ..EngineConfig::default() };
    let cluster = ClusterSpec::uniform("t", 3, 8, 2);
    let mut coord = Coordinator::new(&cluster, &cfg);
    coord.set_wake_policy(wake);
    let n = 2 + rng.below(5) as usize;
    for _ in 0..n {
        let wf = random_workflow(&mut rng, 3, 3);
        let mode = if rng.f64() < 0.5 {
            ExecutionMode::Asynchronous
        } else {
            ExecutionMode::Sequential
        };
        let arrival = rng.f64() * 120.0;
        coord.add_workflow(wf, mode, arrival).unwrap();
    }
    if rng.f64() < 0.6 {
        let mut plan = ResourcePlan::new()
            .resize(20.0 + rng.f64() * 40.0, 1)
            .resize(80.0 + rng.f64() * 40.0, -1);
        if rng.f64() < 0.5 {
            plan = plan.with_autoscale(AutoscalePolicy {
                interval: 10.0,
                min_nodes: 2,
                max_nodes: 5,
                step: 1,
                ..Default::default()
            });
        }
        coord.set_resource_plan(plan).unwrap();
    }
    coord
}

/// Attach a shared in-memory sink and hand back the keeper handle.
fn attach(coord: &mut Coordinator) -> Rc<RefCell<MemSink>> {
    let sink = Rc::new(RefCell::new(MemSink::new()));
    coord.set_event_sink(Box::new(Rc::clone(&sink)));
    sink
}

/// The full event stream of the seed's scenario run to completion.
fn events_of(seed: u64, wake: WakePolicy) -> Vec<ObsEvent> {
    let mut coord = coordinator_for(seed, wake);
    let sink = attach(&mut coord);
    let mut ex = VirtualExecutor::new();
    coord.run(&mut ex).unwrap();
    let events = sink.borrow().events.clone();
    events
}

fn ndjson(events: &[ObsEvent]) -> String {
    events.iter().map(|e| e.to_ndjson() + "\n").collect()
}

fn n_of(events: &[ObsEvent], tag: &str) -> usize {
    events.iter().filter(|e| e.tag() == tag).count()
}

#[test]
fn stream_is_bit_identical_across_wake_policies_and_reruns() {
    for seed in 0..16u64 {
        let scan = events_of(seed, WakePolicy::FullScan);
        let cal = events_of(seed, WakePolicy::Calendar);
        assert!(
            matches!(scan.first(), Some(ObsEvent::CapacityOffered { t, .. }) if *t == 0.0),
            "seed {seed}: the stream must open with the initial offered capacity"
        );
        assert_eq!(
            ndjson(&scan),
            ndjson(&cal),
            "seed {seed}: FullScan and Calendar must render identical NDJSON"
        );
        assert_eq!(
            cal,
            events_of(seed, WakePolicy::Calendar),
            "seed {seed}: rerunning the same seed must replay the same stream"
        );
        // Structural sanity on a completed failure-free run: everything
        // that arrived completed, and every submission ran exactly once.
        assert_eq!(
            n_of(&scan, "workflow_arrived"),
            n_of(&scan, "workflow_completed"),
            "seed {seed}: arrivals vs workflow completions"
        );
        assert_eq!(
            n_of(&scan, "task_submitted"),
            n_of(&scan, "task_completed"),
            "seed {seed}: submissions vs completions"
        );
        assert_eq!(
            n_of(&scan, "task_started"),
            n_of(&scan, "task_completed"),
            "seed {seed}: starts vs completions"
        );
    }
}

#[test]
fn resume_concatenation_equals_uninterrupted_stream() {
    let t_ck = 40.0;
    let mut checkpointed = 0;
    for seed in 0..16u64 {
        let full = events_of(seed, WakePolicy::Calendar);
        let mut coord = coordinator_for(seed, WakePolicy::Calendar);
        let pre = attach(&mut coord);
        let mut ex = VirtualExecutor::new();
        let snap = match coord.run_until(&mut ex, Some(t_ck)).unwrap() {
            RunOutcome::Checkpointed(s) => s,
            // Every workflow of this seed drained before t_ck — the
            // completed-run property above already covers it.
            RunOutcome::Completed(_) => continue,
        };
        checkpointed += 1;
        let prefix = pre.borrow().events.clone();
        assert!(
            matches!(prefix.last(), Some(ObsEvent::CheckpointTaken { .. })),
            "seed {seed}: the prefix must end with the seam marker"
        );
        // Resume under the opposite wake policy: the stream must not
        // care how the loop wakes. A resumed run emits no fresh
        // initial-capacity point — the prefix already carries it.
        let mut coord = Coordinator::restore(*snap).unwrap();
        coord.set_wake_policy(WakePolicy::FullScan);
        let post = attach(&mut coord);
        let mut ex = VirtualExecutor::new();
        coord.run(&mut ex).unwrap();
        let mut joined = strip_checkpoint_markers(&prefix);
        joined.extend(post.borrow().events.iter().cloned());
        assert_eq!(
            ndjson(&joined),
            ndjson(&full),
            "seed {seed}: prefix + resumed stream must equal the uninterrupted one"
        );
        assert_eq!(joined, full, "seed {seed}: typed events agree too");
    }
    assert!(checkpointed >= 4, "too few scenarios reached t = {t_ck}: {checkpointed}");
}

/// Single-task workflow: 1 core for `tx` seconds, deterministic.
fn solo(tx: f64) -> Workflow {
    let mut dag = Dag::new();
    dag.add_node("A");
    Workflow {
        name: "solo".into(),
        sets: vec![TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), tx).with_sigma(0.0)],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0])],
        asynchronous: vec![Pipeline::new("a").stage(&[0])],
    }
}

fn catalog(tx: f64) -> Catalog {
    Catalog::new().insert("solo", solo(tx))
}

/// Poisson traffic over a shrinking allocation with MTBF faults and
/// unlimited retries (the `tests/resilience.rs` scenario shape).
fn faulty_spec(seed: u64) -> TrafficSpec {
    TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 1.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 30.0,
        max_workflows: 100_000,
        seed,
        plan: Some(ResourcePlan::new().resize(15.0, -1)),
        checkpoint_at: None,
        policy: None,
        failure: Some(FailureSpec {
            retry: RetryPolicy { max_attempts: 0, base: 2.0, factor: 2.0, jitter: 0.25 },
            ..FailureSpec::mtbf(8.0)
        }),
    }
}

/// Run the spec to completion with a memory sink attached.
fn traffic_events(spec: &TrafficSpec) -> (TrafficReport, Vec<ObsEvent>) {
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let sink = Rc::new(RefCell::new(MemSink::new()));
    let obs = TrafficObs { sink: Some(Box::new(Rc::clone(&sink))), profile: None };
    let outcome =
        run_traffic_resumable_obs(spec, &catalog(4.0), &cluster, &EngineConfig::ideal(), obs)
            .unwrap();
    let TrafficOutcome::Completed(rep) = outcome else {
        panic!("spec has no checkpoint time, the run must complete")
    };
    let events = sink.borrow().events.clone();
    (*rep, events)
}

#[test]
fn failure_lane_stream_is_deterministic_and_accounted() {
    let mut total_kills = 0;
    for seed in 1..=3u64 {
        let spec = faulty_spec(seed);
        let (rep, events) = traffic_events(&spec);
        let (rep2, events2) = traffic_events(&spec);
        assert_eq!(rep, rep2, "seed {seed}: reports must be identical across reruns");
        assert_eq!(events, events2, "seed {seed}: streams must be identical across reruns");

        let kills = n_of(&events, "task_killed");
        let victims: usize = events
            .iter()
            .map(|e| match e {
                ObsEvent::NodeFault { victims, .. } => *victims,
                _ => 0,
            })
            .sum();
        let resubmits = events
            .iter()
            .filter(|e| matches!(e, ObsEvent::TaskSubmitted { attempt, .. } if *attempt > 0))
            .count();
        let first_submits = events
            .iter()
            .filter(|e| matches!(e, ObsEvent::TaskSubmitted { attempt: 0, .. }))
            .count();
        assert_eq!(victims, kills, "seed {seed}: every kill is some fault's victim");
        assert_eq!(
            n_of(&events, "retry_scheduled"),
            kills,
            "seed {seed}: unlimited retries back off every kill"
        );
        assert_eq!(resubmits, kills, "seed {seed}: every backoff resubmits");
        assert_eq!(n_of(&events, "retries_exhausted"), 0, "seed {seed}: nothing exhausts");
        assert_eq!(
            first_submits,
            n_of(&events, "task_completed"),
            "seed {seed}: unlimited retries drop nothing"
        );
        assert_eq!(n_of(&events, "resize"), 1, "seed {seed}: the planned drain applies once");
        total_kills += kills;
    }
    assert!(total_kills > 0, "mtbf 8 s over 30 s x 3 seeds must kill something");
}

#[test]
fn chained_stream_and_profile_match_the_uninterrupted_run() {
    let spec = faulty_spec(2);
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let cfg = EngineConfig::ideal();
    let (straight_rep, straight) = traffic_events(&spec);

    // One shared sink and one shared profile span every leg.
    let shared = Rc::new(RefCell::new(MemSink::new()));
    let profile = Rc::new(RefCell::new(EngineProfile::new()));
    let leg = || TrafficObs {
        sink: Some(Box::new(Rc::clone(&shared))),
        profile: Some(Rc::clone(&profile)),
    };
    let (chained_rep, legs) =
        run_chained_obs(&spec, &catalog(4.0), &cluster, &cfg, 7.0, leg).unwrap();
    assert!(legs >= 2, "a 7 s cadence over a ~30 s run must take several legs, got {legs}");
    assert_eq!(chained_rep, straight_rep, "chained report == uninterrupted report");

    let events = shared.borrow().events.clone();
    assert_eq!(n_of(&events, "checkpoint"), legs, "one seam marker per leg");
    assert_eq!(
        strip_checkpoint_markers(&events),
        straight,
        "markers stripped, the chained stream equals the uninterrupted one"
    );

    // The shared profile accumulated across every leg: lane counters
    // must equal the event counts of the whole run.
    let p = profile.borrow();
    assert_eq!(p.checkpoints, legs as u64, "checkpoint lane");
    assert_eq!(p.arrivals, n_of(&events, "workflow_arrived") as u64, "arrival lane");
    assert_eq!(p.completions, n_of(&events, "task_completed") as u64, "drain lane");
    assert_eq!(p.tasks_started, n_of(&events, "task_started") as u64, "launch flow");
    assert_eq!(p.faults, n_of(&events, "node_fault") as u64, "failure lane");
    let resubmits = events
        .iter()
        .filter(|e| matches!(e, ObsEvent::TaskSubmitted { attempt, .. } if *attempt > 0))
        .count();
    assert_eq!(p.retries_resubmitted, resubmits as u64, "retry lane");
    assert_eq!(
        p.submissions + p.retries_resubmitted,
        n_of(&events, "task_submitted") as u64,
        "every submission event is a first submission or a retry"
    );
    assert!(p.loop_iterations > 0 && p.driver_wakes > 0, "loop accounting moved");
    assert!(
        p.sched_rounds.count() > 0 && p.drain_rounds.count() > 0,
        "hot-round histograms sampled"
    );
}
