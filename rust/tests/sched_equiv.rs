//! Equivalence property test: the shape-bucketed scheduler must
//! reproduce the pre-refactor flat-queue drain order **bit-for-bit**
//! for every legacy policy, across random push/drain/release sequences
//! and elastic resizes.
//!
//! The reference below is a line-faithful port of the old
//! `pilot::scheduler` internals (flat queue + policy sort with the
//! FIFO fast path + failed-shape memo + compaction). Both schedulers
//! drive twin allocators; identical placement sequences keep the twins
//! identical, so any divergence — order, placement slots, or surviving
//! queue — fails the property at the first drifting round.

use std::collections::HashSet;

use asyncflow::resources::{Allocator, ClusterSpec, NodeSpec, Placement, ResourceRequest};
use asyncflow::sched::{DrainCtx, Policy, QueuedTask, Scheduler};
use asyncflow::util::prop::check_bool;
use asyncflow::util::rng::Rng;

/// The pre-refactor scheduler, verbatim: one flat vector, policy sort
/// per drain (with the `fifo_sorted` fast path), failed-shape memo,
/// insertion-order compaction.
struct LegacyScheduler {
    policy: Policy,
    queue: Vec<QueuedTask>,
    arrival_seq: u64,
    arrivals: Vec<u64>,
    fifo_sorted: bool,
}

impl LegacyScheduler {
    fn new(policy: Policy) -> LegacyScheduler {
        LegacyScheduler {
            policy,
            queue: Vec::new(),
            arrival_seq: 0,
            arrivals: Vec::new(),
            fifo_sorted: true,
        }
    }

    fn push(&mut self, t: QueuedTask) {
        match self.queue.last() {
            Some(last) => {
                if t.submitted_at < last.submitted_at {
                    self.fifo_sorted = false;
                }
            }
            None => self.fifo_sorted = true,
        }
        self.queue.push(t);
        self.arrivals.push(self.arrival_seq);
        self.arrival_seq += 1;
    }

    fn order(&mut self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.queue.len()).collect();
        if self.fifo_sorted
            && matches!(self.policy, Policy::FifoBackfill | Policy::FifoStrict)
        {
            return idx;
        }
        match self.policy {
            Policy::PipelineAge => idx.sort_by(|&a, &b| {
                let (ta, tb) = (&self.queue[a], &self.queue[b]);
                ta.priority
                    .cmp(&tb.priority)
                    .then(ta.submitted_at.total_cmp(&tb.submitted_at))
                    .then(self.arrivals[a].cmp(&self.arrivals[b]))
            }),
            Policy::FifoBackfill | Policy::FifoStrict => idx.sort_by(|&a, &b| {
                self.queue[a]
                    .submitted_at
                    .total_cmp(&self.queue[b].submitted_at)
                    .then(self.arrivals[a].cmp(&self.arrivals[b]))
            }),
            Policy::SmallestFirst => idx.sort_by(|&a, &b| {
                let (ta, tb) = (&self.queue[a], &self.queue[b]);
                (ta.req.cpu_cores + 100 * ta.req.gpus)
                    .cmp(&(tb.req.cpu_cores + 100 * tb.req.gpus))
                    .then(self.arrivals[a].cmp(&self.arrivals[b]))
            }),
            _ => panic!("legacy reference only covers the pre-refactor policies"),
        }
        idx
    }

    fn drain(&mut self, alloc: &mut Allocator) -> Vec<(usize, Placement)> {
        let order = self.order();
        let mut placed: Vec<(usize, Placement)> = Vec::new();
        let mut remove: Vec<bool> = Vec::new();
        let mut failed_shapes: HashSet<ResourceRequest> = HashSet::new();
        for &i in &order {
            let t = self.queue[i];
            if failed_shapes.contains(&t.req) {
                if self.policy == Policy::FifoStrict {
                    break;
                }
                continue;
            }
            match alloc.try_alloc(&t.req) {
                Some(placement) => {
                    if remove.is_empty() {
                        remove = vec![false; self.queue.len()];
                    }
                    placed.push((t.uid, placement));
                    remove[i] = true;
                }
                None => {
                    if self.policy == Policy::FifoStrict {
                        break;
                    }
                    failed_shapes.insert(t.req);
                }
            }
        }
        if placed.is_empty() {
            return placed;
        }
        let mut q = Vec::with_capacity(self.queue.len() - placed.len());
        let mut a = Vec::with_capacity(q.capacity());
        for (i, t) in self.queue.iter().enumerate() {
            if !remove[i] {
                q.push(*t);
                a.push(self.arrivals[i]);
            }
        }
        self.queue = q;
        self.arrivals = a;
        if !self.fifo_sorted {
            self.fifo_sorted = self
                .queue
                .windows(2)
                .all(|w| w[0].submitted_at <= w[1].submitted_at);
        }
        placed
    }
}

/// One step of a random scheduler workload.
#[derive(Debug, Clone, Copy)]
enum Op {
    /// Push a task: (cores 1..=8, gpus 0..=2, priority 0..=3,
    /// out-of-order submit-time nudge).
    Push(u32, u32, u64, bool),
    /// Drain one round on both schedulers and compare.
    Drain,
    /// Release the k-th oldest live placement on both allocators.
    Release(usize),
    /// Append a node to both allocators.
    Grow,
    /// Gracefully drain the least-busy node on both allocators.
    Shrink,
}

fn gen_ops(rng: &mut Rng, n: usize) -> Vec<Op> {
    (0..n)
        .map(|_| match rng.below(10) {
            0 | 1 | 2 | 3 => Op::Push(
                1 + rng.below(8) as u32,
                rng.below(3) as u32,
                rng.below(4),
                rng.f64() < 0.15,
            ),
            4 | 5 | 6 => Op::Drain,
            7 => Op::Release(rng.below(64) as usize),
            8 => Op::Grow,
            _ => Op::Shrink,
        })
        .collect()
}

fn equivalent_under(policy: Policy, ops: &[Op]) -> bool {
    let cluster = ClusterSpec::uniform("t", 3, 10, 2);
    let mut bucketed = Scheduler::new(policy);
    let mut legacy = LegacyScheduler::new(policy);
    let mut alloc_b = Allocator::new(&cluster);
    let mut alloc_l = Allocator::new(&cluster);
    let mut clock = 0.0f64;
    let mut uid = 0usize;
    let mut live: Vec<(usize, Placement)> = Vec::new();
    for &op in ops {
        match op {
            Op::Push(cores, gpus, priority, backdated) => {
                clock += 1.0;
                // An out-of-order push models a retried submission with
                // a historical timestamp — the fifo_sorted edge case.
                let at = if backdated { clock - 5.5 } else { clock };
                let t = QueuedTask {
                    uid,
                    req: ResourceRequest::new(cores, gpus),
                    priority,
                    submitted_at: at,
                    tenant: uid % 3,
                    est: 1.0 + (uid % 7) as f64,
                };
                uid += 1;
                bucketed.push(t);
                legacy.push(t);
            }
            Op::Drain => {
                clock += 1.0;
                let new: Vec<(usize, Placement)> = bucketed
                    .drain_schedulable(&mut alloc_b, &DrainCtx::at(clock))
                    .into_iter()
                    .map(|s| (s.uid, s.placement))
                    .collect();
                let old = legacy.drain(&mut alloc_l);
                if new != old {
                    return false;
                }
                live.extend(new);
                // Surviving queues must match in insertion order too.
                let qb: Vec<usize> = bucketed.queued().iter().map(|t| t.uid).collect();
                let ql: Vec<usize> = legacy.queue.iter().map(|t| t.uid).collect();
                if qb != ql {
                    return false;
                }
            }
            Op::Release(k) => {
                if !live.is_empty() {
                    let (_, p) = live.remove(k % live.len());
                    alloc_b.release(&p);
                    alloc_l.release(&p);
                }
            }
            Op::Grow => {
                alloc_b.add_node(NodeSpec { cores: 10, gpus: 2 });
                alloc_l.add_node(NodeSpec { cores: 10, gpus: 2 });
            }
            Op::Shrink => {
                if let Some(&i) = alloc_b.drain_candidates(1).first() {
                    // Same state on both sides, so the candidate is
                    // drainable on both.
                    alloc_b.drain_node(i).unwrap();
                    alloc_l.drain_node(i).unwrap();
                }
            }
        }
        if !(alloc_b.check_invariants() && alloc_l.check_invariants()) {
            return false;
        }
    }
    // Final drains until both settle, to flush pending comparisons.
    for _ in 0..3 {
        clock += 1.0;
        let new: Vec<(usize, Placement)> = bucketed
            .drain_schedulable(&mut alloc_b, &DrainCtx::at(clock))
            .into_iter()
            .map(|s| (s.uid, s.placement))
            .collect();
        let old = legacy.drain(&mut alloc_l);
        if new != old {
            return false;
        }
        for (_, p) in &new {
            alloc_b.release(p);
            alloc_l.release(p);
        }
    }
    bucketed.queue_len() == legacy.queue.len()
}

#[test]
fn bucketed_scheduler_matches_legacy_flat_queue_bit_for_bit() {
    for (seed, policy) in [
        (0xF1F0_0001u64, Policy::FifoBackfill),
        (0xF1F0_0002, Policy::FifoStrict),
        (0xF1F0_0003, Policy::PipelineAge),
        (0xF1F0_0004, Policy::SmallestFirst),
    ] {
        check_bool(
            seed,
            120,
            |rng: &mut Rng, size| gen_ops(rng, size.0 * 6),
            |ops| equivalent_under(policy, ops),
        );
    }
}

#[test]
fn saturated_drain_is_shape_bounded_not_queue_bounded() {
    // The perf contract behind the refactor, asserted via the probe
    // counters: a fully-blocked drain over 5_000 queued tasks in 5
    // shapes examines zero tasks and probes exactly 5 shapes.
    let cluster = ClusterSpec::uniform("t", 2, 8, 1);
    let mut alloc = Allocator::new(&cluster);
    // Saturate: take both nodes completely.
    let mut hogs = Vec::new();
    for _ in 0..2 {
        hogs.push(alloc.try_alloc(&ResourceRequest::new(8, 1)).unwrap());
    }
    let mut s = Scheduler::new(Policy::FifoBackfill);
    for uid in 0..5_000 {
        let (c, g) = [(1, 0), (2, 0), (4, 0), (1, 1), (2, 1)][uid % 5];
        s.push(QueuedTask {
            uid,
            req: ResourceRequest::new(c, g),
            priority: 0,
            submitted_at: uid as f64,
            tenant: 0,
            est: 1.0,
        });
    }
    let before = s.stats();
    assert!(s.drain_schedulable(&mut alloc, &DrainCtx::at(0.0)).is_empty());
    let after = s.stats();
    assert_eq!(after.tasks_examined - before.tasks_examined, 0);
    assert_eq!(after.shape_probes - before.shape_probes, 5);
    assert_eq!(s.queue_len(), 5_000);
}

#[test]
fn bucket_identity_is_first_seen_order_never_map_order() {
    // Regression guard for the DET002 fix (hash map -> ordered map in
    // ShapeQueue::index): bucket ids, queued() order, and demand must
    // be pure functions of the push sequence. Two queues fed the same
    // interleaved shape stream must agree exactly, and the ids must be
    // the first-seen ordinals — shapes are deliberately pushed in
    // non-sorted order so any map-traversal-derived assignment (sorted
    // by shape, or hash order) would misnumber them.
    use asyncflow::sched::{OrdKey, ShapeQueue};
    let shapes = [(8, 1), (1, 0), (4, 4), (1, 0), (8, 1), (2, 0), (4, 4), (2, 0)];
    let build = || {
        let mut q = ShapeQueue::new();
        for (uid, &(c, g)) in shapes.iter().enumerate() {
            let t = QueuedTask {
                uid,
                req: ResourceRequest::new(c, g),
                priority: 0,
                submitted_at: uid as f64,
                tenant: 0,
                est: 1.0,
            };
            q.push(t, |t, seq| OrdKey { major: 0, time: t.submitted_at, seq });
        }
        q
    };
    let (a, b) = (build(), build());
    // First-seen ordinals: (8,1)=0, (1,0)=1, (4,4)=2, (2,0)=3.
    let expect = [(8, 1), (1, 0), (4, 4), (2, 0)];
    for (id, &(c, g)) in expect.iter().enumerate() {
        assert_eq!(a.shape(id), ResourceRequest::new(c, g), "bucket {id}");
    }
    assert_eq!(
        a.bucket_ids().collect::<Vec<_>>(),
        b.bucket_ids().collect::<Vec<_>>()
    );
    assert_eq!(a.demand(), b.demand());
    let uids = |q: &ShapeQueue| q.queued().iter().map(|t| t.uid).collect::<Vec<_>>();
    assert_eq!(uids(&a), uids(&b));
    // queued() recovers the exact push order (checkpoint contract).
    assert_eq!(uids(&a), (0..shapes.len()).collect::<Vec<_>>());
}
