//! Integration tests for failure injection and resilience.
//!
//! The headline invariants:
//!
//! 1. **Crash/resume determinism under faults** — for any seed, MTBF
//!    and scheduling policy, checkpoint-at-T + JSON round-trip +
//!    resume is bit-identical to the uninterrupted run, including
//!    snapshots taken while a killed task sits in retry backoff and
//!    snapshots taken just before a fault fires.
//! 2. **Progress under unlimited retries** — with a finite fault rate
//!    and an unbounded retry budget every workflow completes, and the
//!    resilience ledger conserves: completed goodput is exactly the
//!    work the tasks carried, lost work is what the kills destroyed.
//! 3. **Typed exhaustion** — a capped retry budget surfaces
//!    `Error::RetriesExhausted`, never a hang or a silent drop.

use asyncflow::dag::Dag;
use asyncflow::engine::EngineConfig;
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::failure::cadence::run_chained;
use asyncflow::failure::{FailureSpec, RetryPolicy};
use asyncflow::pilot::ResourcePlan;
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::task::TaskSetSpec;
use asyncflow::traffic::{
    run_traffic, run_traffic_resumable, ArrivalProcess, Catalog, TrafficCheckpoint,
    TrafficOutcome, TrafficReport, TrafficSpec, WorkloadMix,
};
use asyncflow::util::json::{FromJson, Json, ToJson};
use asyncflow::Error;

/// Single-task workflow: 1 core for `tx` seconds, deterministic.
fn solo(tx: f64) -> Workflow {
    let mut dag = Dag::new();
    dag.add_node("A");
    Workflow {
        name: "solo".into(),
        sets: vec![TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), tx).with_sigma(0.0)],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0])],
        asynchronous: vec![Pipeline::new("a").stage(&[0])],
    }
}

fn catalog(tx: f64) -> Catalog {
    Catalog::new().insert("solo", solo(tx))
}

/// Unlimited retries with the given first backoff.
fn unlimited(base: f64) -> RetryPolicy {
    RetryPolicy { max_attempts: 0, base, factor: 2.0, jitter: 0.25 }
}

/// Run `spec` uninterrupted, then again preempted at `t_ck` with a
/// full JSON round-trip of the checkpoint before resuming; returns
/// both reports (panics if the run finishes before the checkpoint).
fn straight_and_resumed(
    spec: &TrafficSpec,
    cat: &Catalog,
    cluster: &ClusterSpec,
    cfg: &EngineConfig,
    t_ck: f64,
) -> (TrafficReport, TrafficReport, TrafficCheckpoint) {
    let straight = run_traffic(spec, cat, cluster, cfg).unwrap();
    let preempted = TrafficSpec { checkpoint_at: Some(t_ck), ..spec.clone() };
    let outcome = run_traffic_resumable(&preempted, cat, cluster, cfg).unwrap();
    let TrafficOutcome::Checkpointed(ck) = outcome else {
        panic!("run finished before the t = {t_ck} checkpoint")
    };
    let wire = ck.to_json().to_string();
    let parsed = TrafficCheckpoint::from_json(&Json::parse(&wire).unwrap()).unwrap();
    let ck_copy = TrafficCheckpoint::from_json(&Json::parse(&wire).unwrap()).unwrap();
    let resumed = parsed.resume(None).unwrap();
    (straight, resumed, ck_copy)
}

#[test]
fn faulty_resume_is_bit_identical_across_seeds_rates_and_policies() {
    // The checkpoint.rs headline matrix, now with a live stochastic
    // fault process and retry pipeline layered on top: a Poisson
    // stream over an allocation that also loses a node gracefully at
    // t = 15, killed by MTBF faults at two intensities, three seeds x
    // all three scheduling policies x checkpoints on both sides of the
    // drain. Resuming must replay the exact fault schedule (the fault
    // RNG position rides in the snapshot), the retry backoffs and the
    // attempt counters — bit for bit.
    use asyncflow::sched::Policy;
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let cfg = EngineConfig::ideal();
    for policy in [Policy::FifoBackfill, Policy::WeightedFair, Policy::Backfill] {
        for seed in [1, 2, 3] {
            for mtbf in [8.0, 25.0] {
                let failure = FailureSpec {
                    retry: unlimited(2.0),
                    ..FailureSpec::mtbf(mtbf)
                };
                let spec = TrafficSpec {
                    process: ArrivalProcess::Poisson { rate: 1.0 },
                    mix: WorkloadMix::parse("solo").unwrap(),
                    duration: 30.0,
                    max_workflows: 100_000,
                    seed,
                    plan: Some(ResourcePlan::new().resize(15.0, -1)),
                    checkpoint_at: None,
                    policy: Some(policy),
                    failure: Some(failure),
                };
                for t_ck in [7.0, 21.0] {
                    let (straight, resumed, ck) =
                        straight_and_resumed(&spec, &catalog(4.0), &cluster, &cfg, t_ck);
                    assert!(
                        ck.sim.failure.is_some(),
                        "snapshot must carry the fault-process state"
                    );
                    assert_eq!(
                        straight, resumed,
                        "{policy:?}, seed {seed}, mtbf {mtbf}, ck {t_ck}: \
                         reports must be identical"
                    );
                    assert_eq!(
                        straight.to_json().to_string(),
                        resumed.to_json().to_string(),
                        "{policy:?}, seed {seed}, mtbf {mtbf}, ck {t_ck}: \
                         bit-identical JSON"
                    );
                    assert_eq!(straight.failed_tasks, 0, "unlimited retries drop nothing");
                }
            }
        }
    }
}

#[test]
fn snapshot_mid_retry_backoff_restores_exactly() {
    // Deterministic construction of the juiciest snapshot state: a
    // trace fault kills the only running task at t = 5, its retry is
    // due at t = 15 (base 10, no jitter), and the checkpoint lands at
    // t = 8 — squarely inside the backoff window. The killed-but-live
    // task must ride the snapshot through the retry queue, not the run
    // queue and not the free list.
    let cluster = ClusterSpec::uniform("t", 1, 1, 0);
    let cfg = EngineConfig::ideal();
    let mut failure = FailureSpec::parse_trace("5:0").unwrap();
    failure.retry = RetryPolicy { max_attempts: 0, base: 10.0, factor: 1.0, jitter: 0.0 };
    let spec = TrafficSpec {
        process: ArrivalProcess::Deterministic { interval: 4.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 12.0,
        max_workflows: 100_000,
        seed: 1,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: Some(failure),
    };
    let (straight, resumed, ck) =
        straight_and_resumed(&spec, &catalog(10.0), &cluster, &cfg, 8.0);

    // The snapshot really is mid-backoff.
    assert_eq!(ck.sim.retries.len(), 1, "one task waiting out its backoff at t = 8");
    assert_eq!(ck.sim.retries[0].uid, 0, "the first task is the victim");
    assert!((ck.sim.retries[0].due - 15.0).abs() < 1e-9, "due = kill + base backoff");
    assert_eq!(ck.sim.retries[0].attempt, 1);
    assert_eq!(ck.sim.attempts, vec![(0, 1)], "attempt counter rides the snapshot");
    assert!(ck.sim.failure.is_some());

    assert_eq!(straight, resumed);
    assert_eq!(straight.to_json().to_string(), resumed.to_json().to_string());
    // The fault accounting is exact: one fault, one victim killed 5 s
    // into a 10 s task, retried once, nothing exhausted.
    let r = straight.resilience.expect("failure-enabled run must report resilience");
    assert_eq!(r.failures_injected, 1);
    assert_eq!(r.tasks_killed, 1);
    assert_eq!(r.retries_scheduled, 1);
    assert_eq!(r.retries_exhausted, 0);
    assert!((r.lost_core_s - 5.0).abs() < 1e-9, "5 core-seconds died with the kill");
    assert_eq!(r.lost_gpu_s, 0.0);
    // All three arrivals complete; goodput is their full carried work.
    assert_eq!(straight.workflows.len(), 3);
    assert_eq!(straight.failed_tasks, 0);
    assert!((r.goodput_core_s - 30.0).abs() < 1e-6);
}

#[test]
fn checkpoint_just_before_a_kill_replays_the_fault_on_resume() {
    // The fault fires at t = 9.5, the checkpoint at t = 9.0: the kill,
    // the lost-work accounting and the retry all happen in the
    // *resumed* leg, off the snapshotted trace cursor.
    let cluster = ClusterSpec::uniform("t", 1, 1, 0);
    let cfg = EngineConfig::ideal();
    let mut failure = FailureSpec::parse_trace("9.5:0").unwrap();
    failure.retry = RetryPolicy { max_attempts: 0, base: 2.0, factor: 1.0, jitter: 0.0 };
    let spec = TrafficSpec {
        process: ArrivalProcess::Deterministic { interval: 4.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 12.0,
        max_workflows: 100_000,
        seed: 1,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: Some(failure),
    };
    let (straight, resumed, ck) =
        straight_and_resumed(&spec, &catalog(10.0), &cluster, &cfg, 9.0);
    assert!(ck.sim.retries.is_empty(), "nothing killed yet at t = 9");
    assert_eq!(straight, resumed);
    assert_eq!(straight.to_json().to_string(), resumed.to_json().to_string());
    let r = straight.resilience.unwrap();
    assert_eq!(r.tasks_killed, 1, "the t = 9.5 fault kills the 10 s task");
    assert!((r.lost_core_s - 9.5).abs() < 1e-9);
    assert_eq!(straight.workflows.len(), 3);
    assert_eq!(straight.failed_tasks, 0, "the victim retries and finishes");
}

#[test]
fn unlimited_retries_complete_everything_and_conserve_the_ledger() {
    // Aggressive fault rate (per-node MTBF 3 s against 3 s tasks) with
    // an unbounded retry budget: progress is guaranteed, and the
    // resilience ledger must conserve — every completed task carried
    // exactly tx core-seconds of goodput, every kill destroyed only
    // partial work, every kill got a retry, nothing was exhausted.
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let cfg = EngineConfig::ideal();
    let failure = FailureSpec { retry: unlimited(1.0), ..FailureSpec::mtbf(3.0) };
    let spec = TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 1.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 30.0,
        max_workflows: 100_000,
        seed: 7,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: Some(failure),
    };
    let rep = run_traffic(&spec, &catalog(3.0), &cluster, &cfg).unwrap();
    let n = rep.workflows.len();
    assert!(n > 10, "a 30 s Poisson(1) window must admit a real stream, got {n}");
    assert_eq!(rep.total_tasks, n, "solo: one task per workflow");
    assert_eq!(rep.failed_tasks, 0, "unlimited retries never drop a task");
    assert_eq!(rep.backlog.final_tasks(), 0, "stream fully drained");

    let r = rep.resilience.expect("failure-enabled run must report resilience");
    assert!(r.failures_injected > 0, "MTBF 3 s over 2 nodes must fire within the run");
    assert!(r.tasks_killed > 0, "a saturated stream must lose tasks to those faults");
    assert_eq!(
        r.tasks_killed, r.retries_scheduled,
        "unlimited budget: every kill is granted a retry"
    );
    assert_eq!(r.retries_exhausted, 0);
    // Conservation: completed goodput is exactly the carried work (tx
    // = 3 s x 1 core per task, zero overhead, sigma 0), and lost work
    // is strictly positive partial progress.
    assert!(
        (r.goodput_core_s - 3.0 * n as f64).abs() < 1e-6,
        "goodput {} != 3 x {n} tasks",
        r.goodput_core_s
    );
    assert_eq!(r.goodput_gpu_s, 0.0);
    assert!(r.lost_core_s > 0.0, "kills destroy partial work");
    assert!(
        r.lost_core_s < r.tasks_killed as f64 * 3.0 + 1e-9,
        "a kill cannot destroy more than one full task's work"
    );
}

#[test]
fn capped_retries_surface_a_typed_error_not_a_hang() {
    // Two trace faults aimed at the same task: attempt 1 is granted
    // (max = 1), attempt 2 exhausts the budget mid-run. The engine
    // must abort with the typed error naming the workflow, the task
    // and the attempt count.
    let cluster = ClusterSpec::uniform("t", 1, 1, 0);
    let mut failure = FailureSpec::parse_trace("5:0,20:0").unwrap();
    failure.retry = RetryPolicy { max_attempts: 1, base: 10.0, factor: 1.0, jitter: 0.0 };
    let spec = TrafficSpec {
        process: ArrivalProcess::Deterministic { interval: 1000.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 10.0,
        max_workflows: 100_000,
        seed: 1,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: Some(failure),
    };
    // Timeline: the 10 s task runs [0, 10), dies at 5, retries at 15
    // (base backoff 10), runs [15, 25), dies again at 20 — budget gone.
    let err = run_traffic(&spec, &catalog(10.0), &cluster, &EngineConfig::ideal())
        .expect_err("the second kill must exhaust the retry budget");
    match err {
        Error::RetriesExhausted { workflow, uid, attempts } => {
            assert_eq!(workflow, "solo");
            assert_eq!(uid, 0);
            assert_eq!(attempts, 2);
        }
        other => panic!("expected RetriesExhausted, got {other:?}"),
    }
}

#[test]
fn kills_on_a_draining_node_shed_capacity_and_the_run_recovers() {
    // Kill-vs-drain at engine scale: one node starts a graceful drain
    // at t = 5, then a trace fault at t = 7 hard-kills both nodes.
    // The drained node's busy share must leave the offered-capacity
    // timeline at the kill instant (not at the task's would-have-been
    // completion), the victims must retry on the survivor, and the
    // whole thing must still be checkpoint-exact mid-recovery.
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let cfg = EngineConfig::ideal();
    let mut failure = FailureSpec::parse_trace("7:0,7:1").unwrap();
    failure.retry = RetryPolicy { max_attempts: 0, base: 1.0, factor: 1.0, jitter: 0.0 };
    let spec = TrafficSpec {
        process: ArrivalProcess::Deterministic { interval: 2.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 12.0,
        max_workflows: 100_000,
        seed: 1,
        plan: Some(ResourcePlan::new().resize(5.0, -1)),
        checkpoint_at: None,
        policy: None,
        failure: Some(failure),
    };
    // t_ck = 7.5: post-kill, mid-drain, with retries due at t = 8
    // still pending in the snapshot.
    let (straight, resumed, ck) =
        straight_and_resumed(&spec, &catalog(10.0), &cluster, &cfg, 7.5);
    assert!(!ck.sim.retries.is_empty(), "t = 7 victims are waiting out backoff at 7.5");
    assert!(ck.sim.draining.iter().any(|&d| d), "the t = 5 drain is still in force");
    assert_eq!(straight, resumed);
    assert_eq!(straight.to_json().to_string(), resumed.to_json().to_string());

    let r = straight.resilience.unwrap();
    assert!(r.tasks_killed >= 1, "the t = 7 sweep catches running work");
    assert_eq!(straight.failed_tasks, 0);
    assert_eq!(straight.workflows.len(), 6, "every arrival completes on the survivor");
    assert_eq!(
        straight.capacity.final_capacity(),
        (2, 0),
        "the drained node never returns; the killed survivor does"
    );
    // The drained node's share left at the kill (t = 7), not at its
    // task's original completion (t = 10).
    assert!(
        straight.capacity.points.iter().any(|&(t, c, _)| (t - 7.0).abs() < 1e-9 && c == 2),
        "offered capacity must step to 2 cores at the kill instant: {:?}",
        straight.capacity.points
    );
}

#[test]
fn chained_periodic_checkpoints_match_the_uninterrupted_run() {
    // The --checkpoint-every machinery: snapshot every 5 s, JSON
    // round-trip every leg, resume — under live faults and retries —
    // and the final report must still be bit-identical to the run
    // that never stopped.
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let cfg = EngineConfig::ideal();
    let failure = FailureSpec { retry: unlimited(2.0), ..FailureSpec::mtbf(8.0) };
    let spec = TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 1.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 30.0,
        max_workflows: 100_000,
        seed: 2,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: Some(failure),
    };
    let cat = catalog(4.0);
    let straight = run_traffic(&spec, &cat, &cluster, &cfg).unwrap();
    let (chained, legs) = run_chained(&spec, &cat, &cluster, &cfg, 5.0).unwrap();
    assert!(legs >= 3, "a 30+ s run at a 5 s cadence must take several legs, got {legs}");
    assert_eq!(straight, chained, "periodic checkpointing must not perturb the run");
    assert_eq!(straight.to_json().to_string(), chained.to_json().to_string());
    assert_eq!(straight.resilience, chained.resilience);
}
