//! Integration tests over the public API: real (wall-clock) execution
//! with the stress executor, config-file round trips, failure
//! injection, and determinism across executors.

use asyncflow::config;
use asyncflow::dag::Dag;
use asyncflow::ddmd::{ddmd_workflow, DdmdConfig};
use asyncflow::engine::{run, simulate_cfg, EngineConfig, ExecutionMode};
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::exec::{StressExecutor, StressMode};
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::task::TaskSetSpec;

/// Small fork workflow with deterministic TX.
fn fork_wf(tx_scale: f64) -> Workflow {
    let mut dag = Dag::new();
    let a = dag.add_node("A");
    let b = dag.add_node("B");
    let c = dag.add_node("C");
    dag.add_edge(a, b).unwrap();
    dag.add_edge(a, c).unwrap();
    Workflow {
        name: "fork".into(),
        sets: vec![
            TaskSetSpec::new("A", 2, ResourceRequest::new(1, 0), 10.0 * tx_scale).with_sigma(0.0),
            TaskSetSpec::new("B", 3, ResourceRequest::new(1, 0), 20.0 * tx_scale).with_sigma(0.0),
            TaskSetSpec::new("C", 3, ResourceRequest::new(1, 0), 20.0 * tx_scale).with_sigma(0.0),
        ],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0]).stage(&[1]).stage(&[2])],
        asynchronous: vec![
            Pipeline::new("p0").stage(&[0]).stage(&[1]),
            Pipeline::new("p1").stage(&[2]),
        ],
    }
}

#[test]
fn stress_executor_matches_virtual_executor() {
    // The same workflow must produce (approximately) the same makespan
    // under real threads as under virtual time — the coordinator logic
    // is shared; only the clock differs.
    let wf = fork_wf(1.0);
    let cluster = ClusterSpec::uniform("t", 1, 8, 0);
    let cfg = EngineConfig { task_overhead: 0.0, stage_overhead: 0.0, ..Default::default() };

    let virt = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);

    // Real execution at 1:200 scale (10 paper-s -> 50 wall-ms).
    let mut real = StressExecutor::new(0.005, StressMode::Sleep);
    let rep = run(&wf, &cluster, ExecutionMode::Asynchronous, &cfg, &mut real).unwrap();

    assert_eq!(rep.records.len(), virt.records.len());
    let rel = (rep.makespan - virt.makespan).abs() / virt.makespan;
    assert!(
        rel < 0.35,
        "real {:.1}s vs virtual {:.1}s (rel {rel:.2})",
        rep.makespan,
        virt.makespan
    );
    // Ordering invariants hold in both domains.
    for r in &rep.records {
        assert!(r.started >= r.submitted - 1e-9);
        assert!(r.finished > r.started);
    }
}

#[test]
fn async_beats_sequential_under_real_concurrency() {
    let wf = fork_wf(1.0);
    let cluster = ClusterSpec::uniform("t", 1, 8, 0);
    let cfg = EngineConfig { task_overhead: 0.0, stage_overhead: 0.0, ..Default::default() };
    let mut seq_ex = StressExecutor::new(0.004, StressMode::Sleep);
    let seq = run(&wf, &cluster, ExecutionMode::Sequential, &cfg, &mut seq_ex).unwrap();
    let mut asy_ex = StressExecutor::new(0.004, StressMode::Sleep);
    let asy = run(&wf, &cluster, ExecutionMode::Asynchronous, &cfg, &mut asy_ex).unwrap();
    assert!(
        asy.makespan < seq.makespan,
        "async {:.1} !< seq {:.1}",
        asy.makespan,
        seq.makespan
    );
}

#[test]
fn failure_injection_is_reported_not_fatal() {
    let wf = fork_wf(1.0);
    let cluster = ClusterSpec::uniform("t", 1, 8, 0);
    let cfg = EngineConfig { task_overhead: 0.0, stage_overhead: 0.0, ..Default::default() };
    let mut ex = StressExecutor::new(0.002, StressMode::Sleep);
    ex.inject_failure(0);
    ex.inject_failure(3);
    let rep = run(&wf, &cluster, ExecutionMode::Sequential, &cfg, &mut ex).unwrap();
    assert_eq!(rep.failed_tasks, 2);
    assert_eq!(rep.records.iter().filter(|r| r.failed).count(), 2);
    // All tasks still ran to completion states.
    assert!(rep.records.iter().all(|r| r.finished.is_finite()));
}

#[test]
fn abort_on_failure_stops_the_run() {
    let wf = fork_wf(1.0);
    let cluster = ClusterSpec::uniform("t", 1, 8, 0);
    let cfg = EngineConfig {
        task_overhead: 0.0,
        stage_overhead: 0.0,
        abort_on_failure: true,
        ..Default::default()
    };
    let mut ex = StressExecutor::new(0.002, StressMode::Sleep);
    ex.inject_failure(0);
    assert!(run(&wf, &cluster, ExecutionMode::Sequential, &cfg, &mut ex).is_err());
}

#[test]
fn config_file_round_trip_drives_engine() {
    let json = r#"{
      "workflow": {
        "name": "from-config",
        "sets": [
          {"name": "A", "tasks": 2, "cores": 2, "tx": 30.0, "sigma": 0.0},
          {"name": "B", "tasks": 4, "cores": 1, "gpus": 1, "tx": 15.0, "sigma": 0.0},
          {"name": "C", "tasks": 4, "cores": 1, "tx": 15.0, "sigma": 0.0}
        ],
        "edges": [["A", "B"], ["A", "C"]],
        "sequential": [[["A"], ["B"], ["C"]]],
        "asynchronous": [[["A"], ["B"]], [["C"]]]
      },
      "cluster": {"name": "mini", "nodes": [{"cores": 8, "gpus": 4, "count": 2}]},
      "engine": {"seed": 9, "task_overhead": 0.0, "stage_overhead": 0.0, "policy": "fifo"}
    }"#;
    let dir = std::env::temp_dir().join("asyncflow_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.json");
    std::fs::write(&path, json).unwrap();

    let (wf, cluster, cfg) = config::load_experiment(&path).unwrap();
    let seq = simulate_cfg(&wf, &cluster, ExecutionMode::Sequential, &cfg);
    let asy = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
    // Sequential: 30 + 15 + 15; async: 30 + max(15, 15) = 45.
    assert!((seq.makespan - 60.0).abs() < 1e-6, "{}", seq.makespan);
    assert!((asy.makespan - 45.0).abs() < 1e-6, "{}", asy.makespan);
}

#[test]
fn ddmd_small_runs_as_stress_workflow_real_time() {
    // The DDMD workflow built for the e2e example also runs under the
    // plain stress executor (bodies ignored) — useful to separate
    // coordination bugs from ML-body bugs.
    let wf = ddmd_workflow(&DdmdConfig::small());
    let cluster = ClusterSpec::local_small();
    let cfg = EngineConfig { task_overhead: 0.0, stage_overhead: 0.0, ..Default::default() };
    let mut ex = StressExecutor::new(0.02, StressMode::Sleep);
    let rep = run(&wf, &cluster, ExecutionMode::Asynchronous, &cfg, &mut ex).unwrap();
    assert_eq!(rep.records.len() as u64, wf.total_tasks());
    assert_eq!(rep.failed_tasks, 0);
}

#[test]
fn virtual_determinism_across_repeated_runs() {
    let wf = ddmd_workflow(&DdmdConfig::paper());
    let cluster = ClusterSpec::summit_paper();
    let cfg = EngineConfig::default();
    let a = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
    let b = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.cpu_utilization, b.cpu_utilization);
    let starts_a: Vec<f64> = a.records.iter().map(|r| r.started).collect();
    let starts_b: Vec<f64> = b.records.iter().map(|r| r.started).collect();
    assert_eq!(starts_a, starts_b);
}
