//! Paper-validation integration tests: every numbered claim from §5–§7
//! that has a closed-form value, checked end to end through the public
//! API. These are the repository's "does it reproduce the paper"
//! gate (see EXPERIMENTS.md for the narrative version).

use asyncflow::dag::{figures, DagAnalysis};
use asyncflow::ddmd::{ddmd_workflow, DdmdConfig};
use asyncflow::engine::{simulate_cfg, EngineConfig, ExecutionMode};
use asyncflow::experiments::{check_shapes, run_table3, PAPER_TABLE3};
use asyncflow::model;
use asyncflow::resources::ClusterSpec;
use asyncflow::workflows::{cdg1, cdg2, fig3b_dag};

/// §5.1 / Fig. 2 (E7): DOA_dep for the four reference graphs.
#[test]
fn e7_fig2_doa_dep() {
    assert_eq!(DagAnalysis::of(&figures::chain(6)).doa_dep, 0);
    assert_eq!(DagAnalysis::of(&figures::fig2b()).doa_dep, 1);
    assert_eq!(DagAnalysis::of(&figures::fig2c()).doa_dep, 4);
    for n in [1usize, 3, 9] {
        assert_eq!(DagAnalysis::of(&figures::edgeless(n + 1)).doa_dep, n);
    }
}

/// §5.3 worked example (E8): tSeq = 7500 s, tAsync = 5500 s, I ~ 26%.
#[test]
fn e8_worked_example_closed_forms() {
    assert!((model::improvement(7500.0, 5500.0) - 0.26667).abs() < 1e-4);
    assert!((model::t_async_ddmd_eqn6(3, 526.0, 85.0, 63.0) - 1345.0).abs() < 1e-9);
}

/// §7.1 (E9): the DDMD prediction chain — Eqn. 2 gives 3 x 526 = 1578;
/// the ideal simulator lands within 8% of Eqn. 6's 1345.
#[test]
fn e9_ddmd_prediction_chain() {
    let mut cfg = DdmdConfig::paper();
    cfg.tx_sigma_frac = 0.0;
    let wf = ddmd_workflow(&cfg);
    let cluster = ClusterSpec::summit_paper();
    assert!((model::t_seq(&wf, &cluster, 0.0) - 1578.0).abs() < 1e-6);

    let ideal = EngineConfig::ideal();
    let seq = simulate_cfg(&wf, &cluster, ExecutionMode::Sequential, &ideal);
    assert!((seq.makespan - 1578.0).abs() < 1.0);
    let asy = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &ideal);
    let eqn6 = model::t_async_ddmd_eqn6(3, 526.0, 85.0, 63.0);
    assert!(
        (asy.makespan - eqn6).abs() / eqn6 < 0.08,
        "sim {} vs eqn6 {eqn6}",
        asy.makespan
    );
}

/// Table 3 (E1–E3): DOA columns exact; I columns in the paper's bands;
/// orderings preserved.
#[test]
fn e1_e3_table3_shape() {
    let rows = run_table3(42);
    assert!(check_shapes(&rows).is_empty(), "{:?}", check_shapes(&rows));
    for (row, paper) in rows.iter().zip(PAPER_TABLE3.iter()) {
        assert_eq!(row.prediction.doa_dep, paper.doa_dep);
        assert_eq!(row.prediction.doa_res, paper.doa_res);
        assert_eq!(row.prediction.wla, paper.wla);
        // Predictions agree with our own measurements within 15%
        // (the paper reports <6% for its runs; ours includes the
        // stochastic max-of-96 stage stretch the model ignores).
        let rel = (row.prediction.t_async - row.asy.makespan).abs() / row.asy.makespan;
        assert!(rel < 0.15, "{}: pred {} meas {}", row.name, row.prediction.t_async, row.asy.makespan);
    }
}

/// Figs. 4–6 (E4–E6): asynchronicity must raise mean utilization for
/// DDMD and c-DG2, and leave c-DG1 roughly flat.
#[test]
fn e4_e6_utilization_shapes() {
    let cfg = asyncflow::experiments::paper_engine_config(42);
    // DDMD on Summit: GPU utilization improves markedly (Fig. 4).
    let wf = ddmd_workflow(&DdmdConfig::paper());
    let cl = ClusterSpec::summit_paper();
    let seq = simulate_cfg(&wf, &cl, ExecutionMode::Sequential, &cfg);
    let asy = simulate_cfg(&wf, &cl, ExecutionMode::Asynchronous, &cfg);
    assert!(asy.gpu_utilization > seq.gpu_utilization + 0.05, "Fig 4 shape");

    // c-DG2 (Fig. 6): clear improvement.
    let cl8 = ClusterSpec::summit_8gpu();
    let wf2 = cdg2();
    let s2 = simulate_cfg(&wf2, &cl8, ExecutionMode::Sequential, &cfg);
    let a2 = simulate_cfg(&wf2, &cl8, ExecutionMode::Asynchronous, &cfg);
    assert!(a2.cpu_utilization > s2.cpu_utilization, "Fig 6 shape");

    // c-DG1 (Fig. 5): negligible change (within 5 points).
    let wf1 = cdg1();
    let s1 = simulate_cfg(&wf1, &cl8, ExecutionMode::Sequential, &cfg);
    let a1 = simulate_cfg(&wf1, &cl8, ExecutionMode::Asynchronous, &cfg);
    assert!((a1.cpu_utilization - s1.cpu_utilization).abs() < 0.05, "Fig 5 shape");
}

/// §5.2's collapse scenario: when every branch needs 100% of the
/// allocation, the async DG degenerates to a chain and I <= 0.
#[test]
fn s52_collapse_to_chain() {
    // R_i = R-tilde for all i (§5.2): every task set needs 100% of the
    // allocation — the otherwise-independent chains collapse to a
    // single chain and asynchronicity buys nothing.
    let mut cfgw = DdmdConfig::paper();
    cfgw.simulation = asyncflow::ddmd::TaskTypeSpec { tasks: 96, cores: 4, gpus: 1, tx: 340.0 };
    // One monolithic MPI aggregation spanning every core: no waves can
    // slide in beside a Simulation set.
    cfgw.aggregation = asyncflow::ddmd::TaskTypeSpec { tasks: 1, cores: 2688, gpus: 0, tx: 85.0 };
    cfgw.training = asyncflow::ddmd::TaskTypeSpec { tasks: 96, cores: 4, gpus: 1, tx: 63.0 };
    cfgw.inference = asyncflow::ddmd::TaskTypeSpec { tasks: 96, cores: 16, gpus: 1, tx: 38.0 };
    cfgw.tx_sigma_frac = 0.0;
    let wf = ddmd_workflow(&cfgw);
    let cl = ClusterSpec::summit_paper();
    assert_eq!(model::doa_res_analytic(&wf, &cl), 0, "no branch pair co-fits");
    let ideal = EngineConfig::ideal();
    let seq = simulate_cfg(&wf, &cl, ExecutionMode::Sequential, &ideal);
    let asy = simulate_cfg(&wf, &cl, ExecutionMode::Asynchronous, &ideal);
    let i = asy.improvement_over(&seq);
    assert!(i.abs() < 0.05, "collapse scenario still showed I = {i:.3}");
}

/// Fig. 3b reconstruction invariants (documented in workflows::mod).
#[test]
fn fig3b_reconstruction_invariants() {
    let d = fig3b_dag();
    let a = DagAnalysis::of(&d);
    assert_eq!(a.doa_dep, 2);
    assert_eq!(d.parents(7), &[4, 5]);
    assert!(d.independent(1, 4) && d.independent(2, 5) && d.independent(1, 5));
}

/// The model's verdict matches measurement on both sides of the
/// asynchronicity decision (the paper's core design-guidance claim).
#[test]
fn model_verdict_matches_measurement() {
    let cl8 = ClusterSpec::summit_8gpu();
    let cfg = asyncflow::experiments::paper_engine_config(42);
    // c-DG2: model says go async; measurement agrees.
    let p2 = model::predict(&cdg2(), &cl8);
    let s = simulate_cfg(&cdg2(), &cl8, ExecutionMode::Sequential, &cfg);
    let a = simulate_cfg(&cdg2(), &cl8, ExecutionMode::Asynchronous, &cfg);
    assert!(p2.improvement > 0.1 && a.improvement_over(&s) > 0.1);
    // c-DG1: model says don't bother; measurement agrees.
    let p1 = model::predict(&cdg1(), &cl8);
    let s = simulate_cfg(&cdg1(), &cl8, ExecutionMode::Sequential, &cfg);
    let a = simulate_cfg(&cdg1(), &cl8, ExecutionMode::Asynchronous, &cfg);
    assert!(p1.improvement < 0.03 && a.improvement_over(&s) < 0.03);
}
