//! Equivalence property test: the event-calendar engine loop
//! ([`WakePolicy::Calendar`]) must reproduce the legacy full-scan loop
//! ([`WakePolicy::FullScan`]) **bit-for-bit** — every task record,
//! every report field, and every mid-run snapshot — across random
//! workflow mixes, arrival patterns, scheduling policies, and elastic
//! resizes (in the style of `tests/sched_equiv.rs`).
//!
//! The calendar is an execution strategy, not simulation state: wake
//! times are derived from driver state (`next_activation`), never
//! serialized. The cross-resume cases prove it — a snapshot taken
//! under either loop resumes under the *other* to an identical run.

use asyncflow::checkpoint::SimSnapshot;
use asyncflow::dag::Dag;
use asyncflow::engine::{
    Coordinator, EngineConfig, ExecutionMode, RunOutcome, RunReport, WakePolicy,
};
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::pilot::{AutoscalePolicy, Policy, ResourcePlan};
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::sim::VirtualExecutor;
use asyncflow::task::TaskSetSpec;
use asyncflow::util::json::ToJson;
use asyncflow::util::rng::Rng;
use asyncflow::workflows::random_workflow;

/// Build the seed's scenario from scratch: same seed, same coordinator
/// — only the wake policy differs between the two runs under test.
fn coordinator_for(seed: u64, wake: WakePolicy) -> Coordinator {
    let mut rng = Rng::new(seed);
    let policy = [Policy::FifoBackfill, Policy::WeightedFair, Policy::Backfill]
        [rng.below(3) as usize];
    let cfg = EngineConfig { policy, seed: seed ^ 0x5eed, ..EngineConfig::default() };
    let cluster = ClusterSpec::uniform("t", 3, 8, 2);
    let mut coord = Coordinator::new(&cluster, &cfg);
    coord.set_wake_policy(wake);
    let n = 2 + rng.below(5) as usize;
    for _ in 0..n {
        let wf = random_workflow(&mut rng, 3, 3);
        let mode = if rng.f64() < 0.5 {
            ExecutionMode::Asynchronous
        } else {
            ExecutionMode::Sequential
        };
        let arrival = rng.f64() * 120.0;
        coord.add_workflow(wf, mode, arrival).unwrap();
    }
    // Most scenarios run elastic: a grow and a drain land while traffic
    // is live, and half of those also run the backlog autoscaler — the
    // resize/autoscale lanes of the calendar are then load-bearing.
    if rng.f64() < 0.6 {
        let mut plan = ResourcePlan::new()
            .resize(20.0 + rng.f64() * 40.0, 1)
            .resize(80.0 + rng.f64() * 40.0, -1);
        if rng.f64() < 0.5 {
            plan = plan.with_autoscale(AutoscalePolicy {
                interval: 10.0,
                min_nodes: 2,
                max_nodes: 5,
                step: 1,
                ..Default::default()
            });
        }
        coord.set_resource_plan(plan).unwrap();
    }
    coord
}

fn run_complete(seed: u64, wake: WakePolicy) -> Vec<RunReport> {
    let mut ex = VirtualExecutor::new();
    coordinator_for(seed, wake).run(&mut ex).unwrap()
}

/// Compare every simulation-derived report field at the bit level.
/// `RunReport` deliberately has no `PartialEq` (it carries wall-clock
/// accounting — `sched_wall` — and the strategy-dependent
/// `driver_steps` counter, both excluded here); the record streams go
/// through `Debug`, whose f64 formatting round-trips, so equal strings
/// mean equal bits.
fn assert_reports_identical(a: &[RunReport], b: &[RunReport], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: member count");
    for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
        let tag = format!("{what}: member {i} ({})", ra.workflow);
        assert_eq!(ra.workflow, rb.workflow, "{tag}: workflow");
        assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits(), "{tag}: makespan");
        assert_eq!(
            format!("{:?}", ra.records),
            format!("{:?}", rb.records),
            "{tag}: task records"
        );
        assert_eq!(
            format!("{:?}", ra.trace),
            format!("{:?}", rb.trace),
            "{tag}: utilization trace"
        );
        assert_eq!(
            ra.cpu_utilization.to_bits(),
            rb.cpu_utilization.to_bits(),
            "{tag}: cpu utilization"
        );
        assert_eq!(
            ra.gpu_utilization.to_bits(),
            rb.gpu_utilization.to_bits(),
            "{tag}: gpu utilization"
        );
        assert_eq!(ra.throughput.to_bits(), rb.throughput.to_bits(), "{tag}: throughput");
        assert_eq!(ra.doa_res, rb.doa_res, "{tag}: doa_res");
        assert_eq!(ra.failed_tasks, rb.failed_tasks, "{tag}: failed tasks");
        assert_eq!(ra.sched_rounds, rb.sched_rounds, "{tag}: sched rounds");
        assert_eq!(ra.peak_live_tasks, rb.peak_live_tasks, "{tag}: peak live tasks");
        assert_eq!(ra.capacity, rb.capacity, "{tag}: capacity timeline");
    }
}

#[test]
fn calendar_loop_matches_full_scan_bit_for_bit() {
    let mut scan_steps = 0u64;
    let mut cal_steps = 0u64;
    for seed in 0..24u64 {
        let scan = run_complete(seed, WakePolicy::FullScan);
        let cal = run_complete(seed, WakePolicy::Calendar);
        assert_reports_identical(&scan, &cal, &format!("seed {seed}"));
        // The whole point of the calendar: it never wakes a driver the
        // scan would not have woken, and usually wakes far fewer.
        let (ss, cs) = (scan[0].driver_steps, cal[0].driver_steps);
        assert!(cs <= ss, "seed {seed}: calendar stepped more drivers ({cs} > {ss})");
        scan_steps += ss;
        cal_steps += cs;
    }
    assert!(
        cal_steps < scan_steps,
        "across all seeds the calendar must save wake-ups: {cal_steps} vs {scan_steps}"
    );
}

#[test]
fn snapshots_agree_and_cross_resume_is_bit_identical() {
    // Checkpoint the same scenario at the same instant under both
    // loops: the snapshots must serialize identically (the calendar
    // leaves no trace in the wire format), and each snapshot must
    // resume under the *opposite* policy to the same completed run as
    // the uninterrupted baseline.
    let t_ck = 40.0;
    let snap_of = |seed: u64, wake: WakePolicy| -> Option<Box<SimSnapshot>> {
        let mut ex = VirtualExecutor::new();
        match coordinator_for(seed, wake).run_until(&mut ex, Some(t_ck)).unwrap() {
            RunOutcome::Checkpointed(s) => Some(s),
            RunOutcome::Completed(_) => None,
        }
    };
    let resume = |snap: SimSnapshot, wake: WakePolicy| -> Vec<RunReport> {
        let mut coord = Coordinator::restore(snap).unwrap();
        coord.set_wake_policy(wake);
        let mut ex = VirtualExecutor::new();
        coord.run(&mut ex).unwrap()
    };
    let mut checkpointed = 0;
    for seed in 0..12u64 {
        let Some(s_scan) = snap_of(seed, WakePolicy::FullScan) else {
            // Every workflow of this seed drained before t_ck — fine,
            // the completed-run property above already covers it.
            continue;
        };
        let s_cal = snap_of(seed, WakePolicy::Calendar)
            .expect("both loops take the same trajectory, so both must checkpoint");
        checkpointed += 1;
        assert_eq!(
            s_scan.to_json().to_string(),
            s_cal.to_json().to_string(),
            "seed {seed}: mid-run snapshots must serialize identically"
        );
        // Cross-resume, both directions.
        let scan_then_cal = resume((*s_scan).clone(), WakePolicy::Calendar);
        let cal_then_scan = resume((*s_cal).clone(), WakePolicy::FullScan);
        assert_reports_identical(
            &scan_then_cal,
            &cal_then_scan,
            &format!("seed {seed} cross-resume"),
        );
        // ... and the resumed trajectory is the uninterrupted one.
        let baseline = run_complete(seed, WakePolicy::FullScan);
        assert_eq!(baseline.len(), scan_then_cal.len(), "seed {seed}: member count");
        for (i, (r, b)) in scan_then_cal.iter().zip(&baseline).enumerate() {
            assert_eq!(
                r.makespan.to_bits(),
                b.makespan.to_bits(),
                "seed {seed}: member {i} makespan after resume"
            );
            assert_eq!(
                format!("{:?}", r.records),
                format!("{:?}", b.records),
                "seed {seed}: member {i} records after resume"
            );
            assert_eq!(r.capacity, b.capacity, "seed {seed}: member {i} capacity");
        }
    }
    assert!(checkpointed >= 4, "too few scenarios reached t = {t_ck}: {checkpointed}");
}

/// Single-task workflow: 1 core for `tx` seconds, deterministic.
fn solo(tx: f64) -> Workflow {
    let mut dag = Dag::new();
    dag.add_node("A");
    Workflow {
        name: "solo".into(),
        sets: vec![TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), tx).with_sigma(0.0)],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0])],
        asynchronous: vec![Pipeline::new("a").stage(&[0])],
    }
}

#[test]
fn calendar_saves_an_order_of_magnitude_of_wakeups_under_wide_traffic() {
    // The perf contract behind the refactor (the acceptance bar of the
    // scale bench, asserted here on a deterministic miniature): 100
    // long-running workflows arrive one second apart, so the scan loop
    // re-steps every live driver on every arrival — O(live²) wake-ups —
    // while the calendar wakes each driver only when it has due work.
    let run = |wake: WakePolicy| -> Vec<RunReport> {
        let cluster = ClusterSpec::uniform("t", 25, 4, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.set_wake_policy(wake);
        for i in 0..100 {
            coord
                .add_workflow(solo(1000.0), ExecutionMode::Asynchronous, i as f64)
                .unwrap();
        }
        let mut ex = VirtualExecutor::new();
        coord.run(&mut ex).unwrap()
    };
    let scan = run(WakePolicy::FullScan);
    let cal = run(WakePolicy::Calendar);
    assert_reports_identical(&scan, &cal, "wide traffic");
    let (ss, cs) = (scan[0].driver_steps, cal[0].driver_steps);
    assert!(
        ss >= 5 * cs,
        "calendar must beat the scan by >= 5x on wide traffic: scan {ss}, calendar {cs}"
    );
}
