//! Coordinator integration tests: determinism, merged-DAG equivalence,
//! late-arrival behavior, and single-driver parity with `engine::run`.

use asyncflow::campaign::Campaign;
use asyncflow::engine::{
    run, simulate_cfg, Coordinator, EngineConfig, ExecutionMode,
};
use asyncflow::pilot::Policy;
use asyncflow::resources::ClusterSpec;
use asyncflow::sim::VirtualExecutor;
use asyncflow::util::prop::check;
use asyncflow::util::rng::Rng;
use asyncflow::workflows::{cdg1, cdg2, random_workflow};

#[test]
fn same_seed_identical_online_reports() {
    let camp = Campaign::new("det").add(cdg1()).add(cdg2());
    let cluster = ClusterSpec::summit_8gpu();
    let cfg = EngineConfig { seed: 11, ..EngineConfig::default() };
    let a = camp.simulate_online(&[0.0, 250.0], &cluster, &cfg).unwrap();
    let b = camp.simulate_online(&[0.0, 250.0], &cluster, &cfg).unwrap();
    assert_eq!(a.campaign.makespan, b.campaign.makespan);
    for (ma, mb) in a.members.iter().zip(&b.members) {
        assert_eq!(ma.makespan, mb.makespan);
        let sa: Vec<f64> = ma.records.iter().map(|r| r.started).collect();
        let sb: Vec<f64> = mb.records.iter().map(|r| r.started).collect();
        assert_eq!(sa, sb, "identical per-task start times for {}", ma.workflow);
    }
}

#[test]
fn zero_arrivals_equal_merged_dag_under_both_policies() {
    // Simultaneous arrivals over one shared agent must reproduce the
    // statically merged super-workflow exactly — including under the
    // priority-sensitive PipelineAge policy, which exercises the
    // per-driver pipeline-offset namespacing.
    let camp = Campaign::new("eq").add(cdg1()).add(cdg2());
    let cluster = ClusterSpec::summit_8gpu();
    for policy in [Policy::FifoBackfill, Policy::PipelineAge] {
        let cfg = EngineConfig { policy, ..EngineConfig::ideal() };
        let (_, merged) = camp.simulate(&cluster, &cfg).unwrap();
        let online = camp.simulate_online(&[0.0, 0.0], &cluster, &cfg).unwrap();
        assert!(
            (online.campaign.makespan - merged.makespan).abs() < 1e-9,
            "{policy:?}: online {} vs merged {}",
            online.campaign.makespan,
            merged.makespan
        );
        // Not only the makespan: the entire start-time multiset matches.
        let mut on: Vec<f64> = online
            .members
            .iter()
            .flat_map(|m| m.records.iter().map(|r| r.started))
            .collect();
        let mut mg: Vec<f64> = merged.records.iter().map(|r| r.started).collect();
        on.sort_by(f64::total_cmp);
        mg.sort_by(f64::total_cmp);
        assert_eq!(on, mg, "{policy:?}: per-task start times diverged");
    }
}

#[test]
fn staggered_arrivals_differ_from_simultaneous() {
    let camp = Campaign::new("lag").add(cdg1()).add(cdg2());
    let cluster = ClusterSpec::summit_8gpu();
    let cfg = EngineConfig::ideal();
    let zero = camp.simulate_online(&[0.0, 0.0], &cluster, &cfg).unwrap();
    let lag = camp.simulate_online(&[0.0, 300.0], &cluster, &cfg).unwrap();
    assert!(
        (zero.campaign.makespan - lag.campaign.makespan).abs() > 1e-6,
        "a 300 s stagger must change the campaign makespan"
    );
    // Internal consistency of the staggered run.
    assert_eq!(
        lag.campaign.records.len(),
        zero.campaign.records.len(),
        "same total work either way"
    );
    for m in &lag.members {
        for r in &m.records {
            assert!(r.started >= r.submitted - 1e-9);
            assert!(r.finished > r.started);
        }
    }
    let member_max = lag.members.iter().map(|m| m.makespan).fold(0.0f64, f64::max);
    assert!((lag.campaign.makespan - member_max).abs() < 1e-9);
}

#[test]
fn pure_time_shift_for_a_lone_late_workflow() {
    // A single workflow arriving at t=T on an idle allocation runs
    // exactly as at t=0, shifted by T (deterministic TX streams).
    let wf = cdg2();
    let cluster = ClusterSpec::summit_8gpu();
    let cfg = EngineConfig::default();
    let base = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
    let mut coord = Coordinator::new(&cluster, &cfg);
    coord.add_workflow(wf, ExecutionMode::Asynchronous, 500.0).unwrap();
    let mut ex = VirtualExecutor::new();
    let late = coord.run(&mut ex).unwrap().pop().unwrap();
    // 1e-6 tolerance: event times are computed as (500 + x) instead of
    // x, so ULP-level float drift accumulates along the event chain.
    assert!(
        (late.makespan - (base.makespan + 500.0)).abs() < 1e-6,
        "late {} vs base {} + 500",
        late.makespan,
        base.makespan
    );
}

#[test]
fn property_single_driver_coordinator_matches_run() {
    // engine::run is defined as "coordinator with one driver"; verify
    // the equivalence holds observably on random workflows, in every
    // execution mode, against the legacy behavior snapshot (task count,
    // monotone lifecycle, identical repeated results).
    let cluster = ClusterSpec::uniform("prop", 3, 16, 2);
    check(
        0xC00D,
        25,
        |rng: &mut Rng, size| {
            let mut r = rng.fork(size.0 as u64 + 31);
            random_workflow(&mut r, 4, 3)
        },
        |wf| {
            for s in &wf.sets {
                if cluster.check(&s.req).is_err() {
                    return Ok(()); // unsatisfiable by construction: skip
                }
            }
            for mode in [
                ExecutionMode::Sequential,
                ExecutionMode::Asynchronous,
                ExecutionMode::Adaptive,
            ] {
                let cfg = EngineConfig::default();
                let mut ex1 = VirtualExecutor::new();
                let via_run = run(wf, &cluster, mode, &cfg, &mut ex1)
                    .map_err(|e| e.to_string())?;
                let mut coord = Coordinator::new(&cluster, &cfg);
                coord
                    .add_workflow(wf.clone(), mode, 0.0)
                    .map_err(|e| e.to_string())?;
                let mut ex2 = VirtualExecutor::new();
                let via_coord = coord
                    .run(&mut ex2)
                    .map_err(|e| e.to_string())?
                    .pop()
                    .expect("one report");
                if via_run.makespan != via_coord.makespan {
                    return Err(format!(
                        "{mode:?}: run {} != coordinator {}",
                        via_run.makespan, via_coord.makespan
                    ));
                }
                if via_run.records.len() != via_coord.records.len()
                    || via_run.records.len() as u64 != wf.total_tasks()
                {
                    return Err(format!("{mode:?}: task count mismatch"));
                }
                for (a, b) in via_run.records.iter().zip(&via_coord.records) {
                    if a.started != b.started || a.finished != b.finished {
                        return Err(format!("{mode:?}: task {} timeline diverged", a.uid));
                    }
                }
            }
            Ok(())
        },
    );
}
