//! Integration tests for the streaming-traffic subsystem: saturation
//! behavior (bounded vs growing backlog), determinism, trace-driven
//! equivalence with explicit `--arrivals` offsets, the streamed
//! coordinator's bounded live-state guarantee, and elastic allocations
//! (timed grow/drain plans and the backlog-driven autoscaler) under
//! live traffic.

use asyncflow::campaign::Campaign;
use asyncflow::dag::Dag;
use asyncflow::engine::EngineConfig;
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::pilot::{AutoscalePolicy, ResourcePlan};
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::task::TaskSetSpec;
use asyncflow::traffic::{
    run_traffic, ArrivalProcess, Catalog, TraceArrival, TrafficSpec, WorkloadMix,
};

/// Single-task workflow: 1 core for `tx` seconds, deterministic.
fn solo(tx: f64) -> Workflow {
    let mut dag = Dag::new();
    dag.add_node("A");
    Workflow {
        name: "solo".into(),
        sets: vec![TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), tx).with_sigma(0.0)],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0])],
        asynchronous: vec![Pipeline::new("a").stage(&[0])],
    }
}

fn catalog() -> Catalog {
    Catalog::new().insert("solo", solo(10.0))
}

/// 4 cores, so service capacity is 0.4 solo-workflows per second.
fn cluster() -> ClusterSpec {
    ClusterSpec::uniform("t", 1, 4, 0)
}

fn spec(process: ArrivalProcess, duration: f64, seed: u64) -> TrafficSpec {
    TrafficSpec {
        process,
        mix: WorkloadMix::parse("solo").unwrap(),
        duration,
        max_workflows: 100_000,
        seed,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: None,
    }
}

#[test]
fn sub_capacity_poisson_keeps_wait_and_backlog_bounded() {
    // lambda = 0.05/s vs capacity 0.4/s: offered load ~12.5%.
    let rep = run_traffic(
        &spec(ArrivalProcess::Poisson { rate: 0.05 }, 4000.0, 1),
        &catalog(),
        &cluster(),
        &EngineConfig::ideal(),
    )
    .unwrap();
    assert!(rep.workflows.len() > 120, "got {} arrivals", rep.workflows.len());
    assert!(rep.wait.mean < 2.0, "wait mean {} under light load", rep.wait.mean);
    assert!(rep.wait.p99 < 15.0, "wait p99 {}", rep.wait.p99);
    assert!(
        rep.mean_backlog_tasks < 1.0,
        "mean backlog {} under light load",
        rep.mean_backlog_tasks
    );
    assert!(!rep.is_saturated());
    // Every workflow completed; TTX >= service time.
    assert!(rep.workflows.iter().all(|w| w.ttx >= 10.0 - 1e-9));
    assert_eq!(rep.failed_tasks, 0);
}

#[test]
fn super_capacity_poisson_grows_backlog_monotonically() {
    // lambda = 1.0/s vs capacity 0.4/s: the queue must build for as
    // long as arrivals continue.
    let rep = run_traffic(
        &spec(ArrivalProcess::Poisson { rate: 1.0 }, 400.0, 2),
        &catalog(),
        &cluster(),
        &EngineConfig::ideal(),
    )
    .unwrap();
    assert!(rep.workflows.len() > 300);
    assert!(
        rep.backlog_second_half > 2.0 * rep.backlog_first_half,
        "backlog halves: {} -> {}",
        rep.backlog_first_half,
        rep.backlog_second_half
    );
    assert!(rep.is_saturated());
    // Quarter-by-quarter the mean backlog keeps climbing.
    let q = |a: f64, b: f64| rep.backlog.mean_tasks_between(a, b);
    assert!(q(100.0, 200.0) > q(0.0, 100.0));
    assert!(q(200.0, 300.0) > q(100.0, 200.0));
    assert!(q(300.0, 400.0) > q(200.0, 300.0));
    // Waits are dominated by queueing, far above the 10 s service time.
    assert!(rep.wait.mean > 50.0, "wait mean {}", rep.wait.mean);
    // The run still drains: final backlog is zero and makespan extends
    // past the arrival window.
    assert_eq!(rep.backlog.final_tasks(), 0);
    assert!(rep.makespan > 400.0);
}

#[test]
fn rate_sweep_crosses_the_saturation_knee() {
    // Same window, rising rate: the verdict must flip from bounded to
    // saturated as the offered load crosses capacity (0.4/s).
    let verdicts: Vec<bool> = [0.05, 0.2, 0.8, 1.6]
        .iter()
        .map(|&rate| {
            run_traffic(
                &spec(ArrivalProcess::Poisson { rate }, 500.0, 5),
                &catalog(),
                &cluster(),
                &EngineConfig::ideal(),
            )
            .unwrap()
            .is_saturated()
        })
        .collect();
    assert!(!verdicts[0], "12.5% load must be bounded");
    assert!(verdicts[2], "200% load must saturate");
    assert!(verdicts[3], "400% load must saturate");
}

#[test]
fn identical_seed_and_rate_reproduce_the_report_bit_for_bit() {
    let s = spec(ArrivalProcess::Poisson { rate: 0.2 }, 1000.0, 7);
    let run = || {
        run_traffic(&s, &catalog(), &cluster(), &EngineConfig::ideal()).unwrap()
    };
    let r1 = run();
    let r2 = run();
    assert_eq!(r1, r2, "same spec, same report (PartialEq)");
    assert_eq!(
        r1.to_json().to_string(),
        r2.to_json().to_string(),
        "same spec, bit-identical serialized report"
    );
    // A different traffic seed draws different arrivals.
    let r3 = run_traffic(
        &spec(ArrivalProcess::Poisson { rate: 0.2 }, 1000.0, 8),
        &catalog(),
        &cluster(),
        &EngineConfig::ideal(),
    )
    .unwrap();
    assert_ne!(r1.to_json().to_string(), r3.to_json().to_string());
}

#[test]
fn trace_driven_arrivals_reproduce_explicit_offsets_exactly() {
    // A trace [0, 300] must be indistinguishable from
    // `campaign --arrivals 0,300` over the same members.
    let cfg = EngineConfig::ideal();
    let trace = ArrivalProcess::Trace(vec![
        TraceArrival { at: 0.0, workload: Some("solo".into()) },
        TraceArrival { at: 300.0, workload: Some("solo".into()) },
    ]);
    let rep = run_traffic(&spec(trace, 1000.0, 1), &catalog(), &cluster(), &cfg).unwrap();
    let camp = Campaign::new("c").add(solo(10.0)).add(solo(10.0));
    let online = camp.simulate_online(&[0.0, 300.0], &cluster(), &cfg).unwrap();
    assert_eq!(rep.workflows.len(), 2);
    for (i, w) in rep.workflows.iter().enumerate() {
        assert!((w.arrival - online.arrivals[i]).abs() < 1e-12);
        assert!((w.finish - online.members[i].makespan).abs() < 1e-12);
        assert!((w.ttx - online.member_ttx(i)).abs() < 1e-12);
    }
    assert!((rep.makespan - online.campaign.makespan).abs() < 1e-12);
}

#[test]
fn trace_file_round_trips_through_the_parser() {
    let dir = std::env::temp_dir().join("asyncflow_traffic_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("arrivals.json");
    std::fs::write(
        &path,
        r#"{"arrivals": [0, 50, {"t": 125.5, "workload": "solo"}]}"#,
    )
    .unwrap();
    let process = asyncflow::traffic::load_trace_file(path.to_str().unwrap()).unwrap();
    let rep = run_traffic(
        &spec(process, 1000.0, 1),
        &catalog(),
        &cluster(),
        &EngineConfig::ideal(),
    )
    .unwrap();
    assert_eq!(rep.workflows.len(), 3);
    assert_eq!(rep.workflows[0].arrival, 0.0);
    assert_eq!(rep.workflows[1].arrival, 50.0);
    assert_eq!(rep.workflows[2].arrival, 125.5);
    assert_eq!(rep.workflows[2].name, "solo");
}

#[test]
fn streamed_1k_workflows_keep_live_state_bounded() {
    // 1000 workflows, sub-capacity deterministic arrivals: the
    // coordinator must recycle per-task state, keeping the live
    // high-water mark at in-flight + queued — not the total stream.
    let rep = run_traffic(
        &spec(ArrivalProcess::Deterministic { interval: 5.0 }, 5000.0, 3),
        &catalog(),
        &cluster(),
        &EngineConfig::ideal(),
    )
    .unwrap();
    assert_eq!(rep.workflows.len(), 1000);
    assert_eq!(rep.total_tasks, 1000);
    assert!(
        rep.peak_live_tasks <= 8,
        "peak live task state {} must stay near in-flight + queued, not 1000",
        rep.peak_live_tasks
    );
    // Sub-capacity: essentially no queueing.
    assert!(rep.wait.p99 < 1.0);
    assert!(!rep.is_saturated());
}

#[test]
fn mix_ratio_shapes_the_sampled_stream() {
    let cat = Catalog::new()
        .insert("fast", solo(5.0))
        .insert("slow", solo(20.0));
    let s = TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 0.1 },
        mix: WorkloadMix::parse("fast:3,slow:1").unwrap(),
        duration: 4000.0,
        max_workflows: 100_000,
        seed: 11,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: None,
    };
    let rep = run_traffic(&s, &cat, &cluster(), &EngineConfig::ideal()).unwrap();
    let fast = rep.workflows.iter().filter(|w| w.name == "fast").count();
    let slow = rep.workflows.len() - fast;
    assert!(fast > slow, "3:1 mix must favor 'fast' ({fast} vs {slow})");
    let frac = fast as f64 / rep.workflows.len() as f64;
    assert!((0.55..=0.95).contains(&frac), "fast fraction {frac}");
}

#[test]
fn shrink_under_saturation_never_strands_work_and_reproduces_bit_for_bit() {
    // 2 nodes x 2 cores (service capacity 0.4 wf/s) vs lambda = 1.0/s;
    // half the allocation drains mid-window. Draining must never strand
    // work: tasks already on the draining node run to completion,
    // nothing new lands on it, and the whole stream still drains.
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let plan = ResourcePlan::new().resize(150.0, -1);
    let run = || {
        run_traffic(
            &TrafficSpec {
                plan: Some(plan.clone()),
                ..spec(ArrivalProcess::Poisson { rate: 1.0 }, 300.0, 2)
            },
            &catalog(),
            &cluster,
            &EngineConfig::ideal(),
        )
        .unwrap()
    };
    let rep = run();
    // The timeline tracks *offered* capacity: under saturation the
    // drained node is fully busy at t = 150, so its cores leave the
    // timeline only as the tasks occupying them finish — at or after
    // the drain, never before.
    assert_eq!(rep.capacity.points.first(), Some(&(0.0, 4, 0)));
    assert_eq!(rep.capacity.final_capacity(), (2, 0));
    assert!(!rep.capacity.is_constant());
    assert!(
        rep.capacity.points[1..].iter().all(|&(t, c, _)| t >= 150.0 - 1e-9 && c < 4),
        "drained cores may only leave at/after the drain: {:?}",
        rep.capacity.points
    );
    // Utilization integrates against offered capacity: a true fraction.
    assert!(
        rep.cpu_utilization <= 1.0 + 1e-9,
        "utilization must stay in [0,1], got {}",
        rep.cpu_utilization
    );
    // No stranded work: every streamed workflow completes its task.
    assert_eq!(rep.failed_tasks, 0);
    assert_eq!(rep.backlog.final_tasks(), 0);
    assert!(rep.workflows.iter().all(|w| w.finish >= w.arrival + 10.0 - 1e-9));
    assert!(rep.is_saturated());
    // Same seed + same resize plan: bit-for-bit identical reports.
    let rep2 = run();
    assert_eq!(rep, rep2, "same spec + plan, same report (PartialEq)");
    assert_eq!(
        rep.to_json().to_string(),
        rep2.to_json().to_string(),
        "same spec + plan, bit-identical serialized report"
    );
    // Against the fixed full-size allocation the same load drains sooner.
    let fixed = run_traffic(
        &spec(ArrivalProcess::Poisson { rate: 1.0 }, 300.0, 2),
        &catalog(),
        &cluster,
        &EngineConfig::ideal(),
    )
    .unwrap();
    assert!(
        rep.makespan > fixed.makespan + 1e-9,
        "losing half the cores must stretch the drain: {} vs {}",
        rep.makespan,
        fixed.makespan
    );
}

#[test]
fn shrinking_idle_capacity_raises_reported_utilization() {
    // 2 x 1-core nodes, one 10 s task at a time: the second node is
    // never touched (spanning placement prefers the fullest-free node,
    // ties toward index 0). Draining the idle node at t = 20 halves the
    // offered core-seconds from t = 20 on without changing a single
    // placement, so the *same* work must read as higher utilization —
    // the elastic-metrics regression from the capacity-timeline fix.
    let cluster = ClusterSpec::uniform("t", 2, 1, 0);
    let arrivals = ArrivalProcess::Deterministic { interval: 10.0 };
    let fixed = run_traffic(
        &spec(arrivals.clone(), 40.0, 1),
        &catalog(),
        &cluster,
        &EngineConfig::ideal(),
    )
    .unwrap();
    let elastic = run_traffic(
        &TrafficSpec {
            plan: Some(ResourcePlan::new().resize(20.0, -1)),
            ..spec(arrivals, 40.0, 1)
        },
        &catalog(),
        &cluster,
        &EngineConfig::ideal(),
    )
    .unwrap();
    // Identical schedule: same makespan, no queueing in either run.
    assert_eq!(fixed.workflows.len(), 4);
    assert!((fixed.makespan - elastic.makespan).abs() < 1e-9);
    assert!(elastic.wait.max < 1e-9);
    // 4 tasks x 10 s x 1 core = 40 core-s. Fixed: 40 / (2 x 40) = 50%.
    // Elastic: 40 / (2 x 20 + 1 x 20) = 2/3.
    assert!((fixed.cpu_utilization - 0.5).abs() < 1e-9);
    assert!((elastic.cpu_utilization - 2.0 / 3.0).abs() < 1e-9);
    assert!(
        elastic.cpu_utilization > fixed.cpu_utilization + 0.1,
        "shrinking idle capacity must raise utilization ({} vs {})",
        elastic.cpu_utilization,
        fixed.cpu_utilization
    );
    assert_eq!(elastic.capacity.points, vec![(0.0, 2, 0), (20.0, 1, 0)]);
}

#[test]
fn autoscaler_relieves_saturation_and_scales_back_down() {
    // 1 x 1-core node vs one 10 s workflow every 2 s: hopelessly
    // saturated when fixed. The backlog-driven autoscaler must grow the
    // allocation, cut wait and makespan, and shed idle nodes again once
    // the stream ends.
    let cluster = ClusterSpec::uniform("t", 1, 1, 0);
    let policy = AutoscalePolicy {
        interval: 4.0,
        min_nodes: 1,
        max_nodes: 8,
        step: 2,
        down_idle: 0.5,
        ..AutoscalePolicy::default()
    };
    let arrivals = ArrivalProcess::Deterministic { interval: 2.0 };
    let fixed = run_traffic(
        &spec(arrivals.clone(), 20.0, 1),
        &catalog(),
        &cluster,
        &EngineConfig::ideal(),
    )
    .unwrap();
    let scaled = run_traffic(
        &TrafficSpec {
            plan: Some(ResourcePlan::new().with_autoscale(policy)),
            ..spec(arrivals, 20.0, 1)
        },
        &catalog(),
        &cluster,
        &EngineConfig::ideal(),
    )
    .unwrap();
    assert_eq!(scaled.workflows.len(), 10);
    assert_eq!(scaled.failed_tasks, 0);
    assert!(!scaled.capacity.is_constant(), "growth must be recorded");
    assert!(
        scaled.capacity.peak().0 >= 3,
        "autoscaler must have grown, peak {:?}",
        scaled.capacity.peak()
    );
    assert!(
        scaled.makespan < fixed.makespan - 1e-9,
        "autoscaling must beat the fixed 1-core serialization: {} vs {}",
        scaled.makespan,
        fixed.makespan
    );
    assert!(scaled.wait.mean < fixed.wait.mean);
    // Scale-down: once the queue stays empty and the allocation idles,
    // capacity is shed again (graceful drains, min_nodes floor).
    assert!(
        scaled.capacity.final_capacity().0 < scaled.capacity.peak().0,
        "idle-down must shed nodes: {:?}",
        scaled.capacity.points
    );
}

#[test]
fn unknown_workload_and_empty_windows_error() {
    let err = run_traffic(
        &TrafficSpec {
            process: ArrivalProcess::Poisson { rate: 0.1 },
            mix: WorkloadMix::parse("nope").unwrap(),
            duration: 1000.0,
            max_workflows: 10,
            seed: 1,
            plan: None,
            checkpoint_at: None,
            policy: None,
            failure: None,
        },
        &catalog(),
        &cluster(),
        &EngineConfig::ideal(),
    );
    assert!(err.is_err(), "unknown workload must error");
    let err = run_traffic(
        &spec(ArrivalProcess::Trace(vec![]), 1000.0, 1),
        &catalog(),
        &cluster(),
        &EngineConfig::ideal(),
    );
    assert!(err.is_err(), "an empty arrival set must error");
}

// ----- pluggable scheduling policies ----------------------------------

/// Single-set workflow with `tasks` parallel 1-core tasks of `tx` s.
fn burst(tasks: u32, tx: f64) -> Workflow {
    let mut dag = Dag::new();
    dag.add_node("A");
    Workflow {
        name: "burst".into(),
        sets: vec![TaskSetSpec::new("A", tasks, ResourceRequest::new(1, 0), tx).with_sigma(0.0)],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0])],
        asynchronous: vec![Pipeline::new("a").stage(&[0])],
    }
}

#[test]
fn policy_matrix_is_deterministic_and_fifo_override_is_transparent() {
    use asyncflow::sched::Policy;
    // Each policy reproduces itself bit-for-bit; the explicit fifo
    // override equals the config default (EngineConfig::ideal is
    // FifoBackfill) — the pre-refactor report, untouched.
    let base = spec(ArrivalProcess::Poisson { rate: 0.5 }, 300.0, 9);
    let run = |policy: Option<Policy>| {
        run_traffic(
            &TrafficSpec { policy, ..base.clone() },
            &catalog(),
            &cluster(),
            &EngineConfig::ideal(),
        )
        .unwrap()
    };
    let default = run(None);
    let explicit = run(Some(Policy::FifoBackfill));
    assert_eq!(
        default.to_json().to_string(),
        explicit.to_json().to_string(),
        "--policy fifo must reproduce the default report bit-for-bit"
    );
    for policy in [Policy::FifoBackfill, Policy::WeightedFair, Policy::Backfill] {
        let a = run(Some(policy));
        let b = run(Some(policy));
        assert_eq!(a, b, "{policy:?} must be deterministic");
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a.failed_tasks, 0);
        assert_eq!(a.backlog.final_tasks(), 0, "{policy:?} must drain the stream");
    }
}

#[test]
fn weighted_fair_bounds_solo_wait_below_the_fifo_starvation_case() {
    use asyncflow::sched::Policy;
    // One greedy member floods a 4-core pilot with 40 x 10 s tasks at
    // t = 0; ten solo workflows arrive afterwards. Under FIFO the solos
    // queue behind the whole flood (p95 wait near the ~100 s drain);
    // weighted fair sharing hands each freed core to the starved
    // tenant, bounding solo p95 wait near one service time.
    let cat = Catalog::new()
        .insert("greedy", burst(40, 10.0))
        .insert("solo", solo(10.0));
    let mut arrivals = vec![TraceArrival { at: 0.0, workload: Some("greedy".into()) }];
    for k in 0..10 {
        arrivals.push(TraceArrival {
            at: 5.0 + 10.0 * k as f64,
            workload: Some("solo".into()),
        });
    }
    let run = |policy: Policy| {
        run_traffic(
            &TrafficSpec {
                process: ArrivalProcess::Trace(arrivals.clone()),
                mix: WorkloadMix::parse("solo").unwrap(),
                duration: 200.0,
                max_workflows: 100_000,
                seed: 1,
                plan: None,
                checkpoint_at: None,
                policy: Some(policy),
                failure: None,
            },
            &cat,
            &cluster(),
            &EngineConfig::ideal(),
        )
        .unwrap()
    };
    let fifo = run(Policy::FifoBackfill);
    let fair = run(Policy::WeightedFair);
    let solo_waits = |rep: &asyncflow::traffic::TrafficReport| {
        let mut xs: Vec<f64> = rep
            .workflows
            .iter()
            .filter(|w| w.name == "solo")
            .map(|w| w.wait)
            .collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        xs
    };
    let fifo_waits = solo_waits(&fifo);
    let fair_waits = solo_waits(&fair);
    assert_eq!(fifo_waits.len(), 10);
    let fifo_p95 = fifo_waits[fifo_waits.len() - 1];
    let fair_p95 = fair_waits[fair_waits.len() - 1];
    assert!(
        fifo_p95 > 40.0,
        "FIFO must starve the late solos behind the flood, got max wait {fifo_p95}"
    );
    assert!(
        fair_p95 <= 15.0,
        "fair sharing must bound solo wait near one service time, got {fair_p95}"
    );
    assert!(fair_p95 < fifo_p95 / 2.0);
    // The report quantifies it: Jain over waits is higher under fair,
    // and the per-workload breakdown carries both classes.
    assert!(
        fair.fairness_index > fifo.fairness_index,
        "Jain {:.3} (fair) vs {:.3} (fifo)",
        fair.fairness_index,
        fifo.fairness_index
    );
    let names: Vec<&str> = fair.wait_by_workload.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(names, ["greedy", "solo"]);
    // Everybody still finishes under both disciplines.
    assert_eq!(fifo.failed_tasks, 0);
    assert_eq!(fair.failed_tasks, 0);
    assert_eq!(fair.total_tasks, fifo.total_tasks);
}

#[test]
fn sweep_composes_with_autoscaler_and_shifts_the_knee() {
    // The autoscaler knee sweep from the ROADMAP's elastic scenario
    // family: the same mid rate saturates a fixed 1-core pilot but
    // stays bounded once --autoscale may grow to 4 nodes, i.e. the
    // saturation knee moves right; a low rate is bounded either way.
    let cluster = ClusterSpec::uniform("t", 1, 1, 0);
    let policy = AutoscalePolicy {
        interval: 5.0,
        min_nodes: 1,
        max_nodes: 4,
        step: 1,
        ..AutoscalePolicy::default()
    };
    let run = |rate: f64, autoscale: bool| {
        run_traffic(
            &TrafficSpec {
                plan: autoscale.then(|| ResourcePlan::new().with_autoscale(policy.clone())),
                ..spec(ArrivalProcess::Poisson { rate }, 400.0, 3)
            },
            &catalog(),
            &cluster,
            &EngineConfig::ideal(),
        )
        .unwrap()
    };
    // Capacity 0.1 wf/s fixed, 0.4 wf/s at full growth.
    let low_fixed = run(0.02, false);
    let low_scaled = run(0.02, true);
    assert!(!low_fixed.is_saturated(), "20% load bounded on the fixed pilot");
    assert!(!low_scaled.is_saturated());
    let mid_fixed = run(0.2, false);
    let mid_scaled = run(0.2, true);
    assert!(
        mid_fixed.is_saturated(),
        "200% of fixed capacity must saturate (growth {:.2})",
        mid_fixed.backlog_growth()
    );
    assert!(
        !mid_scaled.is_saturated(),
        "the autoscaled pilot must absorb the same rate (growth {:.2}, peak {:?})",
        mid_scaled.backlog_growth(),
        mid_scaled.capacity.peak()
    );
    assert!(mid_scaled.capacity.peak().0 > 1, "the knee shift comes from growth");
    assert!(mid_scaled.wait.mean < mid_fixed.wait.mean);
}
