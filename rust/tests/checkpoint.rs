//! Integration tests for the checkpoint/resume subsystem.
//!
//! The headline invariant: for any seed and checkpoint time,
//! checkpoint-at-T + serialize + parse + resume produces a
//! `TrafficReport` **bit-identical** to the uninterrupted run —
//! including runs whose snapshot lands mid-drain of a draining node.
//! Resuming on a shrunken pilot completes every workflow (graceful
//! drains strand nothing) at a makespan penalty.

use asyncflow::dag::Dag;
use asyncflow::engine::EngineConfig;
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::pilot::ResourcePlan;
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::task::TaskSetSpec;
use asyncflow::traffic::{
    run_traffic, run_traffic_resumable, ArrivalProcess, Catalog, TrafficCheckpoint,
    TrafficOutcome, TrafficReport, TrafficSpec, WorkloadMix,
};
use asyncflow::util::json::{FromJson, Json, ToJson};

/// Single-task workflow: 1 core for `tx` seconds, deterministic.
fn solo(tx: f64) -> Workflow {
    let mut dag = Dag::new();
    dag.add_node("A");
    Workflow {
        name: "solo".into(),
        sets: vec![TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), tx).with_sigma(0.0)],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0])],
        asynchronous: vec![Pipeline::new("a").stage(&[0])],
    }
}

fn catalog() -> Catalog {
    Catalog::new().insert("solo", solo(10.0))
}

/// Run `spec` uninterrupted, then again preempted at `t_ck` with a
/// full JSON round-trip of the checkpoint before resuming; returns
/// both reports (panics if the run finishes before the checkpoint).
fn straight_and_resumed(
    spec: &TrafficSpec,
    cat: &Catalog,
    cluster: &ClusterSpec,
    cfg: &EngineConfig,
    t_ck: f64,
) -> (TrafficReport, TrafficReport, TrafficCheckpoint) {
    let straight = run_traffic(spec, cat, cluster, cfg).unwrap();
    let preempted = TrafficSpec { checkpoint_at: Some(t_ck), ..spec.clone() };
    let outcome = run_traffic_resumable(&preempted, cat, cluster, cfg).unwrap();
    let TrafficOutcome::Checkpointed(ck) = outcome else {
        panic!("run finished before the t = {t_ck} checkpoint")
    };
    // Serialize -> parse: the wire format must capture everything.
    let wire = ck.to_json().to_string();
    let parsed = TrafficCheckpoint::from_json(&Json::parse(&wire).unwrap()).unwrap();
    let ck_copy = TrafficCheckpoint::from_json(&Json::parse(&wire).unwrap()).unwrap();
    let resumed = parsed.resume(None).unwrap();
    (straight, resumed, ck_copy)
}

#[test]
fn resume_is_bit_identical_across_seeds_checkpoint_times_and_policies() {
    // Saturated Poisson stream over an allocation that loses a node
    // mid-window: checkpoints both before and after the drain, three
    // seeds x all three headline scheduling policies. The resumed
    // report must equal the uninterrupted one bit for bit (PartialEq
    // over every f64, and the serialized JSON) — under weighted fair
    // sharing that means the per-tenant usage ledger, and under
    // conservative backfill the in-flight completion projections, are
    // rebuilt exactly from the snapshot.
    use asyncflow::sched::Policy;
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let cfg = EngineConfig::ideal();
    for policy in [Policy::FifoBackfill, Policy::WeightedFair, Policy::Backfill] {
        for seed in [1, 2, 3] {
            let spec = TrafficSpec {
                process: ArrivalProcess::Poisson { rate: 1.0 },
                mix: WorkloadMix::parse("solo").unwrap(),
                duration: 30.0,
                max_workflows: 100_000,
                seed,
                plan: Some(ResourcePlan::new().resize(15.0, -1)),
                checkpoint_at: None,
                policy: Some(policy),
                failure: None,
            };
            for t_ck in [7.0, 21.0] {
                let (straight, resumed, ck) =
                    straight_and_resumed(&spec, &catalog(), &cluster, &cfg, t_ck);
                assert_eq!(
                    ck.sim.now, t_ck,
                    "snapshot clock must land exactly on the checkpoint time"
                );
                assert_eq!(
                    straight, resumed,
                    "{policy:?}, seed {seed}, checkpoint {t_ck}: reports must be identical"
                );
                assert_eq!(
                    straight.to_json().to_string(),
                    resumed.to_json().to_string(),
                    "{policy:?}, seed {seed}, checkpoint {t_ck}: bit-identical JSON"
                );
                assert_eq!(straight.total_tasks, resumed.total_tasks);
                assert_eq!(straight.failed_tasks, 0);
            }
        }
    }
}

#[test]
fn checkpoint_mid_drain_of_a_draining_node_restores_exactly() {
    // Deterministic construction of the mid-drain state: 2 x 2-core
    // nodes, a 10 s 1-core workflow every 2 s, one node drained at
    // t = 5 while its task has 7+ seconds left. At the t = 7
    // checkpoint the drained node is still busy, another task is
    // queued, and two arrivals are pending — every snapshot population
    // is non-trivial, including the drain flags.
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let cfg = EngineConfig::ideal();
    let spec = TrafficSpec {
        process: ArrivalProcess::Deterministic { interval: 2.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 12.0,
        max_workflows: 100_000,
        seed: 1,
        plan: Some(ResourcePlan::new().resize(5.0, -1)),
        checkpoint_at: None,
        policy: None,
        failure: None,
    };
    let (straight, resumed, ck) =
        straight_and_resumed(&spec, &catalog(), &cluster, &cfg, 7.0);

    // The snapshot really is mid-drain: some node is draining *and*
    // still hosts a running placement.
    let draining: Vec<usize> = (0..ck.sim.draining.len())
        .filter(|&i| ck.sim.draining[i])
        .collect();
    assert_eq!(draining.len(), 1, "exactly one node draining at t = 7");
    let busy_on_draining = ck.sim.running.iter().any(|r| {
        r.placement.slots.iter().any(|&(node, _, _)| node == draining[0])
    });
    assert!(busy_on_draining, "the draining node must still be running work");
    assert!(!ck.sim.queue.is_empty(), "contention must have queued work");
    assert!(!ck.sim.pending.is_empty(), "later arrivals must still be pending");

    assert_eq!(straight, resumed);
    assert_eq!(straight.to_json().to_string(), resumed.to_json().to_string());
    // The drained node's core left the offered capacity only when its
    // task released it — identically in both runs.
    assert_eq!(straight.capacity, resumed.capacity);
    assert!(!resumed.capacity.is_constant());
}

#[test]
fn resume_with_jittered_builtin_workloads_is_bit_identical() {
    // Paper workloads with TX jitter (sigma > 0): the per-set TX
    // streams must draw identically across the checkpoint boundary.
    let cluster = ClusterSpec::summit_8gpu();
    let cfg = EngineConfig::default();
    let spec = TrafficSpec {
        process: ArrivalProcess::Deterministic { interval: 400.0 },
        mix: WorkloadMix::parse("cdg2-small,cdg1-small").unwrap(),
        duration: 2000.0,
        max_workflows: 100_000,
        seed: 5,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: None,
    };
    let (straight, resumed, ck) =
        straight_and_resumed(&spec, &Catalog::builtin(), &cluster, &cfg, 600.0);
    assert!(
        !ck.sim.drivers.is_empty() || !ck.sim.pending.is_empty(),
        "t = 600 must land mid-stream (arrivals at 800+ are still pending)"
    );
    assert_eq!(straight, resumed);
    assert_eq!(straight.to_json().to_string(), resumed.to_json().to_string());
}

#[test]
fn resume_on_a_shrunken_pilot_completes_all_work_with_a_makespan_penalty() {
    // Preempted at t = 7, resumed on a pilot that immediately loses
    // half its nodes (the preemptible / backfill scenario): every
    // workflow must still finish — graceful drains let running work
    // complete and nothing is stranded — at a strictly larger
    // makespan than the uninterrupted full-size run.
    let cluster = ClusterSpec::uniform("t", 4, 1, 0);
    let cfg = EngineConfig::ideal();
    let spec = TrafficSpec {
        process: ArrivalProcess::Deterministic { interval: 2.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 20.0,
        max_workflows: 100_000,
        seed: 1,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: None,
    };
    let straight = run_traffic(&spec, &catalog(), &cluster, &cfg).unwrap();
    assert_eq!(straight.workflows.len(), 10);

    let preempted = TrafficSpec { checkpoint_at: Some(7.0), ..spec.clone() };
    let TrafficOutcome::Checkpointed(ck) =
        run_traffic_resumable(&preempted, &catalog(), &cluster, &cfg).unwrap()
    else {
        panic!("stream runs past t = 7")
    };
    let shrunk = ck.resume(Some(ResourcePlan::new().resize(0.0, -2))).unwrap();

    // All work completes; nothing stranded.
    assert_eq!(shrunk.workflows.len(), 10);
    assert_eq!(shrunk.total_tasks, straight.total_tasks);
    assert_eq!(shrunk.failed_tasks, 0);
    assert_eq!(shrunk.backlog.final_tasks(), 0);
    assert!(shrunk
        .workflows
        .iter()
        .all(|w| w.finish >= w.arrival + 10.0 - 1e-9));
    // ... at the expected cost: the 2-node tail serves the same queue
    // strictly slower than 4 nodes would have.
    assert!(
        shrunk.makespan > straight.makespan + 1e-9,
        "halving the pilot must stretch the makespan: {} vs {}",
        shrunk.makespan,
        straight.makespan
    );
    // The capacity timeline records the resume-time shrink: offered
    // cores step down from 4 and end at 2.
    assert_eq!(shrunk.capacity.points.first(), Some(&(0.0, 4, 0)));
    assert_eq!(shrunk.capacity.final_capacity(), (2, 0));
    // Work running on the drained nodes at the resume instant finished
    // there: no core leaves the offered capacity before t = 7.
    assert!(shrunk.capacity.points[1..].iter().all(|&(t, _, _)| t >= 7.0 - 1e-9));
}

#[test]
fn resume_with_autoscaler_grows_the_follow_up_allocation() {
    // Resume a saturated run with an autoscaler attached: the follow-up
    // pilot grows under backlog pressure and beats the fixed-size
    // uninterrupted run.
    let cluster = ClusterSpec::uniform("t", 1, 1, 0);
    let cfg = EngineConfig::ideal();
    let spec = TrafficSpec {
        process: ArrivalProcess::Deterministic { interval: 2.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 20.0,
        max_workflows: 100_000,
        seed: 1,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: None,
    };
    let straight = run_traffic(&spec, &catalog(), &cluster, &cfg).unwrap();
    let preempted = TrafficSpec { checkpoint_at: Some(6.0), ..spec };
    let TrafficOutcome::Checkpointed(ck) =
        run_traffic_resumable(&preempted, &catalog(), &cluster, &cfg).unwrap()
    else {
        panic!("stream runs past t = 6")
    };
    let scaled = ck
        .resume(Some(ResourcePlan::new().with_autoscale(
            asyncflow::pilot::AutoscalePolicy {
                interval: 4.0,
                min_nodes: 1,
                max_nodes: 8,
                step: 2,
                ..Default::default()
            },
        )))
        .unwrap();
    assert_eq!(scaled.workflows.len(), 10);
    assert_eq!(scaled.failed_tasks, 0);
    assert!(
        scaled.capacity.peak().0 > 1,
        "autoscaler must grow the resumed allocation: {:?}",
        scaled.capacity.points
    );
    assert!(
        scaled.makespan < straight.makespan - 1e-9,
        "the grown follow-up pilot must beat the fixed 1-core run: {} vs {}",
        scaled.makespan,
        straight.makespan
    );
}

#[test]
fn run_traffic_refuses_a_checkpoint_it_cannot_return() {
    // The plain run_traffic entry point cannot hand back a snapshot;
    // hitting the preemption point there is an error, not silence.
    let cluster = ClusterSpec::uniform("t", 1, 1, 0);
    let spec = TrafficSpec {
        process: ArrivalProcess::Deterministic { interval: 2.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 10.0,
        max_workflows: 100_000,
        seed: 1,
        plan: None,
        checkpoint_at: Some(5.0),
        policy: None,
        failure: None,
    };
    let err = run_traffic(&spec, &catalog(), &cluster, &EngineConfig::ideal());
    assert!(err.is_err(), "run_traffic must refuse to swallow a checkpoint");
    // Non-finite checkpoint times would silently never fire; rejected.
    for bad in [f64::NAN, f64::INFINITY] {
        let spec = TrafficSpec { checkpoint_at: Some(bad), ..spec.clone() };
        assert!(
            run_traffic_resumable(&spec, &catalog(), &cluster, &EngineConfig::ideal())
                .is_err(),
            "checkpoint_at = {bad} must error"
        );
    }
}

#[test]
fn corrupted_snapshots_are_rejected_not_restored() {
    let cluster = ClusterSpec::uniform("t", 1, 1, 0);
    let spec = TrafficSpec {
        process: ArrivalProcess::Deterministic { interval: 2.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 10.0,
        max_workflows: 100_000,
        seed: 1,
        plan: None,
        checkpoint_at: Some(5.0),
        policy: None,
        failure: None,
    };
    let TrafficOutcome::Checkpointed(ck) =
        run_traffic_resumable(&spec, &catalog(), &cluster, &EngineConfig::ideal()).unwrap()
    else {
        panic!("must checkpoint at t = 5")
    };
    let wire = ck.to_json().to_string();
    // Sanity: the uncorrupted wire restores.
    assert!(TrafficCheckpoint::from_json(&Json::parse(&wire).unwrap()).is_ok());
    // Unsupported snapshot version (keyed off the current constant so
    // a schema bump cannot silently neuter this check).
    let tag = format!("\"version\":{}", asyncflow::checkpoint::SNAPSHOT_VERSION);
    assert!(wire.contains(&tag), "wire must carry the version tag");
    let bumped = wire.replacen(&tag, "\"version\":999", 2);
    assert!(TrafficCheckpoint::from_json(&Json::parse(&bumped).unwrap()).is_err());
    // Structural damage: a slab smaller than its live tasks + free list.
    let slab = ck.sim.slab_len;
    assert!(slab >= 1, "t = 5 snapshot holds live tasks");
    let torn = wire.replace(
        &format!("\"slab_len\":{slab}"),
        &format!("\"slab_len\":{}", slab - 1),
    );
    assert_ne!(torn, wire, "slab_len must appear in the wire format");
    assert!(
        TrafficCheckpoint::from_json(&Json::parse(&torn).unwrap()).is_err(),
        "inconsistent uid slab must be rejected"
    );
}
