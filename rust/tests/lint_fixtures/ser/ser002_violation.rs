// Fixture: SER002 must fire when the watched struct's fields no
// longer hash to the recorded fingerprint (i.e. someone edited the
// snapshot schema without bumping SNAPSHOT_VERSION and re-recording).

pub const SNAPSHOT_VERSION: u64 = 1;
pub const SNAPSHOT_FIELDS_FINGERPRINT: &str = "v1:0000000000000000";

pub struct Snap {
    pub a: f64,
    pub b: Vec<usize>,
}
