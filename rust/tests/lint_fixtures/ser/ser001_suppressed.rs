// Fixture: a reasoned suppression on the impl line silences SER001.

pub struct ExportOnly {
    pub x: f64,
}

// lint:allow(SER001): fixture — write-only metrics export, never restored
impl ToJson for ExportOnly {
    fn to_json(&self) -> Json {
        obj([("x", Json::from(self.x))])
    }
}
