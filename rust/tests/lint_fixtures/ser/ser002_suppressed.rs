// Fixture: a reasoned suppression on the fingerprint line silences
// SER002 (e.g. mid-migration, with the follow-up tracked elsewhere).

pub const SNAPSHOT_VERSION: u64 = 1;
// lint:allow(SER002): fixture — migration in flight, re-record before merge
pub const SNAPSHOT_FIELDS_FINGERPRINT: &str = "v1:0000000000000000";

pub struct Snap {
    pub a: f64,
    pub b: Vec<usize>,
}
