// Fixture: SER001 must fire on a ToJson impl with no FromJson pair.

pub struct OneWay {
    pub x: f64,
}

impl ToJson for OneWay {
    fn to_json(&self) -> Json {
        obj([("x", Json::from(self.x))])
    }
}
