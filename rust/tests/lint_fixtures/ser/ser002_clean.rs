// Fixture: the recorded fingerprint matches the struct's field list,
// so SER002 stays quiet. The constant below is fnv1a64 of
// `Snap{a:f64;b:Vec < usize >}` under schema version 1.

pub const SNAPSHOT_VERSION: u64 = 1;
pub const SNAPSHOT_FIELDS_FINGERPRINT: &str = "v1:03141af8a738c3b1";

pub struct Snap {
    pub a: f64,
    pub b: Vec<usize>,
}
