// Fixture: paired ToJson/FromJson impls round-trip and are clean.

pub struct Pair {
    pub x: f64,
}

impl ToJson for Pair {
    fn to_json(&self) -> Json {
        obj([("x", Json::from(self.x))])
    }
}

impl FromJson for Pair {
    fn from_json(v: &Json) -> Result<Pair> {
        Ok(Pair { x: v.req_f64("x")? })
    }
}
