// Fixture: per-line suppressions with reasons silence DET002.

use std::collections::HashMap; // lint:allow(DET002): fixture — never iterated
pub struct Index {
    // lint:allow(DET002): fixture — lookup-only, order cannot leak
    by_shape: HashMap<u32, usize>,
}
