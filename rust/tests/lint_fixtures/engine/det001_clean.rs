// Fixture: epsilon comparisons through the shared constant are clean,
// and test code may use raw tolerances freely.

pub fn due(now: f64, t: f64, eps: f64) -> bool {
    now + eps >= t
}

#[cfg(test)]
mod tests {
    #[test]
    fn tolerances_in_tests_are_fine() {
        assert!((0.1_f64 + 0.2).abs() - 0.3 < 1e-12);
    }
}
