// Fixture: DET003 must fire on wall-clock reads outside the timing
// allowlist (one finding per Instant/SystemTime token).

pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
