// Fixture: a reasoned suppression silences DET001 on the line below.

pub fn due(now: f64, t: f64) -> bool {
    // lint:allow(DET001): fixture — demonstrating a documented exception
    now + 1e-12 >= t
}
