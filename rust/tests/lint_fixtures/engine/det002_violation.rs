// Fixture: DET002 must fire on hash-ordered collections in
// replay-critical modules (two findings: the import and the field).

use std::collections::HashMap;

pub struct Index {
    by_shape: HashMap<u32, usize>,
}
