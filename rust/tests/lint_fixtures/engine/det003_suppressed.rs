// Fixture: a reasoned per-line suppression silences DET003.

pub fn stamp() -> u64 {
    // lint:allow(DET003): fixture — perf counter only, value never reaches state
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos() as u64
}
