// Fixture: ordered collections are the sanctioned choice.

use std::collections::BTreeMap;

pub struct Index {
    by_shape: BTreeMap<u32, usize>,
}
