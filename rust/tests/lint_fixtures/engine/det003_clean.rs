// Fixture: wall-clock timing through the sanctioned Stopwatch wrapper
// is clean (the wrapper lives in an allowlisted module).

pub fn timed_len(xs: &[f64]) -> (usize, std::time::Duration) {
    let sw = crate::util::bench::Stopwatch::start();
    (xs.len(), sw.elapsed())
}
