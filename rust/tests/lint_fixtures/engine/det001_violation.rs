// Fixture: DET001 must fire on a raw clock-epsilon literal in an
// engine-scoped module. (Not compiled; lexed by tests/lint.rs.)

pub fn due(now: f64, t: f64) -> bool {
    now + 1e-12 >= t
}
