// Fixture: at or under the budget (two sites, budget two) the ratchet
// stays quiet; test code never counts.

pub fn f(xs: &[u32]) -> u32 {
    let a = xs.first().unwrap();
    let b = xs.last().expect("non-empty");
    a + b
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwraps_in_tests_are_free() {
        let xs = [1u32, 2];
        assert_eq!(super::f(&xs), 3);
        assert_eq!(*xs.first().unwrap(), 1);
    }
}
