// Fixture: a suppressed (audited) site does not count toward the
// PANIC001 budget — three sites minus one suppression fits budget 2.

pub fn f(xs: &[u32]) -> u32 {
    let a = xs.first().unwrap();
    let b = xs.last().expect("non-empty");
    // lint:allow(PANIC001): fixture — index 1 checked by the caller
    let c = xs.get(1).unwrap();
    a + b + c
}
