// Fixture: three non-test unwrap/expect sites against a budget of two
// must trip the PANIC001 ratchet (one aggregate finding).

pub fn f(xs: &[u32]) -> u32 {
    let a = xs.first().unwrap();
    let b = xs.last().expect("non-empty");
    let c = xs.get(1).unwrap();
    a + b + c
}
