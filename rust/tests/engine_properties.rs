//! Property-based integration tests over the whole engine: invariants
//! that must hold for *any* workflow on *any* cluster.

use asyncflow::engine::{compile, simulate_cfg, EngineConfig, ExecutionMode};
use asyncflow::entk::Workflow;
use asyncflow::metrics::TaskRecord;
use asyncflow::resources::ClusterSpec;
use asyncflow::util::prop::check;
use asyncflow::util::rng::Rng;
use asyncflow::workflows::random_workflow;

fn cluster() -> ClusterSpec {
    ClusterSpec::uniform("prop", 3, 16, 2)
}

/// Sweep a run's records and verify the allocation is never
/// oversubscribed at any instant (cores and GPUs).
fn assert_no_oversubscription(records: &[TaskRecord], cluster: &ClusterSpec) -> Result<(), String> {
    let mut evs: Vec<(f64, i64, i64)> = Vec::new();
    for r in records {
        evs.push((r.started, r.cores as i64, r.gpus as i64));
        evs.push((r.finished, -(r.cores as i64), -(r.gpus as i64)));
    }
    evs.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let (mut c, mut g) = (0i64, 0i64);
    for (t, dc, dg) in evs {
        c += dc;
        g += dg;
        if c > cluster.total_cores() as i64 {
            return Err(format!("cores oversubscribed at t={t}: {c}"));
        }
        if g > cluster.total_gpus() as i64 {
            return Err(format!("gpus oversubscribed at t={t}: {g}"));
        }
    }
    Ok(())
}

fn assert_dependencies_respected(
    wf: &Workflow,
    records: &[TaskRecord],
    mode: ExecutionMode,
) -> Result<(), String> {
    // A task of jobset J must not start before every task of every dep
    // jobset has finished.
    let jobsets = compile(wf, mode);
    let mut set_last_finish = vec![0.0f64; wf.sets.len()];
    for r in records {
        set_last_finish[r.set_idx] = set_last_finish[r.set_idx].max(r.finished);
    }
    let mut set_first_start = vec![f64::INFINITY; wf.sets.len()];
    for r in records {
        set_first_start[r.set_idx] = set_first_start[r.set_idx].min(r.started);
    }
    for js in &jobsets {
        for &d in &js.deps {
            let dep_set = jobsets[d].set_idx;
            if set_first_start[js.set_idx] + 1e-9 < set_last_finish[dep_set] {
                return Err(format!(
                    "set {} started {:.2} before dep {} finished {:.2}",
                    wf.sets[js.set_idx].name,
                    set_first_start[js.set_idx],
                    wf.sets[dep_set].name,
                    set_last_finish[dep_set]
                ));
            }
        }
    }
    Ok(())
}

#[test]
fn property_no_oversubscription_and_deps_hold() {
    check(
        0xE2E,
        40,
        |rng: &mut Rng, size| {
            let mut r = rng.fork(size.0 as u64 + 17);
            random_workflow(&mut r, 4, 3)
        },
        |wf| {
            let cl = cluster();
            // Resource requests in random_workflow may exceed this small
            // cluster's nodes; clamp by validation and skip those.
            for s in &wf.sets {
                if cl.check(&s.req).is_err() {
                    return Ok(()); // unsatisfiable by construction: skip
                }
            }
            for mode in [
                ExecutionMode::Sequential,
                ExecutionMode::Asynchronous,
                ExecutionMode::Adaptive,
            ] {
                let rep = simulate_cfg(wf, &cl, mode, &EngineConfig::default());
                assert_no_oversubscription(&rep.records, &cl)?;
                assert_dependencies_respected(wf, &rep.records, mode)?;
                if rep.records.len() as u64 != wf.total_tasks() {
                    return Err("not all tasks executed".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_async_never_slower_than_seq_without_overheads() {
    // With zero overheads and identical TX draws, the asynchronous
    // realization can only remove barriers, never add work: tAsync <=
    // tSeq + epsilon.
    check(
        0xFA57,
        30,
        |rng: &mut Rng, size| {
            let mut r = rng.fork(size.0 as u64);
            random_workflow(&mut r, 4, 3)
        },
        |wf| {
            let cl = cluster();
            for s in &wf.sets {
                if cl.check(&s.req).is_err() {
                    return Ok(());
                }
            }
            let cfg = EngineConfig { seed: 5, ..EngineConfig::ideal() };
            let seq = simulate_cfg(wf, &cl, ExecutionMode::Sequential, &cfg);
            let asy = simulate_cfg(wf, &cl, ExecutionMode::Asynchronous, &cfg);
            // Allow a small tolerance: scheduling order differences can
            // shuffle same-shape tasks with different sampled TX.
            if asy.makespan > seq.makespan * 1.10 + 1.0 {
                return Err(format!(
                    "async {:.1} much slower than seq {:.1}",
                    asy.makespan, seq.makespan
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn property_makespan_equals_last_finish_and_utilization_bounded() {
    check(
        0x717E,
        30,
        |rng: &mut Rng, size| {
            let mut r = rng.fork(size.0 as u64 + 99);
            random_workflow(&mut r, 5, 2)
        },
        |wf| {
            let cl = cluster();
            for s in &wf.sets {
                if cl.check(&s.req).is_err() {
                    return Ok(());
                }
            }
            let rep = simulate_cfg(wf, &cl, ExecutionMode::Asynchronous, &EngineConfig::default());
            let last = rep.records.iter().map(|r| r.finished).fold(0.0, f64::max);
            if (rep.makespan - last).abs() > 1e-9 {
                return Err("makespan != last finish".into());
            }
            for (u, name) in [(rep.cpu_utilization, "cpu"), (rep.gpu_utilization, "gpu")] {
                if !(0.0..=1.0 + 1e-9).contains(&u) {
                    return Err(format!("{name} utilization {u} out of [0,1]"));
                }
            }
            Ok(())
        },
    );
}
