//! Fixture-corpus harness for `asyncflow lint`, plus the self-check:
//! every rule must fire on its violating fixture, stay quiet on the
//! suppressed and clean ones, and the repo's own `src/` must lint
//! green under the shipped `lint.conf`.
//!
//! The fixtures live under `tests/lint_fixtures/<module>/…` — the
//! `lint_fixtures` path component is a module marker (like `src`), so
//! `engine/det001_violation.rs` classifies as module
//! `engine::det001_violation` and falls inside the engine rule scopes.
//! Cargo does not compile `.rs` files in test subdirectories; the
//! linter only lexes them.

use std::path::PathBuf;

use asyncflow::lint::{lint_files, lint_paths, module_of, Finding, LintConfig, SourceFile};

fn fixture_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(rel)
}

/// Lint one fixture file with optional config overrides.
fn lint_fixture(rel: &str, overrides: &str) -> Vec<Finding> {
    let mut cfg = LintConfig::default();
    cfg.apply(overrides).expect("fixture config overrides parse");
    let p = fixture_path(rel);
    lint_paths(&[p.to_string_lossy().into_owned()], &cfg).expect("fixture lints")
}

fn rules_of(findings: &[Finding]) -> Vec<&str> {
    findings.iter().map(|f| f.rule.as_str()).collect()
}

#[test]
fn det001_fires_on_violation_quiet_on_suppressed_and_clean() {
    let bad = lint_fixture("engine/det001_violation.rs", "");
    assert_eq!(rules_of(&bad), vec!["DET001"], "{bad:?}");
    assert!(bad[0].message.contains("1e-12"));
    assert!(bad[0].suggestion.contains("engine::EPS"));

    let sup = lint_fixture("engine/det001_suppressed.rs", "");
    assert!(sup.is_empty(), "{sup:?}");
    let clean = lint_fixture("engine/det001_clean.rs", "");
    assert!(clean.is_empty(), "{clean:?}");
}

#[test]
fn det002_fires_on_violation_quiet_on_suppressed_and_clean() {
    let bad = lint_fixture("engine/det002_violation.rs", "");
    // Two findings: the `use` and the field type.
    assert_eq!(rules_of(&bad), vec!["DET002", "DET002"], "{bad:?}");

    let sup = lint_fixture("engine/det002_suppressed.rs", "");
    assert!(sup.is_empty(), "{sup:?}");
    let clean = lint_fixture("engine/det002_clean.rs", "");
    assert!(clean.is_empty(), "{clean:?}");
}

#[test]
fn det003_fires_on_violation_quiet_on_suppressed_and_clean() {
    let bad = lint_fixture("engine/det003_violation.rs", "");
    assert_eq!(rules_of(&bad), vec!["DET003", "DET003"], "{bad:?}");
    assert!(bad[0].suggestion.contains("Stopwatch"));

    let sup = lint_fixture("engine/det003_suppressed.rs", "");
    assert!(sup.is_empty(), "{sup:?}");
    let clean = lint_fixture("engine/det003_clean.rs", "");
    assert!(clean.is_empty(), "{clean:?}");
}

#[test]
fn det003_allowlisted_module_is_exempt() {
    // The same wall-clock fixture, re-scoped as if it lived in an
    // allowlisted timing module.
    let mut cfg = LintConfig::default();
    cfg.apply("det003.allow = engine::det003_violation\n").unwrap();
    let p = fixture_path("engine/det003_violation.rs");
    let out = lint_paths(&[p.to_string_lossy().into_owned()], &cfg).unwrap();
    assert!(out.is_empty(), "{out:?}");
}

#[test]
fn ser001_fires_on_orphan_quiet_on_suppressed_and_paired() {
    let bad = lint_fixture("ser/ser001_violation.rs", "");
    assert_eq!(rules_of(&bad), vec!["SER001"], "{bad:?}");
    assert!(bad[0].message.contains("OneWay"));
    assert!(bad[0].message.contains("FromJson"));

    let sup = lint_fixture("ser/ser001_suppressed.rs", "");
    assert!(sup.is_empty(), "{sup:?}");
    let clean = lint_fixture("ser/ser001_clean.rs", "");
    assert!(clean.is_empty(), "{clean:?}");
}

#[test]
fn ser001_allowlist_exempts_named_types() {
    let out = lint_fixture("ser/ser001_violation.rs", "ser001.allow = OneWay\n");
    assert!(out.is_empty(), "{out:?}");
}

/// Config overrides pointing SER002 at one fixture file.
fn ser002_overrides(rel_file: &str) -> String {
    format!("ser002.file = {rel_file}\nser002.watch = {rel_file}#Snap\n")
}

#[test]
fn ser002_fires_on_stale_fingerprint_and_suggestion_round_trips() {
    let rel = "ser/ser002_violation.rs";
    let bad = lint_fixture(rel, &ser002_overrides("ser002_violation.rs"));
    assert_eq!(rules_of(&bad), vec!["SER002"], "{bad:?}");
    assert!(bad[0].message.contains("v1:0000000000000000"));

    // The suggestion carries the correct expected value: splicing it
    // into the source must make the rule go quiet (this is exactly the
    // re-record workflow the finding prescribes).
    let expected = bad[0]
        .suggestion
        .split('"')
        .find(|s| s.starts_with('v') && s.contains(':'))
        .expect("suggestion quotes the expected fingerprint")
        .to_string();
    let p = fixture_path(rel);
    let src = std::fs::read_to_string(&p).unwrap();
    let fixed = src.replace("v1:0000000000000000", &expected);
    assert_ne!(src, fixed, "placeholder fingerprint present in fixture");
    let path_str = p.to_string_lossy().into_owned();
    let file = SourceFile::lex(path_str.clone(), module_of(&path_str), &fixed);
    let mut cfg = LintConfig::default();
    cfg.apply(&ser002_overrides("ser002_violation.rs")).unwrap();
    let out = lint_files(&[file], &cfg);
    assert!(out.is_empty(), "re-recorded fingerprint still flagged: {out:?}");
}

#[test]
fn ser002_quiet_on_suppressed_and_clean() {
    let sup = lint_fixture(
        "ser/ser002_suppressed.rs",
        &ser002_overrides("ser002_suppressed.rs"),
    );
    assert!(sup.is_empty(), "{sup:?}");
    let clean = lint_fixture("ser/ser002_clean.rs", &ser002_overrides("ser002_clean.rs"));
    assert!(clean.is_empty(), "{clean:?}");
}

#[test]
fn ser002_missing_fingerprint_const_is_reported_with_recipe() {
    // Strip the recorded const entirely: the rule must demand one and
    // hand over the exact declaration to paste.
    let rel = "ser/ser002_violation.rs";
    let p = fixture_path(rel);
    let src = std::fs::read_to_string(&p).unwrap();
    let stripped: String = src
        .lines()
        .filter(|l| !l.contains("SNAPSHOT_FIELDS_FINGERPRINT"))
        .collect::<Vec<_>>()
        .join("\n");
    let path_str = p.to_string_lossy().into_owned();
    let file = SourceFile::lex(path_str.clone(), module_of(&path_str), &stripped);
    let mut cfg = LintConfig::default();
    cfg.apply(&ser002_overrides("ser002_violation.rs")).unwrap();
    let out = lint_files(&[file], &cfg);
    assert_eq!(rules_of(&out), vec!["SER002"], "{out:?}");
    assert!(out[0].suggestion.contains("SNAPSHOT_FIELDS_FINGERPRINT"));
    assert!(out[0].suggestion.contains("v1:"), "{}", out[0].suggestion);
}

#[test]
fn panic001_ratchet_fires_over_budget_quiet_at_or_under() {
    let over = "panic.budget = panic:2\n";
    let bad = lint_fixture("panic/panic001_violation.rs", over);
    assert_eq!(rules_of(&bad), vec!["PANIC001"], "{bad:?}");
    assert!(bad[0].message.contains("3"), "{}", bad[0].message);
    assert!(bad[0].message.contains("budget is 2"), "{}", bad[0].message);

    // A suppressed (audited) site drops out of the count.
    let sup = lint_fixture("panic/panic001_suppressed.rs", over);
    assert!(sup.is_empty(), "{sup:?}");
    // At budget, and test-code unwraps never count.
    let clean = lint_fixture("panic/panic001_clean.rs", over);
    assert!(clean.is_empty(), "{clean:?}");
    // Tighten the ratchet: the clean fixture trips at budget 1.
    let tightened = lint_fixture("panic/panic001_clean.rs", "panic.budget = panic:1\n");
    assert_eq!(rules_of(&tightened), vec!["PANIC001"], "{tightened:?}");
}

#[test]
fn ndjson_records_are_single_line_json() {
    let bad = lint_fixture("engine/det001_violation.rs", "");
    let line = bad[0].to_json().to_string();
    assert!(!line.contains('\n'));
    for key in ["\"rule\"", "\"severity\"", "\"file\"", "\"line\"", "\"col\"", "\"message\"", "\"suggestion\""] {
        assert!(line.contains(key), "missing {key} in {line}");
    }
}

/// The acceptance gate: the repo's own sources lint green under the
/// shipped configuration — zero findings, which also means zero
/// unexplained (reasonless, unknown-rule, or unused) suppressions,
/// and a SNAPSHOT_FIELDS_FINGERPRINT that matches the sources.
#[test]
fn self_check_repo_src_is_clean_under_shipped_config() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let cfg = LintConfig::load(&root.join("lint.conf")).expect("lint.conf parses");
    let src = root.join("src");
    let findings = lint_paths(&[src.to_string_lossy().into_owned()], &cfg).unwrap();
    assert!(
        findings.is_empty(),
        "repo sources must lint clean; findings:\n{}",
        findings
            .iter()
            .map(|f| f.render_human())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

/// Editing a snapshot field without bumping the version must fail —
/// demonstrated against the real `src/checkpoint/snapshot.rs` by
/// renaming a field in-memory.
#[test]
fn editing_a_real_snapshot_field_without_version_bump_fails_lint() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let cfg = LintConfig::load(&root.join("lint.conf")).expect("lint.conf parses");
    let mut files = Vec::new();
    for rel in ["src/checkpoint/snapshot.rs", "src/engine/driver.rs"] {
        let p = root.join(rel);
        let mut text = std::fs::read_to_string(&p).unwrap();
        if rel.ends_with("snapshot.rs") {
            assert!(text.contains("pub peak_live: usize"), "field moved? update this test");
            text = text.replace("pub peak_live: usize", "pub peak_live_tasks: usize");
        }
        let path_str = p.to_string_lossy().into_owned();
        files.push(SourceFile::lex(path_str.clone(), module_of(&path_str), &text));
    }
    let findings = lint_files(&files, &cfg);
    assert!(
        findings.iter().any(|f| f.rule == "SER002"),
        "renamed snapshot field must trip SER002: {findings:?}"
    );
}
