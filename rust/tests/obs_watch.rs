//! Acceptance tests for the watch console family (`obs::tail`,
//! `obs::window`, `obs::watch`, `obs::render`):
//!
//! 1. **Headline bit-equality** — `watch --once`'s reconstruction must
//!    reproduce the live [`TrafficReport`]'s figures down to
//!    `f64::to_bits`, clean and fault-injected runs alike, and its
//!    rendered lines must appear verbatim in the live render.
//! 2. **Windowed rollups vs full recompute** — [`WindowStats`]'
//!    incremental eviction must agree with a from-scratch scan of the
//!    raw event prefix at every step, across seeds × [`WakePolicy`].
//! 3. **Tail parsing** — any chunking of a stream through
//!    [`TailParser`], and any offset resume, must parse exactly what
//!    the one-shot parser sees; resume-concatenated (chained) streams
//!    roll up like the uninterrupted run's.
//! 4. **Deterministic figures** — the `trace --render` SVGs are
//!    byte-identical across reruns of the same seed.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use asyncflow::dag::Dag;
use asyncflow::engine::{Coordinator, EngineConfig, ExecutionMode, WakePolicy};
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::failure::cadence::run_chained_obs;
use asyncflow::failure::{FailureSpec, RetryPolicy};
use asyncflow::obs::render::{kind_timeline_svg, overlap_heatmap_svg, util_backlog_svg};
use asyncflow::obs::tail::TailParser;
use asyncflow::obs::trace::{analyze_replayed, parse_stream, replay};
use asyncflow::obs::watch::{headline, render_frame, watch_once};
use asyncflow::obs::window::WindowStats;
use asyncflow::obs::{strip_checkpoint_markers, MemSink, ObsEvent};
use asyncflow::pilot::{AutoscalePolicy, Policy, ResourcePlan};
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::sim::VirtualExecutor;
use asyncflow::task::{TaskKind, TaskSetSpec};
use asyncflow::traffic::{
    run_traffic_resumable_obs, ArrivalProcess, Catalog, TrafficObs, TrafficOutcome,
    TrafficReport, TrafficSpec, WorkloadMix,
};
use asyncflow::util::rng::Rng;
use asyncflow::util::stats::Summary;
use asyncflow::workflows::random_workflow;

/// Two-kind chain (the `tests/obs_trace.rs` shape): four GPU-bound
/// "simulation" tasks feeding one "training" task.
fn chain() -> Workflow {
    let mut dag = Dag::new();
    let a = dag.add_node("sim");
    let b = dag.add_node("train");
    dag.add_edge(a, b).unwrap();
    Workflow {
        name: "chain".into(),
        sets: vec![
            TaskSetSpec::new("sim", 4, ResourceRequest::new(2, 1), 20.0)
                .with_sigma(0.1)
                .with_kind(TaskKind::MdSimulation { chunks: 1 }),
            TaskSetSpec::new("train", 1, ResourceRequest::new(4, 0), 10.0)
                .with_sigma(0.1)
                .with_kind(TaskKind::Training { steps: 1 }),
        ],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0]).stage(&[1])],
        asynchronous: vec![Pipeline::new("p").stage(&[0]).stage(&[1])],
    }
}

/// Single-task workflow: 1 core for `tx` seconds, deterministic.
fn solo(tx: f64) -> Workflow {
    let mut dag = Dag::new();
    dag.add_node("A");
    Workflow {
        name: "solo".into(),
        sets: vec![TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), tx).with_sigma(0.0)],
        dag,
        sequential: vec![Pipeline::new("s").stage(&[0])],
        asynchronous: vec![Pipeline::new("a").stage(&[0])],
    }
}

fn chain_spec(seed: u64) -> TrafficSpec {
    TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 0.5 },
        mix: WorkloadMix::parse("chain").unwrap(),
        duration: 40.0,
        max_workflows: 100_000,
        seed,
        plan: None,
        checkpoint_at: None,
        policy: None,
        failure: None,
    }
}

/// Poisson traffic over a shrinking allocation with MTBF faults and
/// unlimited retries (the `tests/obs_trace.rs` resilience shape).
fn faulty_spec(seed: u64) -> TrafficSpec {
    TrafficSpec {
        process: ArrivalProcess::Poisson { rate: 1.0 },
        mix: WorkloadMix::parse("solo").unwrap(),
        duration: 30.0,
        max_workflows: 100_000,
        seed,
        plan: Some(ResourcePlan::new().resize(15.0, -1)),
        checkpoint_at: None,
        policy: None,
        failure: Some(FailureSpec {
            retry: RetryPolicy { max_attempts: 0, base: 2.0, factor: 2.0, jitter: 0.25 },
            ..FailureSpec::mtbf(8.0)
        }),
    }
}

/// Run `spec` to completion with a memory sink attached.
fn run_with_stream(
    spec: &TrafficSpec,
    cat: &Catalog,
    cluster: &ClusterSpec,
) -> (TrafficReport, Vec<ObsEvent>) {
    let sink = Rc::new(RefCell::new(MemSink::new()));
    let obs = TrafficObs { sink: Some(Box::new(Rc::clone(&sink))), profile: None };
    let outcome =
        run_traffic_resumable_obs(spec, cat, cluster, &EngineConfig::ideal(), obs).unwrap();
    let TrafficOutcome::Completed(rep) = outcome else {
        panic!("spec has no checkpoint time, the run must complete")
    };
    let events = sink.borrow().events.clone();
    (*rep, events)
}

fn ndjson(events: &[ObsEvent]) -> String {
    events.iter().map(|e| e.to_ndjson() + "\n").collect()
}

fn assert_summary_bits(got: Option<&Summary>, want: &Summary, what: &str) {
    let got = got.unwrap_or_else(|| panic!("{what}: headline produced no summary"));
    assert_eq!(got.n, want.n, "{what}: n");
    for (g, w, field) in [
        (got.mean, want.mean, "mean"),
        (got.std, want.std, "std"),
        (got.min, want.min, "min"),
        (got.max, want.max, "max"),
        (got.p50, want.p50, "p50"),
        (got.p95, want.p95, "p95"),
        (got.p99, want.p99, "p99"),
    ] {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: {field}");
    }
}

/// The bit-equality core: every figure the live report prints,
/// reconstructed from the stream, compared at the bit level.
fn assert_headline_matches(rep: &TrafficReport, events: &[ObsEvent], what: &str) {
    let run = replay(events).unwrap();
    let h = headline(&run);
    assert_eq!(h.n_workflows, rep.workflows.len(), "{what}: workflows");
    assert_eq!(h.n_tasks, rep.total_tasks, "{what}: tasks");
    assert_eq!(h.failed_tasks, rep.failed_tasks, "{what}: failed tasks");
    assert_eq!(h.n_unfinished, 0, "{what}: a complete stream leaves nothing open");
    for (g, w, field) in [
        (h.makespan, rep.makespan, "makespan"),
        (h.cpu_utilization, rep.cpu_utilization, "cpu utilization"),
        (h.gpu_utilization, rep.gpu_utilization, "gpu utilization"),
        (h.task_throughput, rep.task_throughput, "task throughput"),
        (h.workflow_throughput, rep.workflow_throughput, "workflow throughput"),
        (h.mean_backlog_tasks, rep.mean_backlog_tasks, "mean backlog"),
    ] {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: {field}");
    }
    assert_eq!(h.peak_backlog, rep.peak_backlog, "{what}: peak backlog");
    assert_eq!(
        h.arrival_window.map(f64::to_bits),
        Some(rep.arrival_window.to_bits()),
        "{what}: arrival window"
    );
    assert_eq!(
        h.backlog_first_half.map(f64::to_bits),
        Some(rep.backlog_first_half.to_bits()),
        "{what}: first-half backlog"
    );
    assert_eq!(
        h.backlog_second_half.map(f64::to_bits),
        Some(rep.backlog_second_half.to_bits()),
        "{what}: second-half backlog"
    );
    assert_eq!(
        h.backlog_growth().map(f64::to_bits),
        Some(rep.backlog_growth().to_bits()),
        "{what}: backlog growth"
    );
    assert_eq!(h.is_saturated(), Some(rep.is_saturated()), "{what}: saturation verdict");
    assert_summary_bits(h.wait.as_ref(), &rep.wait, &format!("{what}: wait"));
    assert_summary_bits(h.ttx.as_ref(), &rep.ttx, &format!("{what}: ttx"));
    match (&h.ledger, &rep.resilience) {
        (None, None) => {}
        (Some(g), Some(w)) => {
            assert_eq!(g.failures_injected, w.failures_injected, "{what}: failures");
            assert_eq!(g.tasks_killed, w.tasks_killed, "{what}: kills");
            assert_eq!(g.retries_scheduled, w.retries_scheduled, "{what}: retries");
            assert_eq!(g.retries_exhausted, w.retries_exhausted, "{what}: exhausted");
            for (gf, wf, field) in [
                (g.lost_core_s, w.lost_core_s, "lost core-s"),
                (g.lost_gpu_s, w.lost_gpu_s, "lost gpu-s"),
                (g.goodput_core_s, w.goodput_core_s, "goodput core-s"),
                (g.goodput_gpu_s, w.goodput_gpu_s, "goodput gpu-s"),
            ] {
                assert_eq!(gf.to_bits(), wf.to_bits(), "{what}: {field}");
            }
        }
        (g, w) => panic!("{what}: ledger presence mismatch ({g:?} vs {w:?})"),
    }
    // Rendered lines diff cleanly: every headline line is verbatim in
    // the live render (which just carries extra lines).
    let live = rep.render(false);
    for line in h.render().lines() {
        assert!(live.contains(line), "{what}: headline line {line:?} missing from live render");
    }
}

#[test]
fn headline_matches_the_live_report_bit_for_bit() {
    let cat = Catalog::new().insert("chain", chain());
    let cluster = ClusterSpec::uniform("t", 3, 8, 2);
    for seed in [5, 7] {
        let (rep, events) = run_with_stream(&chain_spec(seed), &cat, &cluster);
        assert_headline_matches(&rep, &events, &format!("chain seed {seed}"));
    }
    let cat = Catalog::new().insert("solo", solo(4.0));
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let mut total_kills = 0;
    for seed in 1..=3u64 {
        let (rep, events) = run_with_stream(&faulty_spec(seed), &cat, &cluster);
        assert_headline_matches(&rep, &events, &format!("faulty seed {seed}"));
        total_kills += rep.resilience.map_or(0, |r| r.tasks_killed);
    }
    assert!(total_kills > 0, "the faulty seeds must exercise the resilience lines");
}

/// Independent windowed recompute, rebuilt from the raw prefix at every
/// checkpoint: lane counts by direct scan with the same
/// `t > now − w` comparison, instantaneous gauges derived from lane
/// totals (never from `WindowStats`' own increments).
#[derive(Default)]
struct Brute {
    now: f64,
    t0: Option<f64>,
    /// uid → kind of its latest submission.
    kinds: BTreeMap<usize, String>,
    /// uid → `(kind, cores, gpus)` of tasks started and not retired,
    /// with the shape taken from the *start* event.
    live: BTreeMap<usize, (String, u64, u64)>,
    /// slot → (arrival, started?).
    slots: BTreeMap<usize, (f64, bool)>,
    waits: Vec<(f64, f64)>,
    ttxs: Vec<(f64, f64)>,
    kind_running: BTreeMap<String, u64>,
    kind_peak: BTreeMap<String, u64>,
    kind_done: BTreeMap<String, u64>,
    // Cumulative lane totals (counted, not mirrored).
    subs: u64,
    resubs: u64,
    starts: u64,
    dones: u64,
    kills: u64,
    sched: u64,
    peak_queued: u64,
    peak_running: u64,
}

impl Brute {
    fn push(&mut self, ev: &ObsEvent) {
        let t = ev.time();
        if self.t0.is_none() {
            self.t0 = Some(t);
        }
        if t > self.now {
            self.now = t;
        }
        match ev {
            ObsEvent::WorkflowArrived { slot, arrival, .. } => {
                self.slots.insert(*slot, (*arrival, false));
            }
            ObsEvent::TaskSubmitted { uid, kind, attempt, .. } => {
                self.subs += 1;
                if *attempt > 0 {
                    self.resubs += 1;
                }
                self.kinds.insert(*uid, kind.clone());
            }
            ObsEvent::TaskStarted { uid, slot, cores, gpus, .. } => {
                self.starts += 1;
                let kind = self.kinds.get(uid).cloned().unwrap_or_default();
                *self.kind_running.entry(kind.clone()).or_insert(0) += 1;
                let r = self.kind_running[&kind];
                let p = self.kind_peak.entry(kind.clone()).or_insert(0);
                *p = (*p).max(r);
                self.live.insert(*uid, (kind, *cores, *gpus));
                if let Some(s) = self.slots.get_mut(slot) {
                    if !s.1 {
                        s.1 = true;
                        self.waits.push((t, t - s.0));
                    }
                }
            }
            ObsEvent::TaskCompleted { uid, .. } => {
                self.dones += 1;
                if let Some((kind, _, _)) = self.live.remove(uid) {
                    *self.kind_running.entry(kind.clone()).or_insert(0) -= 1;
                    *self.kind_done.entry(kind).or_insert(0) += 1;
                }
            }
            ObsEvent::TaskKilled { uid, .. } => {
                self.kills += 1;
                if let Some((kind, _, _)) = self.live.remove(uid) {
                    *self.kind_running.entry(kind).or_insert(0) -= 1;
                }
            }
            ObsEvent::RetryScheduled { .. } => self.sched += 1,
            ObsEvent::WorkflowCompleted { slot, .. } => {
                if let Some(&(arrival, _)) = self.slots.get(slot) {
                    self.ttxs.push((t, t - arrival));
                }
            }
            _ => {}
        }
        let (queued, running, _) = self.gauges();
        self.peak_queued = self.peak_queued.max(queued);
        self.peak_running = self.peak_running.max(running);
    }

    /// `(queued, running, backoff)` derived purely from lane totals.
    fn gauges(&self) -> (u64, u64, u64) {
        (
            self.subs - self.starts,
            self.starts - self.dones - self.kills,
            self.sched - self.resubs,
        )
    }
}

/// Events in `prefix` matching `pred` with time strictly after `cut`.
fn count(prefix: &[ObsEvent], pred: impl Fn(&ObsEvent) -> bool, cut: f64) -> u64 {
    prefix.iter().filter(|e| pred(e) && e.time() > cut).count() as u64
}

/// The `tests/obs_stream.rs` scenario matrix: random workflows and
/// policies, elastic plans with autoscalers for most seeds.
fn coordinator_for(seed: u64, wake: WakePolicy) -> Coordinator {
    let mut rng = Rng::new(seed);
    let policy = [Policy::FifoBackfill, Policy::WeightedFair, Policy::Backfill]
        [rng.below(3) as usize];
    let cfg = EngineConfig { policy, seed: seed ^ 0x5eed, ..EngineConfig::default() };
    let cluster = ClusterSpec::uniform("t", 3, 8, 2);
    let mut coord = Coordinator::new(&cluster, &cfg);
    coord.set_wake_policy(wake);
    let n = 2 + rng.below(5) as usize;
    for _ in 0..n {
        let wf = random_workflow(&mut rng, 3, 3);
        let mode = if rng.f64() < 0.5 {
            ExecutionMode::Asynchronous
        } else {
            ExecutionMode::Sequential
        };
        let arrival = rng.f64() * 120.0;
        coord.add_workflow(wf, mode, arrival).unwrap();
    }
    if rng.f64() < 0.6 {
        let mut plan = ResourcePlan::new()
            .resize(20.0 + rng.f64() * 40.0, 1)
            .resize(80.0 + rng.f64() * 40.0, -1);
        if rng.f64() < 0.5 {
            plan = plan.with_autoscale(AutoscalePolicy {
                interval: 10.0,
                min_nodes: 2,
                max_nodes: 5,
                step: 1,
                ..Default::default()
            });
        }
        coord.set_resource_plan(plan).unwrap();
    }
    coord
}

fn events_of(seed: u64, wake: WakePolicy) -> Vec<ObsEvent> {
    let mut coord = coordinator_for(seed, wake);
    let sink = Rc::new(RefCell::new(MemSink::new()));
    coord.set_event_sink(Box::new(Rc::clone(&sink)));
    let mut ex = VirtualExecutor::new();
    coord.run(&mut ex).unwrap();
    let events = sink.borrow().events.clone();
    events
}

#[test]
fn windowed_rollups_match_a_full_recompute() {
    for seed in 0..6u64 {
        let mut frames = Vec::new();
        for wake in [WakePolicy::Calendar, WakePolicy::FullScan] {
            let events = events_of(seed, wake);
            for window in [25.0, 80.0, f64::INFINITY] {
                let mut ws = WindowStats::new(window);
                let mut brute = Brute::default();
                for (i, ev) in events.iter().enumerate() {
                    ws.push(ev);
                    brute.push(ev);
                    // Full recompute every few events and at the end.
                    if i % 7 != 0 && i + 1 != events.len() {
                        continue;
                    }
                    let prefix = &events[..=i];
                    let what = format!("seed {seed} {wake:?} w={window} event {i}");
                    let cut = brute.now - window;
                    let win = ws.in_window();
                    let scan = |pred: fn(&ObsEvent) -> bool| count(prefix, pred, cut);
                    assert_eq!(
                        win.arrivals,
                        scan(|e| matches!(e, ObsEvent::WorkflowArrived { .. })),
                        "{what}: in-window arrivals"
                    );
                    assert_eq!(
                        win.submissions,
                        scan(|e| matches!(e, ObsEvent::TaskSubmitted { .. })),
                        "{what}: in-window submissions"
                    );
                    assert_eq!(
                        win.starts,
                        scan(|e| matches!(e, ObsEvent::TaskStarted { .. })),
                        "{what}: in-window starts"
                    );
                    assert_eq!(
                        win.completions,
                        scan(|e| matches!(e, ObsEvent::TaskCompleted { .. })),
                        "{what}: in-window completions"
                    );
                    assert_eq!(
                        win.faults,
                        scan(|e| matches!(e, ObsEvent::NodeFault { .. })),
                        "{what}: in-window faults"
                    );
                    assert_eq!(
                        win.kills,
                        scan(|e| matches!(e, ObsEvent::TaskKilled { .. })),
                        "{what}: in-window kills"
                    );
                    assert_eq!(
                        win.retries,
                        scan(|e| matches!(e, ObsEvent::RetryScheduled { .. })),
                        "{what}: in-window retries"
                    );
                    // Instantaneous gauges from lane totals alone.
                    let (queued, running, backoff) = brute.gauges();
                    assert_eq!(ws.queued(), queued, "{what}: queued");
                    assert_eq!(ws.running(), running, "{what}: running");
                    assert_eq!(ws.backoff(), backoff, "{what}: backoff");
                    assert_eq!(
                        ws.peaks(),
                        (brute.peak_queued, brute.peak_running),
                        "{what}: peaks"
                    );
                    // Resources in use: summed from start-event shapes.
                    let (mut uc, mut ug) = (0u64, 0u64);
                    for (_, c, g) in brute.live.values() {
                        uc += c;
                        ug += g;
                    }
                    assert_eq!(ws.used(), (uc, ug), "{what}: used resources");
                    // Windowed latency summaries over the same samples.
                    let waits: Vec<f64> = brute
                        .waits
                        .iter()
                        .filter(|&&(t, _)| t > cut)
                        .map(|&(_, v)| v)
                        .collect();
                    let ttxs: Vec<f64> = brute
                        .ttxs
                        .iter()
                        .filter(|&&(t, _)| t > cut)
                        .map(|&(_, v)| v)
                        .collect();
                    assert_eq!(ws.wait(), Summary::try_of(&waits), "{what}: wait summary");
                    assert_eq!(ws.ttx(), Summary::try_of(&ttxs), "{what}: ttx summary");
                    // Per-kind table against the independent lane maps.
                    for row in ws.kind_table() {
                        assert_eq!(
                            row.running,
                            brute.kind_running.get(&row.kind).copied().unwrap_or(0),
                            "{what}: kind {} running",
                            row.kind
                        );
                        assert_eq!(
                            row.peak,
                            brute.kind_peak.get(&row.kind).copied().unwrap_or(0),
                            "{what}: kind {} peak",
                            row.kind
                        );
                        assert_eq!(
                            row.completed,
                            brute.kind_done.get(&row.kind).copied().unwrap_or(0),
                            "{what}: kind {} completed",
                            row.kind
                        );
                    }
                    // Rates: the exact effective-window expression.
                    let span = brute.now - brute.t0.unwrap();
                    let eff = if span > 0.0 { window.min(span) } else { window };
                    assert_eq!(ws.effective_window().to_bits(), eff.to_bits(), "{what}: eff");
                    let want_rate = if eff.is_finite() && eff > 0.0 {
                        win.arrivals as f64 / eff
                    } else {
                        0.0
                    };
                    assert_eq!(
                        ws.rate(win.arrivals).to_bits(),
                        want_rate.to_bits(),
                        "{what}: arrival rate"
                    );
                }
                if window == 25.0 {
                    frames.push(render_frame(&ws, "matrix", false));
                }
            }
        }
        // Both wake policies emitted the same stream, so the dashboard
        // frames must be byte-identical too.
        assert_eq!(frames[0], frames[1], "seed {seed}: frames differ across wake policies");
    }
}

#[test]
fn tailed_chunks_and_offset_resume_match_the_one_shot_parse() {
    let cat = Catalog::new().insert("solo", solo(4.0));
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let (_, events) = run_with_stream(&faulty_spec(1), &cat, &cluster);
    let text = ndjson(&events);
    let want = parse_stream(&text).unwrap();
    let frame_of = |events: &[ObsEvent]| {
        let mut ws = WindowStats::new(60.0);
        for ev in events {
            ws.push(ev);
        }
        render_frame(&ws, "tail", false)
    };
    let want_frame = frame_of(&want);
    for chunk in [1usize, 7, 64, 4096] {
        let mut p = TailParser::new();
        let mut got = Vec::new();
        for piece in text.as_bytes().chunks(chunk) {
            p.feed(piece, &mut got).unwrap();
        }
        p.finish(&mut got).unwrap();
        assert_eq!(got, want, "chunk size {chunk}");
        assert_eq!(p.offset(), text.len() as u64, "chunk size {chunk}");
        assert_eq!(frame_of(&got), want_frame, "chunk size {chunk}: rollup frame");
    }
    // Stop mid-line, then resume a fresh parser from the reported
    // offset: nothing replays, nothing is lost.
    let cut = text.len() * 2 / 3;
    let mut first = TailParser::new();
    let mut got = Vec::new();
    first.feed(&text.as_bytes()[..cut], &mut got).unwrap();
    let off = first.offset() as usize;
    assert!(off <= cut, "offset counts complete lines only");
    let mut second = TailParser::resume_at(off as u64);
    second.feed(&text.as_bytes()[off..], &mut got).unwrap();
    second.finish(&mut got).unwrap();
    assert_eq!(got, want, "offset resume");
    assert_eq!(frame_of(&got), want_frame, "offset resume: rollup frame");
}

#[test]
fn chained_streams_watch_like_the_uninterrupted_run() {
    let cat = Catalog::new().insert("solo", solo(4.0));
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let cfg = EngineConfig::ideal();
    let spec = faulty_spec(3);
    let (_, straight) = run_with_stream(&spec, &cat, &cluster);

    let shared = Rc::new(RefCell::new(MemSink::new()));
    let leg = || TrafficObs { sink: Some(Box::new(Rc::clone(&shared))), profile: None };
    let (_, legs) = run_chained_obs(&spec, &cat, &cluster, &cfg, 7.0, leg).unwrap();
    assert!(legs >= 2, "a 7 s cadence over a ~30 s run must take several legs, got {legs}");
    let chained = shared.borrow().events.clone();

    // Seam markers stripped, the resume-concatenated stream is the
    // uninterrupted one — so the console shows the same dashboard.
    let stripped = strip_checkpoint_markers(&chained);
    assert_eq!(stripped, straight, "stripped chained stream == uninterrupted stream");
    assert_eq!(
        watch_once(&stripped, "s", 60.0),
        watch_once(&straight, "s", 60.0),
        "one-shot dashboards agree"
    );
    // Markers left in, the headline still reconstructs identically
    // (replay treats them as annotations).
    assert_eq!(
        headline(&replay(&chained).unwrap()).render(),
        headline(&replay(&straight).unwrap()).render(),
        "headline survives the seam markers"
    );
    // The multi-leg NDJSON tails exactly like a one-shot parse.
    let text = ndjson(&chained);
    let want = parse_stream(&text).unwrap();
    for chunk in [3usize, 117] {
        let mut p = TailParser::new();
        let mut got = Vec::new();
        for piece in text.as_bytes().chunks(chunk) {
            p.feed(piece, &mut got).unwrap();
        }
        p.finish(&mut got).unwrap();
        assert_eq!(got, want, "chunk size {chunk}");
    }
}

#[test]
fn svg_renders_are_byte_identical_per_seed() {
    let cat = Catalog::new().insert("solo", solo(4.0));
    let cluster = ClusterSpec::uniform("t", 2, 2, 0);
    let spec = faulty_spec(2);
    let (_, e1) = run_with_stream(&spec, &cat, &cluster);
    let (_, e2) = run_with_stream(&spec, &cat, &cluster);
    let (r1, r2) = (replay(&e1).unwrap(), replay(&e2).unwrap());
    let (a1, a2) = (analyze_replayed(&r1).unwrap(), analyze_replayed(&r2).unwrap());
    let pairs = [
        (overlap_heatmap_svg(&a1), overlap_heatmap_svg(&a2), "overlap heatmap"),
        (kind_timeline_svg(&r1), kind_timeline_svg(&r2), "kind timeline"),
        (util_backlog_svg(&r1), util_backlog_svg(&r2), "util/backlog strip"),
    ];
    for (x, y, what) in &pairs {
        assert_eq!(x, y, "{what}: same seed must render identical bytes");
        assert!(x.starts_with("<svg"), "{what}: svg root");
        assert!(x.trim_end().ends_with("</svg>"), "{what}: closed root");
        assert!(!x.contains("NaN") && !x.contains("inf"), "{what}: finite coordinates");
    }
}

#[test]
fn watch_once_cli_reproduces_the_live_report_headline() {
    let cat = Catalog::new().insert("chain", chain());
    let cluster = ClusterSpec::uniform("t", 3, 8, 2);
    let (rep, events) = run_with_stream(&chain_spec(7), &cat, &cluster);
    let dir = std::env::temp_dir().join("asyncflow_obs_watch_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("events.ndjson");
    std::fs::write(&path, ndjson(&events)).unwrap();

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_asyncflow"))
        .args(["watch", path.to_str().unwrap(), "--once"])
        .output()
        .unwrap();
    assert!(out.status.success(), "watch --once failed: {:?}", out);
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert!(stdout.contains("asyncflow watch — "), "frame header");
    // Every headline line the live run printed appears verbatim.
    let live = rep.render(false);
    for prefix in ["  wait    ", "  TTX     ", "  backlog ", "  makespan "] {
        let line = live
            .lines()
            .find(|l| l.starts_with(prefix))
            .unwrap_or_else(|| panic!("live render lacks a {prefix:?} line"));
        assert!(stdout.contains(line), "watch --once must print the live line {line:?}");
    }

    let rdir = dir.join("svg");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_asyncflow"))
        .args(["trace", path.to_str().unwrap(), "--render", rdir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "trace --render failed: {:?}", out);
    let run = replay(&events).unwrap();
    let analysis = analyze_replayed(&run).unwrap();
    for (file, want) in [
        ("trace_overlap.svg", overlap_heatmap_svg(&analysis)),
        ("trace_kinds.svg", kind_timeline_svg(&run)),
        ("trace_util.svg", util_backlog_svg(&run)),
    ] {
        let got = std::fs::read_to_string(rdir.join(file)).unwrap();
        assert_eq!(got, want, "{file}: CLI render must match the library render");
    }
    assert!(rdir.join("trace_chrome.json").exists(), "chrome trace written");
    let _ = std::fs::remove_dir_all(&dir);
}
