//! Config system (substrate S18): JSON descriptions of clusters,
//! workflows and engine settings, so experiments are reproducible from
//! checked-in files (`configs/*.json`) rather than code edits.

use std::path::Path;

use crate::dag::Dag;
use crate::engine::EngineConfig;
use crate::entk::{Pipeline, Stage, Workflow};
use crate::error::{Error, Result};
use crate::pilot::Policy;
use crate::resources::{ClusterSpec, NodeSpec, ResourceRequest};
use crate::task::TaskSetSpec;
use crate::util::json::{obj, Json};

/// Load a cluster from JSON:
/// `{"name": ..., "nodes": [{"cores": 168, "gpus": 6, "count": 16}]}`
/// or `{"profile": "summit_paper"}`.
pub fn cluster_from_json(v: &Json) -> Result<ClusterSpec> {
    if let Some(p) = v.get("profile").as_str() {
        return match p {
            "summit_paper" => Ok(ClusterSpec::summit_paper()),
            "summit_706" => Ok(ClusterSpec::summit_706()),
            "summit_8gpu" => Ok(ClusterSpec::summit_8gpu()),
            "local_small" => Ok(ClusterSpec::local_small()),
            other => Err(Error::Config(format!("unknown cluster profile '{other}'"))),
        };
    }
    let name = v.req_str("name")?.to_string();
    let mut nodes = Vec::new();
    for n in v.req_arr("nodes")? {
        let count = n.get("count").as_u64().unwrap_or(1) as usize;
        let spec = NodeSpec {
            cores: n.req_f64("cores")? as u32,
            gpus: n.get("gpus").as_u64().unwrap_or(0) as u32,
        };
        nodes.extend(std::iter::repeat(spec).take(count));
    }
    if nodes.is_empty() {
        return Err(Error::Config("cluster has no nodes".into()));
    }
    Ok(ClusterSpec { name, nodes })
}

pub fn cluster_to_json(c: &ClusterSpec) -> Json {
    obj([
        ("name", Json::from(c.name.clone())),
        (
            "nodes",
            Json::Arr(
                c.nodes
                    .iter()
                    .map(|n| {
                        obj([
                            ("cores", Json::from(n.cores as usize)),
                            ("gpus", Json::from(n.gpus as usize)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Load a workflow from JSON. Schema:
/// ```json
/// {
///   "name": "wf",
///   "sets": [{"name": "T0", "tasks": 4, "cores": 2, "gpus": 1,
///             "tx": 30.0, "sigma": 0.05}],
///   "edges": [["T0", "T1"]],
///   "sequential": [[["T0"], ["T1"]]],
///   "asynchronous": [[["T0"]], [["T1"]]]
/// }
/// ```
/// (realizations = array of pipelines; pipeline = array of stages;
/// stage = array of set names.)
pub fn workflow_from_json(v: &Json) -> Result<Workflow> {
    let name = v.req_str("name")?.to_string();
    let mut dag = Dag::new();
    let mut sets = Vec::new();
    for s in v.req_arr("sets")? {
        let sname = s.req_str("name")?.to_string();
        dag.add_node(sname.clone());
        let mut set = TaskSetSpec::new(
            sname,
            s.req_f64("tasks")? as u32,
            ResourceRequest::new(
                s.req_f64("cores")? as u32,
                s.get("gpus").as_u64().unwrap_or(0) as u32,
            ),
            s.req_f64("tx")?,
        );
        if let Some(sig) = s.get("sigma").as_f64() {
            set = set.with_sigma(sig);
        }
        sets.push(set);
    }
    for e in v.req_arr("edges")? {
        let pair = e
            .as_arr()
            .ok_or_else(|| Error::Config("edge must be a 2-array".into()))?;
        if pair.len() != 2 {
            return Err(Error::Config("edge must be a 2-array".into()));
        }
        let find = |j: &Json| -> Result<usize> {
            let n = j.as_str().ok_or_else(|| Error::Config("edge endpoint".into()))?;
            dag.node_by_name(n)
                .ok_or_else(|| Error::Config(format!("unknown set '{n}' in edge")))
        };
        dag.add_edge(find(&pair[0])?, find(&pair[1])?)?;
    }
    let parse_realization = |key: &str| -> Result<Vec<Pipeline>> {
        let mut pipelines = Vec::new();
        for (pi, p) in v.req_arr(key)?.iter().enumerate() {
            let stages = p
                .as_arr()
                .ok_or_else(|| Error::Config("pipeline must be an array of stages".into()))?;
            let mut pipe = Pipeline::new(format!("{name}-{key}-{pi}"));
            for st in stages {
                let names = st
                    .as_arr()
                    .ok_or_else(|| Error::Config("stage must be an array of names".into()))?;
                let mut ids = Vec::new();
                for n in names {
                    let n = n.as_str().ok_or_else(|| Error::Config("set name".into()))?;
                    ids.push(
                        dag.node_by_name(n)
                            .ok_or_else(|| Error::Config(format!("unknown set '{n}'")))?,
                    );
                }
                pipe.stages.push(Stage::of(&ids));
            }
            pipelines.push(pipe);
        }
        Ok(pipelines)
    };
    let sequential = parse_realization("sequential")?;
    let asynchronous = parse_realization("asynchronous")?;
    let _ = parse_realization;
    let wf = Workflow { name, sets, dag, sequential, asynchronous };
    wf.validate()?;
    Ok(wf)
}

/// Engine settings from JSON (all fields optional).
pub fn engine_from_json(v: &Json) -> Result<EngineConfig> {
    let mut cfg = EngineConfig::default();
    if let Some(s) = v.get("seed").as_u64() {
        cfg.seed = s;
    }
    if let Some(t) = v.get("task_overhead").as_f64() {
        cfg.task_overhead = t;
    }
    if let Some(t) = v.get("stage_overhead").as_f64() {
        cfg.stage_overhead = t;
    }
    if let Some(p) = v.get("policy").as_str() {
        cfg.policy = p.parse::<Policy>()?;
    }
    Ok(cfg)
}

/// Load `{workflow, cluster, engine}` from a config file.
pub fn load_experiment(path: impl AsRef<Path>) -> Result<(Workflow, ClusterSpec, EngineConfig)> {
    let text = std::fs::read_to_string(path.as_ref())?;
    let v = Json::parse(&text)?;
    let wf = workflow_from_json(&v.get("workflow").clone())?;
    let cluster = cluster_from_json(&v.get("cluster").clone())?;
    let engine = engine_from_json(&v.get("engine").clone())?;
    Ok((wf, cluster, engine))
}

#[cfg(test)]
mod tests {
    use super::*;

    const WF: &str = r#"{
      "workflow": {
        "name": "toy",
        "sets": [
          {"name": "A", "tasks": 2, "cores": 1, "tx": 10.0},
          {"name": "B", "tasks": 2, "cores": 1, "gpus": 1, "tx": 5.0, "sigma": 0.0}
        ],
        "edges": [["A", "B"]],
        "sequential": [[["A"], ["B"]]],
        "asynchronous": [[["A"], ["B"]]]
      },
      "cluster": {"profile": "local_small"},
      "engine": {"seed": 1, "policy": "fifo", "task_overhead": 0.0}
    }"#;

    #[test]
    fn parses_full_experiment() {
        let v = Json::parse(WF).unwrap();
        let wf = workflow_from_json(&v.get("workflow").clone()).unwrap();
        assert_eq!(wf.sets.len(), 2);
        assert_eq!(wf.sets[1].req.gpus, 1);
        assert_eq!(wf.sets[1].tx_sigma_frac, 0.0);
        assert_eq!(wf.dag.parents(1), &[0]);
        let c = cluster_from_json(&v.get("cluster").clone()).unwrap();
        assert_eq!(c.name, "local-small");
        let e = engine_from_json(&v.get("engine").clone()).unwrap();
        assert_eq!(e.seed, 1);
        assert_eq!(e.task_overhead, 0.0);
    }

    #[test]
    fn cluster_inline_nodes() {
        let v = Json::parse(
            r#"{"name": "c", "nodes": [{"cores": 4, "gpus": 1, "count": 3}]}"#,
        )
        .unwrap();
        let c = cluster_from_json(&v).unwrap();
        assert_eq!(c.nodes.len(), 3);
        assert_eq!(c.total_gpus(), 3);
        // round-trip through cluster_to_json
        let c2 = cluster_from_json(&cluster_to_json(&c)).unwrap();
        assert_eq!(c2.total_cores(), c.total_cores());
    }

    #[test]
    fn rejects_bad_configs() {
        for bad in [
            r#"{"profile": "nope"}"#,
            r#"{"name": "c", "nodes": []}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(cluster_from_json(&v).is_err(), "{bad}");
        }
        let v = Json::parse(r#"{"policy": "zzz"}"#).unwrap();
        assert!(engine_from_json(&v).is_err());
        // Workflow referencing an unknown set in an edge.
        let v = Json::parse(
            r#"{"name":"w","sets":[{"name":"A","tasks":1,"cores":1,"tx":1}],
                "edges":[["A","Z"]],"sequential":[[["A"]]],"asynchronous":[[["A"]]]}"#,
        )
        .unwrap();
        assert!(workflow_from_json(&v).is_err());
    }

    #[test]
    fn load_experiment_from_file() {
        let dir = std::env::temp_dir().join("asyncflow_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("exp.json");
        std::fs::write(&p, WF).unwrap();
        let (wf, c, e) = load_experiment(&p).unwrap();
        assert_eq!(wf.name, "toy");
        assert_eq!(c.nodes.len(), 2);
        assert_eq!(e.seed, 1);
    }
}
