//! Micro-benchmark harness (no `criterion` offline).
//!
//! Provides warmup + repeated timed runs with summary statistics, and a
//! table printer shared by the `benches/` binaries so every paper
//! table/figure regenerator reports in a consistent format.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// Wall-clock stopwatch for perf *accounting* (e.g. the coordinator's
/// `sched_wall`). This is the sanctioned wall-clock read for engine
/// code: simulation state must never depend on the host clock
/// (`asyncflow lint` DET003 rejects `Instant`/`SystemTime` outside the
/// timing allowlist), so engine modules measure themselves through
/// this type instead of touching `Instant` directly — the elapsed time
/// may only flow into reporting fields, never into the event loop.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Stopwatch {
        Stopwatch(Instant::now())
    }

    /// Wall-clock time elapsed since [`start`](Stopwatch::start).
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
}

/// Result of a timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Seconds per iteration.
    pub secs: Summary,
}

impl BenchResult {
    pub fn throughput_per_sec(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.secs.mean
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), iters, secs: Summary::of(&times) }
}

/// Render a benchmark result line (criterion-ish).
pub fn report(r: &BenchResult) {
    println!(
        "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
        r.name,
        fmt_time(r.secs.mean),
        fmt_time(r.secs.p50),
        fmt_time(r.secs.p95),
        r.iters
    );
}

pub fn report_header() {
    println!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "p50", "p95"
    );
    println!("{}", "-".repeat(88));
}

pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Simple fixed-width table printer for paper-table reproduction output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String], widths: &[usize]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            out.push('\n');
        };
        line(&mut out, &self.headers, &widths);
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row, &widths);
        }
        out
    }

    pub fn print(&self) {
        print!("{self}");
    }
}

// Compact rendering via `Display` (so `.to_string()` keeps working for
// existing callers without shadowing `ToString`).
impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 10);
        assert!(r.secs.mean >= 0.0);
        assert!(r.secs.p95 >= r.secs.p50 || (r.secs.p95 - r.secs.p50).abs() < 1e-9);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.to_string();
        assert!(s.contains("a"));
        assert!(s.contains("---"));
        assert!(s.lines().count() == 3);
    }
}
