//! Infrastructure substrates built from `std` (the offline environment
//! ships no serde/clap/rand/criterion — we implement what we need).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
