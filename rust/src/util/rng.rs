//! Deterministic PRNG (substrate S2): splitmix64-seeded xoshiro256++,
//! plus the samplers the workload generators need (uniform, normal,
//! exponential). No `rand` crate offline.
//!
//! Streams are checkpointable: [`Rng::state`] / [`Rng::from_state`]
//! capture and restore the full generator state (the four xoshiro words
//! *and* the cached Box–Muller spare), so a snapshot taken mid-stream
//! resumes bit-identically.

use crate::error::Result;
use crate::util::json::{f64_or_nan, from_f64_nan, from_u64, obj, FromJson, Json, ToJson};

/// Complete serializable [`Rng`] state. The xoshiro words use all 64
/// bits, so they serialize via the lossless encoding
/// ([`from_u64`]); the Box–Muller spare must be captured too or the
/// normal-sample stream would shift by one draw after restore.
#[derive(Debug, Clone, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub spare_normal: Option<f64>,
}

impl ToJson for RngState {
    fn to_json(&self) -> Json {
        obj([
            ("s", Json::Arr(self.s.iter().map(|&w| from_u64(w)).collect())),
            (
                "spare_normal",
                match self.spare_normal {
                    Some(z) => from_f64_nan(z),
                    None => Json::Null,
                },
            ),
        ])
    }
}

impl FromJson for RngState {
    fn from_json(v: &Json) -> Result<RngState> {
        let words = v.req_arr("s")?;
        if words.len() != 4 {
            return Err(crate::error::Error::Config(format!(
                "rng state: expected 4 state words, got {}",
                words.len()
            )));
        }
        let mut s = [0u64; 4];
        for (i, w) in words.iter().enumerate() {
            s[i] = w.as_u64_lossless().ok_or_else(|| {
                crate::error::Error::Config(format!("rng state: bad word #{i}"))
            })?;
        }
        let spare_normal = match v.get("spare_normal") {
            Json::Null => None,
            z => Some(f64_or_nan(z)?),
        };
        Ok(RngState { s, spare_normal })
    }
}

/// xoshiro256++ with a splitmix64 seeding routine. Deterministic across
/// platforms; every experiment takes an explicit seed so results are
/// reproducible run-to-run.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal sample (Box–Muller produces pairs).
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-task jitter, per-branch use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Capture the full generator state (checkpointing).
    pub fn state(&self) -> RngState {
        RngState { s: self.s, spare_normal: self.spare_normal }
    }

    /// Rebuild a generator from a captured state; continues the stream
    /// exactly where [`Rng::state`] left it.
    pub fn from_state(st: &RngState) -> Rng {
        Rng { s: st.s, spare_normal: st.spare_normal }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0, 1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Lemire-style rejection-free for our purposes (n << 2^64).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// N(mu, sigma), truncated at a floor (durations must stay positive).
    pub fn normal_pos(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).max(mu * 0.01).max(1e-9)
    }

    /// Exponential with rate lambda (inter-arrival sampling).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(42);
        let n = 200_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn normal_pos_stays_positive() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            assert!(r.normal_pos(10.0, 50.0) > 0.0);
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_are_independent_and_pinned() {
        // Forked streams must (a) differ from the parent and from each
        // other, (b) not disturb the parent beyond the one seeding
        // draw, and (c) depend only on (parent position, tag) — the
        // property the per-set TX streams build on.
        let mut parent_a = Rng::new(99);
        let mut parent_b = Rng::new(99);
        let mut f1 = parent_a.fork(1);
        let mut g1 = parent_b.fork(1);
        let seq = |r: &mut Rng| (0..16).map(|_| r.next_u64()).collect::<Vec<_>>();
        assert_eq!(seq(&mut f1), seq(&mut g1), "same position + tag, same stream");
        // Same parent position, different tag: different stream.
        let mut parent_c = Rng::new(99);
        let mut f2 = parent_c.fork(2);
        assert_ne!(seq(&mut f1), seq(&mut f2));
        // The parents advanced identically (one seeding draw each).
        assert_eq!(seq(&mut parent_a), seq(&mut parent_b));
        // Child streams do not echo the parent stream.
        let mut parent_d = Rng::new(99);
        let mut child = parent_d.fork(7);
        assert_ne!(seq(&mut child), seq(&mut parent_d));
    }

    #[test]
    fn state_round_trip_resumes_exactly() {
        // Capture mid-stream (including a cached Box–Muller spare) and
        // verify the restored generator continues bit-identically.
        let mut r = Rng::new(1234);
        for _ in 0..17 {
            r.next_u64();
        }
        let _ = r.normal(); // leaves a spare normal cached
        let st = r.state();
        assert!(st.spare_normal.is_some(), "Box–Muller spare must be captured");
        let mut restored = Rng::from_state(&st);
        for _ in 0..8 {
            assert_eq!(restored.normal().to_bits(), r.normal().to_bits());
        }
        for _ in 0..64 {
            assert_eq!(restored.next_u64(), r.next_u64());
        }
        // And through the JSON spine (full-width words survive).
        let mut r2 = Rng::new(0xDEAD_BEEF_DEAD_BEEF);
        r2.next_u64();
        let wire = r2.state().to_json().to_string();
        let back = RngState::from_json(&crate::util::json::Json::parse(&wire).unwrap())
            .unwrap();
        assert_eq!(back, r2.state());
        let mut r3 = Rng::from_state(&back);
        assert_eq!(r3.next_u64(), r2.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
