//! Minimal JSON parser + serializer (substrate S1).
//!
//! The offline build environment has no `serde`; configs, artifact
//! metadata (`artifacts/*.meta.json`) and experiment reports all speak
//! JSON, so we implement RFC 8259 parsing with precise error offsets.
//!
//! The [`ToJson`] / [`FromJson`] traits are the crate's serialization
//! spine: core state types (resource requests, task specs, records,
//! workflows, resource plans, RNG state) implement them so the
//! [`checkpoint`](crate::checkpoint) subsystem — and future consumers
//! like distributed coordinators — can snapshot and restore structured
//! state through one deterministic wire format.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// Serialize into a [`Json`] value (deterministic: objects are
/// `BTreeMap`s, so the same value always renders the same bytes).
pub trait ToJson {
    fn to_json(&self) -> Json;
}

/// Reconstruct from a [`Json`] value; the inverse of [`ToJson`].
/// Implementations must round-trip: `T::from_json(&t.to_json()) == t`.
pub trait FromJson: Sized {
    fn from_json(v: &Json) -> Result<Self>;
}

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // ----- typed accessors --------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    /// Exact signed-integer view: `None` for non-numbers, fractions,
    /// and magnitudes beyond what an `f64` stores exactly (2^53).
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|f| {
            if f.fract() == 0.0 && f.abs() <= (1u64 << 53) as f64 {
                Some(f as i64)
            } else {
                None
            }
        })
    }

    /// Lossless `u64` view: accepts plain numbers (exact integers up to
    /// 2^53) *and* decimal strings, the encoding [`from_u64`] emits for
    /// full-width values that an `f64` JSON number cannot carry.
    pub fn as_u64_lossless(&self) -> Option<u64> {
        match self {
            Json::Str(s) => s.parse().ok(),
            _ => self.as_u64(),
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Required-field accessors used by the config layer.
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| Error::Config(format!("missing/invalid number field '{key}'")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| Error::Config(format!("missing/invalid string field '{key}'")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| Error::Config(format!("missing/invalid array field '{key}'")))
    }

    /// Required unsigned integer, accepting the lossless string
    /// encoding of [`from_u64`] (restore paths for seeds, priorities
    /// and RNG words, which use all 64 bits).
    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get(key).as_u64_lossless().ok_or_else(|| {
            Error::Config(format!("missing/invalid unsigned integer field '{key}'"))
        })
    }

    pub fn req_bool(&self, key: &str) -> Result<bool> {
        self.get(key)
            .as_bool()
            .ok_or_else(|| Error::Config(format!("missing/invalid boolean field '{key}'")))
    }

    pub fn req_obj(&self, key: &str) -> Result<&BTreeMap<String, Json>> {
        self.get(key)
            .as_obj()
            .ok_or_else(|| Error::Config(format!("missing/invalid object field '{key}'")))
    }

    /// Required signed integer (exact; see [`Json::as_i64`]).
    pub fn req_i64(&self, key: &str) -> Result<i64> {
        self.get(key)
            .as_i64()
            .ok_or_else(|| Error::Config(format!("missing/invalid integer field '{key}'")))
    }

    // ----- serialization ----------------------------------------------
    // Compact rendering is `Display` (so `.to_string()` works); pretty
    // rendering is the inherent method below.

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        f.write_str(&s)
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Ergonomic object builder: `obj([("a", Json::Num(1.0))])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(items: I) -> Json {
    Json::Obj(items.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Serialize a slice of [`ToJson`] values into a JSON array.
pub fn arr_of<T: ToJson>(xs: &[T]) -> Json {
    Json::Arr(xs.iter().map(|x| x.to_json()).collect())
}

/// Parse a required array field whose elements are [`FromJson`] —
/// the inverse of [`arr_of`] under a key.
pub fn parse_arr<T: FromJson>(v: &Json, key: &str) -> Result<Vec<T>> {
    let mut out = Vec::new();
    for x in v.req_arr(key)? {
        out.push(T::from_json(x)?);
    }
    Ok(out)
}

/// Lossless `u64` encoding: values an `f64` carries exactly go out as
/// numbers; full-width values (seeds, RNG words) as decimal strings.
/// Read back with [`Json::as_u64_lossless`] / [`Json::req_u64`].
pub fn from_u64(v: u64) -> Json {
    if v <= (1u64 << 53) {
        Json::Num(v as f64)
    } else {
        Json::Str(v.to_string())
    }
}

/// NaN-safe `f64` encoding: JSON has no NaN literal, and task records
/// legitimately hold NaN for not-yet-started/finished timestamps, so
/// NaN maps to `null`. Read back with [`f64_or_nan`].
pub fn from_f64_nan(v: f64) -> Json {
    if v.is_nan() {
        Json::Null
    } else {
        Json::Num(v)
    }
}

/// Inverse of [`from_f64_nan`]: `null` -> NaN, numbers pass through.
pub fn f64_or_nan(v: &Json) -> Result<f64> {
    match v {
        Json::Null => Ok(f64::NAN),
        Json::Num(n) => Ok(*n),
        _ => Err(Error::Config("expected a number or null".into())),
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.i, message: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                            continue; // hex4 advanced i already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // Decode UTF-8 multi-byte sequences verbatim.
                    let start = self.i;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        return Err(self.err("invalid utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first >> 5 == 0b110 {
        2
    } else if first >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
        // surrogate pair (😀 U+1F600)
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
        // raw multi-byte utf-8 passthrough
        let v = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo"));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"abc", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn error_carries_offset() {
        match Json::parse("[1, x]") {
            Err(Error::Json { offset, .. }) => assert_eq!(offset, 4),
            other => panic!("expected Json error, got {other:?}"),
        }
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"a":[1,2.5,null,true],"b":{"c":"d\ne"},"empty":[],"eo":{}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
        // pretty round-trips too
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1]}"#).unwrap();
        assert_eq!(v.req_f64("n").unwrap(), 3.0);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_arr("a").unwrap().len(), 1);
        assert!(v.req_f64("missing").is_err());
        assert_eq!(v.get("n").as_u64(), Some(3));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }

    #[test]
    fn typed_required_accessors() {
        let v = Json::parse(r#"{"n": 7, "b": true, "o": {"x": 1}, "i": -4, "f": 1.5}"#)
            .unwrap();
        assert_eq!(v.req_u64("n").unwrap(), 7);
        assert!(v.req_bool("b").unwrap());
        assert_eq!(v.req_obj("o").unwrap().len(), 1);
        assert_eq!(v.req_i64("i").unwrap(), -4);
        // Wrong types and missing keys all error.
        assert!(v.req_u64("b").is_err(), "bool is not a u64");
        assert!(v.req_u64("i").is_err(), "negative is not a u64");
        assert!(v.req_u64("f").is_err(), "fraction is not a u64");
        assert!(v.req_bool("n").is_err());
        assert!(v.req_obj("n").is_err());
        assert!(v.req_i64("f").is_err());
        assert!(v.req_u64("missing").is_err());
        assert!(v.req_bool("missing").is_err());
        assert!(v.req_obj("missing").is_err());
    }

    #[test]
    fn string_escapes_round_trip_through_serializer() {
        // Every escape class: quote, backslash, control chars, \u escape
        // below 0x20, multi-byte UTF-8 and an astral-plane char.
        for s in [
            "plain",
            "quote\"backslash\\slash/",
            "ctl\n\r\t\u{8}\u{c}",
            "low\u{1}\u{1f}",
            "héllo wörld",
            "emoji 😀 done",
        ] {
            let v = Json::Str(s.to_string());
            let wire = v.to_string();
            assert_eq!(Json::parse(&wire).unwrap().as_str(), Some(s), "via {wire}");
        }
    }

    #[test]
    fn large_and_negative_integers_round_trip() {
        // Exact integers on both sides of the 1e15 formatting switch.
        for n in [
            0.0,
            -1.0,
            9007199254740992.0,  // 2^53
            -9007199254740992.0, // -2^53
            1e18,
            -123456789012345.0,
        ] {
            let wire = Json::Num(n).to_string();
            assert_eq!(Json::parse(&wire).unwrap(), Json::Num(n), "via {wire}");
        }
        // Full-width u64s survive via the lossless string encoding.
        for v in [0u64, 1 << 53, u64::MAX, u64::MAX - 1] {
            let j = from_u64(v);
            let wire = j.to_string();
            let back = Json::parse(&wire).unwrap();
            assert_eq!(back.as_u64_lossless(), Some(v), "via {wire}");
        }
        // ... and the plain-number path stays a number for small values.
        assert_eq!(from_u64(42), Json::Num(42.0));
        assert_eq!(Json::parse("42").unwrap().as_u64_lossless(), Some(42));
    }

    #[test]
    fn nan_maps_to_null_and_back() {
        assert_eq!(from_f64_nan(f64::NAN), Json::Null);
        assert_eq!(from_f64_nan(2.5), Json::Num(2.5));
        assert!(f64_or_nan(&Json::Null).unwrap().is_nan());
        assert_eq!(f64_or_nan(&Json::Num(3.0)).unwrap(), 3.0);
        assert!(f64_or_nan(&Json::Bool(true)).is_err());
    }
}
