//! Mini property-testing harness (substrate S3; no `proptest` offline).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; on failure it performs a bounded
//! shrink-by-regeneration pass (re-draws with decreasing size hints) and
//! reports the smallest failing case's debug representation.

use crate::util::rng::Rng;

/// Size hint passed to generators; starts small so early cases are tiny.
#[derive(Debug, Clone, Copy)]
pub struct Size(pub usize);

/// Run a property over `cases` generated inputs.
///
/// Panics (like an assert) with the failing case on the first violation.
pub fn check<T, G, P>(seed: u64, cases: usize, mut generate: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, Size) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        // Grow sizes over the run: early cases are small and debuggable.
        let size = Size(1 + case * 20 / cases.max(1));
        let input = generate(&mut rng, size);
        if let Err(msg) = prop(&input) {
            // Shrink-by-regeneration: try progressively smaller sizes with
            // fresh draws, keep the smallest failure found.
            let mut smallest = format!("{input:?}");
            let mut smallest_msg = msg;
            let mut shrink_rng = rng.fork(0xBAD);
            for s in (1..=size.0).rev() {
                for _ in 0..20 {
                    let cand = generate(&mut shrink_rng, Size(s));
                    if let Err(m) = prop(&cand) {
                        smallest = format!("{cand:?}");
                        smallest_msg = m;
                    }
                }
            }
            panic!(
                "property failed (seed={seed}, case={case}):\n  input: {smallest}\n  error: {smallest_msg}"
            );
        }
    }
}

/// Convenience: property returning bool.
pub fn check_bool<T, G, P>(seed: u64, cases: usize, generate: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, Size) -> T,
    P: FnMut(&T) -> bool,
{
    check(seed, cases, generate, move |t| {
        if prop(t) {
            Ok(())
        } else {
            Err("property returned false".to_string())
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_bool(
            1,
            200,
            |rng, size| (0..size.0).map(|_| rng.below(100)).collect::<Vec<_>>(),
            |xs| xs.iter().all(|&x| x < 100),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        check_bool(
            2,
            200,
            |rng, _| rng.below(10),
            |&x| x != 7, // will eventually draw a 7
        );
    }

    #[test]
    fn sizes_grow() {
        let mut max_seen = 0;
        check_bool(
            3,
            100,
            |_, size| size.0,
            |&s| {
                max_seen = max_seen.max(s);
                true
            },
        );
        assert!(max_seen > 10);
    }
}
