//! Small statistics toolkit (substrate S5) used by metrics and benches.

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Like [`Summary::of`] but total: `None` on an empty sample
    /// instead of panicking (streaming reports may legitimately see
    /// zero samples, e.g. a traffic window with no completions).
    pub fn try_of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            None
        } else {
            Some(Summary::of(xs))
        }
    }

    /// All-zero placeholder (`n == 0`) for rendering empty samples.
    pub fn empty() -> Summary {
        Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p95: 0.0, p99: 0.0 }
    }

    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least squares fit y = a + b x; returns (a, b, r2).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (y - (a + b * x)).powi(2))
        .sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    (a, b, r2)
}

/// Relative difference |a-b| / max(|a|,|b|,eps).
pub fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn try_of_handles_empty_samples() {
        assert_eq!(Summary::try_of(&[]), None);
        assert_eq!(Summary::try_of(&[2.0]), Some(Summary::of(&[2.0])));
        let e = Summary::empty();
        assert_eq!(e.n, 0);
        assert_eq!(e.mean, 0.0);
    }

    #[test]
    fn summary_single_element() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rel_diff_symmetric() {
        assert!((rel_diff(100.0, 106.0) - 0.0566).abs() < 1e-3);
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
        assert_eq!(rel_diff(1.0, 2.0), rel_diff(2.0, 1.0));
    }
}
