//! Tiny CLI argument parser (substrate S4; no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Option keys that are boolean flags (no value follows).
    known_flags: Vec<&'static str>,
}

impl Args {
    /// Parse an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        known_flags: &[&'static str],
    ) -> Result<Args> {
        let mut out = Args { known_flags: known_flags.to_vec(), ..Default::default() };
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional.
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if out.known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        out.flags.push(body.to_string());
                    } else {
                        let v = it.next().unwrap();
                        out.options.insert(body.to_string(), v);
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env(known_flags: &[&'static str]) -> Result<Args> {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: expected a number, got '{s}'"))),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: expected an integer, got '{s}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: expected an integer, got '{s}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), &["verbose", "dry-run"]).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["run", "--mode", "async", "--scale=0.01", "extra"]);
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.get("mode"), Some("async"));
        assert_eq!(a.get("scale"), Some("0.01"));
    }

    #[test]
    fn known_flags_take_no_value() {
        let a = parse(&["--verbose", "run"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["run", "--dry-run"]);
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn unknown_before_option_is_flag() {
        let a = parse(&["--unknown", "--mode", "x"]);
        assert!(a.flag("unknown"));
        assert_eq!(a.get("mode"), Some("x"));
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--mode", "x", "--", "--not-an-option"]);
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--scale", "0.5", "--n", "12"]);
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_usize("n", 0).unwrap(), 12);
        assert_eq!(a.get_f64("missing", 2.5).unwrap(), 2.5);
        let bad = parse(&["--n", "xy"]);
        assert!(bad.get_usize("n", 0).is_err());
    }
}
