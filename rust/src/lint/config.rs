//! Lint configuration: rule scopes, allowlists, watched snapshot
//! structs, and panic budgets.
//!
//! Defaults encode this repo's determinism contract; a `lint.conf`
//! file (plain `key = value` lines) overrides individual keys so the
//! fixture harness and future modules can re-scope rules without
//! recompiling. Unknown keys are rejected — a typo in a lint config
//! must not silently disable a rule.

use crate::error::{Error, Result};

/// Parsed lint configuration. See [`LintConfig::default`] for the
/// repo contract and [`LintConfig::apply`] for the file syntax.
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// DET001: module prefixes in which raw clock-epsilon literals are
    /// forbidden.
    pub det001_scope: Vec<String>,
    /// DET001: path suffixes exempt from the rule — the single file
    /// that *defines* the exported epsilon constant.
    pub det001_allow_files: Vec<String>,
    /// DET002: module prefixes in which `HashMap`/`HashSet` are
    /// forbidden (iteration order feeds replay state).
    pub det002_scope: Vec<String>,
    /// DET003: module prefixes allowed to touch the wall clock.
    pub det003_allow: Vec<String>,
    /// SER001: type names exempt from the paired-impl requirement.
    pub ser001_allow: Vec<String>,
    /// SER002: path suffix of the file holding `SNAPSHOT_VERSION` and
    /// the recorded field-list fingerprint. Empty disables the rule.
    pub ser002_file: String,
    /// SER002: `(path suffix, struct name)` pairs whose field lists
    /// feed the fingerprint.
    pub ser002_watch: Vec<(String, String)>,
    /// PANIC001: `(module prefix, allowed count)` ratchet budgets for
    /// non-test `unwrap()`/`expect()` calls.
    pub panic_budgets: Vec<(String, usize)>,
}

impl Default for LintConfig {
    /// The asyncflow determinism contract, as enforced on `rust/src`.
    fn default() -> LintConfig {
        fn strs(xs: &[&str]) -> Vec<String> {
            xs.iter().map(|s| s.to_string()).collect()
        }
        LintConfig {
            det001_scope: strs(&["engine", "exec", "sim", "sched", "checkpoint", "failure"]),
            det001_allow_files: strs(&["engine/mod.rs"]),
            det002_scope: strs(&[
                "engine",
                "checkpoint",
                "sched",
                "metrics",
                "exec",
                "sim",
                "failure",
            ]),
            det003_allow: strs(&["util::bench", "exec::stress", "ddmd::mlexec"]),
            ser001_allow: Vec::new(),
            ser002_file: "checkpoint/snapshot.rs".to_string(),
            ser002_watch: vec![
                ("checkpoint/snapshot.rs".to_string(), "PendingMember".to_string()),
                ("checkpoint/snapshot.rs".to_string(), "DriverEntry".to_string()),
                ("checkpoint/snapshot.rs".to_string(), "FinishedMember".to_string()),
                ("checkpoint/snapshot.rs".to_string(), "LiveTask".to_string()),
                ("checkpoint/snapshot.rs".to_string(), "RunningEntry".to_string()),
                ("checkpoint/snapshot.rs".to_string(), "SimSnapshot".to_string()),
                ("engine/driver.rs".to_string(), "DriverState".to_string()),
                // Failure-injection state rides inside SimSnapshot (v3):
                // every struct on that wire path is schema-watched.
                ("failure/mod.rs".to_string(), "FailureEvent".to_string()),
                ("failure/mod.rs".to_string(), "RetryPolicy".to_string()),
                ("failure/mod.rs".to_string(), "FailureSpec".to_string()),
                ("failure/mod.rs".to_string(), "RetryEntry".to_string()),
                ("failure/mod.rs".to_string(), "ResilienceStats".to_string()),
                ("failure/mod.rs".to_string(), "FailureState".to_string()),
            ],
            panic_budgets: Vec::new(),
        }
    }
}

impl LintConfig {
    /// Default contract with the overrides from a config file applied.
    pub fn load(path: &std::path::Path) -> Result<LintConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("lint config {}: {e}", path.display())))?;
        let mut cfg = LintConfig::default();
        cfg.apply(&text)?;
        Ok(cfg)
    }

    /// Apply `key = value` overrides. Syntax:
    ///
    /// ```text
    /// # comment
    /// det001.scope       = engine, exec, sim, sched, checkpoint
    /// det001.allow_files = engine/mod.rs
    /// det002.scope       = engine, checkpoint, sched, metrics
    /// det003.allow       = util::bench, exec::stress
    /// ser001.allow       = ScratchOnly
    /// ser002.file        = checkpoint/snapshot.rs
    /// ser002.watch       = checkpoint/snapshot.rs#SimSnapshot, engine/driver.rs#DriverState
    /// panic.budget       = engine:4, checkpoint:2
    /// ```
    ///
    /// Each key *replaces* its default list entirely; an empty value
    /// clears it (e.g. `ser002.file =` disables SER002).
    pub fn apply(&mut self, text: &str) -> Result<()> {
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("lint config line {}: expected key = value", lineno + 1))
            })?;
            let key = key.trim();
            let items: Vec<String> = value
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            match key {
                "det001.scope" => self.det001_scope = items,
                "det001.allow_files" => self.det001_allow_files = items,
                "det002.scope" => self.det002_scope = items,
                "det003.allow" => self.det003_allow = items,
                "ser001.allow" => self.ser001_allow = items,
                "ser002.file" => {
                    self.ser002_file = items.first().cloned().unwrap_or_default();
                }
                "ser002.watch" => {
                    let mut watch = Vec::new();
                    for it in &items {
                        let (file, name) = it.split_once('#').ok_or_else(|| {
                            Error::Config(format!(
                                "lint config line {}: ser002.watch entry '{it}' \
                                 must be file#Struct",
                                lineno + 1
                            ))
                        })?;
                        watch.push((file.trim().to_string(), name.trim().to_string()));
                    }
                    self.ser002_watch = watch;
                }
                "panic.budget" => {
                    let mut budgets = Vec::new();
                    for it in &items {
                        // Split on the *last* colon so nested module
                        // scopes (`obs::tail:0`) parse.
                        let (module, n) = it.rsplit_once(':').ok_or_else(|| {
                            Error::Config(format!(
                                "lint config line {}: panic.budget entry '{it}' \
                                 must be module:count",
                                lineno + 1
                            ))
                        })?;
                        let n: usize = n.trim().parse().map_err(|_| {
                            Error::Config(format!(
                                "lint config line {}: bad budget count in '{it}'",
                                lineno + 1
                            ))
                        })?;
                        budgets.push((module.trim().to_string(), n));
                    }
                    self.panic_budgets = budgets;
                }
                other => {
                    return Err(Error::Config(format!(
                        "lint config line {}: unknown key '{other}'",
                        lineno + 1
                    )));
                }
            }
        }
        Ok(())
    }

    /// Whether `module` is `prefix` or a descendant (`prefix::…`).
    pub fn module_in(scopes: &[String], module: &str) -> bool {
        scopes.iter().any(|s| {
            module == s || (module.len() > s.len() && module.starts_with(s) && module.as_bytes()[s.len()] == b':')
        })
    }

    /// Whether `path` ends with one of the `/`-separated suffixes in
    /// `entries` (on a component boundary).
    pub fn path_matches(entries: &[String], path: &str) -> bool {
        let norm = path.replace('\\', "/");
        entries.iter().any(|e| {
            norm == *e
                || norm
                    .strip_suffix(e.as_str())
                    .is_some_and(|head| head.ends_with('/'))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_encode_the_contract() {
        let c = LintConfig::default();
        assert!(LintConfig::module_in(&c.det001_scope, "engine::coordinator"));
        assert!(LintConfig::module_in(&c.det001_scope, "engine"));
        assert!(!LintConfig::module_in(&c.det001_scope, "util::stats"));
        // Prefix match is per-component: `engine2` is not `engine`.
        assert!(!LintConfig::module_in(&c.det001_scope, "engine2"));
        assert!(LintConfig::path_matches(&c.det001_allow_files, "src/engine/mod.rs"));
        assert!(!LintConfig::path_matches(&c.det001_allow_files, "src/fengine/mod.rs"));
    }

    #[test]
    fn apply_overrides_and_clears() {
        let mut c = LintConfig::default();
        c.apply(
            "# comment\n\
             det003.allow = util::bench\n\
             ser002.file =\n\
             panic.budget = engine:3, sched:0\n",
        )
        .unwrap();
        assert_eq!(c.det003_allow, vec!["util::bench".to_string()]);
        assert!(c.ser002_file.is_empty());
        assert_eq!(c.panic_budgets, vec![("engine".to_string(), 3), ("sched".to_string(), 0)]);
        // Untouched keys keep their defaults.
        assert!(!c.det001_scope.is_empty());
    }

    #[test]
    fn panic_budget_accepts_nested_module_scopes() {
        let mut c = LintConfig::default();
        c.apply("panic.budget = obs::tail:0, engine:15\n").unwrap();
        assert_eq!(
            c.panic_budgets,
            vec![("obs::tail".to_string(), 0), ("engine".to_string(), 15)]
        );
    }

    #[test]
    fn apply_rejects_unknown_keys_and_bad_entries() {
        let mut c = LintConfig::default();
        assert!(c.apply("nope.key = 1\n").is_err());
        assert!(c.apply("panic.budget = engine\n").is_err());
        assert!(c.apply("ser002.watch = missing-hash\n").is_err());
        assert!(c.apply("just a line\n").is_err());
    }
}
