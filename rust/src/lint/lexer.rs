//! Comment- and string-aware Rust tokenizer for `asyncflow lint`.
//!
//! A real parse is unnecessary for the determinism contract: every rule
//! keys off token *streams* (identifiers, literals, punctuation with
//! line/column spans), so the lexer only has to get the hard lexical
//! cases right — nested block comments, string/char/raw-string
//! literals, lifetimes vs char literals, float exponents — and never
//! report a match from inside a comment or a string.
//!
//! Beyond tokens, lexing extracts the two structural facts rules need:
//!
//! - **suppressions** — `// lint:allow(RULE_ID): reason` comments,
//!   bound to the code line they cover (their own line for trailing
//!   comments, the next code line otherwise);
//! - **test regions** — the line spans of `#[cfg(test)] mod … { … }`
//!   items, so rules can exempt test code (an `assert!` tolerance of
//!   `1e-12` is not a clock epsilon).

/// Lexical class of one token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`HashMap`, `impl`, `unwrap`).
    Ident,
    /// Numeric literal, including any type suffix (`1e-12`, `0xff`,
    /// `10f64`).
    Num,
    /// String literal of any flavor (`"…"`, `r#"…"#`, `b"…"`).
    Str,
    /// Char or byte-char literal (`'a'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Single punctuation character.
    Punct,
}

/// One source token with its 1-based position.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// An inline suppression: `// lint:allow(RULE_ID): reason`.
#[derive(Debug, Clone)]
pub struct Suppression {
    /// The rule id inside the parentheses, verbatim.
    pub rule: String,
    /// The mandatory justification after the closing `):`. Empty when
    /// the author omitted it — which is itself a finding (LINT001).
    pub reason: String,
    /// Line the comment sits on.
    pub line: u32,
    /// The code line this suppression covers: its own line when the
    /// comment trails code, otherwise the next line holding a token.
    /// `None` when nothing follows (dangling suppression).
    pub target: Option<u32>,
}

/// A lexed source file plus the derived structural facts.
#[derive(Debug)]
pub struct SourceFile {
    /// Path as given to the linter (used in findings).
    pub path: String,
    /// Module path relative to the crate root, e.g.
    /// `engine::coordinator` (see [`module_of`](crate::lint::module_of)).
    pub module: String,
    pub tokens: Vec<Tok>,
    pub suppressions: Vec<Suppression>,
    /// Line spans (inclusive) of `#[cfg(test)] mod … { … }` items.
    test_regions: Vec<(u32, u32)>,
}

impl SourceFile {
    /// Tokenize `text`, extracting suppressions and test regions.
    pub fn lex(path: impl Into<String>, module: impl Into<String>, text: &str) -> SourceFile {
        let mut cur = Cur { chars: text.chars().collect(), i: 0, line: 1, col: 1 };
        let mut tokens: Vec<Tok> = Vec::new();
        let mut suppressions: Vec<Suppression> = Vec::new();

        while let Some(c) = cur.peek() {
            let (tline, tcol) = (cur.line, cur.col);
            if c.is_whitespace() {
                cur.bump();
                continue;
            }
            // Line comment (also covers `///` and `//!` doc comments).
            if c == '/' && cur.peek_at(1) == Some('/') {
                let mut body = String::new();
                while let Some(ch) = cur.peek() {
                    if ch == '\n' {
                        break;
                    }
                    body.push(ch);
                    cur.bump();
                }
                // Doc comments (`///`, `//!`) are documentation — text
                // *about* the suppression syntax must not act as a
                // suppression. Only plain `//` comments count.
                let doc = body.starts_with("///") || body.starts_with("//!");
                if !doc {
                    if let Some(s) = parse_suppression(&body, tline) {
                        suppressions.push(s);
                    }
                }
                continue;
            }
            // Block comment, nested.
            if c == '/' && cur.peek_at(1) == Some('*') {
                cur.bump();
                cur.bump();
                let mut depth = 1usize;
                while depth > 0 {
                    match (cur.peek(), cur.peek_at(1)) {
                        (Some('*'), Some('/')) => {
                            cur.bump();
                            cur.bump();
                            depth -= 1;
                        }
                        (Some('/'), Some('*')) => {
                            cur.bump();
                            cur.bump();
                            depth += 1;
                        }
                        (Some(_), _) => {
                            cur.bump();
                        }
                        (None, _) => break,
                    }
                }
                continue;
            }
            // Plain string literal.
            if c == '"' {
                let text = lex_plain_string(&mut cur);
                tokens.push(Tok { kind: TokKind::Str, text, line: tline, col: tcol });
                continue;
            }
            // Raw strings, byte strings, raw identifiers: r"…", r#"…"#,
            // b"…", b'…', br#"…"#, r#ident.
            if c == 'r' || c == 'b' {
                if let Some(tok) = lex_r_or_b(&mut cur, tline, tcol) {
                    tokens.push(tok);
                    continue;
                }
                // Fall through: ordinary identifier starting with r/b.
            }
            // Lifetime or char literal.
            if c == '\'' {
                tokens.push(lex_quote(&mut cur, tline, tcol));
                continue;
            }
            // Number.
            if c.is_ascii_digit() {
                let text = lex_number(&mut cur);
                tokens.push(Tok { kind: TokKind::Num, text, line: tline, col: tcol });
                continue;
            }
            // Identifier / keyword.
            if c == '_' || c.is_alphabetic() {
                let text = lex_ident(&mut cur);
                tokens.push(Tok { kind: TokKind::Ident, text, line: tline, col: tcol });
                continue;
            }
            // Single punctuation character.
            cur.bump();
            tokens.push(Tok {
                kind: TokKind::Punct,
                text: c.to_string(),
                line: tline,
                col: tcol,
            });
        }

        // Bind each suppression to the code line it covers.
        for s in &mut suppressions {
            let trailing = tokens.iter().any(|t| t.line == s.line);
            s.target = if trailing {
                Some(s.line)
            } else {
                tokens.iter().map(|t| t.line).find(|&l| l > s.line)
            };
        }

        let test_regions = find_test_regions(&tokens);
        SourceFile {
            path: path.into(),
            module: module.into(),
            tokens,
            suppressions,
            test_regions,
        }
    }

    /// Whether `line` falls inside a `#[cfg(test)] mod … { … }` item.
    pub fn in_test_code(&self, line: u32) -> bool {
        self.test_regions
            .iter()
            .any(|&(start, end)| start <= line && line <= end)
    }
}

/// Character cursor tracking 1-based line/column.
struct Cur {
    chars: Vec<char>,
    i: usize,
    line: u32,
    col: u32,
}

impl Cur {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.i).copied()
    }

    fn peek_at(&self, off: usize) -> Option<char> {
        self.chars.get(self.i + off).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.i).copied()?;
        self.i += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }
}

/// `"…"` with backslash escapes; the opening quote is at the cursor.
fn lex_plain_string(cur: &mut Cur) -> String {
    let mut out = String::new();
    if let Some(q) = cur.bump() {
        out.push(q);
    }
    while let Some(ch) = cur.bump() {
        out.push(ch);
        if ch == '\\' {
            if let Some(e) = cur.bump() {
                out.push(e);
            }
            continue;
        }
        if ch == '"' {
            break;
        }
    }
    out
}

/// Literals and raw identifiers introduced by `r` or `b`. Returns
/// `None` when the cursor is actually at an ordinary identifier.
fn lex_r_or_b(cur: &mut Cur, line: u32, col: u32) -> Option<Tok> {
    let c = cur.peek()?;
    // Byte char: b'…'
    if c == 'b' && cur.peek_at(1) == Some('\'') {
        let mut text = String::new();
        if let Some(b) = cur.bump() {
            text.push(b);
        }
        let t = lex_quote(cur, line, col);
        text.push_str(&t.text);
        return Some(Tok { kind: TokKind::Char, text, line, col });
    }
    // Byte string: b"…"
    if c == 'b' && cur.peek_at(1) == Some('"') {
        let mut text = String::new();
        if let Some(b) = cur.bump() {
            text.push(b);
        }
        text.push_str(&lex_plain_string(cur));
        return Some(Tok { kind: TokKind::Str, text, line, col });
    }
    // Raw (byte) string: r"…", r#"…"#, br#"…"#, rb is not Rust.
    let raw_start = match c {
        'r' => 1,
        'b' if cur.peek_at(1) == Some('r') => 2,
        _ => return None,
    };
    let mut hashes = 0usize;
    while cur.peek_at(raw_start + hashes) == Some('#') {
        hashes += 1;
    }
    if cur.peek_at(raw_start + hashes) == Some('"') {
        let mut text = String::new();
        for _ in 0..raw_start + hashes + 1 {
            if let Some(ch) = cur.bump() {
                text.push(ch);
            }
        }
        // Scan for `"` followed by `hashes` hash marks.
        loop {
            match cur.bump() {
                None => break,
                Some('"') => {
                    text.push('"');
                    let mut n = 0usize;
                    while n < hashes && cur.peek() == Some('#') {
                        cur.bump();
                        text.push('#');
                        n += 1;
                    }
                    if n == hashes {
                        break;
                    }
                }
                Some(ch) => text.push(ch),
            }
        }
        return Some(Tok { kind: TokKind::Str, text, line, col });
    }
    // Raw identifier: r#ident.
    if c == 'r' && cur.peek_at(1) == Some('#') {
        let after = cur.peek_at(2);
        if after.is_some_and(|a| a == '_' || a.is_alphabetic()) {
            let mut text = String::new();
            cur.bump();
            cur.bump();
            text.push_str("r#");
            text.push_str(&lex_ident(cur));
            return Some(Tok { kind: TokKind::Ident, text, line, col });
        }
    }
    None
}

/// `'` at the cursor: lifetime (`'a`) or char literal (`'a'`, `'\n'`).
fn lex_quote(cur: &mut Cur, line: u32, col: u32) -> Tok {
    let next = cur.peek_at(1);
    let after = cur.peek_at(2);
    let is_lifetime = match next {
        Some(a) if a == '_' || a.is_alphabetic() => after != Some('\''),
        _ => false,
    };
    let mut text = String::from("'");
    cur.bump();
    if is_lifetime {
        while let Some(a) = cur.peek() {
            if a == '_' || a.is_alphanumeric() {
                text.push(a);
                cur.bump();
            } else {
                break;
            }
        }
        return Tok { kind: TokKind::Lifetime, text, line, col };
    }
    while let Some(a) = cur.bump() {
        text.push(a);
        if a == '\\' {
            if let Some(e) = cur.bump() {
                text.push(e);
            }
            continue;
        }
        if a == '\'' {
            break;
        }
    }
    Tok { kind: TokKind::Char, text, line, col }
}

/// Numeric literal starting at the cursor (first char is a digit).
fn lex_number(cur: &mut Cur) -> String {
    let mut text = String::new();
    if let Some(d) = cur.bump() {
        text.push(d);
    }
    // Radix literal: consume the alphanumeric tail wholesale.
    if text == "0" && matches!(cur.peek(), Some('x' | 'X' | 'o' | 'b')) {
        while let Some(a) = cur.peek() {
            if a.is_ascii_alphanumeric() || a == '_' {
                text.push(a);
                cur.bump();
            } else {
                break;
            }
        }
        return text;
    }
    // Integer part.
    while let Some(a) = cur.peek() {
        if a.is_ascii_digit() || a == '_' {
            text.push(a);
            cur.bump();
        } else {
            break;
        }
    }
    // Fraction: `.` followed by a digit (never `..` or a method call).
    if cur.peek() == Some('.') && cur.peek_at(1).is_some_and(|d| d.is_ascii_digit()) {
        text.push('.');
        cur.bump();
        while let Some(a) = cur.peek() {
            if a.is_ascii_digit() || a == '_' {
                text.push(a);
                cur.bump();
            } else {
                break;
            }
        }
    }
    // Exponent: e/E, optional sign, at least one digit.
    if matches!(cur.peek(), Some('e' | 'E')) {
        let exp_ok = match cur.peek_at(1) {
            Some('+') | Some('-') => cur.peek_at(2).is_some_and(|d| d.is_ascii_digit()),
            Some(d) => d.is_ascii_digit(),
            None => false,
        };
        if exp_ok {
            if let Some(e) = cur.bump() {
                text.push(e);
            }
            if matches!(cur.peek(), Some('+' | '-')) {
                if let Some(s) = cur.bump() {
                    text.push(s);
                }
            }
            while let Some(a) = cur.peek() {
                if a.is_ascii_digit() || a == '_' {
                    text.push(a);
                    cur.bump();
                } else {
                    break;
                }
            }
        }
    }
    // Type suffix (`f64`, `u32`, `usize` …).
    while let Some(a) = cur.peek() {
        if a.is_ascii_alphanumeric() || a == '_' {
            text.push(a);
            cur.bump();
        } else {
            break;
        }
    }
    text
}

fn lex_ident(cur: &mut Cur) -> String {
    let mut text = String::new();
    while let Some(a) = cur.peek() {
        if a == '_' || a.is_alphanumeric() {
            text.push(a);
            cur.bump();
        } else {
            break;
        }
    }
    text
}

/// Parse `lint:allow(RULE_ID): reason` out of a line comment body.
fn parse_suppression(comment: &str, line: u32) -> Option<Suppression> {
    let idx = comment.find("lint:allow(")?;
    let rest = &comment[idx + "lint:allow(".len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let after = rest[close + 1..].trim_start();
    let reason = match after.strip_prefix(':') {
        Some(r) => r.trim().to_string(),
        None => String::new(),
    };
    Some(Suppression { rule, reason, line, target: None })
}

fn is_punct(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i)
        .is_some_and(|t| t.kind == TokKind::Punct && t.text == text)
}

fn is_ident(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i)
        .is_some_and(|t| t.kind == TokKind::Ident && t.text == text)
}

/// Line spans of `#[cfg(test)] mod … { … }` items.
fn find_test_regions(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !(is_punct(toks, i, "#")
            && is_punct(toks, i + 1, "[")
            && is_ident(toks, i + 2, "cfg")
            && is_punct(toks, i + 3, "(")
            && is_ident(toks, i + 4, "test")
            && is_punct(toks, i + 5, ")")
            && is_punct(toks, i + 6, "]"))
        {
            i += 1;
            continue;
        }
        let start_line = toks[i].line;
        let mut j = i + 7;
        // Skip any further attribute groups before the item.
        while is_punct(toks, j, "#") && is_punct(toks, j + 1, "[") {
            let mut depth = 0usize;
            j += 1;
            while j < toks.len() {
                if is_punct(toks, j, "[") {
                    depth += 1;
                } else if is_punct(toks, j, "]") {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        if !is_ident(toks, j, "mod") {
            i += 1;
            continue;
        }
        // Find the opening brace of the mod body (a `mod x;` has none).
        let mut k = j;
        while k < toks.len() && !is_punct(toks, k, "{") && !is_punct(toks, k, ";") {
            k += 1;
        }
        if !is_punct(toks, k, "{") {
            i = k;
            continue;
        }
        let mut depth = 0usize;
        let mut end_line = u32::MAX; // unterminated: rest of file
        while k < toks.len() {
            if is_punct(toks, k, "{") {
                depth += 1;
            } else if is_punct(toks, k, "}") {
                depth -= 1;
                if depth == 0 {
                    end_line = toks[k].line;
                    break;
                }
            }
            k += 1;
        }
        out.push((start_line, end_line));
        i = k + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lex(src: &str) -> SourceFile {
        SourceFile::lex("test.rs", "test", src)
    }

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src)
            .tokens
            .into_iter()
            .map(|t| (t.kind, t.text))
            .collect()
    }

    #[test]
    fn idents_numbers_puncts() {
        let ts = kinds("let x = 1e-12;");
        assert_eq!(
            ts,
            vec![
                (TokKind::Ident, "let".into()),
                (TokKind::Ident, "x".into()),
                (TokKind::Punct, "=".into()),
                (TokKind::Num, "1e-12".into()),
                (TokKind::Punct, ";".into()),
            ]
        );
    }

    #[test]
    fn number_forms() {
        let ts = kinds("0xff_u32 1_000 2.5 1.0e-12 7f64 1..3 4.max(5)");
        let texts: Vec<&str> = ts.iter().map(|(_, s)| s.as_str()).collect();
        assert!(texts.contains(&"0xff_u32"));
        assert!(texts.contains(&"1_000"));
        assert!(texts.contains(&"2.5"));
        assert!(texts.contains(&"1.0e-12"));
        assert!(texts.contains(&"7f64"));
        // Ranges and method calls do not swallow the dot.
        assert!(texts.contains(&"1") && texts.contains(&"3"));
        assert!(texts.contains(&"4") && texts.contains(&"max"));
    }

    #[test]
    fn strings_hide_their_contents() {
        let ts = kinds(r#"let s = "HashMap Instant::now 1e-12"; x"#);
        assert!(ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .all(|(_, s)| s != "HashMap" && s != "Instant"));
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Str && s.contains("HashMap")));
    }

    #[test]
    fn raw_strings_and_escapes() {
        let ts = kinds("r#\"a \" b\"# \"esc\\\"aped\" b\"bytes\" x");
        let strs: Vec<&str> = ts
            .iter()
            .filter(|(k, _)| *k == TokKind::Str)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(strs.len(), 3, "{strs:?}");
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Ident && s == "x"));
    }

    #[test]
    fn comments_are_not_tokens() {
        let ts = kinds("a // HashMap\n/* Instant /* nested */ */ b");
        let idents: Vec<&str> = ts.iter().map(|(_, s)| s.as_str()).collect();
        assert_eq!(idents, vec!["a", "b"]);
    }

    #[test]
    fn lifetimes_vs_chars() {
        let ts = kinds("&'a str 'x' '\\n' b'z' 'static");
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Lifetime && s == "'a"));
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Char && s == "'x'"));
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Char && s == "'\\n'"));
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Char && s == "b'z'"));
        assert!(ts
            .iter()
            .any(|(k, s)| *k == TokKind::Lifetime && s == "'static"));
    }

    #[test]
    fn suppression_binds_to_next_code_line() {
        let f = lex("// lint:allow(DET001): epsilon docs\nlet x = 1;\n");
        assert_eq!(f.suppressions.len(), 1);
        let s = &f.suppressions[0];
        assert_eq!(s.rule, "DET001");
        assert_eq!(s.reason, "epsilon docs");
        assert_eq!(s.target, Some(2));
    }

    #[test]
    fn trailing_suppression_binds_to_its_own_line() {
        let f = lex("let x = 1; // lint:allow(DET002): audited\n");
        assert_eq!(f.suppressions[0].target, Some(1));
    }

    #[test]
    fn doc_comments_never_suppress() {
        let f = lex("/// Use `lint:allow(DET001): reason` to suppress.\n//! lint:allow(DET002): nope\nfn f() {}\n");
        assert!(f.suppressions.is_empty());
    }

    #[test]
    fn suppression_without_reason_is_kept_but_empty() {
        let f = lex("// lint:allow(DET003)\nfn f() {}\n");
        assert_eq!(f.suppressions[0].reason, "");
    }

    #[test]
    fn test_regions_cover_cfg_test_mods() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let f = lex(src);
        assert!(!f.in_test_code(1));
        assert!(f.in_test_code(3));
        assert!(f.in_test_code(4));
        assert!(!f.in_test_code(6));
    }

    #[test]
    fn cfg_test_on_non_mod_items_is_ignored() {
        let f = lex("#[cfg(test)]\nuse std::fmt;\nfn x() {}\n");
        assert!(!f.in_test_code(3));
    }
}
