//! The six determinism-contract rules.
//!
//! | id       | guards against                                              |
//! |----------|-------------------------------------------------------------|
//! | DET001   | raw clock-epsilon literals drifting out of sync             |
//! | DET002   | hash-order nondeterminism feeding replay state              |
//! | DET003   | wall-clock reads outside the sanctioned timing modules      |
//! | SER001   | one-way (`ToJson`-only / `FromJson`-only) snapshot types    |
//! | SER002   | snapshot schema edits without a `SNAPSHOT_VERSION` bump     |
//! | PANIC001 | the non-test `unwrap()`/`expect()` count creeping upward    |
//!
//! Per-file rules implement [`Rule::check_file`]; corpus rules
//! (pairing, fingerprints, budgets) implement [`Rule::finish`] over
//! the whole file set.

use super::config::LintConfig;
use super::lexer::{SourceFile, Tok, TokKind};
use super::{Ctx, Severity};

/// One lint rule. Stateless; all context flows through [`Ctx`].
pub trait Rule {
    /// Stable rule id (`DET001`, …) — what suppressions name.
    fn id(&self) -> &'static str;
    /// One-line description for `--explain`-style output and docs.
    fn describe(&self) -> &'static str;
    /// Per-file pass.
    fn check_file(&self, _file: &SourceFile, _cfg: &LintConfig, _ctx: &mut Ctx) {}
    /// Corpus pass, after every file has been lexed.
    fn finish(&self, _files: &[SourceFile], _cfg: &LintConfig, _ctx: &mut Ctx) {}
}

/// Every rule, in the order findings are documented.
pub fn all_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(Det001),
        Box::new(Det002),
        Box::new(Det003),
        Box::new(Ser001),
        Box::new(Ser002),
        Box::new(Panic001),
    ]
}

fn is_punct(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i)
        .is_some_and(|t| t.kind == TokKind::Punct && t.text == text)
}

fn is_ident(toks: &[Tok], i: usize, text: &str) -> bool {
    toks.get(i)
        .is_some_and(|t| t.kind == TokKind::Ident && t.text == text)
}

// ---------------------------------------------------------------- DET001

/// The one admissible clock-epsilon value; must match
/// [`crate::engine::EPS`]. Cross-checked by a unit test below so the
/// rule and the constant cannot drift apart.
const EPS_VALUE: f64 = 1e-12;

pub struct Det001;

impl Rule for Det001 {
    fn id(&self) -> &'static str {
        "DET001"
    }

    fn describe(&self) -> &'static str {
        "raw clock-epsilon literal outside the exported engine::EPS constant"
    }

    fn check_file(&self, file: &SourceFile, cfg: &LintConfig, ctx: &mut Ctx) {
        if !LintConfig::module_in(&cfg.det001_scope, &file.module)
            || LintConfig::path_matches(&cfg.det001_allow_files, &file.path)
        {
            return;
        }
        for t in &file.tokens {
            if t.kind != TokKind::Num || file.in_test_code(t.line) {
                continue;
            }
            let cleaned: String = t
                .text
                .chars()
                .filter(|c| *c != '_')
                .collect::<String>()
                .to_ascii_lowercase();
            let cleaned = cleaned.trim_end_matches("f64").trim_end_matches("f32");
            if cleaned.parse::<f64>().is_ok_and(|v| v == EPS_VALUE) {
                ctx.emit(
                    file,
                    "DET001",
                    Severity::Error,
                    t.line,
                    t.col,
                    format!(
                        "raw clock-epsilon literal `{}`: every due-time comparison \
                         must share one rounding contract",
                        t.text
                    ),
                    "use crate::engine::EPS instead of repeating the literal".to_string(),
                );
            }
        }
    }
}

// ---------------------------------------------------------------- DET002

pub struct Det002;

impl Rule for Det002 {
    fn id(&self) -> &'static str {
        "DET002"
    }

    fn describe(&self) -> &'static str {
        "hash-ordered collection in a replay-critical module"
    }

    fn check_file(&self, file: &SourceFile, cfg: &LintConfig, ctx: &mut Ctx) {
        if !LintConfig::module_in(&cfg.det002_scope, &file.module) {
            return;
        }
        for t in &file.tokens {
            if t.kind == TokKind::Ident
                && (t.text == "HashMap" || t.text == "HashSet")
                && !file.in_test_code(t.line)
            {
                ctx.emit(
                    file,
                    "DET002",
                    Severity::Error,
                    t.line,
                    t.col,
                    format!(
                        "`{}` in module `{}`: iteration order is randomized per \
                         process and can leak into replay state or snapshots",
                        t.text, file.module
                    ),
                    format!(
                        "use BTree{} (ordered), or sort at the iteration boundary \
                         and suppress with a reason",
                        &t.text[4..]
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------- DET003

pub struct Det003;

impl Rule for Det003 {
    fn id(&self) -> &'static str {
        "DET003"
    }

    fn describe(&self) -> &'static str {
        "wall-clock read outside the sanctioned timing modules"
    }

    fn check_file(&self, file: &SourceFile, cfg: &LintConfig, ctx: &mut Ctx) {
        if LintConfig::module_in(&cfg.det003_allow, &file.module) {
            return;
        }
        for t in &file.tokens {
            if t.kind == TokKind::Ident
                && (t.text == "Instant" || t.text == "SystemTime")
                && !file.in_test_code(t.line)
            {
                ctx.emit(
                    file,
                    "DET003",
                    Severity::Error,
                    t.line,
                    t.col,
                    format!(
                        "wall-clock type `{}` in module `{}`: simulated runs must \
                         be bit-identical across hosts and reruns",
                        t.text, file.module
                    ),
                    "route timing through util::bench::Stopwatch, or add the module \
                     to det003.allow if it legitimately owns wall-clock execution"
                        .to_string(),
                );
            }
        }
    }
}

// ---------------------------------------------------------------- SER001

pub struct Ser001;

/// `(type name, file index, line, col)` of one trait impl.
type ImplSite = (String, usize, u32, u32);

fn collect_impls(files: &[SourceFile], trait_name: &str) -> Vec<ImplSite> {
    let mut out = Vec::new();
    for (fi, file) in files.iter().enumerate() {
        let toks = &file.tokens;
        let mut i = 0usize;
        while i < toks.len() {
            if !is_ident(toks, i, "impl") || file.in_test_code(toks[i].line) {
                i += 1;
                continue;
            }
            let mut j = i + 1;
            if is_punct(toks, j, "<") {
                j = skip_angles(toks, j);
            }
            if !is_ident(toks, j, trait_name) || !is_ident(toks, j + 1, "for") {
                i += 1;
                continue;
            }
            // Type path after `for`: keep the last identifier of
            // `crate::foo::Bar`, ignore generic arguments.
            let mut k = j + 2;
            let mut name: Option<(String, u32, u32)> = None;
            while k < toks.len() {
                if toks[k].kind == TokKind::Ident {
                    name = Some((toks[k].text.clone(), toks[k].line, toks[k].col));
                    k += 1;
                    if is_punct(toks, k, ":") && is_punct(toks, k + 1, ":") {
                        k += 2;
                        continue;
                    }
                }
                break;
            }
            if let Some((n, line, col)) = name {
                out.push((n, fi, line, col));
            }
            i = k;
        }
    }
    out
}

/// Skip a balanced `< … >` group; `i` points at the opening `<`.
fn skip_angles(toks: &[Tok], i: usize) -> usize {
    let mut depth = 0i32;
    let mut j = i;
    while j < toks.len() {
        if is_punct(toks, j, "<") {
            depth += 1;
        } else if is_punct(toks, j, ">") {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    j
}

impl Rule for Ser001 {
    fn id(&self) -> &'static str {
        "SER001"
    }

    fn describe(&self) -> &'static str {
        "ToJson without a paired FromJson (or vice versa)"
    }

    fn finish(&self, files: &[SourceFile], cfg: &LintConfig, ctx: &mut Ctx) {
        let to = collect_impls(files, "ToJson");
        let from = collect_impls(files, "FromJson");
        let has_to: std::collections::BTreeSet<&str> =
            to.iter().map(|(n, ..)| n.as_str()).collect();
        let has_from: std::collections::BTreeSet<&str> =
            from.iter().map(|(n, ..)| n.as_str()).collect();
        let orphan = |sites: &[ImplSite],
                          other: &std::collections::BTreeSet<&str>,
                          present: &str,
                          missing: &str,
                          ctx: &mut Ctx| {
            for (name, fi, line, col) in sites {
                if other.contains(name.as_str())
                    || cfg.ser001_allow.iter().any(|a| a == name)
                {
                    continue;
                }
                ctx.emit(
                    &files[*fi],
                    "SER001",
                    Severity::Error,
                    *line,
                    *col,
                    format!(
                        "`{name}` implements {present} but not {missing}: \
                         snapshots containing it cannot round-trip"
                    ),
                    format!(
                        "add `impl {missing} for {name}`, or suppress with a reason \
                         if one-way serialization is intended"
                    ),
                );
            }
        };
        orphan(&to, &has_from, "ToJson", "FromJson", ctx);
        orphan(&from, &has_to, "FromJson", "ToJson", ctx);
    }
}

// ---------------------------------------------------------------- SER002

pub struct Ser002;

/// FNV-1a 64-bit over `bytes`. Chosen because it is trivial to
/// re-implement anywhere (CI scripts, other languages) and stable
/// forever — this hash is persisted in source as the schema
/// fingerprint, so it must never change.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Extract `struct name { … }` field lists as `(field, type)` pairs,
/// with type tokens space-joined. `None` when the struct is missing
/// or not a braced struct.
fn struct_fields(file: &SourceFile, name: &str) -> Option<Vec<(String, String)>> {
    let t = &file.tokens;
    let mut i = 0usize;
    while i + 1 < t.len() {
        if !(is_ident(t, i, "struct") && is_ident(t, i + 1, name) && !file.in_test_code(t[i].line))
        {
            i += 1;
            continue;
        }
        let mut j = i + 2;
        if is_punct(t, j, "<") {
            j = skip_angles(t, j);
        }
        if !is_punct(t, j, "{") {
            return None; // tuple or unit struct: not snapshot material
        }
        j += 1;
        let mut fields = Vec::new();
        loop {
            // Skip field attributes.
            while is_punct(t, j, "#") && is_punct(t, j + 1, "[") {
                let mut depth = 0i32;
                j += 1;
                while j < t.len() {
                    if is_punct(t, j, "[") {
                        depth += 1;
                    } else if is_punct(t, j, "]") {
                        depth -= 1;
                        if depth == 0 {
                            j += 1;
                            break;
                        }
                    }
                    j += 1;
                }
            }
            if j >= t.len() || is_punct(t, j, "}") {
                break;
            }
            // Skip visibility (`pub`, `pub(crate)`).
            if is_ident(t, j, "pub") {
                j += 1;
                if is_punct(t, j, "(") {
                    let mut depth = 0i32;
                    while j < t.len() {
                        if is_punct(t, j, "(") {
                            depth += 1;
                        } else if is_punct(t, j, ")") {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        j += 1;
                    }
                }
            }
            if t.get(j).map(|x| x.kind) != Some(TokKind::Ident) {
                return None;
            }
            let fname = t[j].text.clone();
            j += 1;
            if !is_punct(t, j, ":") {
                return None;
            }
            j += 1;
            // Type tokens until a top-level `,` or the closing `}`.
            let mut ty: Vec<&str> = Vec::new();
            let mut depth = 0i32;
            let mut angle = 0i32;
            while j < t.len() {
                let tok = &t[j];
                if tok.kind == TokKind::Punct {
                    match tok.text.as_str() {
                        "(" | "[" | "{" => depth += 1,
                        ")" | "]" => depth -= 1,
                        "}" => {
                            if depth == 0 {
                                break;
                            }
                            depth -= 1;
                        }
                        "<" => angle += 1,
                        ">" => angle = (angle - 1).max(0),
                        "," => {
                            if depth == 0 && angle == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                }
                ty.push(tok.text.as_str());
                j += 1;
            }
            fields.push((fname, ty.join(" ")));
            if is_punct(t, j, ",") {
                j += 1;
            }
        }
        return Some(fields);
    }
    None
}

/// Canonical schema string for the watched structs, in watch order:
/// one `Name{field:type;field:type}` line per struct, `\n`-joined.
/// Returns `Err(struct name)` for the first watched struct that
/// cannot be extracted.
fn canonical_schema(
    files: &[SourceFile],
    cfg: &LintConfig,
) -> std::result::Result<String, (String, String)> {
    let mut parts = Vec::new();
    for (suffix, name) in &cfg.ser002_watch {
        let entry = [suffix.clone()];
        let file = files
            .iter()
            .find(|f| LintConfig::path_matches(&entry, &f.path))
            .ok_or_else(|| (suffix.clone(), name.clone()))?;
        let fields =
            struct_fields(file, name).ok_or_else(|| (file.path.clone(), name.clone()))?;
        let body: Vec<String> = fields
            .into_iter()
            .map(|(f, ty)| format!("{f}:{ty}"))
            .collect();
        parts.push(format!("{name}{{{}}}", body.join(";")));
    }
    Ok(parts.join("\n"))
}

/// The expected fingerprint constant value for the current sources,
/// `"v{SNAPSHOT_VERSION}:{fnv1a64 hex}"`. Public so the fixture
/// harness (and a re-record helper) can compute it the same way the
/// rule does. `None` when the schema file or version const is absent
/// from `files`.
pub fn expected_fingerprint(files: &[SourceFile], cfg: &LintConfig) -> Option<String> {
    if cfg.ser002_file.is_empty() {
        return None;
    }
    let entry = [cfg.ser002_file.clone()];
    let schema = files
        .iter()
        .find(|f| LintConfig::path_matches(&entry, &f.path))?;
    let version = find_const_num(schema, "SNAPSHOT_VERSION")?;
    let canon = canonical_schema(files, cfg).ok()?;
    Some(format!("v{version}:{:016x}", fnv1a64(canon.as_bytes())))
}

/// First `NAME … = <number>` token sequence; returns the literal's
/// integer value.
fn find_const_num(file: &SourceFile, name: &str) -> Option<u64> {
    let t = &file.tokens;
    for i in 0..t.len() {
        if !is_ident(t, i, name) {
            continue;
        }
        for j in i + 1..(i + 6).min(t.len()) {
            if is_punct(t, j, "=") {
                let lit = t.get(j + 1)?;
                if lit.kind == TokKind::Num {
                    let digits: String =
                        lit.text.chars().take_while(|c| c.is_ascii_digit()).collect();
                    return digits.parse().ok();
                }
                return None;
            }
        }
        return None;
    }
    None
}

/// First `NAME … = "string"` token sequence; returns the unquoted
/// value and its position.
fn find_const_str(file: &SourceFile, name: &str) -> Option<(String, u32, u32)> {
    let t = &file.tokens;
    for i in 0..t.len() {
        if !is_ident(t, i, name) {
            continue;
        }
        for j in i + 1..(i + 8).min(t.len()) {
            if is_punct(t, j, "=") {
                let lit = t.get(j + 1)?;
                if lit.kind == TokKind::Str && lit.text.len() >= 2 {
                    let inner = lit.text[1..lit.text.len() - 1].to_string();
                    return Some((inner, lit.line, lit.col));
                }
                return None;
            }
        }
        return None;
    }
    None
}

impl Rule for Ser002 {
    fn id(&self) -> &'static str {
        "SER002"
    }

    fn describe(&self) -> &'static str {
        "snapshot field lists changed without a SNAPSHOT_VERSION bump"
    }

    fn finish(&self, files: &[SourceFile], cfg: &LintConfig, ctx: &mut Ctx) {
        if cfg.ser002_file.is_empty() {
            return;
        }
        let entry = [cfg.ser002_file.clone()];
        let Some(schema) = files
            .iter()
            .find(|f| LintConfig::path_matches(&entry, &f.path))
        else {
            // Partial lint run that does not include the schema file:
            // nothing to check against.
            return;
        };
        let Some(version) = find_const_num(schema, "SNAPSHOT_VERSION") else {
            ctx.emit(
                schema,
                "SER002",
                Severity::Error,
                1,
                1,
                format!("`SNAPSHOT_VERSION` const not found in {}", schema.path),
                "declare `pub const SNAPSHOT_VERSION: u64 = …;` next to the snapshot \
                 structs"
                    .to_string(),
            );
            return;
        };
        let canon = match canonical_schema(files, cfg) {
            Ok(c) => c,
            Err((where_, name)) => {
                // A watched file missing from a partial lint run is not
                // an error; a watched struct missing from its file is.
                if files.iter().any(|f| {
                    LintConfig::path_matches(&[where_.clone()], &f.path) || f.path == where_
                }) {
                    ctx.emit(
                        schema,
                        "SER002",
                        Severity::Error,
                        1,
                        1,
                        format!("watched snapshot struct `{name}` not found in {where_}"),
                        "fix ser002.watch in lint.conf or restore the struct".to_string(),
                    );
                }
                return;
            }
        };
        let expected = format!("v{version}:{:016x}", fnv1a64(canon.as_bytes()));
        match find_const_str(schema, "SNAPSHOT_FIELDS_FINGERPRINT") {
            None => ctx.emit(
                schema,
                "SER002",
                Severity::Error,
                1,
                1,
                "snapshot schema fingerprint is not recorded: field-list edits would \
                 go unnoticed"
                    .to_string(),
                format!(
                    "declare `pub const SNAPSHOT_FIELDS_FINGERPRINT: &str = \
                     \"{expected}\";` next to SNAPSHOT_VERSION"
                ),
            ),
            Some((recorded, line, col)) => {
                if recorded != expected {
                    ctx.emit(
                        schema,
                        "SER002",
                        Severity::Error,
                        line,
                        col,
                        format!(
                            "snapshot field lists changed: fingerprint is \"{recorded}\" \
                             but sources hash to \"{expected}\""
                        ),
                        format!(
                            "bump SNAPSHOT_VERSION (with a migration note) if the schema \
                             really changed, then set SNAPSHOT_FIELDS_FINGERPRINT to \
                             \"{expected}\""
                        ),
                    );
                }
            }
        }
    }
}

// -------------------------------------------------------------- PANIC001

pub struct Panic001;

impl Rule for Panic001 {
    fn id(&self) -> &'static str {
        "PANIC001"
    }

    fn describe(&self) -> &'static str {
        "non-test unwrap()/expect() count above the ratcheted budget"
    }

    fn finish(&self, files: &[SourceFile], cfg: &LintConfig, ctx: &mut Ctx) {
        for (scope, budget) in &cfg.panic_budgets {
            let scope_vec = [scope.clone()];
            let mut count = 0usize;
            let mut last: Option<(usize, u32, u32)> = None;
            for (fi, file) in files.iter().enumerate() {
                if !LintConfig::module_in(&scope_vec, &file.module) {
                    continue;
                }
                let toks = &file.tokens;
                for i in 0..toks.len() {
                    let hit = is_punct(toks, i, ".")
                        && (is_ident(toks, i + 1, "unwrap") || is_ident(toks, i + 1, "expect"))
                        && is_punct(toks, i + 2, "(");
                    if !hit {
                        continue;
                    }
                    let site = &toks[i + 1];
                    if file.in_test_code(site.line) {
                        continue;
                    }
                    // A suppressed site is excluded from the count (and
                    // the suppression registers as used).
                    if ctx.site_allowed(file, "PANIC001", site.line) {
                        continue;
                    }
                    count += 1;
                    last = Some((fi, site.line, site.col));
                }
            }
            if count > *budget {
                if let Some((fi, line, col)) = last {
                    ctx.emit_unsuppressable(
                        &files[fi],
                        "PANIC001",
                        Severity::Error,
                        line,
                        col,
                        format!(
                            "module `{scope}` has {count} non-test unwrap()/expect() \
                             call(s); the ratcheted budget is {budget}"
                        ),
                        "convert new sites to `?`/match, suppress individual audited \
                         sites with a reason, or raise panic.budget in lint.conf when \
                         the ratchet legitimately moves"
                            .to_string(),
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_epsilon_matches_engine_eps() {
        // DET001 exists to keep every epsilon equal to engine::EPS; the
        // rule's own notion of the value must therefore match it.
        assert_eq!(EPS_VALUE, crate::engine::EPS);
    }

    #[test]
    fn fnv1a64_reference_vectors() {
        // Published FNV-1a test vectors: the fingerprint format is
        // persisted in source, so the hash must never drift.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn struct_fields_extracts_types_verbatim() {
        let src = "pub struct S {\n    pub a: f64,\n    #[allow(dead_code)]\n    b: Vec<(usize, String)>,\n    pub(crate) c: Option<Box<S>>,\n}\n";
        let f = SourceFile::lex("x.rs", "x", src);
        let fields = struct_fields(&f, "S").unwrap();
        assert_eq!(
            fields,
            vec![
                ("a".to_string(), "f64".to_string()),
                ("b".to_string(), "Vec < ( usize , String ) >".to_string()),
                ("c".to_string(), "Option < Box < S > >".to_string()),
            ]
        );
    }

    #[test]
    fn struct_fields_ignores_test_doubles_and_other_structs() {
        let src = "struct Other { x: u8 }\n#[cfg(test)]\nmod tests {\n    struct S { y: u8 }\n}\nstruct S { z: u16 }\n";
        let f = SourceFile::lex("x.rs", "x", src);
        let fields = struct_fields(&f, "S").unwrap();
        assert_eq!(fields, vec![("z".to_string(), "u16".to_string())]);
    }

    #[test]
    fn const_extractors() {
        let src = "pub const SNAPSHOT_VERSION: u64 = 2;\npub const SNAPSHOT_FIELDS_FINGERPRINT: &str = \"v2:dead\";\n";
        let f = SourceFile::lex("x.rs", "x", src);
        assert_eq!(find_const_num(&f, "SNAPSHOT_VERSION"), Some(2));
        let (s, line, _) = find_const_str(&f, "SNAPSHOT_FIELDS_FINGERPRINT").unwrap();
        assert_eq!(s, "v2:dead");
        assert_eq!(line, 2);
    }
}
