//! `asyncflow lint` — a zero-dependency determinism-contract linter
//! for this repo's own sources.
//!
//! The engine's headline guarantee is bit-for-bit replay: the same
//! workload produces the same event trace, the same snapshots, the
//! same metrics, on any host, any number of times. That guarantee is
//! easy to break with one innocent-looking line — a stray `1e-12`, a
//! `HashMap` iterated into a snapshot, an `Instant::now()` in the
//! simulation path — and none of those break a unit test the day they
//! land. This module encodes the contract as six mechanical rules
//! (see [`rules`]) and runs them over the token stream of every
//! source file, so violations fail CI instead of surfacing weeks
//! later as an unreproducible trace divergence.
//!
//! Design choices:
//!
//! - **Token-level, not AST-level.** A hand-rolled lexer
//!   ([`lexer::SourceFile`]) understands comments, strings, char
//!   literals and `#[cfg(test)]` regions — enough to never report a
//!   match inside a comment or test helper, without dragging in a
//!   parser dependency (the crate builds with zero external deps).
//! - **Suppressions carry evidence.** `// lint:allow(RULE): reason`
//!   silences one finding on the line it covers; the reason is
//!   mandatory (LINT001) and unused suppressions are themselves
//!   findings (LINT002), so the suppression inventory stays an
//!   auditable list of known, justified exceptions.
//! - **Findings are data.** `--format ndjson` emits one JSON object
//!   per finding for CI artifacts; the human format renders
//!   `file:line:col`, the message, and a concrete fix suggestion.

mod config;
mod lexer;
mod rules;

pub use config::LintConfig;
pub use lexer::{SourceFile, Suppression, Tok, TokKind};
pub use rules::{all_rules, expected_fingerprint, fnv1a64, Rule};

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::{obj, Json};

/// Finding severity. `--deny` fails on *any* finding; severity only
/// affects presentation and lets downstream tooling triage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn label(&self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One lint finding with a span and a concrete fix suggestion.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: String,
    pub severity: Severity,
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub message: String,
    pub suggestion: String,
}

impl Finding {
    /// `file:line:col severity[RULE]: message` + an indented help line.
    pub fn render_human(&self) -> String {
        let mut s = format!(
            "{}:{}:{} {}[{}]: {}",
            self.file,
            self.line,
            self.col,
            self.severity.label(),
            self.rule,
            self.message
        );
        if !self.suggestion.is_empty() {
            s.push_str("\n    help: ");
            s.push_str(&self.suggestion);
        }
        s
    }

    /// One NDJSON record (compact JSON, one line).
    pub fn to_json(&self) -> Json {
        obj([
            ("rule", Json::from(self.rule.clone())),
            ("severity", Json::from(self.severity.label())),
            ("file", Json::from(self.file.clone())),
            ("line", Json::from(self.line as usize)),
            ("col", Json::from(self.col as usize)),
            ("message", Json::from(self.message.clone())),
            ("suggestion", Json::from(self.suggestion.clone())),
        ])
    }
}

/// Shared rule context: accumulates findings and tracks which
/// suppressions actually fired.
pub struct Ctx {
    findings: Vec<Finding>,
    /// `(file path, suppression index)` pairs that suppressed (or
    /// excluded from a count) at least one site.
    used: BTreeSet<(String, usize)>,
}

impl Ctx {
    fn new() -> Ctx {
        Ctx { findings: Vec::new(), used: BTreeSet::new() }
    }

    /// Record a finding unless a valid suppression covers `line`.
    #[allow(clippy::too_many_arguments)]
    pub fn emit(
        &mut self,
        file: &SourceFile,
        rule: &str,
        severity: Severity,
        line: u32,
        col: u32,
        message: String,
        suggestion: String,
    ) {
        if self.site_allowed(file, rule, line) {
            return;
        }
        self.emit_unsuppressable(file, rule, severity, line, col, message, suggestion);
    }

    /// Record a finding that inline suppressions cannot silence
    /// (aggregate findings like PANIC001, whose *sites* are the
    /// suppressable unit).
    #[allow(clippy::too_many_arguments)]
    pub fn emit_unsuppressable(
        &mut self,
        file: &SourceFile,
        rule: &str,
        severity: Severity,
        line: u32,
        col: u32,
        message: String,
        suggestion: String,
    ) {
        self.findings.push(Finding {
            rule: rule.to_string(),
            severity,
            file: file.path.clone(),
            line,
            col,
            message,
            suggestion,
        });
    }

    /// Whether a valid (reason-carrying) suppression for `rule` covers
    /// `line`; marks it used. Rules that count sites (PANIC001) call
    /// this directly to exclude audited sites.
    pub fn site_allowed(&mut self, file: &SourceFile, rule: &str, line: u32) -> bool {
        for (i, s) in file.suppressions.iter().enumerate() {
            if s.rule == rule && s.target == Some(line) && !s.reason.is_empty() {
                self.used.insert((file.path.clone(), i));
                return true;
            }
        }
        false
    }
}

/// Crate-relative module path for a source file: components after the
/// last `src` (or `lint_fixtures`, for the test corpus) marker, with
/// `mod.rs`/`lib.rs`/`main.rs` collapsing into their parent.
///
/// `src/engine/coordinator.rs` → `engine::coordinator`;
/// `src/engine/mod.rs` → `engine`; `src/lib.rs` → `` (crate root).
pub fn module_of(path: &str) -> String {
    let norm = path.replace('\\', "/");
    let comps: Vec<&str> = norm.split('/').filter(|c| !c.is_empty()).collect();
    let start = comps
        .iter()
        .rposition(|c| *c == "src" || *c == "lint_fixtures")
        .map(|i| i + 1)
        .unwrap_or(comps.len().saturating_sub(1));
    let mut parts: Vec<&str> = comps[start..].to_vec();
    if let Some(last) = parts.last_mut() {
        *last = last.strip_suffix(".rs").unwrap_or(last);
    }
    if matches!(parts.last().copied(), Some("mod") | Some("lib") | Some("main")) {
        parts.pop();
    }
    parts.join("::")
}

/// Run every rule over `files`, then audit the suppression inventory.
/// Findings come back sorted by `(file, line, col, rule)`.
pub fn lint_files(files: &[SourceFile], cfg: &LintConfig) -> Vec<Finding> {
    let mut ctx = Ctx::new();
    let rules = all_rules();
    for rule in &rules {
        for f in files {
            rule.check_file(f, cfg, &mut ctx);
        }
        rule.finish(files, cfg, &mut ctx);
    }
    // Suppression hygiene: every `lint:allow` must name a real rule,
    // carry a reason, attach to code, and actually fire.
    for f in files {
        for (i, s) in f.suppressions.iter().enumerate() {
            let known = rules.iter().any(|r| r.id() == s.rule);
            let (rule, severity, message, suggestion) = if s.reason.is_empty() {
                (
                    "LINT001",
                    Severity::Error,
                    format!(
                        "suppression for {} has no reason: write \
                         `lint:allow({}): <why this site is safe>`",
                        s.rule, s.rule
                    ),
                    "every suppression must explain itself; the inventory of \
                     exceptions is part of the determinism contract"
                        .to_string(),
                )
            } else if !known {
                (
                    "LINT001",
                    Severity::Error,
                    format!("suppression names unknown rule `{}`", s.rule),
                    "valid rule ids: DET001, DET002, DET003, SER001, SER002, PANIC001"
                        .to_string(),
                )
            } else if s.target.is_none() {
                (
                    "LINT001",
                    Severity::Error,
                    format!("suppression for {} attaches to no code line", s.rule),
                    "place it on, or directly above, the line it covers".to_string(),
                )
            } else if !ctx.used.contains(&(f.path.clone(), i)) {
                (
                    "LINT002",
                    Severity::Warning,
                    format!("unused suppression for {}: nothing fires here", s.rule),
                    "delete it (stale suppressions hide future regressions)".to_string(),
                )
            } else {
                continue;
            };
            ctx.findings.push(Finding {
                rule: rule.to_string(),
                severity,
                file: f.path.clone(),
                line: s.line,
                col: 1,
                message,
                suggestion,
            });
        }
    }
    let mut out = ctx.findings;
    out.sort_by(|a, b| {
        (&a.file, a.line, a.col, &a.rule).cmp(&(&b.file, b.line, b.col, &b.rule))
    });
    out
}

/// Lex one file from disk (path recorded as given).
pub fn lex_path(path: &Path) -> Result<SourceFile> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Config(format!("lint: cannot read {}: {e}", path.display())))?;
    let p = path.to_string_lossy().replace('\\', "/");
    let module = module_of(&p);
    Ok(SourceFile::lex(p, module, &text))
}

/// Lint files and/or directories (recursing into directories for
/// `.rs` files, in sorted order so output is stable).
pub fn lint_paths(paths: &[String], cfg: &LintConfig) -> Result<Vec<Finding>> {
    let mut rs_files: Vec<PathBuf> = Vec::new();
    for p in paths {
        let path = PathBuf::from(p);
        if path.is_dir() {
            collect_rs(&path, &mut rs_files)?;
        } else {
            rs_files.push(path);
        }
    }
    rs_files.sort();
    rs_files.dedup();
    let mut files = Vec::with_capacity(rs_files.len());
    for p in &rs_files {
        files.push(lex_path(p)?);
    }
    Ok(lint_files(&files, cfg))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| Error::Config(format!("lint: cannot read dir {}: {e}", dir.display())))?;
    for entry in entries {
        let entry = entry.map_err(Error::Io)?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str, module: &str, cfg: &LintConfig) -> Vec<Finding> {
        let f = SourceFile::lex(format!("src/{}.rs", module.replace("::", "/")), module, src);
        lint_files(&[f], cfg)
    }

    #[test]
    fn module_of_maps_paths() {
        assert_eq!(module_of("rust/src/engine/coordinator.rs"), "engine::coordinator");
        assert_eq!(module_of("src/engine/mod.rs"), "engine");
        assert_eq!(module_of("src/lib.rs"), "");
        assert_eq!(module_of("src/main.rs"), "");
        assert_eq!(module_of("tests/lint_fixtures/engine/det001_bad.rs"), "engine::det001_bad");
        assert_eq!(module_of("standalone.rs"), "standalone");
    }

    #[test]
    fn det001_fires_and_suppression_silences() {
        let cfg = LintConfig::default();
        let bad = run("fn f(a: f64, b: f64) -> bool { a + 1e-12 > b }", "engine::x", &cfg);
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert_eq!(bad[0].rule, "DET001");

        let ok = run(
            "// lint:allow(DET001): doc example, not a comparison\n\
             fn f(a: f64, b: f64) -> bool { a + 1e-12 > b }",
            "engine::x",
            &cfg,
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn suppression_without_reason_is_lint001_and_does_not_silence() {
        let cfg = LintConfig::default();
        let out = run(
            "// lint:allow(DET001)\nfn f(a: f64) -> bool { a > 1e-12 }",
            "engine::x",
            &cfg,
        );
        let rules: Vec<&str> = out.iter().map(|f| f.rule.as_str()).collect();
        assert!(rules.contains(&"DET001"), "{out:?}");
        assert!(rules.contains(&"LINT001"), "{out:?}");
    }

    #[test]
    fn unused_suppression_is_lint002() {
        let cfg = LintConfig::default();
        let out = run("// lint:allow(DET002): just in case\nfn f() {}", "engine::x", &cfg);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, "LINT002");
        assert_eq!(out[0].severity, Severity::Warning);
    }

    #[test]
    fn unknown_rule_id_is_lint001() {
        let cfg = LintConfig::default();
        let out = run("// lint:allow(DET999): nope\nfn f() {}", "engine::x", &cfg);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, "LINT001");
    }

    #[test]
    fn findings_render_and_serialize() {
        let f = Finding {
            rule: "DET001".into(),
            severity: Severity::Error,
            file: "src/engine/x.rs".into(),
            line: 3,
            col: 7,
            message: "raw epsilon".into(),
            suggestion: "use EPS".into(),
        };
        assert_eq!(
            f.render_human(),
            "src/engine/x.rs:3:7 error[DET001]: raw epsilon\n    help: use EPS"
        );
        let j = f.to_json().to_string();
        assert!(j.contains("\"rule\":\"DET001\""), "{j}");
        assert!(j.contains("\"line\":3"), "{j}");
        assert!(!j.contains('\n'), "NDJSON records must be single-line: {j}");
    }

    #[test]
    fn out_of_scope_modules_are_untouched() {
        let cfg = LintConfig::default();
        let out = run(
            "use std::collections::HashMap;\nfn f() -> f64 { 1e-12 }",
            "util::stats",
            &cfg,
        );
        assert!(out.is_empty(), "{out:?}");
    }
}
