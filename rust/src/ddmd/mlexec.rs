//! Real ML executor: DeepDriveMD task bodies backed by the PJRT
//! runtime. Every task runs on its own thread and calls the compiled
//! JAX/Pallas artifacts through a [`RuntimeHandle`] — the full L3 -> L2
//! -> L1 path with Python nowhere in sight.
//!
//! Data flow (mirrors DeepDriveMD):
//! - **Simulation** advances Lennard-Jones MD (`md_step`), featurizes
//!   each chunk into a contact-map row (`contact_map`) and deposits
//!   frames in the shared store;
//! - **Aggregation** drains frames into fixed-size training batches;
//! - **Training** runs `ae_train` SGD steps over batches, updating the
//!   shared autoencoder parameters and logging the loss curve;
//! - **Inference** scores batches with `ae_infer` (reconstruction
//!   error), records outlier statistics, and perturbs the seed
//!   coordinates of the worst offenders (driving the next iteration's
//!   sampling, like DeepDriveMD's outlier-guided restarts).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::exec::{Completion, Executor, RunningTask};
use crate::runtime::{RuntimeHandle, Tensor};
use crate::task::TaskKind;
use crate::util::rng::Rng;

/// Model geometry (must match `python/compile/model.py` / the manifest).
pub const N_ATOMS: usize = 64;
pub const INPUT_DIM: usize = N_ATOMS * N_ATOMS;
pub const BATCH: usize = 32;
pub const LATENT: usize = 16;
const PARAM_DIMS: [(&str, &[usize]); 8] = [
    ("w1", &[INPUT_DIM, 256]),
    ("b1", &[256]),
    ("w2", &[256, LATENT]),
    ("b2", &[LATENT]),
    ("w3", &[LATENT, 256]),
    ("b3", &[256]),
    ("w4", &[256, INPUT_DIM]),
    ("b4", &[INPUT_DIM]),
];

/// Shared DeepDriveMD state.
#[derive(Debug)]
pub struct DdmdStore {
    /// Featurized frames waiting for aggregation.
    pub frames: Vec<Vec<f32>>,
    /// Training batches (each [BATCH, INPUT_DIM]).
    pub batches: Vec<Tensor>,
    /// Autoencoder parameters (8 tensors).
    pub params: Vec<Tensor>,
    /// Loss curve (step, loss) across all Training tasks.
    pub losses: Vec<(usize, f32)>,
    /// Outlier scores from Inference tasks.
    pub scores: Vec<f32>,
    /// Per-simulation seed state (coords, vels), keyed round-robin.
    pub md_state: Vec<(Tensor, Tensor)>,
    /// Monotone counters.
    pub train_steps_done: usize,
    pub frames_produced: usize,
    rng: Rng,
}

impl DdmdStore {
    pub fn new(seed: u64) -> DdmdStore {
        let mut rng = Rng::new(seed);
        // He-init parameters (matches model.init_params semantics).
        let params = PARAM_DIMS
            .iter()
            .map(|(_, dims)| {
                let n: usize = dims.iter().product();
                let data = if dims.len() == 2 {
                    let scale = (2.0 / dims[0] as f64).sqrt();
                    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
                } else {
                    vec![0.0f32; n]
                };
                Tensor::from_vec(data, dims).unwrap()
            })
            .collect();
        DdmdStore {
            frames: vec![],
            batches: vec![],
            params,
            losses: vec![],
            scores: vec![],
            md_state: vec![],
            train_steps_done: 0,
            frames_produced: 0,
            rng,
        }
    }

    /// Fresh MD seed: a jittered cubic lattice (physically reasonable).
    fn fresh_md_state(&mut self) -> (Tensor, Tensor) {
        let side = (N_ATOMS as f64).powf(1.0 / 3.0).ceil() as usize;
        let spacing = 1.2f32;
        let mut coords = Vec::with_capacity(N_ATOMS * 3);
        'outer: for i in 0..side {
            for j in 0..side {
                for k in 0..side {
                    if coords.len() >= N_ATOMS * 3 {
                        break 'outer;
                    }
                    coords.push(i as f32 * spacing + 0.05 * self.rng.normal() as f32);
                    coords.push(j as f32 * spacing + 0.05 * self.rng.normal() as f32);
                    coords.push(k as f32 * spacing + 0.05 * self.rng.normal() as f32);
                }
            }
        }
        let vels = vec![0.0f32; N_ATOMS * 3];
        (
            Tensor::from_vec(coords, &[N_ATOMS, 3]).unwrap(),
            Tensor::from_vec(vels, &[N_ATOMS, 3]).unwrap(),
        )
    }

    fn take_md_state(&mut self, slot: usize) -> (Tensor, Tensor) {
        while self.md_state.len() <= slot {
            let s = self.fresh_md_state();
            self.md_state.push(s);
        }
        self.md_state[slot].clone()
    }
}

/// Executor running DeepDriveMD bodies on real threads + PJRT.
pub struct MlExecutor {
    runtime: RuntimeHandle,
    store: Arc<Mutex<DdmdStore>>,
    epoch: Instant,
    tx_chan: Sender<(usize, bool)>,
    rx_chan: Receiver<(usize, bool)>,
    in_flight: usize,
    lr: f32,
    next_slot: usize,
}

impl MlExecutor {
    pub fn new(runtime: RuntimeHandle, seed: u64) -> MlExecutor {
        let (tx_chan, rx_chan) = channel();
        MlExecutor {
            runtime,
            store: Arc::new(Mutex::new(DdmdStore::new(seed))),
            epoch: Instant::now(),
            tx_chan,
            rx_chan,
            in_flight: 0,
            lr: 0.005,
            next_slot: 0,
        }
    }

    pub fn store(&self) -> Arc<Mutex<DdmdStore>> {
        Arc::clone(&self.store)
    }
}

impl Executor for MlExecutor {
    fn launch(&mut self, task: &RunningTask) {
        let uid = task.uid;
        let kind = task.kind.unwrap_or(TaskKind::Stress);
        let runtime = self.runtime.clone();
        let store = Arc::clone(&self.store);
        let chan = self.tx_chan.clone();
        let lr = self.lr;
        let nominal_tx = task.tx;
        let slot = self.next_slot;
        self.next_slot += 1;
        self.in_flight += 1;
        std::thread::spawn(move || {
            let ok = run_body(&kind, &runtime, &store, lr, nominal_tx, slot).is_ok();
            let _ = chan.send((uid, !ok));
        });
    }

    fn wait_next(&mut self) -> Option<Completion> {
        if self.in_flight == 0 {
            return None;
        }
        let (uid, failed) = self.rx_chan.recv().ok()?;
        self.in_flight -= 1;
        Some(Completion { uid, finished_at: self.now(), failed })
    }

    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }
}

fn run_body(
    kind: &TaskKind,
    rt: &RuntimeHandle,
    store: &Arc<Mutex<DdmdStore>>,
    lr: f32,
    nominal_tx: f64,
    slot: usize,
) -> crate::error::Result<()> {
    match kind {
        TaskKind::MdSimulation { chunks } => {
            let (mut coords, mut vels) = store.lock().unwrap().take_md_state(slot % 64);
            for _ in 0..*chunks {
                let out = rt.execute("md_step", vec![coords.clone(), vels.clone()])?;
                coords = out[0].clone();
                vels = out[1].clone();
                let feat = rt.execute("contact_map", vec![coords.clone()])?;
                let mut st = store.lock().unwrap();
                st.frames.push(feat[0].data.clone());
                st.frames_produced += 1;
            }
            let mut st = store.lock().unwrap();
            let slot = slot % 64;
            while st.md_state.len() <= slot {
                let s = st.fresh_md_state();
                st.md_state.push(s);
            }
            st.md_state[slot] = (coords, vels);
            Ok(())
        }
        TaskKind::Aggregation => {
            let mut st = store.lock().unwrap();
            while st.frames.len() >= BATCH {
                let rows: Vec<Vec<f32>> = st.frames.drain(..BATCH).collect();
                let mut data = Vec::with_capacity(BATCH * INPUT_DIM);
                for r in rows {
                    data.extend(r);
                }
                st.batches
                    .push(Tensor::from_vec(data, &[BATCH, INPUT_DIM]).unwrap());
            }
            Ok(())
        }
        TaskKind::Training { steps } => {
            for s in 0..*steps {
                let (params, batch) = {
                    let st = store.lock().unwrap();
                    if st.batches.is_empty() {
                        // Nothing to train on yet (dependency guarantees
                        // usually prevent this; tolerate gracefully).
                        return Ok(());
                    }
                    let b = st.batches[(st.train_steps_done + s) % st.batches.len()].clone();
                    (st.params.clone(), b)
                };
                let mut inputs = params;
                inputs.push(batch);
                inputs.push(Tensor::scalar(lr));
                let out = rt.execute("ae_train", inputs)?;
                let mut st = store.lock().unwrap();
                let loss = out[8].data[0];
                st.params = out[..8].to_vec();
                st.train_steps_done += 1;
                let step = st.train_steps_done;
                st.losses.push((step, loss));
            }
            Ok(())
        }
        TaskKind::Inference => {
            let (params, batch) = {
                let st = store.lock().unwrap();
                if st.batches.is_empty() {
                    return Ok(());
                }
                let b = st.batches[st.scores.len() % st.batches.len()].clone();
                (st.params.clone(), b)
            };
            let mut inputs = params;
            inputs.push(batch);
            let out = rt.execute("ae_infer", inputs)?;
            let mut st = store.lock().unwrap();
            st.scores.extend(out[0].data.iter().copied());
            // Outlier-guided restart: perturb the seed state of the slot
            // with the worst reconstruction (novel conformation).
            if let Some((worst, _)) = out[0]
                .data
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
            {
                let jitter: Vec<f32> =
                    (0..N_ATOMS * 3).map(|_| 0.02 * st.rng.normal() as f32).collect();
                let k = worst % st.md_state.len().max(1);
                if k < st.md_state.len() {
                    for (c, j) in st.md_state[k].0.data.iter_mut().zip(&jitter) {
                        *c += j;
                    }
                }
            }
            Ok(())
        }
        TaskKind::Stress => {
            // Fallback: behave like a stress task at 1:100 scale.
            std::thread::sleep(std::time::Duration::from_secs_f64(nominal_tx * 0.01));
            Ok(())
        }
    }
}
