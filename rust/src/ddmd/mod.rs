//! DeepDriveMD (substrate S15): the paper's contribution #1 — an
//! asynchronous implementation of the ML-driven molecular-dynamics
//! ensemble workflow of Brace et al. (IPDPS 2022).
//!
//! Four task types per iteration: Simulation -> Aggregation -> Training
//! -> Inference (Table 1). The sequential realization is one pipeline of
//! `4 x iterations` stages; the asynchronous realization runs one
//! pipeline per iteration, multiplexed on a single pilot (the GPU-bound
//! Simulation sets stagger on resource contention, yielding Fig. 3a's
//! three independent chains and WLA = 1 on the Summit allocation).
//!
//! For *real* execution (`mlexec::MlExecutor`, behind the `pjrt`
//! feature) the four task bodies
//! invoke the AOT-compiled JAX/Pallas artifacts (MD, featurization,
//! autoencoder training/inference) through the PJRT runtime.

#[cfg(feature = "pjrt")]
pub mod mlexec;

use crate::dag::Dag;
use crate::entk::{Pipeline, Workflow};
use crate::resources::ResourceRequest;
use crate::task::{TaskKind, TaskSetSpec};

/// Per-task-type parameters (one row of Table 1).
#[derive(Debug, Clone, Copy)]
pub struct TaskTypeSpec {
    pub tasks: u32,
    pub cores: u32,
    pub gpus: u32,
    pub tx: f64,
}

/// DeepDriveMD workflow parameters.
#[derive(Debug, Clone)]
pub struct DdmdConfig {
    pub iterations: usize,
    pub simulation: TaskTypeSpec,
    pub aggregation: TaskTypeSpec,
    pub training: TaskTypeSpec,
    pub inference: TaskTypeSpec,
    pub tx_sigma_frac: f64,
    /// Real-execution knobs (ignored by virtual runs).
    pub md_chunks_per_sim: usize,
    pub train_steps: usize,
}

impl DdmdConfig {
    /// Table 1 verbatim (TX already scaled down 4x from Brace et al.,
    /// as in the paper; sigma = 0.05).
    pub fn paper() -> DdmdConfig {
        DdmdConfig {
            iterations: 3,
            simulation: TaskTypeSpec { tasks: 96, cores: 4, gpus: 1, tx: 340.0 },
            aggregation: TaskTypeSpec { tasks: 16, cores: 32, gpus: 0, tx: 85.0 },
            training: TaskTypeSpec { tasks: 1, cores: 4, gpus: 1, tx: 63.0 },
            inference: TaskTypeSpec { tasks: 96, cores: 16, gpus: 1, tx: 38.0 },
            tx_sigma_frac: 0.05,
            md_chunks_per_sim: 4,
            train_steps: 30,
        }
    }

    /// Small instance for real wall-clock execution on the local host
    /// (examples/ddmd_e2e.rs): 2 iterations, a handful of tasks, and a
    /// tiny cluster profile (`ClusterSpec::local_small`).
    pub fn small() -> DdmdConfig {
        DdmdConfig {
            iterations: 2,
            simulation: TaskTypeSpec { tasks: 4, cores: 1, gpus: 1, tx: 8.0 },
            aggregation: TaskTypeSpec { tasks: 2, cores: 2, gpus: 0, tx: 2.0 },
            training: TaskTypeSpec { tasks: 1, cores: 1, gpus: 1, tx: 2.0 },
            inference: TaskTypeSpec { tasks: 2, cores: 1, gpus: 1, tx: 1.0 },
            tx_sigma_frac: 0.05,
            // 4 sims x 16 chunks = 64 contact-map frames per iteration
            // = 2 training batches of 32 per iteration.
            md_chunks_per_sim: 16,
            train_steps: 25,
        }
    }

    /// The sequential per-iteration TTX (Eqn. 2 inner sum): 526 s for
    /// the paper configuration.
    pub fn t_iteration(&self) -> f64 {
        self.simulation.tx + self.aggregation.tx + self.training.tx + self.inference.tx
    }
}

/// Build the DeepDriveMD [`Workflow`] (both realizations + DG).
pub fn ddmd_workflow(cfg: &DdmdConfig) -> Workflow {
    let mut dag = Dag::new();
    let mut sets: Vec<TaskSetSpec> = Vec::with_capacity(cfg.iterations * 4);
    let mut chain_nodes: Vec<[usize; 4]> = Vec::with_capacity(cfg.iterations);

    for it in 0..cfg.iterations {
        let mk = |name: String, t: &TaskTypeSpec, kind: TaskKind| {
            TaskSetSpec::new(name, t.tasks, ResourceRequest::new(t.cores, t.gpus), t.tx)
                .with_sigma(cfg.tx_sigma_frac)
                .with_kind(kind)
        };
        let sim = dag.add_node(format!("Sim{it}"));
        sets.push(mk(
            format!("Sim{it}"),
            &cfg.simulation,
            TaskKind::MdSimulation { chunks: cfg.md_chunks_per_sim },
        ));
        let agg = dag.add_node(format!("Aggr{it}"));
        sets.push(mk(format!("Aggr{it}"), &cfg.aggregation, TaskKind::Aggregation));
        let train = dag.add_node(format!("Train{it}"));
        sets.push(mk(
            format!("Train{it}"),
            &cfg.training,
            TaskKind::Training { steps: cfg.train_steps },
        ));
        let infer = dag.add_node(format!("Infer{it}"));
        sets.push(mk(format!("Infer{it}"), &cfg.inference, TaskKind::Inference));
        dag.add_edge(sim, agg).unwrap();
        dag.add_edge(agg, train).unwrap();
        dag.add_edge(train, infer).unwrap();
        chain_nodes.push([sim, agg, train, infer]);
    }

    // Sequential: one pipeline, iterations back-to-back (the paper's
    // baseline: "a single pipeline ... each stage executes sequentially").
    let mut seq = Pipeline::new("ddmd-sequential");
    for c in &chain_nodes {
        for &s in c {
            seq = seq.stage(&[s]);
        }
    }

    // Asynchronous: one pipeline per iteration (Fig. 3a's staggered
    // chains; the stagger emerges from GPU contention).
    let asynchronous = chain_nodes
        .iter()
        .enumerate()
        .map(|(it, c)| {
            let mut p = Pipeline::new(format!("ddmd-iter{it}"));
            for &s in c {
                p = p.stage(&[s]);
            }
            p
        })
        .collect();

    let wf = Workflow {
        name: format!("DeepDriveMD-x{}", cfg.iterations),
        sets,
        dag,
        sequential: vec![seq],
        asynchronous,
    };
    wf.validate().expect("ddmd builder produces valid workflows");
    wf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{simulate_cfg, EngineConfig, ExecutionMode};
    use crate::resources::ClusterSpec;

    #[test]
    fn paper_config_matches_table1() {
        let cfg = DdmdConfig::paper();
        assert_eq!(cfg.iterations, 3);
        assert!((cfg.t_iteration() - 526.0).abs() < 1e-9);
    }

    #[test]
    fn workflow_structure() {
        let wf = ddmd_workflow(&DdmdConfig::paper());
        assert_eq!(wf.sets.len(), 12);
        assert_eq!(wf.sequential[0].stages.len(), 12);
        assert_eq!(wf.asynchronous.len(), 3);
        let a = wf.analysis();
        // Three independent chains -> DOA_dep = 2 (§7.1).
        assert_eq!(a.doa_dep, 2);
    }

    /// Experiment E1/E9 core shape: async beats sequential by ~15-25%
    /// on the Summit profile, and the measured DOA_res is 1 (WLA = 1).
    #[test]
    fn summit_async_improvement_matches_paper_shape() {
        let wf = ddmd_workflow(&DdmdConfig::paper());
        let cluster = ClusterSpec::summit_paper();
        let cfg = EngineConfig { seed: 7, ..EngineConfig::default() };
        let seq = simulate_cfg(&wf, &cluster, ExecutionMode::Sequential, &cfg);
        let asy = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
        let i = asy.improvement_over(&seq);
        assert!(
            (0.10..=0.30).contains(&i),
            "I = {i:.3} out of the paper's ballpark (0.196); seq={} async={}",
            seq.makespan,
            asy.makespan
        );
        // Analytic DOA_res (Table 3): 1. (The raw trace-measured value
        // can exceed it transiently — see metrics::measured_doa_res.)
        assert_eq!(crate::model::doa_res_analytic(&wf, &cluster), 1);
        // GPU utilization must improve under asynchronicity (Fig. 4).
        assert!(asy.gpu_utilization > seq.gpu_utilization);
    }

    /// Ideal-overhead simulation vs the paper's closed forms: Eqn. 2
    /// gives 3 x 526 = 1578; Eqn. 6 gives 1345.
    #[test]
    fn ideal_simulation_brackets_eqn6() {
        let wf = ddmd_workflow(&DdmdConfig::paper());
        let mut cfgv = DdmdConfig::paper();
        cfgv.tx_sigma_frac = 0.0; // deterministic TX for exact comparison
        let wf0 = ddmd_workflow(&cfgv);
        let _ = wf;
        let cluster = ClusterSpec::summit_paper();
        let cfg = EngineConfig::ideal();
        let seq = simulate_cfg(&wf0, &cluster, ExecutionMode::Sequential, &cfg);
        assert!((seq.makespan - 1578.0).abs() < 1.0, "seq {}", seq.makespan);
        let asy = simulate_cfg(&wf0, &cluster, ExecutionMode::Asynchronous, &cfg);
        let eqn6 = crate::model::t_async_ddmd_eqn6(3, 526.0, 85.0, 63.0);
        // The simulator resolves actual contention; Eqn. 6 is the paper's
        // analytic estimate. They must agree within ~8%.
        let rel = (asy.makespan - eqn6).abs() / eqn6;
        assert!(rel < 0.08, "sim {} vs eqn6 {eqn6}", asy.makespan);
    }
}
