//! Experiment harness (substrate S19): regenerates every table and
//! figure of the paper's evaluation (§7) and reports paper-vs-measured.

use std::path::Path;

use crate::ddmd::{ddmd_workflow, DdmdConfig};
use crate::engine::{simulate_cfg, EngineConfig, ExecutionMode, RunReport};
use crate::entk::Workflow;
use crate::error::Result;
use crate::metrics::ascii_timeline;
use crate::model::{self, Prediction};
use crate::resources::ClusterSpec;
use crate::util::bench::Table;
use crate::workflows::{cdg1, cdg2};

/// Table 3 as printed in the paper (reference values for comparison).
#[derive(Debug, Clone, Copy)]
pub struct PaperRow {
    pub name: &'static str,
    pub doa_dep: usize,
    pub doa_res: usize,
    pub wla: usize,
    pub t_seq_pred: f64,
    pub t_seq_meas: f64,
    pub t_async_pred: f64,
    pub t_async_meas: f64,
    pub i_pred: f64,
    pub i_calc: f64,
}

pub const PAPER_TABLE3: [PaperRow; 3] = [
    PaperRow {
        name: "DeepDriveMD",
        doa_dep: 2,
        doa_res: 1,
        wla: 1,
        t_seq_pred: 1578.0,
        t_seq_meas: 1707.0,
        t_async_pred: 1399.0,
        t_async_meas: 1373.0,
        i_pred: 0.113,
        i_calc: 0.196,
    },
    PaperRow {
        name: "c-DG1",
        doa_dep: 2,
        doa_res: 2,
        wla: 2,
        t_seq_pred: 2000.0,
        t_seq_meas: 1945.0,
        t_async_pred: 1972.0,
        t_async_meas: 1975.0,
        i_pred: 0.014,
        i_calc: -0.015,
    },
    PaperRow {
        name: "c-DG2",
        doa_dep: 2,
        doa_res: 2,
        wla: 2,
        t_seq_pred: 2000.0,
        t_seq_meas: 1856.0,
        t_async_pred: 1378.0,
        t_async_meas: 1372.0,
        i_pred: 0.311,
        i_calc: 0.261,
    },
];

/// One reproduced row: our model prediction + our measured runs.
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub name: String,
    pub prediction: Prediction,
    pub seq: RunReport,
    pub asy: RunReport,
}

impl Table3Row {
    pub fn i_measured(&self) -> f64 {
        self.asy.improvement_over(&self.seq)
    }
}

/// The three experiment workflows on their evaluation clusters.
///
/// DDMD runs on the 96-GPU Summit profile exactly as the paper
/// describes. The c-DG workloads run on the 128-GPU profile: Table 2's
/// c-DG2 rank-2 GPU demand (96 for {T3,T6} + 16 for {T4,T5}) exceeds
/// the stated 96-GPU allocation, while the paper's own Eqn. 3
/// prediction (1300 s) and measurement (1372 s) presume the sets
/// co-run; 128 GPUs is the smallest Summit-shaped allocation under
/// which the paper's numbers are self-consistent. The 96-GPU clipped
/// behaviour is kept as an ablation (`bench_ablations`).
pub fn experiment_workflows() -> Vec<(Workflow, ClusterSpec)> {
    vec![
        (ddmd_workflow(&DdmdConfig::paper()), ClusterSpec::summit_paper()),
        (cdg1(), ClusterSpec::summit_8gpu()),
        (cdg2(), ClusterSpec::summit_8gpu()),
    ]
}

/// Engine settings calibrated to the paper's measured overheads (~4%
/// framework + ~2% async): per-task launch 2 s, stage transition 8 s at
/// paper TX scale.
pub fn paper_engine_config(seed: u64) -> EngineConfig {
    EngineConfig { seed, task_overhead: 2.0, stage_overhead: 8.0, ..Default::default() }
}

/// Experiment E1–E3: regenerate Table 3.
pub fn run_table3(seed: u64) -> Vec<Table3Row> {
    experiment_workflows()
        .into_iter()
        .map(|(wf, cluster)| {
            let cfg = paper_engine_config(seed);
            let prediction = model::predict(&wf, &cluster);
            let seq = simulate_cfg(&wf, &cluster, ExecutionMode::Sequential, &cfg);
            let asy = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
            Table3Row { name: wf.name.clone(), prediction, seq, asy }
        })
        .collect()
}

/// Render the reproduced Table 3 next to the paper's values.
pub fn render_table3(rows: &[Table3Row]) -> String {
    let mut t = Table::new(&[
        "experiment",
        "DOAdep",
        "DOAres",
        "WLA",
        "tSeq pred",
        "tSeq meas",
        "tAsync pred",
        "tAsync meas",
        "I pred",
        "I meas",
        "I paper",
    ]);
    for (row, paper) in rows.iter().zip(PAPER_TABLE3.iter()) {
        t.row(&[
            row.name.clone(),
            format!("{} ({})", row.prediction.doa_dep, paper.doa_dep),
            format!("{} ({})", row.prediction.doa_res, paper.doa_res),
            format!("{} ({})", row.prediction.wla, paper.wla),
            format!("{:.0}", row.prediction.t_seq),
            format!("{:.0}", row.seq.makespan),
            format!("{:.0}", row.prediction.t_async),
            format!("{:.0}", row.asy.makespan),
            format!("{:+.3}", row.prediction.improvement),
            format!("{:+.3}", row.i_measured()),
            format!("{:+.3}", paper.i_calc),
        ]);
    }
    t.to_string()
}

/// Experiments E4–E6: utilization figures. Writes
/// `results/<id>_<mode>.csv` and returns the ASCII rendering.
pub fn run_figure(
    id: &str,
    wf: &Workflow,
    cluster: &ClusterSpec,
    seed: u64,
    out_dir: Option<&Path>,
) -> Result<String> {
    let cfg = paper_engine_config(seed);
    let mut out = String::new();
    for mode in [ExecutionMode::Sequential, ExecutionMode::Asynchronous] {
        let rep = simulate_cfg(wf, cluster, mode, &cfg);
        out.push_str(&format!(
            "== {id} {} : TTX = {:.0} s, cpu util {:.1}%, gpu util {:.1}%\n",
            mode.label(),
            rep.makespan,
            rep.cpu_utilization * 100.0,
            rep.gpu_utilization * 100.0
        ));
        out.push_str(&ascii_timeline(&rep.trace, 72, 6));
        if let Some(dir) = out_dir {
            std::fs::create_dir_all(dir)?;
            std::fs::write(
                dir.join(format!("{id}_{}.csv", mode.label())),
                rep.trace.to_csv(),
            )?;
        }
    }
    Ok(out)
}

/// Shape assertions for the three headline results — used by tests and
/// CI: signs and rough magnitudes must match the paper.
pub fn check_shapes(rows: &[Table3Row]) -> Vec<String> {
    let mut problems = Vec::new();
    let ddmd = &rows[0];
    let i = ddmd.i_measured();
    if !(0.10..=0.30).contains(&i) {
        problems.push(format!("DDMD I={i:.3} not in [0.10, 0.30] (paper 0.196)"));
    }
    let c1 = rows[1].i_measured();
    if !(-0.10..=0.06).contains(&c1) {
        problems.push(format!("c-DG1 I={c1:.3} not ~0 (paper -0.015)"));
    }
    let c2 = rows[2].i_measured();
    if !(0.15..=0.40).contains(&c2) {
        problems.push(format!("c-DG2 I={c2:.3} not in [0.15, 0.40] (paper 0.261)"));
    }
    if !(rows[2].i_measured() > rows[1].i_measured()) {
        problems.push("ordering: c-DG2 must beat c-DG1".into());
    }
    problems
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_reproduces_paper_shapes() {
        let rows = run_table3(42);
        let problems = check_shapes(&rows);
        assert!(problems.is_empty(), "shape violations: {problems:?}");
    }

    #[test]
    fn table3_doa_values_match_paper() {
        let rows = run_table3(43);
        for (row, paper) in rows.iter().zip(PAPER_TABLE3.iter()) {
            assert_eq!(row.prediction.doa_dep, paper.doa_dep, "{}", row.name);
        }
        // DDMD's resource-limited DOA (Table 3's headline subtlety).
        assert_eq!(rows[0].prediction.doa_res, 1);
        assert_eq!(rows[0].prediction.wla, 1);
        // c-DG rows: DOA_res = WLA = 2 on their evaluation cluster.
        assert_eq!(rows[1].prediction.doa_res, 2);
        assert_eq!(rows[2].prediction.doa_res, 2);
    }

    #[test]
    fn render_table3_is_complete() {
        let rows = run_table3(44);
        let s = render_table3(&rows);
        for name in ["DeepDriveMD", "c-DG1", "c-DG2"] {
            assert!(s.contains(name));
        }
        assert!(s.lines().count() >= 5);
    }

    #[test]
    fn figures_render_and_dump_csv() {
        let (wf, cluster) = &experiment_workflows()[0];
        let dir = std::env::temp_dir().join("asyncflow_fig_test");
        let art = run_figure("fig4", wf, cluster, 45, Some(&dir)).unwrap();
        assert!(art.contains("sequential"));
        assert!(art.contains("asynchronous"));
        assert!(dir.join("fig4_sequential.csv").exists());
        assert!(dir.join("fig4_asynchronous.csv").exists());
    }
}
