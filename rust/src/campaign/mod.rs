//! Workflow-level asynchronicity (§1's first level): executing multiple
//! *independent workflows* concurrently on a single pilot allocation,
//! as in IMPECCABLE [20] where "different workflows can be executed
//! without waiting for all instances of one workflow to finish".
//!
//! A [`Campaign`] merges k workflows into one super-workflow whose DAG
//! is the disjoint union of the members' DAGs. Its *sequential*
//! realization runs member workflows back-to-back (each internally in
//! its own sequential realization); its *asynchronous* realization runs
//! every member's asynchronous pipelines concurrently. DOA_dep of the
//! merged DAG grows by the number of extra components, exactly as
//! Fig. 2d's edge-less DG prescribes.
//!
//! Two concurrent execution paths exist:
//!
//! - [`Campaign::simulate`] — the static merged-DAG path: members are
//!   fused into one workflow before execution (all must be known at
//!   t = 0);
//! - [`Campaign::simulate_online`] — the shared-agent path: one
//!   [`Coordinator`] multiplexes a live [`WorkflowDriver`](crate::engine::WorkflowDriver)
//!   per member over a single pilot, so members may *arrive while
//!   others are running* (RADICAL-Pilot / RHAPSODY-style sessions).
//!   With all-zero arrival offsets it reproduces the merged-DAG
//!   asynchronous makespan exactly (see `tests/coordinator.rs`).

use std::time::Duration;

use crate::dag::Dag;
use crate::engine::{
    simulate_cfg, Coordinator, EngineConfig, ExecutionMode, RunReport,
};
use crate::entk::{Pipeline, Stage, Workflow};
use crate::error::{Error, Result};
use crate::resources::ClusterSpec;
use crate::sim::VirtualExecutor;

/// A set of independent workflows executed as one campaign.
#[derive(Debug, Clone)]
pub struct Campaign {
    pub name: String,
    pub members: Vec<Workflow>,
}

impl Campaign {
    pub fn new(name: impl Into<String>) -> Campaign {
        Campaign { name: name.into(), members: vec![] }
    }

    pub fn add(mut self, wf: Workflow) -> Campaign {
        self.members.push(wf);
        self
    }

    /// Merge members into one [`Workflow`].
    ///
    /// Set names are prefixed `"<member>/"` to stay unique. The
    /// sequential realization chains member workflows (workflow-level
    /// BSP: campaign member k starts only when k-1 finished); the
    /// asynchronous realization unions all members' async pipelines.
    pub fn merge(&self) -> Result<Workflow> {
        if self.members.is_empty() {
            return Err(Error::InvalidWorkflow("campaign has no members".into()));
        }
        let mut dag = Dag::new();
        let mut sets = Vec::new();
        let mut offset = Vec::new(); // node-id offset per member
        for (mi, wf) in self.members.iter().enumerate() {
            wf.validate()?;
            offset.push(dag.len());
            let base = dag.len();
            for (i, s) in wf.sets.iter().enumerate() {
                let mut s = s.clone();
                s.name = format!("{}@{mi}/{}", wf.name, s.name);
                dag.add_node(s.name.clone());
                sets.push(s);
                let _ = i;
            }
            for v in 0..wf.dag.len() {
                for &c in wf.dag.children(v) {
                    dag.add_edge(base + v, base + c)?;
                }
            }
        }

        let shift = |p: &Pipeline, base: usize, tag: &String| -> Pipeline {
            Pipeline {
                name: format!("{tag}/{}", p.name),
                stages: p
                    .stages
                    .iter()
                    .map(|st| Stage::of(&st.sets.iter().map(|&s| s + base).collect::<Vec<_>>()))
                    .collect(),
            }
        };

        // Sequential: one pipeline concatenating every member's
        // sequential stages in campaign order.
        let mut seq = Pipeline::new(format!("{}-sequential", self.name));
        for (wf, &base) in self.members.iter().zip(&offset) {
            for p in &wf.sequential {
                for st in &p.stages {
                    seq.stages.push(Stage::of(
                        &st.sets.iter().map(|&s| s + base).collect::<Vec<_>>(),
                    ));
                }
            }
        }

        // Asynchronous: union of member async pipelines.
        let mut asynchronous = Vec::new();
        for (mi, (wf, &base)) in self.members.iter().zip(&offset).enumerate() {
            for p in &wf.asynchronous {
                asynchronous.push(shift(p, base, &format!("{}@{mi}", wf.name)));
            }
        }

        let merged = Workflow {
            name: self.name.clone(),
            sets,
            dag,
            sequential: vec![seq],
            asynchronous,
        };
        merged.validate()?;
        Ok(merged)
    }

    /// Simulate the campaign in both modes; returns (sequential, async).
    pub fn simulate(
        &self,
        cluster: &ClusterSpec,
        cfg: &EngineConfig,
    ) -> Result<(RunReport, RunReport)> {
        let wf = self.merge()?;
        Ok((
            simulate_cfg(&wf, cluster, ExecutionMode::Sequential, cfg),
            simulate_cfg(&wf, cluster, ExecutionMode::Asynchronous, cfg),
        ))
    }

    /// Simulate the campaign *online*: every member runs through its own
    /// driver on one shared pilot agent, member `i` arriving at
    /// `arrivals[i]` engine-seconds (so workflows can join a busy
    /// allocation mid-run). Requires one arrival offset per member.
    ///
    /// # Examples
    ///
    /// Two paper workflows share one allocation; the second arrives
    /// 300 s into the first one's run:
    ///
    /// ```
    /// use asyncflow::campaign::Campaign;
    /// use asyncflow::engine::EngineConfig;
    /// use asyncflow::resources::ClusterSpec;
    /// use asyncflow::workflows::{cdg1, cdg2};
    ///
    /// let camp = Campaign::new("mixed").add(cdg1()).add(cdg2());
    /// let rep = camp
    ///     .simulate_online(&[0.0, 300.0], &ClusterSpec::summit_8gpu(), &EngineConfig::ideal())
    ///     .unwrap();
    /// assert_eq!(rep.members.len(), 2);
    /// // Member TTX is measured from each member's own arrival; the
    /// // campaign TTX spans first arrival to last finish.
    /// assert!(rep.member_ttx(1) > 0.0);
    /// assert!(rep.campaign_ttx() >= rep.member_ttx(0));
    /// ```
    pub fn simulate_online(
        &self,
        arrivals: &[f64],
        cluster: &ClusterSpec,
        cfg: &EngineConfig,
    ) -> Result<CampaignReport> {
        if self.members.is_empty() {
            return Err(Error::InvalidWorkflow("campaign has no members".into()));
        }
        if arrivals.len() != self.members.len() {
            return Err(Error::Config(format!(
                "campaign '{}': {} arrival offsets for {} members",
                self.name,
                arrivals.len(),
                self.members.len()
            )));
        }
        let mut coord = Coordinator::new(cluster, cfg);
        for (wf, &arrival) in self.members.iter().zip(arrivals) {
            coord.add_workflow(wf.clone(), ExecutionMode::Asynchronous, arrival)?;
        }
        let mut ex = VirtualExecutor::new();
        let members = coord.run(&mut ex)?;
        let campaign = merge_member_reports(&self.name, &members, cluster);
        Ok(CampaignReport { arrivals: arrivals.to_vec(), members, campaign })
    }
}

/// Result of an online (shared-agent) campaign run.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    /// Arrival offset of each member (engine seconds).
    pub arrivals: Vec<f64>,
    /// Per-member reports; `makespan` is the member's *absolute* finish
    /// time on the campaign clock (member TTX = [`CampaignReport::member_ttx`]).
    pub members: Vec<RunReport>,
    /// Merged campaign-level view: records re-uid'd into one namespace,
    /// branch/pipeline ids offset per member, one utilization trace.
    pub campaign: RunReport,
}

impl CampaignReport {
    /// Member i's time-to-execution measured from its own arrival.
    pub fn member_ttx(&self, i: usize) -> f64 {
        self.members[i].makespan - self.arrivals[i]
    }

    /// Campaign TTX: first arrival to last finish.
    pub fn campaign_ttx(&self) -> f64 {
        let first = self.arrivals.iter().copied().fold(f64::INFINITY, f64::min);
        self.campaign.makespan - first
    }
}

/// Fuse per-member reports into one campaign-level [`RunReport`]
/// (global task uids, per-member branch/pipeline offsets, shared trace).
/// Shared with the [`traffic`](crate::traffic) load generator, which
/// merges hundreds of streamed members the same way.
pub(crate) fn merge_member_reports(
    name: &str,
    members: &[RunReport],
    cluster: &ClusterSpec,
) -> RunReport {
    // The coordinator stamps every member with the run's full capacity
    // timeline; merged utilization must integrate against it (elastic
    // runs), falling back to the fixed cluster for empty member sets.
    let capacity = members
        .first()
        .map(|m| m.capacity.clone())
        .unwrap_or_else(|| crate::metrics::CapacityTimeline::of_cluster(cluster));
    let mut records = Vec::with_capacity(members.iter().map(|m| m.records.len()).sum());
    let mut branch_off = 0usize;
    let mut pipe_off = 0usize;
    for (mi, m) in members.iter().enumerate() {
        let n_branches = m.records.iter().map(|r| r.branch).max().map_or(0, |b| b + 1);
        let n_pipes = m.records.iter().map(|r| r.pipeline).max().map_or(0, |p| p + 1);
        for r in &m.records {
            let mut r = r.clone();
            r.uid = records.len();
            r.branch += branch_off;
            r.pipeline += pipe_off;
            // "<name>@<member index>/" keeps set names unique even when
            // the same workflow joins a campaign twice (same scheme as
            // Campaign::merge).
            r.set_name = format!("{}@{mi}/{}", m.workflow, r.set_name);
            records.push(r);
        }
        branch_off += n_branches;
        pipe_off += n_pipes;
    }
    let failed: usize = members.iter().map(|m| m.failed_tasks).sum();
    let mut campaign = RunReport::from_records_capacity(
        name,
        ExecutionMode::Asynchronous,
        records,
        capacity,
        failed,
    );
    campaign.sched_rounds = members.first().map_or(0, |m| m.sched_rounds);
    campaign.sched_wall = members.first().map_or(Duration::ZERO, |m| m.sched_wall);
    campaign.driver_steps = members.first().map_or(0, |m| m.driver_steps);
    campaign.peak_live_tasks = members.first().map_or(0, |m| m.peak_live_tasks);
    campaign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddmd::{ddmd_workflow, DdmdConfig};
    use crate::workflows::{cdg1, cdg2};

    fn small_ddmd(iters: usize) -> Workflow {
        let mut c = DdmdConfig::paper();
        c.iterations = iters;
        c.tx_sigma_frac = 0.0;
        ddmd_workflow(&c)
    }

    #[test]
    fn merge_preserves_structure() {
        let camp = Campaign::new("camp").add(small_ddmd(1)).add(small_ddmd(2));
        let wf = camp.merge().unwrap();
        assert_eq!(wf.sets.len(), 4 + 8);
        assert_eq!(wf.dag.edge_count(), 3 + 6);
        wf.validate().unwrap();
        // Disjoint components raise DOA_dep: member1 contributes 1
        // component-chain, member2 has DOA_dep 1 of its own (2 chains).
        let a = wf.analysis();
        assert_eq!(a.doa_dep, 2, "3 independent chains total");
    }

    #[test]
    fn empty_campaign_rejected() {
        assert!(Campaign::new("empty").merge().is_err());
    }

    #[test]
    fn campaign_async_beats_sequential() {
        // Two heterogeneous workflows: c-DG1 (CPU-ish) + c-DG2 share the
        // allocation; workflow-level asynchronicity overlaps them.
        let camp = Campaign::new("mixed").add(cdg1()).add(cdg2());
        let cluster = ClusterSpec::summit_8gpu();
        let cfg = EngineConfig::ideal();
        let (seq, asy) = camp.simulate(&cluster, &cfg).unwrap();
        let i = asy.improvement_over(&seq);
        assert!(
            i > 0.25,
            "workflow-level asynchronicity should pay: I = {i:.3} (seq {} asy {})",
            seq.makespan,
            asy.makespan
        );
        // Both workflows' branches progress concurrently.
        assert!(asy.doa_res >= 1);
    }

    #[test]
    fn online_zero_arrivals_reproduces_merged_async() {
        // The shared-agent coordinator path with simultaneous arrivals
        // must be *exactly* the merged-DAG asynchronous run — same TX
        // draws (order-independent per-set streams), same submission
        // order, same placements, same makespan.
        let camp = Campaign::new("mixed").add(cdg1()).add(cdg2());
        let cluster = ClusterSpec::summit_8gpu();
        let cfg = EngineConfig::ideal();
        let (_, merged_asy) = camp.simulate(&cluster, &cfg).unwrap();
        let online = camp.simulate_online(&[0.0, 0.0], &cluster, &cfg).unwrap();
        assert!(
            (online.campaign.makespan - merged_asy.makespan).abs() < 1e-9,
            "online {} vs merged {}",
            online.campaign.makespan,
            merged_asy.makespan
        );
        assert_eq!(online.campaign.records.len(), merged_asy.records.len());
        assert!((online.campaign.cpu_utilization - merged_asy.cpu_utilization).abs() < 1e-9);
        assert_eq!(online.members.len(), 2);
    }

    #[test]
    fn online_staggered_arrivals_shift_the_second_member() {
        let camp = Campaign::new("staggered").add(cdg1()).add(cdg2());
        let cluster = ClusterSpec::summit_8gpu();
        let cfg = EngineConfig::ideal();
        let zero = camp.simulate_online(&[0.0, 0.0], &cluster, &cfg).unwrap();
        let lag = camp.simulate_online(&[0.0, 400.0], &cluster, &cfg).unwrap();
        // The late member cannot submit before it arrives.
        let first_sub = lag.members[1]
            .records
            .iter()
            .map(|r| r.submitted)
            .fold(f64::INFINITY, f64::min);
        assert!(first_sub >= 400.0 - 1e-9, "first submission at {first_sub}");
        // Staggering produces a strictly different, internally
        // consistent campaign timeline.
        assert!(
            (lag.campaign.makespan - zero.campaign.makespan).abs() > 1e-6,
            "staggered {} == simultaneous {}",
            lag.campaign.makespan,
            zero.campaign.makespan
        );
        let member_max = lag
            .members
            .iter()
            .map(|m| m.makespan)
            .fold(0.0f64, f64::max);
        assert!((lag.campaign.makespan - member_max).abs() < 1e-9);
        assert!(lag.member_ttx(1) > 0.0);
        assert!(lag.campaign_ttx() >= lag.member_ttx(0));
    }

    #[test]
    fn online_rejects_mismatched_arrivals() {
        let camp = Campaign::new("c").add(small_ddmd(1)).add(small_ddmd(1));
        let cluster = ClusterSpec::summit_paper();
        let cfg = EngineConfig::ideal();
        assert!(camp.simulate_online(&[0.0], &cluster, &cfg).is_err());
        assert!(Campaign::new("empty")
            .simulate_online(&[], &cluster, &cfg)
            .is_err());
    }

    #[test]
    fn set_names_are_prefixed_and_unique() {
        let camp = Campaign::new("c").add(small_ddmd(1)).add(small_ddmd(1));
        let wf = camp.merge().unwrap();
        let mut names: Vec<&str> = wf.sets.iter().map(|s| s.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), wf.sets.len(), "duplicate set names after merge");
        assert!(wf.sets[0].name.contains('/'));
    }
}
