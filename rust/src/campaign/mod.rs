//! Workflow-level asynchronicity (§1's first level): executing multiple
//! *independent workflows* concurrently on a single pilot allocation,
//! as in IMPECCABLE [20] where "different workflows can be executed
//! without waiting for all instances of one workflow to finish".
//!
//! A [`Campaign`] merges k workflows into one super-workflow whose DAG
//! is the disjoint union of the members' DAGs. Its *sequential*
//! realization runs member workflows back-to-back (each internally in
//! its own sequential realization); its *asynchronous* realization runs
//! every member's asynchronous pipelines concurrently. DOA_dep of the
//! merged DAG grows by the number of extra components, exactly as
//! Fig. 2d's edge-less DG prescribes.

use crate::dag::Dag;
use crate::engine::{simulate_cfg, EngineConfig, ExecutionMode, RunReport};
use crate::entk::{Pipeline, Stage, Workflow};
use crate::error::{Error, Result};
use crate::resources::ClusterSpec;

/// A set of independent workflows executed as one campaign.
#[derive(Debug, Clone)]
pub struct Campaign {
    pub name: String,
    pub members: Vec<Workflow>,
}

impl Campaign {
    pub fn new(name: impl Into<String>) -> Campaign {
        Campaign { name: name.into(), members: vec![] }
    }

    pub fn add(mut self, wf: Workflow) -> Campaign {
        self.members.push(wf);
        self
    }

    /// Merge members into one [`Workflow`].
    ///
    /// Set names are prefixed `"<member>/"` to stay unique. The
    /// sequential realization chains member workflows (workflow-level
    /// BSP: campaign member k starts only when k-1 finished); the
    /// asynchronous realization unions all members' async pipelines.
    pub fn merge(&self) -> Result<Workflow> {
        if self.members.is_empty() {
            return Err(Error::InvalidWorkflow("campaign has no members".into()));
        }
        let mut dag = Dag::new();
        let mut sets = Vec::new();
        let mut offset = Vec::new(); // node-id offset per member
        for (mi, wf) in self.members.iter().enumerate() {
            wf.validate()?;
            offset.push(dag.len());
            let base = dag.len();
            for (i, s) in wf.sets.iter().enumerate() {
                let mut s = s.clone();
                s.name = format!("{}@{mi}/{}", wf.name, s.name);
                dag.add_node(s.name.clone());
                sets.push(s);
                let _ = i;
            }
            for v in 0..wf.dag.len() {
                for &c in wf.dag.children(v) {
                    dag.add_edge(base + v, base + c)?;
                }
            }
        }

        let shift = |p: &Pipeline, base: usize, tag: &String| -> Pipeline {
            Pipeline {
                name: format!("{tag}/{}", p.name),
                stages: p
                    .stages
                    .iter()
                    .map(|st| Stage::of(&st.sets.iter().map(|&s| s + base).collect::<Vec<_>>()))
                    .collect(),
            }
        };

        // Sequential: one pipeline concatenating every member's
        // sequential stages in campaign order.
        let mut seq = Pipeline::new(format!("{}-sequential", self.name));
        for (wf, &base) in self.members.iter().zip(&offset) {
            for p in &wf.sequential {
                for st in &p.stages {
                    seq.stages.push(Stage::of(
                        &st.sets.iter().map(|&s| s + base).collect::<Vec<_>>(),
                    ));
                }
            }
        }

        // Asynchronous: union of member async pipelines.
        let mut asynchronous = Vec::new();
        for (mi, (wf, &base)) in self.members.iter().zip(&offset).enumerate() {
            for p in &wf.asynchronous {
                asynchronous.push(shift(p, base, &format!("{}@{mi}", wf.name)));
            }
        }

        let merged = Workflow {
            name: self.name.clone(),
            sets,
            dag,
            sequential: vec![seq],
            asynchronous,
        };
        merged.validate()?;
        Ok(merged)
    }

    /// Simulate the campaign in both modes; returns (sequential, async).
    pub fn simulate(
        &self,
        cluster: &ClusterSpec,
        cfg: &EngineConfig,
    ) -> Result<(RunReport, RunReport)> {
        let wf = self.merge()?;
        Ok((
            simulate_cfg(&wf, cluster, ExecutionMode::Sequential, cfg),
            simulate_cfg(&wf, cluster, ExecutionMode::Asynchronous, cfg),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddmd::{ddmd_workflow, DdmdConfig};
    use crate::workflows::{cdg1, cdg2};

    fn small_ddmd(iters: usize) -> Workflow {
        let mut c = DdmdConfig::paper();
        c.iterations = iters;
        c.tx_sigma_frac = 0.0;
        ddmd_workflow(&c)
    }

    #[test]
    fn merge_preserves_structure() {
        let camp = Campaign::new("camp").add(small_ddmd(1)).add(small_ddmd(2));
        let wf = camp.merge().unwrap();
        assert_eq!(wf.sets.len(), 4 + 8);
        assert_eq!(wf.dag.edge_count(), 3 + 6);
        wf.validate().unwrap();
        // Disjoint components raise DOA_dep: member1 contributes 1
        // component-chain, member2 has DOA_dep 1 of its own (2 chains).
        let a = wf.analysis();
        assert_eq!(a.doa_dep, 2, "3 independent chains total");
    }

    #[test]
    fn empty_campaign_rejected() {
        assert!(Campaign::new("empty").merge().is_err());
    }

    #[test]
    fn campaign_async_beats_sequential() {
        // Two heterogeneous workflows: c-DG1 (CPU-ish) + c-DG2 share the
        // allocation; workflow-level asynchronicity overlaps them.
        let camp = Campaign::new("mixed").add(cdg1()).add(cdg2());
        let cluster = ClusterSpec::summit_8gpu();
        let cfg = EngineConfig::ideal();
        let (seq, asy) = camp.simulate(&cluster, &cfg).unwrap();
        let i = asy.improvement_over(&seq);
        assert!(
            i > 0.25,
            "workflow-level asynchronicity should pay: I = {i:.3} (seq {} asy {})",
            seq.makespan,
            asy.makespan
        );
        // Both workflows' branches progress concurrently.
        assert!(asy.doa_res >= 1);
    }

    #[test]
    fn set_names_are_prefixed_and_unique() {
        let camp = Campaign::new("c").add(small_ddmd(1)).add(small_ddmd(1));
        let wf = camp.merge().unwrap();
        let mut names: Vec<&str> = wf.sets.iter().map(|s| s.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), wf.sets.len(), "duplicate set names after merge");
        assert!(wf.sets[0].name.contains('/'));
    }
}
