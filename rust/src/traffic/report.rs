//! Queueing-theoretic reduction of a streamed traffic run: per-workflow
//! wait and TTX, allocation backlog over time, percentiles, throughput.

use crate::campaign::merge_member_reports;
use crate::engine::RunReport;
use crate::failure::ResilienceStats;
use crate::metrics::{jain_index, BacklogTrace, CapacityTimeline};
use crate::resources::ClusterSpec;
use crate::util::json::{obj, Json};
use crate::util::stats::Summary;

/// One streamed workflow's queueing lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkflowStat {
    /// Arrival order index.
    pub index: usize,
    /// Catalog workload name.
    pub name: String,
    /// Arrival time (engine seconds).
    pub arrival: f64,
    /// First task placement (start of service).
    pub first_start: f64,
    /// Last task finish.
    pub finish: f64,
    /// Arrival -> first placement (the queueing delay the paper's
    /// shared-allocation model is meant to bound).
    pub wait: f64,
    /// Arrival -> last finish (per-workflow TTX).
    pub ttx: f64,
    pub tasks: usize,
}

/// Everything measured about one streaming-traffic run.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficReport {
    /// Arrival window the generator used (seconds).
    pub arrival_window: f64,
    /// Per-workflow stats, in arrival order.
    pub workflows: Vec<WorkflowStat>,
    /// Wait-time distribution across workflows.
    pub wait: Summary,
    /// TTX distribution across workflows.
    pub ttx: Summary,
    /// First arrival to last finish (campaign clock).
    pub makespan: f64,
    pub total_tasks: usize,
    pub failed_tasks: usize,
    pub cpu_utilization: f64,
    pub gpu_utilization: f64,
    /// Completed tasks per engine second over the makespan.
    pub task_throughput: f64,
    /// Completed workflows per engine second over the makespan.
    pub workflow_throughput: f64,
    /// Queued-resource step trace (companion of the utilization trace).
    pub backlog: BacklogTrace,
    /// Peak queued (tasks, cores, gpus).
    pub peak_backlog: (u64, u64, u64),
    /// Time-averaged queued tasks over the whole run.
    pub mean_backlog_tasks: f64,
    /// Time-averaged queued tasks over the first half of the arrival
    /// window.
    pub backlog_first_half: f64,
    /// ... and over the second half: the growth signal. A stable system
    /// holds these roughly equal; past the saturation knee the second
    /// half is strictly larger and keeps growing with the window.
    pub backlog_second_half: f64,
    /// High-water mark of live per-task engine state (in-flight +
    /// queued) — the streaming-coordinator memory bound.
    pub peak_live_tasks: usize,
    /// Offered-capacity timeline of the run (free + in-use resources).
    /// Constant without a [`ResourcePlan`](crate::pilot::ResourcePlan);
    /// elastic runs carry one point per change (grows when applied,
    /// gracefully drained cores when released), and every utilization
    /// figure above integrates against it.
    pub capacity: CapacityTimeline,
    /// Per-driver wait breakdown, grouped by catalog workload name
    /// (sorted by name): how long each class of member waited for its
    /// first placement. The starvation diagnostic — under FIFO one
    /// greedy workload class pushes every other class's summary up.
    pub wait_by_workload: Vec<(String, Summary)>,
    /// Jain's fairness index over per-workflow waits (see
    /// [`jain_index`]): 1 = every member waited equally, 1/n = one
    /// member absorbed all the waiting.
    pub fairness_index: f64,
    /// Resilience accounting (failures, kills, retries, lost vs
    /// completed resource-time) when the run injected faults; `None`
    /// for a failure-free run. Coordinator-global: every member report
    /// carries the same stats, reduced here once.
    pub resilience: Option<ResilienceStats>,
}

impl TrafficReport {
    /// Reduce per-member coordinator reports to traffic metrics.
    /// `names`/`arrivals`/`members` are parallel, in arrival order.
    pub(crate) fn build(
        arrival_window: f64,
        names: Vec<String>,
        arrivals: Vec<f64>,
        members: Vec<RunReport>,
        cluster: &ClusterSpec,
    ) -> TrafficReport {
        debug_assert_eq!(names.len(), members.len());
        debug_assert_eq!(arrivals.len(), members.len());
        let mut workflows = Vec::with_capacity(members.len());
        for (i, m) in members.iter().enumerate() {
            // A degenerate zero-task member starts and finishes at its
            // own arrival (guards the folds below against producing
            // non-finite wait/TTX that would poison the summaries).
            let (first_start, finish) = if m.records.is_empty() {
                (arrivals[i], arrivals[i])
            } else {
                (
                    m.records
                        .iter()
                        .map(|r| r.started)
                        .fold(f64::INFINITY, f64::min),
                    m.records.iter().map(|r| r.finished).fold(0.0, f64::max),
                )
            };
            workflows.push(WorkflowStat {
                index: i,
                name: names[i].clone(),
                arrival: arrivals[i],
                first_start,
                finish,
                wait: first_start - arrivals[i],
                ttx: finish - arrivals[i],
                tasks: m.records.len(),
            });
        }
        let waits: Vec<f64> = workflows.iter().map(|w| w.wait).collect();
        let ttxs: Vec<f64> = workflows.iter().map(|w| w.ttx).collect();
        let fairness_index = jain_index(&waits);
        // Per-workload wait breakdown, deterministic (sorted by name).
        let mut by_name: Vec<(String, Vec<f64>)> = Vec::new();
        for w in &workflows {
            match by_name.iter_mut().find(|(n, _)| *n == w.name) {
                Some((_, xs)) => xs.push(w.wait),
                None => by_name.push((w.name.clone(), vec![w.wait])),
            }
        }
        by_name.sort_by(|a, b| a.0.cmp(&b.0));
        let wait_by_workload: Vec<(String, Summary)> = by_name
            .into_iter()
            .map(|(n, xs)| (n, Summary::try_of(&xs).unwrap_or_else(Summary::empty)))
            .collect();

        let merged = merge_member_reports("traffic", &members, cluster);
        let capacity = merged.capacity.clone();
        let backlog = BacklogTrace::from_records(&merged.records);
        let peak_backlog = backlog.peak();
        let mean_backlog_tasks = backlog.mean_tasks();
        let half = arrival_window / 2.0;
        let backlog_first_half = backlog.mean_tasks_between(0.0, half);
        let backlog_second_half = backlog.mean_tasks_between(half, arrival_window);
        let makespan = merged.makespan;
        let workflow_throughput = if makespan > 0.0 {
            workflows.len() as f64 / makespan
        } else {
            0.0
        };

        let resilience = members.first().and_then(|m| m.resilience);

        TrafficReport {
            arrival_window,
            wait: Summary::try_of(&waits).unwrap_or_else(Summary::empty),
            ttx: Summary::try_of(&ttxs).unwrap_or_else(Summary::empty),
            makespan,
            total_tasks: merged.records.len(),
            failed_tasks: merged.failed_tasks,
            cpu_utilization: merged.cpu_utilization,
            gpu_utilization: merged.gpu_utilization,
            task_throughput: merged.throughput,
            workflow_throughput,
            backlog,
            peak_backlog,
            mean_backlog_tasks,
            backlog_first_half,
            backlog_second_half,
            peak_live_tasks: merged.peak_live_tasks,
            capacity,
            wait_by_workload,
            fairness_index,
            resilience,
            workflows,
        }
    }

    /// Second-half over first-half mean backlog — > 1 means the queue
    /// was still growing across the arrival window.
    pub fn backlog_growth(&self) -> f64 {
        self.backlog_second_half / self.backlog_first_half.max(1e-9)
    }

    /// Saturation heuristic: the backlog in the second half of the
    /// arrival window is at least double the first half (with a small
    /// absolute floor so an idle system never counts as saturated).
    pub fn is_saturated(&self) -> bool {
        self.backlog_second_half > 2.0 * self.backlog_first_half.max(0.5)
    }

    /// Human-readable multi-line summary; `verbose` appends one line
    /// per workflow.
    pub fn render(&self, verbose: bool) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "traffic: {} workflows ({} tasks, {} failed) over a {:.0} s arrival window\n",
            self.workflows.len(),
            self.total_tasks,
            self.failed_tasks,
            self.arrival_window,
        ));
        s.push_str(&format!(
            "  wait    mean {:>8.1} s  p50 {:>8.1}  p95 {:>8.1}  p99 {:>8.1}  max {:>8.1}\n",
            self.wait.mean, self.wait.p50, self.wait.p95, self.wait.p99, self.wait.max
        ));
        s.push_str(&format!(
            "  TTX     mean {:>8.1} s  p50 {:>8.1}  p95 {:>8.1}  p99 {:>8.1}  max {:>8.1}\n",
            self.ttx.mean, self.ttx.p50, self.ttx.p95, self.ttx.p99, self.ttx.max
        ));
        s.push_str(&format!(
            "  backlog mean {:.1} tasks  peak {} tasks / {} cores / {} gpus  half-window growth {:.2}x ({})\n",
            self.mean_backlog_tasks,
            self.peak_backlog.0,
            self.peak_backlog.1,
            self.peak_backlog.2,
            self.backlog_growth(),
            if self.is_saturated() { "SATURATED" } else { "bounded" },
        ));
        s.push_str(&format!(
            "  makespan {:.0} s  throughput {:.4} wf/s, {:.3} tasks/s  cpu {:.1}%  gpu {:.1}%\n",
            self.makespan,
            self.workflow_throughput,
            self.task_throughput,
            self.cpu_utilization * 100.0,
            self.gpu_utilization * 100.0,
        ));
        s.push_str(&format!(
            "  peak live task state: {} (in-flight + queued; total streamed {})\n",
            self.peak_live_tasks, self.total_tasks,
        ));
        s.push_str(&format!(
            "  fairness: Jain {:.3} over per-workflow waits\n",
            self.fairness_index
        ));
        if self.wait_by_workload.len() > 1 {
            for (name, w) in &self.wait_by_workload {
                s.push_str(&format!(
                    "    wait[{name}] n {:<4} mean {:>8.1} s  p95 {:>8.1}  max {:>8.1}\n",
                    w.n, w.mean, w.p95, w.max
                ));
            }
        }
        if let Some(r) = &self.resilience {
            s.push_str(&format!(
                "  resilience: {} node failures, {} tasks killed, {} retries ({} exhausted)\n",
                r.failures_injected, r.tasks_killed, r.retries_scheduled, r.retries_exhausted,
            ));
            let delivered = r.goodput_core_s + r.lost_core_s;
            s.push_str(&format!(
                "    goodput {:.0} core-s / {:.0} gpu-s; lost {:.0} core-s / {:.0} gpu-s ({:.1}% of delivered core-time wasted)\n",
                r.goodput_core_s,
                r.goodput_gpu_s,
                r.lost_core_s,
                r.lost_gpu_s,
                if delivered > 0.0 { r.lost_core_s / delivered * 100.0 } else { 0.0 },
            ));
        }
        if !self.capacity.is_constant() {
            let first = self.capacity.points.first().map_or((0, 0), |&(_, c, g)| (c, g));
            let last = self.capacity.final_capacity();
            s.push_str(&format!(
                "  elastic capacity: cores {} -> {} / gpus {} -> {} over {} change points (peak {} cores)\n",
                first.0,
                last.0,
                first.1,
                last.1,
                self.capacity.points.len() - 1,
                self.capacity.peak().0,
            ));
        }
        if verbose {
            for w in &self.workflows {
                s.push_str(&format!(
                    "    #{:<4} {:<14} arrival {:>8.1}  wait {:>8.1}  TTX {:>8.1}  ({} tasks)\n",
                    w.index, w.name, w.arrival, w.wait, w.ttx, w.tasks
                ));
            }
        }
        s
    }

    /// Structured export (deterministic field order via `BTreeMap`):
    /// the same spec and seed serialize bit-identically.
    pub fn to_json(&self) -> Json {
        let wfs = self
            .workflows
            .iter()
            .map(|w| {
                obj([
                    ("index", Json::from(w.index)),
                    ("name", Json::from(w.name.clone())),
                    ("arrival", Json::from(w.arrival)),
                    ("wait", Json::from(w.wait)),
                    ("ttx", Json::from(w.ttx)),
                    ("finish", Json::from(w.finish)),
                    ("tasks", Json::from(w.tasks)),
                ])
            })
            .collect();
        let backlog_points = self
            .backlog
            .points
            .iter()
            .map(|&(t, n, c, g)| {
                Json::Arr(vec![
                    Json::from(t),
                    Json::from(n as f64),
                    Json::from(c as f64),
                    Json::from(g as f64),
                ])
            })
            .collect();
        let capacity_points = self
            .capacity
            .points
            .iter()
            .map(|&(t, c, g)| {
                Json::Arr(vec![Json::from(t), Json::from(c as f64), Json::from(g as f64)])
            })
            .collect();
        let wait_by_workload = self
            .wait_by_workload
            .iter()
            .map(|(name, w)| {
                obj([
                    ("workload", Json::from(name.clone())),
                    ("n", Json::from(w.n)),
                    ("wait_mean", Json::from(w.mean)),
                    ("wait_p50", Json::from(w.p50)),
                    ("wait_p95", Json::from(w.p95)),
                    ("wait_max", Json::from(w.max)),
                ])
            })
            .collect();
        obj([
            ("arrival_window", Json::from(self.arrival_window)),
            ("workflows", Json::Arr(wfs)),
            ("wait_mean", Json::from(self.wait.mean)),
            ("wait_p50", Json::from(self.wait.p50)),
            ("wait_p95", Json::from(self.wait.p95)),
            ("wait_p99", Json::from(self.wait.p99)),
            ("ttx_mean", Json::from(self.ttx.mean)),
            ("ttx_p50", Json::from(self.ttx.p50)),
            ("ttx_p95", Json::from(self.ttx.p95)),
            ("ttx_p99", Json::from(self.ttx.p99)),
            ("makespan", Json::from(self.makespan)),
            ("total_tasks", Json::from(self.total_tasks)),
            ("failed_tasks", Json::from(self.failed_tasks)),
            ("cpu_utilization", Json::from(self.cpu_utilization)),
            ("gpu_utilization", Json::from(self.gpu_utilization)),
            ("task_throughput", Json::from(self.task_throughput)),
            ("workflow_throughput", Json::from(self.workflow_throughput)),
            ("mean_backlog_tasks", Json::from(self.mean_backlog_tasks)),
            ("backlog_first_half", Json::from(self.backlog_first_half)),
            ("backlog_second_half", Json::from(self.backlog_second_half)),
            ("peak_backlog_tasks", Json::from(self.peak_backlog.0 as f64)),
            ("peak_backlog_cores", Json::from(self.peak_backlog.1 as f64)),
            ("peak_backlog_gpus", Json::from(self.peak_backlog.2 as f64)),
            ("peak_live_tasks", Json::from(self.peak_live_tasks)),
            ("saturated", Json::from(self.is_saturated())),
            ("fairness_index", Json::from(self.fairness_index)),
            (
                "resilience",
                match &self.resilience {
                    Some(r) => crate::util::json::ToJson::to_json(r),
                    None => Json::Null,
                },
            ),
            ("wait_by_workload", Json::Arr(wait_by_workload)),
            ("backlog_trace", Json::Arr(backlog_points)),
            ("capacity_trace", Json::Arr(capacity_points)),
        ])
    }

    /// CSV rendering of the per-driver queueing lifecycle:
    /// `index,workload,arrival_s,wait_s,ttx_s,tasks` — one row per
    /// streamed workflow, in arrival order (companion of the backlog
    /// and capacity traces the CLI writes alongside it).
    pub fn waits_csv(&self) -> String {
        let mut s = String::from("index,workload,arrival_s,wait_s,ttx_s,tasks\n");
        for w in &self.workflows {
            s.push_str(&format!(
                "{},{},{:.3},{:.3},{:.3},{}\n",
                w.index, w.name, w.arrival, w.wait, w.ttx, w.tasks
            ));
        }
        s
    }

    /// CSV rendering of the fairness view: one row per workload class
    /// with its wait summary, then an `__all__` row carrying the
    /// cross-member Jain index.
    pub fn fairness_csv(&self) -> String {
        let mut s = String::from(
            "workload,workflows,wait_mean_s,wait_p50_s,wait_p95_s,wait_max_s,jain_index\n",
        );
        for (name, w) in &self.wait_by_workload {
            s.push_str(&format!(
                "{},{},{:.3},{:.3},{:.3},{:.3},\n",
                name, w.n, w.mean, w.p50, w.p95, w.max
            ));
        }
        s.push_str(&format!(
            "__all__,{},{:.3},{:.3},{:.3},{:.3},{:.6}\n",
            self.wait.n,
            self.wait.mean,
            self.wait.p50,
            self.wait.p95,
            self.wait.max,
            self.fairness_index
        ));
        s
    }

    /// CSV rendering of the resilience ledger: one row of counters and
    /// resource-time totals (empty string when the run injected no
    /// faults — the CLI skips the file).
    pub fn resilience_csv(&self) -> String {
        let Some(r) = &self.resilience else {
            return String::new();
        };
        format!(
            "failures_injected,tasks_killed,retries_scheduled,retries_exhausted,\
             lost_core_s,lost_gpu_s,goodput_core_s,goodput_gpu_s\n\
             {},{},{},{},{:.3},{:.3},{:.3},{:.3}\n",
            r.failures_injected,
            r.tasks_killed,
            r.retries_scheduled,
            r.retries_exhausted,
            r.lost_core_s,
            r.lost_gpu_s,
            r.goodput_core_s,
            r.goodput_gpu_s,
        )
    }
}
