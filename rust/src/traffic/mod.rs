//! Streaming workflow traffic: arrival processes, workload mixes, and
//! the load generator that streams workflows through a shared-pilot
//! [`Coordinator`].
//!
//! The paper's core claim is that asynchronous execution raises
//! utilization and throughput when heterogeneous workflows share one
//! allocation. RADICAL-Pilot's production characterization
//! (arXiv:2103.00091) and RHAPSODY's hybrid-workflow campaigns
//! (arXiv:2512.20795) both treat *sustained workflow streams against a
//! fixed allocation* as the defining workload — not a fixed two-member
//! campaign. This module turns the coordinator into that load
//! generator:
//!
//! - [`ArrivalProcess`] — deterministic-interval, Poisson (via
//!   [`Rng::exp`]) or trace-driven workflow arrivals;
//! - [`WorkloadMix`] + [`Catalog`] — each arriving workflow is drawn
//!   from a weighted catalog of named workloads (`ddmd`, `cdg1`,
//!   `cdg2`, scaled variants, or custom entries);
//! - [`run_traffic`] — streams the sampled arrivals through one
//!   [`Coordinator`] on a [`VirtualExecutor`] and reduces the member
//!   reports to a [`TrafficReport`] with queueing metrics: per-workflow
//!   wait, allocation backlog over time, TTX percentiles and sustained
//!   throughput.
//!
//! Sweeping the arrival rate against a fixed allocation locates the
//! *saturation knee*: below it, wait and backlog are bounded; above it,
//! the backlog grows without bound for as long as arrivals continue
//! (`asyncflow traffic --sweep ...`).
//!
//! The allocation itself need not stay fixed: a
//! [`ResourcePlan`](crate::pilot::ResourcePlan) on the [`TrafficSpec`]
//! grows/drains pilot nodes under live traffic (timed `--resize`
//! events, or the backlog-driven `--autoscale` policy), and the
//! [`TrafficReport`] then carries the capacity timeline utilization is
//! integrated against.
//!
//! Determinism: arrivals and mix draws come from two forked streams of
//! the spec's seed, and TX sampling is per-set-stream keyed (see
//! [`WorkflowDriver`](crate::engine::WorkflowDriver)); the same spec,
//! catalog, cluster, engine config — and, for elastic runs, the same
//! resource plan — reproduce a bit-identical [`TrafficReport`].
//!
//! # Examples
//!
//! Two small c-DG2 workflows, 600 s apart, on the paper's allocation:
//!
//! ```
//! use asyncflow::engine::EngineConfig;
//! use asyncflow::resources::ClusterSpec;
//! use asyncflow::traffic::{
//!     run_traffic, ArrivalProcess, Catalog, TrafficSpec, WorkloadMix,
//! };
//!
//! let spec = TrafficSpec {
//!     process: ArrivalProcess::Deterministic { interval: 600.0 },
//!     mix: WorkloadMix::parse("cdg2-small").unwrap(),
//!     duration: 1200.0,
//!     max_workflows: 4,
//!     seed: 1,
//!     plan: None,
//!     checkpoint_at: None,
//!     policy: None,
//!     failure: None,
//! };
//! let report = run_traffic(
//!     &spec,
//!     &Catalog::builtin(),
//!     &ClusterSpec::summit_paper(),
//!     &EngineConfig::ideal(),
//! )
//! .unwrap();
//! assert_eq!(report.workflows.len(), 2);
//! assert!(!report.is_saturated());
//! ```

mod report;

pub use report::{TrafficReport, WorkflowStat};

use std::cell::RefCell;
use std::rc::Rc;

use crate::checkpoint::SimSnapshot;
use crate::ddmd::{ddmd_workflow, DdmdConfig};
use crate::engine::{Coordinator, EngineConfig, ExecutionMode, RunOutcome};
use crate::entk::Workflow;
use crate::error::{Error, Result};
use crate::failure::FailureSpec;
use crate::obs::profile::EngineProfile;
use crate::obs::{EventSink, ObsEvent};
use crate::pilot::ResourcePlan;
use crate::resources::ClusterSpec;
use crate::sched::Policy;
use crate::sim::VirtualExecutor;
use crate::util::json::{from_u64, obj, FromJson, Json, ToJson};
use crate::util::rng::Rng;
use crate::workflows::{cdg1, cdg2};

/// One arrival of a trace-driven process: a time offset and optionally
/// a pinned workload name (`None` draws from the [`WorkloadMix`]).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceArrival {
    /// Arrival offset in engine seconds (>= 0).
    pub at: f64,
    /// Catalog workload to instantiate; `None` samples the mix.
    pub workload: Option<String>,
}

/// How workflow arrival times are generated.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// One arrival every `interval` seconds, starting at t = 0.
    Deterministic { interval: f64 },
    /// Poisson process with `rate` arrivals per second (exponential
    /// inter-arrival times; first arrival strictly after t = 0).
    Poisson { rate: f64 },
    /// Explicit arrival offsets (e.g. replayed from a production log);
    /// taken verbatim, sorted by time — the `duration` window does not
    /// truncate a trace.
    Trace(Vec<TraceArrival>),
}

impl ArrivalProcess {
    /// Concrete arrivals for one run: at most `cap` entries, generated
    /// processes stop at the `duration` horizon.
    pub fn generate(&self, duration: f64, cap: usize, rng: &mut Rng) -> Vec<TraceArrival> {
        let mut out = Vec::new();
        match self {
            ArrivalProcess::Deterministic { interval } => {
                if *interval > 0.0 {
                    let mut t = 0.0;
                    while t < duration && out.len() < cap {
                        out.push(TraceArrival { at: t, workload: None });
                        t += interval;
                    }
                }
            }
            ArrivalProcess::Poisson { rate } => {
                if *rate > 0.0 {
                    let mut t = rng.exp(*rate);
                    while t < duration && out.len() < cap {
                        out.push(TraceArrival { at: t, workload: None });
                        t += rng.exp(*rate);
                    }
                }
            }
            ArrivalProcess::Trace(entries) => {
                // Sort before capping so a capped unsorted trace keeps
                // the *earliest* arrivals, not a file-order prefix.
                out = entries.to_vec();
                out.sort_by(|a, b| a.at.total_cmp(&b.at));
                out.truncate(cap);
            }
        }
        out
    }
}

/// A weighted mix of catalog workload names, e.g. `"ddmd:2,cdg2:1"`.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadMix {
    /// (workload name, weight > 0).
    entries: Vec<(String, f64)>,
    total: f64,
}

impl WorkloadMix {
    /// Parse `"name[:weight],name[:weight],..."`; a bare name weighs 1.
    pub fn parse(spec: &str) -> Result<WorkloadMix> {
        let mut entries = Vec::new();
        let mut total = 0.0;
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, weight) = match part.split_once(':') {
                Some((n, w)) => {
                    let w: f64 = w.trim().parse().map_err(|_| {
                        Error::Config(format!("--mix: bad weight in '{part}'"))
                    })?;
                    (n.trim(), w)
                }
                None => (part, 1.0),
            };
            if name.is_empty() || !weight.is_finite() || weight <= 0.0 {
                return Err(Error::Config(format!("--mix: invalid entry '{part}'")));
            }
            total += weight;
            entries.push((name.to_string(), weight));
        }
        if entries.is_empty() {
            return Err(Error::Config(format!("--mix: no workloads in '{spec}'")));
        }
        Ok(WorkloadMix { entries, total })
    }

    /// Draw one workload name, weight-proportionally.
    pub fn sample(&self, rng: &mut Rng) -> &str {
        let mut u = rng.f64() * self.total;
        for (name, w) in &self.entries {
            if u < *w {
                return name;
            }
            u -= w;
        }
        // Floating-point slop: fall back to the last entry.
        &self.entries.last().expect("mix is non-empty").0
    }

    /// Workload names in the mix (mix-spec order).
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|(n, _)| n.as_str())
    }
}

/// Named workload catalog: each arriving workflow clones one entry.
#[derive(Debug, Clone, Default)]
pub struct Catalog {
    entries: Vec<(String, Workflow)>,
}

impl Catalog {
    pub fn new() -> Catalog {
        Catalog::default()
    }

    /// Builder-style insert (later inserts shadow earlier same names).
    pub fn insert(mut self, name: impl Into<String>, wf: Workflow) -> Catalog {
        let name = name.into();
        self.entries.retain(|(n, _)| *n != name);
        self.entries.push((name, wf));
        self
    }

    pub fn get(&self, name: &str) -> Option<&Workflow> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, wf)| wf)
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// The paper workloads plus scaled variants: `ddmd`, `ddmd-small`,
    /// `cdg1`, `cdg2`, and `cdg1-small` / `cdg2-small` (task counts
    /// divided by 8, TX scaled to 10% — sized so a stream of them
    /// saturates a Summit-scale allocation in minutes, not hours).
    pub fn builtin() -> Catalog {
        Catalog::new()
            .insert("ddmd", ddmd_workflow(&DdmdConfig::paper()))
            .insert("ddmd-small", ddmd_workflow(&DdmdConfig::small()))
            .insert("cdg1", cdg1())
            .insert("cdg2", cdg2())
            .insert("cdg1-small", scaled_workflow(&cdg1(), 8, 0.1))
            .insert("cdg2-small", scaled_workflow(&cdg2(), 8, 0.1))
    }
}

/// Scale a workflow for traffic experiments: divide every set's task
/// count by `tasks_div` (floored at 1) and multiply its mean TX by
/// `tx_scale`. Structure (DAG, realizations, per-task resources) is
/// preserved.
pub fn scaled_workflow(wf: &Workflow, tasks_div: u32, tx_scale: f64) -> Workflow {
    assert!(tasks_div >= 1, "tasks_div must be >= 1");
    assert!(tx_scale > 0.0, "tx_scale must be positive");
    let mut out = wf.clone();
    out.name = format!("{}-div{}", wf.name, tasks_div);
    for s in &mut out.sets {
        s.tasks = (s.tasks / tasks_div).max(1);
        s.tx_mean *= tx_scale;
    }
    out
}

/// One streaming-traffic scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficSpec {
    pub process: ArrivalProcess,
    pub mix: WorkloadMix,
    /// Arrival window in engine seconds: generators stop emitting at
    /// this horizon; already-queued work still drains to completion.
    pub duration: f64,
    /// Hard cap on generated workflows (runaway-sweep guard).
    pub max_workflows: usize,
    /// Seed for the arrival and mix streams (task TX streams use
    /// [`EngineConfig::seed`]).
    pub seed: u64,
    /// Elastic allocation plan (timed `--resize` events and/or the
    /// `--autoscale` policy); `None` keeps the allocation fixed.
    pub plan: Option<ResourcePlan>,
    /// Preemption point (engine seconds): when set, the run stops the
    /// moment the clock reaches it and [`run_traffic_resumable`]
    /// returns a [`TrafficCheckpoint`] instead of a report. `None`
    /// runs to completion.
    pub checkpoint_at: Option<f64>,
    /// Scheduling discipline override (`--policy fifo|fair|backfill`):
    /// `Some(p)` replaces [`EngineConfig::policy`] for this run, `None`
    /// keeps it — so a spec fully describes its scenario. Checkpoints
    /// carry the resolved policy; resumes replay it automatically.
    pub policy: Option<Policy>,
    /// Failure injection (`--mtbf` / `--fail-trace` / `--retry`): node
    /// faults hard-kill running tasks, which re-enter the scheduler
    /// under the spec's retry policy. `None` injects nothing.
    /// Checkpoints carry the live failure-process state; resumes
    /// continue the fault sequence bit-identically.
    pub failure: Option<FailureSpec>,
}

/// Run one traffic scenario: sample arrivals, stream every workflow
/// through a shared-pilot [`Coordinator`] at its arrival time, and
/// reduce the member reports to queueing metrics.
///
/// # Examples
///
/// Stream three small c-DG2 workflows, one every 400 s, through the
/// paper's Summit allocation:
///
/// ```
/// use asyncflow::engine::EngineConfig;
/// use asyncflow::resources::ClusterSpec;
/// use asyncflow::traffic::{run_traffic, ArrivalProcess, Catalog, TrafficSpec, WorkloadMix};
///
/// let spec = TrafficSpec {
///     process: ArrivalProcess::Deterministic { interval: 400.0 },
///     mix: WorkloadMix::parse("cdg2-small").unwrap(),
///     duration: 1200.0,
///     max_workflows: 8,
///     seed: 7,
///     plan: None,
///     checkpoint_at: None,
///     policy: None,
///     failure: None,
/// };
/// let report = run_traffic(
///     &spec,
///     &Catalog::builtin(),
///     &ClusterSpec::summit_paper(),
///     &EngineConfig::ideal(),
/// )
/// .unwrap();
/// assert_eq!(report.workflows.len(), 3); // arrivals at t = 0, 400, 800
/// assert!(report.makespan > 0.0);
/// assert!(report.capacity.is_constant()); // no resource plan attached
/// ```
pub fn run_traffic(
    spec: &TrafficSpec,
    catalog: &Catalog,
    cluster: &ClusterSpec,
    cfg: &EngineConfig,
) -> Result<TrafficReport> {
    match run_traffic_resumable(spec, catalog, cluster, cfg)? {
        TrafficOutcome::Completed(report) => Ok(*report),
        TrafficOutcome::Checkpointed(_) => Err(Error::Config(
            "traffic: the run reached its checkpoint point before finishing; \
             use run_traffic_resumable (CLI: --checkpoint-out + `asyncflow resume`)"
                .into(),
        )),
    }
}

/// Run one [`run_traffic`] per spec, sharding the independent
/// simulations across up to `jobs` OS threads (`jobs == 0` means one
/// per available core). Each simulation is deterministic and fully
/// independent — workers share nothing but the work index — so the
/// returned reports are **byte-identical to the serial runner's**, in
/// input order, for any `jobs` (the CLI's parallel `--sweep --jobs N`;
/// see `tests/traffic.rs`).
pub fn run_traffic_sweep(
    specs: &[TrafficSpec],
    catalog: &Catalog,
    cluster: &ClusterSpec,
    cfg: &EngineConfig,
    jobs: usize,
) -> Result<Vec<TrafficReport>> {
    let jobs = match jobs {
        0 => std::thread::available_parallelism().map_or(1, |n| n.get()),
        n => n,
    }
    .min(specs.len().max(1));
    if jobs <= 1 {
        return specs
            .iter()
            .map(|s| run_traffic(s, catalog, cluster, cfg))
            .collect();
    }
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    // Self-scheduling work queue: each worker claims the next unclaimed
    // spec index, so a slow (saturated) rate never blocks the others.
    // Results land in their input slot — merge order is seed/input
    // order by construction, independent of completion order.
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<TrafficReport>>>> =
        specs.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= specs.len() {
                    break;
                }
                let r = run_traffic(&specs[i], catalog, cluster, cfg);
                *slots[i].lock().expect("sweep slot lock") = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("sweep slot lock")
                .expect("every claimed spec stores a result")
        })
        .collect()
}

/// CSV of a rate sweep's per-rate headline metrics (the CLI table as
/// data): one row per `(rate, report)` pair, input order.
pub fn sweep_csv(rates: &[f64], reports: &[TrafficReport]) -> String {
    let mut out = String::from(
        "rate_per_s,workflows,wait_mean_s,ttx_p50_s,ttx_p95_s,\
         backlog_mean_tasks,backlog_growth,peak_cores,verdict\n",
    );
    for (rate, rep) in rates.iter().zip(reports) {
        out.push_str(&format!(
            "{rate},{},{},{},{},{},{},{},{}\n",
            rep.workflows.len(),
            rep.wait.mean,
            rep.ttx.p50,
            rep.ttx.p95,
            rep.mean_backlog_tasks,
            rep.backlog_growth(),
            rep.capacity.peak().0,
            if rep.is_saturated() { "SATURATED" } else { "bounded" },
        ));
    }
    out
}

/// JSON of a rate sweep: `[{rate, report}, ...]`, input order.
pub fn sweep_json(rates: &[f64], reports: &[TrafficReport]) -> Json {
    Json::Arr(
        rates
            .iter()
            .zip(reports)
            .map(|(rate, rep)| {
                obj([("rate", Json::from(*rate)), ("report", rep.to_json())])
            })
            .collect(),
    )
}

/// Observability attachments for one traffic run: an optional
/// [`EventSink`] (`--emit-events`) and an optional self-profiling
/// handle (`--profile`), threaded into the run's [`Coordinator`].
/// The default attaches nothing and costs nothing.
///
/// Sinks are typically shared handles (`Rc<RefCell<FileSink>>` /
/// `Rc<RefCell<MemSink>>`) so the stream outlives the run — and so one
/// stream can span every leg of a chained checkpoint/resume run (see
/// [`run_chained_obs`](crate::failure::cadence::run_chained_obs)).
#[derive(Default)]
pub struct TrafficObs {
    /// Event sink attached to the run's coordinator.
    pub sink: Option<Box<dyn EventSink>>,
    /// Self-profiling handle (counters accumulate across the run).
    pub profile: Option<Rc<RefCell<EngineProfile>>>,
}

impl TrafficObs {
    /// Attach `self` to a coordinator (consuming the attachments).
    fn install(self, coord: &mut Coordinator) {
        if let Some(sink) = self.sink {
            coord.set_event_sink(sink);
        }
        if let Some(p) = self.profile {
            coord.set_profile_handle(p);
        }
    }
}

/// How a (possibly preempted) traffic run ended.
#[derive(Debug)]
pub enum TrafficOutcome {
    /// The stream drained; the full queueing report.
    Completed(Box<TrafficReport>),
    /// The clock reached [`TrafficSpec::checkpoint_at`] first.
    Checkpointed(Box<TrafficCheckpoint>),
}

/// [`run_traffic`] with preemption support: when
/// [`TrafficSpec::checkpoint_at`] is set and the engine clock reaches
/// it before the stream drains, returns a [`TrafficCheckpoint`]
/// carrying the full simulation snapshot plus the traffic-level
/// bookkeeping (workload names, arrival times, arrival window) needed
/// to finish the report after [`TrafficCheckpoint::resume`].
pub fn run_traffic_resumable(
    spec: &TrafficSpec,
    catalog: &Catalog,
    cluster: &ClusterSpec,
    cfg: &EngineConfig,
) -> Result<TrafficOutcome> {
    run_traffic_resumable_obs(spec, catalog, cluster, cfg, TrafficObs::default())
}

/// [`run_traffic_resumable`] with observability attachments: the sink
/// receives the run's typed event stream and the profile handle its
/// wall-clock counters (see [`TrafficObs`]). The attachments never
/// change the simulation — a run with a sink is bit-identical to one
/// without.
pub fn run_traffic_resumable_obs(
    spec: &TrafficSpec,
    catalog: &Catalog,
    cluster: &ClusterSpec,
    cfg: &EngineConfig,
    obs: TrafficObs,
) -> Result<TrafficOutcome> {
    if !spec.duration.is_finite() || spec.duration <= 0.0 {
        return Err(Error::Config(format!(
            "traffic: invalid duration {}",
            spec.duration
        )));
    }
    if spec.max_workflows == 0 {
        return Err(Error::Config("traffic: max_workflows must be >= 1".into()));
    }
    // Catch mix typos up front, not when an entry is first sampled
    // mid-run (which would be seed-dependent).
    for name in spec.mix.names() {
        if catalog.get(name).is_none() {
            return Err(Error::Config(format!(
                "traffic: unknown workload '{name}' in mix (catalog: {})",
                catalog.names().join(", ")
            )));
        }
    }
    // Per-spec policy override: the spec fully describes the scenario
    // (sweeps and matrices vary the discipline without cloning configs).
    let cfg = match spec.policy {
        Some(p) => EngineConfig { policy: p, ..cfg.clone() },
        None => cfg.clone(),
    };
    let cfg = &cfg;
    let mut root = Rng::new(spec.seed);
    let mut arrival_rng = root.fork(0x5452_4146); // "TRAF"
    let mut mix_rng = root.fork(0x4d49_5858); // "MIXX"
    let arrivals =
        spec.process
            .generate(spec.duration, spec.max_workflows, &mut arrival_rng);
    if arrivals.is_empty() {
        return Err(Error::Config(
            "traffic: arrival process produced no arrivals in the window".into(),
        ));
    }
    // Queueing metrics are windowed over the *actual* arrival span:
    // for the generated processes that is `duration` — unless the
    // max_workflows cap cut the stream short — and a trace is taken
    // verbatim (never truncated to `duration`), so its own span is the
    // window. Windowing over a longer interval than arrivals actually
    // covered would dilute the backlog halves with post-arrival drain
    // and flip a genuinely saturated run to "bounded".
    let last_arrival = arrivals.last().map(|a| a.at).unwrap_or(0.0);
    let arrival_window = match &spec.process {
        ArrivalProcess::Trace(_) => last_arrival.max(f64::MIN_POSITIVE),
        _ if arrivals.len() == spec.max_workflows => {
            last_arrival.max(f64::MIN_POSITIVE)
        }
        _ => spec.duration,
    };

    let mut coord = Coordinator::new(cluster, cfg);
    if let Some(plan) = &spec.plan {
        coord.set_resource_plan(plan.clone())?;
    }
    if let Some(failure) = &spec.failure {
        coord.set_failure_spec(failure.clone())?;
    }
    // Stream header: a fresh traffic run stamps its arrival window
    // before the engine's first event, so a replay can reproduce the
    // report's backlog-saturation verdict. Resumed legs never re-emit
    // it (see `TrafficCheckpoint::resume_until_obs`) — a chained
    // stream carries exactly one header and the resume-concatenation
    // equality is untouched.
    let mut obs = obs;
    if let Some(sink) = obs.sink.as_mut() {
        if sink.enabled() {
            sink.emit(&ObsEvent::TrafficMeta {
                t: 0.0,
                window: arrival_window,
                failure: spec.failure.is_some(),
            });
        }
    }
    obs.install(&mut coord);
    let mut names = Vec::with_capacity(arrivals.len());
    let mut times = Vec::with_capacity(arrivals.len());
    for a in &arrivals {
        let name = match &a.workload {
            Some(n) => n.clone(),
            None => spec.mix.sample(&mut mix_rng).to_string(),
        };
        let wf = catalog.get(&name).ok_or_else(|| {
            Error::Config(format!(
                "traffic: unknown workload '{name}' (catalog: {})",
                catalog.names().join(", ")
            ))
        })?;
        coord.add_workflow(wf.clone(), ExecutionMode::Asynchronous, a.at)?;
        names.push(name);
        times.push(a.at);
    }

    let mut ex = VirtualExecutor::new();
    match coord.run_until(&mut ex, spec.checkpoint_at)? {
        RunOutcome::Completed(members) => Ok(TrafficOutcome::Completed(Box::new(
            TrafficReport::build(arrival_window, names, times, members, cluster),
        ))),
        RunOutcome::Checkpointed(sim) => {
            Ok(TrafficOutcome::Checkpointed(Box::new(TrafficCheckpoint {
                arrival_window,
                names,
                arrivals: times,
                sim: *sim,
            })))
        }
    }
}

/// A preempted traffic run: the simulation snapshot plus the
/// traffic-level bookkeeping needed to finish the [`TrafficReport`]
/// after resuming. Serializes via [`ToJson`]/[`FromJson`] (the CLI's
/// `--checkpoint-out ckpt.json` / `asyncflow resume ckpt.json`).
#[derive(Debug, Clone)]
pub struct TrafficCheckpoint {
    /// Arrival window the generator used (seconds).
    pub arrival_window: f64,
    /// Catalog workload name per member, in registration order.
    pub names: Vec<String>,
    /// Arrival time per member, in registration order.
    pub arrivals: Vec<f64>,
    /// The engine-level snapshot.
    pub sim: SimSnapshot,
}

impl TrafficCheckpoint {
    /// Resume the interrupted run to completion and reduce it to the
    /// same [`TrafficReport`] the uninterrupted run would have
    /// produced (bit-identical for an unchanged allocation). `plan`
    /// optionally reshapes the follow-up pilot: its resize events are
    /// absolute engine times (anything at or before the checkpoint
    /// instant applies immediately), so a preempted run can restart on
    /// a smaller or growing allocation.
    pub fn resume(self, plan: Option<ResourcePlan>) -> Result<TrafficReport> {
        match self.resume_until(plan, None)? {
            TrafficOutcome::Completed(rep) => Ok(*rep),
            TrafficOutcome::Checkpointed(_) => Err(Error::Engine(
                "traffic resume: run without a checkpoint time cannot re-checkpoint".into(),
            )),
        }
    }

    /// [`resume`](Self::resume) with re-preemption support: run until
    /// `checkpoint_at` (an absolute engine time past the snapshot
    /// instant) and hand back a fresh [`TrafficCheckpoint`] if the
    /// clock gets there before the stream drains. The building block
    /// of the periodic `--checkpoint-every` chain: each leg resumes
    /// the previous leg's snapshot and checkpoints again one cadence
    /// later.
    pub fn resume_until(
        self,
        plan: Option<ResourcePlan>,
        checkpoint_at: Option<f64>,
    ) -> Result<TrafficOutcome> {
        self.resume_until_obs(plan, checkpoint_at, TrafficObs::default())
    }

    /// [`resume_until`](Self::resume_until) with observability
    /// attachments. The event stream is derived state and never part of
    /// the checkpoint, so the caller re-attaches a sink per leg —
    /// typically the *same* shared handle, making the concatenated
    /// stream across legs equal the uninterrupted run's stream.
    pub fn resume_until_obs(
        self,
        plan: Option<ResourcePlan>,
        checkpoint_at: Option<f64>,
        obs: TrafficObs,
    ) -> Result<TrafficOutcome> {
        let TrafficCheckpoint { arrival_window, names, arrivals, sim } = self;
        if names.len() != sim.n_members || arrivals.len() != sim.n_members {
            return Err(Error::Config(format!(
                "traffic checkpoint: {} names / {} arrivals for {} members",
                names.len(),
                arrivals.len(),
                sim.n_members
            )));
        }
        let cluster = sim.cluster.clone();
        let mut coord = Coordinator::restore(sim)?;
        if let Some(p) = plan {
            coord.set_resource_plan(p)?;
        }
        obs.install(&mut coord);
        let mut ex = VirtualExecutor::new();
        match coord.run_until(&mut ex, checkpoint_at)? {
            RunOutcome::Completed(members) => Ok(TrafficOutcome::Completed(Box::new(
                TrafficReport::build(arrival_window, names, arrivals, members, &cluster),
            ))),
            RunOutcome::Checkpointed(sim) => {
                Ok(TrafficOutcome::Checkpointed(Box::new(TrafficCheckpoint {
                    arrival_window,
                    names,
                    arrivals,
                    sim: *sim,
                })))
            }
        }
    }
}

impl ToJson for TrafficCheckpoint {
    fn to_json(&self) -> Json {
        obj([
            ("version", from_u64(crate::checkpoint::SNAPSHOT_VERSION)),
            ("arrival_window", Json::from(self.arrival_window)),
            (
                "names",
                Json::Arr(self.names.iter().map(|n| Json::from(n.clone())).collect()),
            ),
            (
                "arrivals",
                Json::Arr(self.arrivals.iter().map(|&t| Json::from(t)).collect()),
            ),
            ("sim", self.sim.to_json()),
        ])
    }
}

impl FromJson for TrafficCheckpoint {
    fn from_json(v: &Json) -> Result<TrafficCheckpoint> {
        let version = v.req_u64("version")?;
        if version != crate::checkpoint::SNAPSHOT_VERSION {
            return Err(Error::Config(format!(
                "traffic checkpoint: version {version} is not supported (expected {})",
                crate::checkpoint::SNAPSHOT_VERSION
            )));
        }
        let mut names = Vec::new();
        for n in v.req_arr("names")? {
            names.push(
                n.as_str()
                    .ok_or_else(|| {
                        Error::Config("traffic checkpoint: names must be strings".into())
                    })?
                    .to_string(),
            );
        }
        let mut arrivals = Vec::new();
        for t in v.req_arr("arrivals")? {
            arrivals.push(t.as_f64().ok_or_else(|| {
                Error::Config("traffic checkpoint: arrivals must be numbers".into())
            })?);
        }
        Ok(TrafficCheckpoint {
            arrival_window: v.req_f64("arrival_window")?,
            names,
            arrivals,
            sim: SimSnapshot::from_json(v.get("sim"))?,
        })
    }
}

/// Parse a trace-driven arrival file. Accepted shapes:
///
/// ```json
/// { "arrivals": [0, 300.5, {"t": 900, "workload": "cdg2"}] }
/// ```
///
/// or a bare top-level array. Plain numbers draw their workload from
/// the mix; objects may pin one with `"workload"`.
pub fn parse_trace(src: &str) -> Result<ArrivalProcess> {
    let v = Json::parse(src)?;
    let arr = match &v {
        Json::Arr(_) => v.as_arr().expect("matched array"),
        _ => v.get("arrivals").as_arr().ok_or_else(|| {
            Error::Config("trace: expected an array or {\"arrivals\": [...]}".into())
        })?,
    };
    let mut out = Vec::with_capacity(arr.len());
    for (i, e) in arr.iter().enumerate() {
        let (at, workload) = match e {
            Json::Num(t) => (*t, None),
            Json::Obj(_) => (
                e.req_f64("t")?,
                e.get("workload").as_str().map(|s| s.to_string()),
            ),
            _ => {
                return Err(Error::Config(format!(
                    "trace: arrival #{i} must be a number or an object with 't'"
                )))
            }
        };
        if !at.is_finite() || at < 0.0 {
            return Err(Error::Config(format!("trace: invalid arrival time {at}")));
        }
        out.push(TraceArrival { at, workload });
    }
    Ok(ArrivalProcess::Trace(out))
}

/// [`parse_trace`] over a file path.
pub fn load_trace_file(path: &str) -> Result<ArrivalProcess> {
    let src = std::fs::read_to_string(path)?;
    parse_trace(&src)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_arrivals_start_at_zero() {
        let mut rng = Rng::new(1);
        let a = ArrivalProcess::Deterministic { interval: 10.0 }.generate(35.0, 100, &mut rng);
        let ts: Vec<f64> = a.iter().map(|x| x.at).collect();
        assert_eq!(ts, vec![0.0, 10.0, 20.0, 30.0]);
    }

    #[test]
    fn poisson_arrivals_are_reproducible_and_in_window() {
        let gen = |seed| {
            let mut rng = Rng::new(seed);
            ArrivalProcess::Poisson { rate: 0.1 }
                .generate(1000.0, 10_000, &mut rng)
                .iter()
                .map(|x| x.at)
                .collect::<Vec<f64>>()
        };
        let a = gen(7);
        let b = gen(7);
        assert_eq!(a, b, "same seed, same arrivals");
        assert_ne!(a, gen(8), "different seed, different arrivals");
        // ~100 expected; loose 3-sigma-ish bounds.
        assert!((60..=140).contains(&a.len()), "got {} arrivals", a.len());
        assert!(a.iter().all(|&t| t > 0.0 && t < 1000.0));
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "sorted by construction");
    }

    #[test]
    fn arrival_cap_is_respected() {
        let mut rng = Rng::new(1);
        let a = ArrivalProcess::Deterministic { interval: 1.0 }.generate(1e9, 25, &mut rng);
        assert_eq!(a.len(), 25);
    }

    #[test]
    fn mix_parses_and_samples_proportionally() {
        let mix = WorkloadMix::parse("a:3, b:1").unwrap();
        assert_eq!(mix.names().collect::<Vec<_>>(), vec!["a", "b"]);
        let mut rng = Rng::new(9);
        let mut na = 0;
        for _ in 0..4000 {
            if mix.sample(&mut rng) == "a" {
                na += 1;
            }
        }
        // E[na] = 3000; loose bounds.
        assert!((2700..=3300).contains(&na), "na = {na}");
        // Bare names weigh 1.
        let m2 = WorkloadMix::parse("solo").unwrap();
        assert_eq!(m2.sample(&mut rng), "solo");
    }

    #[test]
    fn mix_rejects_garbage() {
        assert!(WorkloadMix::parse("").is_err());
        assert!(WorkloadMix::parse("a:0").is_err());
        assert!(WorkloadMix::parse("a:-1").is_err());
        assert!(WorkloadMix::parse("a:x").is_err());
        assert!(WorkloadMix::parse(":2").is_err());
    }

    #[test]
    fn builtin_catalog_has_paper_workloads() {
        let c = Catalog::builtin();
        for name in ["ddmd", "ddmd-small", "cdg1", "cdg2", "cdg1-small", "cdg2-small"] {
            let wf = c.get(name).unwrap_or_else(|| panic!("missing '{name}'"));
            wf.validate().unwrap();
        }
        assert!(c.get("nope").is_none());
    }

    #[test]
    fn scaled_workflow_shrinks_tasks_and_tx() {
        let base = cdg2();
        let s = scaled_workflow(&base, 8, 0.1);
        s.validate().unwrap();
        assert_eq!(s.sets.len(), base.sets.len());
        for (orig, small) in base.sets.iter().zip(&s.sets) {
            assert_eq!(small.tasks, (orig.tasks / 8).max(1));
            assert!((small.tx_mean - orig.tx_mean * 0.1).abs() < 1e-9);
            assert_eq!(small.req, orig.req);
        }
        assert!(s.total_tasks() < base.total_tasks());
    }

    #[test]
    fn parse_trace_accepts_numbers_and_objects() {
        let p = parse_trace(r#"{"arrivals": [0, 300.5, {"t": 900, "workload": "cdg2"}]}"#)
            .unwrap();
        let ArrivalProcess::Trace(entries) = &p else {
            panic!("expected trace")
        };
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0], TraceArrival { at: 0.0, workload: None });
        assert_eq!(entries[1].at, 300.5);
        assert_eq!(entries[2].workload.as_deref(), Some("cdg2"));
        // Bare array form.
        let p2 = parse_trace("[1, 2, 3]").unwrap();
        let ArrivalProcess::Trace(e2) = &p2 else { panic!() };
        assert_eq!(e2.len(), 3);
        // Rejects negatives and junk.
        assert!(parse_trace("[-1]").is_err());
        assert!(parse_trace(r#"[{"workload": "x"}]"#).is_err());
        assert!(parse_trace(r#"{"x": 1}"#).is_err());
        assert!(parse_trace(r#"["zero"]"#).is_err());
    }
}
