//! Discrete-event simulation substrate (S10): a virtual clock driven by
//! a binary-heap event queue, and the [`VirtualExecutor`] that runs
//! workflows in virtual time at Summit scale.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::exec::{Completion, Executor, RunningTask};

/// An event in virtual time. Min-heap by (time, seq) — seq keeps
/// ordering deterministic for simultaneous events.
#[derive(Debug, Clone, Copy)]
struct Event {
    time: f64,
    seq: u64,
    uid: usize,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap semantics inside BinaryHeap (max-heap).
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic virtual-time event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
    now: f64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule a completion at absolute virtual time `t`.
    pub fn push(&mut self, t: f64, uid: usize) {
        debug_assert!(t >= self.now, "cannot schedule into the past");
        self.heap.push(Event { time: t, seq: self.seq, uid });
        self.seq += 1;
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, usize)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        Some((e.time, e.uid))
    }

    /// Time of the earliest pending event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Remove every pending event for `uid` (a killed task's
    /// completion must never fire). `retain` preserves the surviving
    /// events' sequence numbers, so simultaneous-event ordering among
    /// survivors is unchanged.
    pub fn cancel(&mut self, uid: usize) {
        self.heap.retain(|e| e.uid != uid);
    }

    /// Fast-forward the clock (never backwards).
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            debug_assert!(!self.peek_time().is_some_and(|p| t > p + crate::engine::EPS));
            self.now = t;
        }
    }
}

/// Executor that "runs" tasks by scheduling their completion in virtual
/// time. All paper-scale experiments use this backend: 16-node Summit
/// runs complete in milliseconds of wall-clock.
#[derive(Debug, Default)]
pub struct VirtualExecutor {
    queue: EventQueue,
}

impl VirtualExecutor {
    pub fn new() -> VirtualExecutor {
        VirtualExecutor::default()
    }
}

impl Executor for VirtualExecutor {
    fn launch(&mut self, task: &RunningTask) {
        self.queue.push(task.started_at + task.tx, task.uid);
    }

    fn wait_next(&mut self) -> Option<Completion> {
        self.queue
            .pop()
            .map(|(t, uid)| Completion { uid, finished_at: t, failed: false })
    }

    fn now(&self) -> f64 {
        self.queue.now()
    }

    fn peek_next_completion(&self) -> Option<f64> {
        self.queue.peek_time()
    }

    fn advance_to(&mut self, t: f64) {
        self.queue.advance_to(t);
    }

    fn cancel(&mut self, uid: usize) {
        self.queue.cancel(uid);
    }

    fn drain_ready_into(&mut self, out: &mut Vec<Completion>) {
        // Pop the earliest event plus every event at exactly the same
        // virtual instant: one engine wakeup per time point, not per
        // task (the paper-scale workloads complete 96-task sets
        // simultaneously when sigma = 0).
        out.clear();
        if let Some((t, uid)) = self.queue.pop() {
            out.push(Completion { uid, finished_at: t, failed: false });
            while self.queue.peek_time() == Some(t) {
                let (t2, uid2) = self.queue.pop().expect("peeked event exists");
                out.push(Completion { uid: uid2, finished_at: t2, failed: false });
            }
        }
    }

    fn wait_until(&mut self, t: f64) -> bool {
        self.queue.advance_to(t);
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, 0);
        q.push(1.0, 1);
        q.push(2.0, 2);
        assert_eq!(q.pop(), Some((1.0, 1)));
        assert_eq!(q.pop(), Some((2.0, 2)));
        assert_eq!(q.pop(), Some((3.0, 0)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut q = EventQueue::new();
        for uid in 0..5 {
            q.push(1.0, uid);
        }
        let uids: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|(_, u)| u).collect();
        assert_eq!(uids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(5.0, 0);
        q.push(7.0, 1);
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 5.0);
        // Scheduling relative to the new now works.
        q.push(q.now() + 1.0, 2);
        assert_eq!(q.pop(), Some((6.0, 2)));
        assert_eq!(q.pop(), Some((7.0, 1)));
    }

    #[test]
    fn drain_ready_batches_simultaneous_completions() {
        let mut ex = VirtualExecutor::new();
        ex.launch(&RunningTask { uid: 0, tx: 5.0, started_at: 0.0, kind: None });
        ex.launch(&RunningTask { uid: 1, tx: 5.0, started_at: 0.0, kind: None });
        ex.launch(&RunningTask { uid: 2, tx: 9.0, started_at: 0.0, kind: None });
        let batch = ex.drain_ready();
        assert_eq!(batch.len(), 2, "both t=5 completions in one call");
        assert_eq!(batch[0].uid, 0);
        assert_eq!(batch[1].uid, 1);
        assert_eq!(ex.now(), 5.0);
        let batch = ex.drain_ready();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].uid, 2);
        assert!(ex.drain_ready().is_empty());
    }

    #[test]
    fn virtual_executor_completes_in_tx_order() {
        let mut ex = VirtualExecutor::new();
        ex.launch(&RunningTask { uid: 0, tx: 10.0, started_at: 0.0, kind: None });
        ex.launch(&RunningTask { uid: 1, tx: 2.0, started_at: 0.0, kind: None });
        let c1 = ex.wait_next().unwrap();
        assert_eq!(c1.uid, 1);
        assert_eq!(c1.finished_at, 2.0);
        assert_eq!(ex.now(), 2.0);
        let c0 = ex.wait_next().unwrap();
        assert_eq!(c0.uid, 0);
    }
}
