//! Runtime service: a dedicated thread owning the (non-`Send`) PJRT
//! [`Engine`], fronted by a cloneable [`RuntimeHandle`].
//!
//! This mirrors how the paper's platform treats GPUs as scarce shared
//! devices: ML task bodies running on worker threads funnel their
//! compute through this service, and the service thread is the single
//! owner of PJRT state. Requests are processed in arrival order.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use super::{Engine, Tensor};
use crate::error::{Error, Result};

enum Request {
    Execute {
        artifact: String,
        inputs: Vec<Tensor>,
        reply: Sender<Result<Vec<Tensor>>>,
    },
    Stats {
        reply: Sender<(usize, usize)>,
    },
    Shutdown,
}

/// Cloneable client handle to the runtime service thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Request>,
}

impl RuntimeHandle {
    /// Execute an artifact; blocks until the service replies.
    pub fn execute(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Execute { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| Error::Runtime("runtime service is down".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("runtime service dropped the reply".into()))?
    }

    /// (compiles, executions) counters.
    pub fn stats(&self) -> Result<(usize, usize)> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| Error::Runtime("runtime service is down".into()))?;
        rx.recv().map_err(|_| Error::Runtime("runtime service dropped the reply".into()))
    }
}

/// The service thread wrapper.
pub struct RuntimeService {
    tx: Sender<Request>,
    join: Option<JoinHandle<()>>,
}

impl RuntimeService {
    /// Spawn the service thread over an artifact directory.
    pub fn start(artifact_dir: impl Into<std::path::PathBuf>) -> Result<RuntimeService> {
        let dir = artifact_dir.into();
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        // Open the engine on the service thread (PJRT state never moves).
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || {
                let mut engine = match Engine::open(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Execute { artifact, inputs, reply } => {
                            let _ = reply.send(engine.execute(&artifact, &inputs));
                        }
                        Request::Stats { reply } => {
                            let _ = reply.send((engine.compiles, engine.executions));
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("cannot spawn runtime thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("runtime thread died during startup".into()))??;
        Ok(RuntimeService { tx, join: Some(join) })
    }

    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle { tx: self.tx.clone() }
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
    }

    #[test]
    fn service_executes_from_multiple_threads() {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let svc = RuntimeService::start(artifacts_dir()).unwrap();
        let mut joins = vec![];
        for i in 0..4 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                let x = Tensor::from_vec(vec![i as f32; 4], &[2, 2]).unwrap();
                let y = Tensor::from_vec(vec![1.0; 4], &[2, 2]).unwrap();
                let out = h.execute("sanity", vec![x, y]).unwrap();
                // row-sum of constant matrix i: each element = 2*i + 2
                assert_eq!(out[0].data[0], 2.0 * i as f32 + 2.0);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let (compiles, execs) = svc.handle().stats().unwrap();
        assert_eq!(compiles, 1);
        assert_eq!(execs, 4);
    }

    #[test]
    fn missing_dir_errors_cleanly() {
        assert!(RuntimeService::start("/nonexistent/artifacts").is_err());
    }
}
