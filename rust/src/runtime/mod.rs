//! PJRT runtime (substrate S14): loads the AOT-compiled HLO-text
//! artifacts produced by `python/compile/aot.py` and executes them on
//! the CPU PJRT client — the only way L3 touches L1/L2 compute. Python
//! never runs here.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md and /opt/xla-example/README.md).

mod service;
mod tensor;

pub use service::{RuntimeHandle, RuntimeService};
pub use tensor::Tensor;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Metadata sidecar emitted per artifact by `aot.py`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub arg_shapes: Vec<Vec<usize>>,
    pub result_shapes: Vec<Vec<usize>>,
}

impl ArtifactMeta {
    fn from_json(v: &Json) -> Result<ArtifactMeta> {
        let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
            v.req_arr(key)?
                .iter()
                .map(|a| {
                    a.req_arr("shape")?
                        .iter()
                        .map(|d| {
                            d.as_u64()
                                .map(|u| u as usize)
                                .ok_or_else(|| Error::Config("bad dim".into()))
                        })
                        .collect()
                })
                .collect()
        };
        Ok(ArtifactMeta {
            name: v.req_str("name")?.to_string(),
            arg_shapes: shapes("args")?,
            result_shapes: shapes("results")?,
        })
    }
}

/// Owns the PJRT client and compiled executables. NOT `Send` (raw PJRT
/// pointers) — wrap in [`RuntimeService`] for cross-thread use.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    metas: BTreeMap<String, ArtifactMeta>,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Compile-cache statistics (perf accounting).
    pub compiles: usize,
    pub executions: usize,
}

impl Engine {
    /// Open an artifact directory (reads `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = Json::parse(&text)?;
        let mut metas = BTreeMap::new();
        for a in manifest.req_arr("artifacts")? {
            let m = ArtifactMeta::from_json(a)?;
            metas.insert(m.name.clone(), m);
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, dir, metas, executables: BTreeMap::new(), compiles: 0, executions: 0 })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.metas.keys().map(|s| s.as_str()).collect()
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.get(name)
    }

    /// Compile an artifact (idempotent; cached thereafter).
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        if !self.metas.contains_key(name) {
            return Err(Error::Runtime(format!("unknown artifact '{name}'")));
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.insert(name.to_string(), exe);
        self.compiles += 1;
        Ok(())
    }

    /// Execute an artifact with f32 tensors; returns the result tuple.
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.ensure_compiled(name)?;
        let meta = &self.metas[name];
        if inputs.len() != meta.arg_shapes.len() {
            return Err(Error::Runtime(format!(
                "'{name}' expects {} args, got {}",
                meta.arg_shapes.len(),
                inputs.len()
            )));
        }
        for (i, (t, want)) in inputs.iter().zip(&meta.arg_shapes).enumerate() {
            if &t.dims != want {
                return Err(Error::Runtime(format!(
                    "'{name}' arg {i}: shape {:?} != expected {:?}",
                    t.dims, want
                )));
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(Tensor::to_literal).collect::<Result<_>>()?;
        let exe = &self.executables[name];
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        self.executions += 1;
        // aot.py lowers with return_tuple=True: always a top-level tuple.
        let parts = result.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // rust/ -> repo root
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn sanity_artifact_round_trip() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut eng = Engine::open(artifacts_dir()).unwrap();
        assert!(eng.artifact_names().contains(&"sanity"));
        let x = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let y = Tensor::from_vec(vec![1.0; 4], &[2, 2]).unwrap();
        let out = eng.execute("sanity", &[x, y]).unwrap();
        assert_eq!(out.len(), 1);
        // matmul + 2 = [[5,5],[9,9]]
        assert_eq!(out[0].data, vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        if !have_artifacts() {
            return;
        }
        let mut eng = Engine::open(artifacts_dir()).unwrap();
        let bad = Tensor::from_vec(vec![0.0; 6], &[2, 3]).unwrap();
        let ok = Tensor::from_vec(vec![0.0; 4], &[2, 2]).unwrap();
        assert!(eng.execute("sanity", &[bad, ok.clone()]).is_err());
        assert!(eng.execute("sanity", &[ok]).is_err(), "arity");
        assert!(eng.execute("nope", &[]).is_err(), "unknown artifact");
    }

    #[test]
    fn compile_cache_hits() {
        if !have_artifacts() {
            return;
        }
        let mut eng = Engine::open(artifacts_dir()).unwrap();
        let x = Tensor::from_vec(vec![0.0; 4], &[2, 2]).unwrap();
        for _ in 0..3 {
            eng.execute("sanity", &[x.clone(), x.clone()]).unwrap();
        }
        assert_eq!(eng.compiles, 1, "compiled once");
        assert_eq!(eng.executions, 3);
    }
}
