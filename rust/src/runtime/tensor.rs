//! Plain-data f32 tensor: the `Send`-able value type that crosses the
//! runtime service boundary (xla::Literal wraps raw pointers and can't).

use crate::error::{Error, Result};

/// Row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl Tensor {
    pub fn from_vec(data: Vec<f32>, dims: &[usize]) -> Result<Tensor> {
        let expect: usize = dims.iter().product();
        if data.len() != expect {
            return Err(Error::Runtime(format!(
                "tensor data length {} != product of dims {:?}",
                data.len(),
                dims
            )));
        }
        Ok(Tensor { data, dims: dims.to_vec() })
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { data: vec![v], dims: vec![] }
    }

    pub fn zeros(dims: &[usize]) -> Tensor {
        Tensor { data: vec![0.0; dims.iter().product()], dims: dims.to_vec() }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Mean of all elements (loss readouts).
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f32>() / self.data.len() as f32
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        if self.dims.is_empty() {
            return Ok(xla::Literal::scalar(self.data[0]));
        }
        let dims_i64: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims_i64)?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Tensor::from_vec(data, &dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_validates_length() {
        assert!(Tensor::from_vec(vec![1.0, 2.0], &[3]).is_err());
        assert!(Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).is_ok());
    }

    #[test]
    fn scalar_and_zeros() {
        let s = Tensor::scalar(2.5);
        assert_eq!(s.dims, Vec::<usize>::new());
        assert_eq!(s.mean(), 2.5);
        let z = Tensor::zeros(&[2, 4]);
        assert_eq!(z.len(), 8);
        assert_eq!(z.mean(), 0.0);
    }

    #[test]
    fn literal_round_trip() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literal_round_trip() {
        let t = Tensor::scalar(7.25);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back.data, vec![7.25]);
    }
}
