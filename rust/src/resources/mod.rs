//! Resource model (substrate S8): Summit-like nodes, allocation-wide
//! slot accounting, and placement rules.
//!
//! Placement rules mirror RADICAL-Pilot on Summit:
//! - tasks using GPUs are **node-local** (a task's GPUs and cores must
//!   come from a single node — CUDA devices don't span nodes);
//! - CPU-only tasks may **span nodes** (MPI launch across nodes).
//!
//! Allocations are **elastic**: the [`Allocator`] supports appending
//! nodes and gracefully draining them mid-run (see the allocator module
//! docs); the pilot and the engine coordinator drive that API from a
//! [`ResourcePlan`](crate::pilot::ResourcePlan).

mod allocator;

pub use allocator::{Allocator, Placement};

use crate::error::{Error, Result};
use crate::util::json::{arr_of, obj, parse_arr, FromJson, Json, ToJson};

/// Per-task resource requirement (Tables 1–2: "CPU cores/Task",
/// "GPUs/Task").
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ResourceRequest {
    pub cpu_cores: u32,
    pub gpus: u32,
}

impl ResourceRequest {
    pub const fn new(cpu_cores: u32, gpus: u32) -> Self {
        ResourceRequest { cpu_cores, gpus }
    }

    /// GPU tasks must be placed on a single node.
    pub fn node_local(&self) -> bool {
        self.gpus > 0
    }
}

impl ToJson for ResourceRequest {
    fn to_json(&self) -> Json {
        obj([
            ("cores", Json::from(self.cpu_cores as usize)),
            ("gpus", Json::from(self.gpus as usize)),
        ])
    }
}

impl FromJson for ResourceRequest {
    fn from_json(v: &Json) -> Result<ResourceRequest> {
        Ok(ResourceRequest {
            cpu_cores: v.req_u64("cores")? as u32,
            gpus: v.req_u64("gpus")? as u32,
        })
    }
}

/// One compute node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeSpec {
    pub cores: u32,
    pub gpus: u32,
}

impl ToJson for NodeSpec {
    fn to_json(&self) -> Json {
        obj([
            ("cores", Json::from(self.cores as usize)),
            ("gpus", Json::from(self.gpus as usize)),
        ])
    }
}

impl FromJson for NodeSpec {
    fn from_json(v: &Json) -> Result<NodeSpec> {
        Ok(NodeSpec {
            cores: v.req_u64("cores")? as u32,
            gpus: v.req_u64("gpus")? as u32,
        })
    }
}

/// A cluster allocation (the pilot's resource pool).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub name: String,
    pub nodes: Vec<NodeSpec>,
}

impl ToJson for ClusterSpec {
    fn to_json(&self) -> Json {
        obj([
            ("name", Json::from(self.name.clone())),
            ("nodes", arr_of(&self.nodes)),
        ])
    }
}

impl FromJson for ClusterSpec {
    fn from_json(v: &Json) -> Result<ClusterSpec> {
        Ok(ClusterSpec {
            name: v.req_str("name")?.to_string(),
            nodes: parse_arr(v, "nodes")?,
        })
    }
}

impl ClusterSpec {
    pub fn uniform(name: impl Into<String>, nodes: usize, cores: u32, gpus: u32) -> Self {
        ClusterSpec {
            name: name.into(),
            nodes: vec![NodeSpec { cores, gpus }; nodes],
        }
    }

    /// The allocation the paper used, hardware-thread view: 16 Summit
    /// nodes, 2x21 usable physical cores x SMT4 = 168 hardware threads
    /// and 6 V100 GPUs per node (96 GPUs total).
    ///
    /// The c-DG workloads of Table 2 oversubscribe 706 physical cores by
    /// up to 3.6x (e.g. {T1,T2}: 2x16x40 = 1280 cores) while the paper
    /// still reports one-wave stage times; this is only consistent with
    /// scheduling against SMT hardware threads, hence this default.
    pub fn summit_paper() -> Self {
        ClusterSpec::uniform("summit-16-smt", 16, 168, 6)
    }

    /// The strict "706 usable CPU cores" reading (62 of 768 reserved):
    /// 14 nodes keep 44 cores, 2 keep 45. Used as an ablation to show
    /// wave/serialization effects when physical cores bind.
    pub fn summit_706() -> Self {
        let mut nodes = vec![NodeSpec { cores: 44, gpus: 6 }; 14];
        nodes.extend(vec![NodeSpec { cores: 45, gpus: 6 }; 2]);
        ClusterSpec { name: "summit-16-706".into(), nodes }
    }

    /// Summit profile with 8 GPUs/node (128 total): the counterfactual
    /// allocation under which c-DG2's full TX-masking (Eqn. 3) becomes
    /// resource-feasible. Used by the ablation benches.
    pub fn summit_8gpu() -> Self {
        ClusterSpec::uniform("summit-16-8gpu", 16, 168, 8)
    }

    /// Small profile for real (wall-clock) execution on the local host.
    pub fn local_small() -> Self {
        ClusterSpec::uniform("local-small", 2, 8, 2)
    }

    pub fn total_cores(&self) -> u64 {
        self.nodes.iter().map(|n| n.cores as u64).sum()
    }

    pub fn total_gpus(&self) -> u64 {
        self.nodes.iter().map(|n| n.gpus as u64).sum()
    }

    /// Validate that a request is satisfiable at all on this cluster.
    pub fn check(&self, req: &ResourceRequest) -> Result<()> {
        if req.cpu_cores == 0 && req.gpus == 0 {
            return Err(Error::Unsatisfiable("task requests zero resources".into()));
        }
        if req.node_local() {
            let fits_any = self
                .nodes
                .iter()
                .any(|n| n.cores >= req.cpu_cores && n.gpus >= req.gpus);
            if !fits_any {
                return Err(Error::Unsatisfiable(format!(
                    "GPU task ({} cores, {} gpus) does not fit on any single node of '{}'",
                    req.cpu_cores, req.gpus, self.name
                )));
            }
        } else if (req.cpu_cores as u64) > self.total_cores() {
            return Err(Error::Unsatisfiable(format!(
                "CPU task ({} cores) exceeds allocation total {} cores",
                req.cpu_cores,
                self.total_cores()
            )));
        }
        Ok(())
    }

    /// Analytic max number of tasks with request `req` that can run
    /// concurrently on an otherwise-empty allocation. This is what turns
    /// per-set TX into wave-aware set TTX in the model (e.g. DDMD
    /// Inference on the 706-core profile: 2 tasks/node -> 32 concurrent
    /// -> ceil(96/32)=3 waves).
    pub fn max_concurrent(&self, req: &ResourceRequest) -> u64 {
        if req.node_local() {
            self.nodes
                .iter()
                .map(|n| {
                    let by_cores = if req.cpu_cores == 0 {
                        u64::MAX
                    } else {
                        (n.cores / req.cpu_cores) as u64
                    };
                    let by_gpus = (n.gpus / req.gpus) as u64;
                    by_cores.min(by_gpus)
                })
                .sum()
        } else {
            // CPU-only tasks may span nodes: bound by total cores.
            if req.cpu_cores == 0 {
                return u64::MAX;
            }
            self.total_cores() / req.cpu_cores as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summit_profiles() {
        let smt = ClusterSpec::summit_paper();
        assert_eq!(smt.nodes.len(), 16);
        assert_eq!(smt.total_cores(), 16 * 168);
        assert_eq!(smt.total_gpus(), 96);

        let p706 = ClusterSpec::summit_706();
        assert_eq!(p706.total_cores(), 706);
        assert_eq!(p706.total_gpus(), 96);
    }

    #[test]
    fn check_rejects_oversized() {
        let c = ClusterSpec::summit_paper();
        // 7 GPUs on one node is impossible (6/node).
        assert!(c.check(&ResourceRequest::new(1, 7)).is_err());
        // CPU-only task larger than the whole allocation.
        assert!(c.check(&ResourceRequest::new(100_000, 0)).is_err());
        // Zero request is invalid.
        assert!(c.check(&ResourceRequest::new(0, 0)).is_err());
        // Normal requests pass.
        assert!(c.check(&ResourceRequest::new(4, 1)).is_ok());
        assert!(c.check(&ResourceRequest::new(2000, 0)).is_ok());
    }

    #[test]
    fn max_concurrent_gpu_tasks() {
        let c = ClusterSpec::summit_paper();
        // DDMD Simulation: 4 cores + 1 GPU -> 6/node -> 96.
        assert_eq!(c.max_concurrent(&ResourceRequest::new(4, 1)), 96);
        // DDMD Inference on SMT: 16 cores + 1 GPU -> min(10, 6)=6/node -> 96.
        assert_eq!(c.max_concurrent(&ResourceRequest::new(16, 1)), 96);
    }

    #[test]
    fn max_concurrent_on_706_profile_shows_waves() {
        let c = ClusterSpec::summit_706();
        // Inference: 16 cores + 1 GPU -> 2/node (44/16=2) -> 32 concurrent.
        assert_eq!(c.max_concurrent(&ResourceRequest::new(16, 1)), 32);
        // Aggregation (CPU-only, spans nodes): 706/32 = 22.
        assert_eq!(c.max_concurrent(&ResourceRequest::new(32, 0)), 22);
    }

    #[test]
    fn cpu_only_spans_nodes() {
        let c = ClusterSpec::uniform("t", 4, 10, 0);
        // 25-core CPU task spans nodes: total 40 cores -> 1 concurrent.
        assert!(c.check(&ResourceRequest::new(25, 0)).is_ok());
        assert_eq!(c.max_concurrent(&ResourceRequest::new(25, 0)), 1);
    }
}
