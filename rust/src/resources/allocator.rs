//! Allocation-wide slot allocator: tracks free cores/GPUs per node and
//! places task requests under the node-locality rules.
//!
//! This is the pilot agent's view of the allocation; all scheduling
//! decisions go through [`Allocator::try_alloc`] / [`Allocator::release`].
//!
//! ## Elasticity
//!
//! The allocation is *elastic*: nodes can be appended while tasks run
//! ([`Allocator::add_node`]) and drained ([`Allocator::drain_node`]).
//! Draining is graceful — the node is immediately unschedulable (its
//! free cores/GPUs leave the pool and nothing new is placed on it), but
//! placements already on the node keep running; resources they release
//! vanish instead of returning to the pool. A draining node can be
//! brought back with [`Allocator::undrain_node`] (the pilot's `grow`
//! reuses same-shape draining nodes before appending fresh ones).
//!
//! Node indices are stable for the lifetime of the allocator: drained
//! nodes keep their slot (with zero schedulable capacity) so that
//! in-flight [`Placement`]s remain valid.

use super::{ClusterSpec, NodeSpec, ResourceRequest};
use crate::error::{Error, Result};
use crate::util::json::{FromJson, Json, ToJson};

/// Where a running task's resources came from: `(node, cores, gpus)`
/// slices, one per node touched.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub slots: Vec<(usize, u32, u32)>,
}

impl Placement {
    pub fn total_cores(&self) -> u64 {
        self.slots.iter().map(|s| s.1 as u64).sum()
    }
    pub fn total_gpus(&self) -> u64 {
        self.slots.iter().map(|s| s.2 as u64).sum()
    }
}

impl ToJson for Placement {
    fn to_json(&self) -> Json {
        Json::Arr(
            self.slots
                .iter()
                .map(|&(i, c, g)| {
                    Json::Arr(vec![
                        Json::from(i),
                        Json::from(c as usize),
                        Json::from(g as usize),
                    ])
                })
                .collect(),
        )
    }
}

impl FromJson for Placement {
    fn from_json(v: &Json) -> Result<Placement> {
        let arr = v
            .as_arr()
            .ok_or_else(|| Error::Config("placement: expected an array".into()))?;
        let mut slots = Vec::with_capacity(arr.len());
        for s in arr {
            let triple = s.as_arr().filter(|t| t.len() == 3).ok_or_else(|| {
                Error::Config("placement: each slot must be [node, cores, gpus]".into())
            })?;
            let node = triple[0]
                .as_u64()
                .ok_or_else(|| Error::Config("placement: bad node index".into()))?;
            let cores = triple[1]
                .as_u64()
                .ok_or_else(|| Error::Config("placement: bad core count".into()))?;
            let gpus = triple[2]
                .as_u64()
                .ok_or_else(|| Error::Config("placement: bad gpu count".into()))?;
            slots.push((node as usize, cores as u32, gpus as u32));
        }
        Ok(Placement { slots })
    }
}

/// Free-resource bookkeeping over a [`ClusterSpec`].
#[derive(Debug, Clone)]
pub struct Allocator {
    spec: ClusterSpec,
    free_cores: Vec<u32>,
    free_gpus: Vec<u32>,
    /// Per-node in-use counts. Needed explicitly (not derivable from
    /// `spec - free`) because a draining node has zero free capacity
    /// while its running tasks still occupy cores.
    busy_cores: Vec<u32>,
    busy_gpus: Vec<u32>,
    /// Draining nodes are unschedulable; releases on them vanish.
    draining: Vec<bool>,
    total_free_cores: u64,
    total_free_gpus: u64,
    total_busy_cores: u64,
    total_busy_gpus: u64,
    /// Schedulable capacity: spec totals over non-draining nodes.
    cap_cores: u64,
    cap_gpus: u64,
    /// Rotating start index for first-fit, spreading GPU tasks across
    /// nodes instead of hammering node 0.
    cursor: usize,
    /// Node visit order for spanning allocations, descending by free
    /// cores — a lazily-repaired index. Mutations outside
    /// `alloc_spanning` (node-local allocs, releases, node add/drain)
    /// only mark it stale; `alloc_spanning` repairs its own damage
    /// incrementally, so a burst of spanning allocations (one scheduler
    /// drain round placing a whole CPU task set) sorts once instead of
    /// per-task.
    span_order: Vec<usize>,
    span_order_stale: bool,
}

impl Allocator {
    pub fn new(spec: &ClusterSpec) -> Allocator {
        Allocator {
            free_cores: spec.nodes.iter().map(|n| n.cores).collect(),
            free_gpus: spec.nodes.iter().map(|n| n.gpus).collect(),
            busy_cores: vec![0; spec.nodes.len()],
            busy_gpus: vec![0; spec.nodes.len()],
            draining: vec![false; spec.nodes.len()],
            total_free_cores: spec.total_cores(),
            total_free_gpus: spec.total_gpus(),
            total_busy_cores: 0,
            total_busy_gpus: 0,
            cap_cores: spec.total_cores(),
            cap_gpus: spec.total_gpus(),
            cursor: 0,
            span_order: Vec::new(),
            span_order_stale: true,
            spec: spec.clone(),
        }
    }

    /// Current node inventory, *including* drained nodes (stable
    /// indices).
    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    pub fn free_cores(&self) -> u64 {
        self.total_free_cores
    }

    pub fn free_gpus(&self) -> u64 {
        self.total_free_gpus
    }

    pub fn used_cores(&self) -> u64 {
        self.total_busy_cores
    }

    pub fn used_gpus(&self) -> u64 {
        self.total_busy_gpus
    }

    /// Schedulable core capacity: spec cores over non-draining nodes.
    pub fn capacity_cores(&self) -> u64 {
        self.cap_cores
    }

    /// Schedulable GPU capacity: spec GPUs over non-draining nodes.
    pub fn capacity_gpus(&self) -> u64 {
        self.cap_gpus
    }

    /// *Offered* core capacity: everything free plus everything busy —
    /// the schedulable capacity plus cores still occupied by running
    /// tasks on draining nodes. This is what utilization denominators
    /// integrate against: cores in use can never exceed it, and a
    /// gracefully draining node's cores leave the allocation exactly
    /// when the work on them finishes.
    pub fn offered_cores(&self) -> u64 {
        self.total_free_cores + self.total_busy_cores
    }

    /// *Offered* GPU capacity (see [`Allocator::offered_cores`]).
    pub fn offered_gpus(&self) -> u64 {
        self.total_free_gpus + self.total_busy_gpus
    }

    /// Total nodes ever part of the allocation (including drained).
    pub fn node_count(&self) -> usize {
        self.spec.nodes.len()
    }

    /// Nodes currently accepting placements.
    pub fn schedulable_nodes(&self) -> usize {
        self.draining.iter().filter(|&&d| !d).count()
    }

    pub fn is_draining(&self, node: usize) -> bool {
        self.draining[node]
    }

    /// `(free cores, free gpus)` on one node.
    pub fn node_free(&self, node: usize) -> (u32, u32) {
        (self.free_cores[node], self.free_gpus[node])
    }

    /// `(busy cores, busy gpus)` on one node.
    pub fn node_busy(&self, node: usize) -> (u32, u32) {
        (self.busy_cores[node], self.busy_gpus[node])
    }

    /// True once a draining node has no running work left (its cores
    /// are fully gone from the allocation).
    pub fn node_idle(&self, node: usize) -> bool {
        self.busy_cores[node] == 0 && self.busy_gpus[node] == 0
    }

    /// Append a node to the allocation; its capacity is schedulable
    /// immediately. Returns the new node's index.
    pub fn add_node(&mut self, node: NodeSpec) -> usize {
        let i = self.spec.nodes.len();
        self.spec.nodes.push(node);
        self.free_cores.push(node.cores);
        self.free_gpus.push(node.gpus);
        self.busy_cores.push(0);
        self.busy_gpus.push(0);
        self.draining.push(false);
        self.total_free_cores += node.cores as u64;
        self.total_free_gpus += node.gpus as u64;
        self.cap_cores += node.cores as u64;
        self.cap_gpus += node.gpus as u64;
        self.span_order_stale = true;
        i
    }

    /// Mark a node draining: its free capacity leaves the pool now,
    /// nothing new is placed on it, and resources released by its
    /// still-running tasks vanish instead of returning. Errors if the
    /// index is out of bounds or the node is already draining.
    pub fn drain_node(&mut self, node: usize) -> Result<()> {
        if node >= self.spec.nodes.len() {
            return Err(Error::Config(format!(
                "drain_node: no node {node} (allocation has {})",
                self.spec.nodes.len()
            )));
        }
        if self.draining[node] {
            return Err(Error::Config(format!("drain_node: node {node} is already draining")));
        }
        self.total_free_cores -= self.free_cores[node] as u64;
        self.total_free_gpus -= self.free_gpus[node] as u64;
        self.free_cores[node] = 0;
        self.free_gpus[node] = 0;
        self.cap_cores -= self.spec.nodes[node].cores as u64;
        self.cap_gpus -= self.spec.nodes[node].gpus as u64;
        self.draining[node] = true;
        self.span_order_stale = true;
        Ok(())
    }

    /// Bring a draining node back: its unused capacity (spec minus
    /// whatever is still busy) returns to the pool and it accepts
    /// placements again.
    pub fn undrain_node(&mut self, node: usize) -> Result<()> {
        if node >= self.spec.nodes.len() {
            return Err(Error::Config(format!(
                "undrain_node: no node {node} (allocation has {})",
                self.spec.nodes.len()
            )));
        }
        if !self.draining[node] {
            return Err(Error::Config(format!("undrain_node: node {node} is not draining")));
        }
        self.draining[node] = false;
        let fc = self.spec.nodes[node].cores - self.busy_cores[node];
        let fg = self.spec.nodes[node].gpus - self.busy_gpus[node];
        self.free_cores[node] = fc;
        self.free_gpus[node] = fg;
        self.total_free_cores += fc as u64;
        self.total_free_gpus += fg as u64;
        self.cap_cores += self.spec.nodes[node].cores as u64;
        self.cap_gpus += self.spec.nodes[node].gpus as u64;
        self.span_order_stale = true;
        Ok(())
    }

    /// Pick up to `n` nodes to drain: least-busy first (cores, then
    /// GPUs), ties broken toward the highest index (shed the newest
    /// nodes first). Deterministic; draining nodes are never picked.
    pub fn drain_candidates(&self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> =
            (0..self.spec.nodes.len()).filter(|&i| !self.draining[i]).collect();
        idx.sort_by_key(|&i| {
            (self.busy_cores[i], self.busy_gpus[i], std::cmp::Reverse(i))
        });
        idx.truncate(n);
        idx
    }

    /// Cheap feasibility pre-check (no placement computed).
    pub fn may_fit(&self, req: &ResourceRequest) -> bool {
        req.cpu_cores as u64 <= self.total_free_cores
            && req.gpus as u64 <= self.total_free_gpus
    }

    /// Try to place one task; returns `None` when it doesn't currently fit.
    pub fn try_alloc(&mut self, req: &ResourceRequest) -> Option<Placement> {
        if !self.may_fit(req) {
            return None;
        }
        if req.node_local() {
            self.alloc_node_local(req)
        } else {
            self.alloc_spanning(req)
        }
    }

    fn alloc_node_local(&mut self, req: &ResourceRequest) -> Option<Placement> {
        let n = self.free_cores.len();
        for off in 0..n {
            let i = (self.cursor + off) % n;
            // Draining nodes hold zero free capacity, so any nonzero
            // request skips them here without an explicit flag check.
            if self.free_cores[i] >= req.cpu_cores && self.free_gpus[i] >= req.gpus {
                self.free_cores[i] -= req.cpu_cores;
                self.free_gpus[i] -= req.gpus;
                self.busy_cores[i] += req.cpu_cores;
                self.busy_gpus[i] += req.gpus;
                self.total_free_cores -= req.cpu_cores as u64;
                self.total_free_gpus -= req.gpus as u64;
                self.total_busy_cores += req.cpu_cores as u64;
                self.total_busy_gpus += req.gpus as u64;
                self.cursor = (i + 1) % n;
                if req.cpu_cores > 0 {
                    self.span_order_stale = true;
                }
                return Some(Placement { slots: vec![(i, req.cpu_cores, req.gpus)] });
            }
        }
        None
    }

    fn alloc_spanning(&mut self, req: &ResourceRequest) -> Option<Placement> {
        // total_free_cores >= cpu_cores was pre-checked; greedily take
        // cores from the fullest-free nodes to limit fragmentation.
        if self.span_order_stale {
            self.span_order = (0..self.free_cores.len()).collect();
            self.span_order
                .sort_by_key(|&i| std::cmp::Reverse(self.free_cores[i]));
            self.span_order_stale = false;
        }
        let mut remaining = req.cpu_cores;
        let mut slots = Vec::new();
        // Visit nodes in cached descending-free-cores order. Draining
        // nodes sort to the back with zero free cores and are never
        // reached (the pre-check guarantees the nonzero prefix covers
        // the request).
        let mut consumed = 0usize;
        for &i in &self.span_order {
            if remaining == 0 {
                break;
            }
            let take = self.free_cores[i].min(remaining);
            consumed += 1;
            if take > 0 {
                slots.push((i, take, 0));
                remaining -= take;
            }
        }
        debug_assert_eq!(remaining, 0);
        for &(i, c, _) in &slots {
            self.free_cores[i] -= c;
            self.busy_cores[i] += c;
        }
        self.total_free_cores -= req.cpu_cores as u64;
        self.total_busy_cores += req.cpu_cores as u64;
        self.repair_span_order(consumed);
        Some(Placement { slots })
    }

    /// Restore `span_order`'s descending-free-cores invariant after a
    /// spanning allocation that consumed its first `consumed` entries:
    /// all but the last are drained to zero free cores and belong at
    /// the back; the last — possibly only partially drained — is
    /// re-positioned by binary search. In place, via rotates: no
    /// comparison sort, no allocations.
    fn repair_span_order(&mut self, consumed: usize) {
        if consumed == 0 {
            return;
        }
        let n = self.span_order.len();
        // [drained.., partial, rest..] -> [partial, rest.., drained..].
        self.span_order.rotate_left(consumed - 1);
        // Slot the partial node (now at index 0) into the still-sorted
        // rest.
        let rest_len = n - consumed;
        let free = self.free_cores[self.span_order[0]];
        let pos = self.span_order[1..1 + rest_len]
            .partition_point(|&i| self.free_cores[i] >= free);
        self.span_order[..=pos].rotate_left(1);
    }

    /// Re-apply a known placement (checkpoint restore): subtracts the
    /// placement's slices from the free pool exactly as if
    /// [`Allocator::try_alloc`] had produced it. Errors — leaving the
    /// allocator untouched — when any slice does not fit its node,
    /// which on a restore path means the snapshot is inconsistent.
    pub fn claim(&mut self, p: &Placement) -> Result<()> {
        // Validate cumulatively (a malformed placement may list one
        // node twice) before mutating anything.
        let mut need: std::collections::BTreeMap<usize, (u64, u64)> =
            std::collections::BTreeMap::new();
        for &(i, cores, gpus) in &p.slots {
            let e = need.entry(i).or_insert((0, 0));
            e.0 += cores as u64;
            e.1 += gpus as u64;
        }
        for (&i, &(cores, gpus)) in &need {
            if i >= self.spec.nodes.len()
                || (self.free_cores[i] as u64) < cores
                || (self.free_gpus[i] as u64) < gpus
            {
                return Err(Error::Engine(format!(
                    "claim: slice ({cores} cores, {gpus} gpus) does not fit node {i}"
                )));
            }
        }
        for &(i, cores, gpus) in &p.slots {
            self.free_cores[i] -= cores;
            self.free_gpus[i] -= gpus;
            self.busy_cores[i] += cores;
            self.busy_gpus[i] += gpus;
            self.total_free_cores -= cores as u64;
            self.total_free_gpus -= gpus as u64;
            self.total_busy_cores += cores as u64;
            self.total_busy_gpus += gpus as u64;
        }
        self.span_order_stale = true;
        Ok(())
    }

    /// First-fit rotation position (serialized by checkpoints so a
    /// restored allocator probes nodes in the same order).
    pub fn cursor(&self) -> usize {
        self.cursor
    }

    /// Restore the first-fit rotation position (checkpoint restore).
    pub fn set_cursor(&mut self, cursor: usize) {
        let n = self.spec.nodes.len().max(1);
        self.cursor = cursor % n;
    }

    /// The cached spanning-allocation node order, when it is currently
    /// valid (`None` while stale). Checkpoints carry it because its
    /// tie-breaks among equal-free nodes are repair-history dependent:
    /// a freshly sorted index is *a* valid order but not necessarily
    /// *the* order the interrupted run would have used next.
    pub fn span_order_state(&self) -> Option<&[usize]> {
        if self.span_order_stale {
            None
        } else {
            Some(&self.span_order)
        }
    }

    /// Restore a captured spanning order (checkpoint restore). Errors
    /// unless `order` is a permutation of the node indices in
    /// non-increasing free-core order — the invariant `alloc_spanning`
    /// relies on.
    pub fn restore_span_order(&mut self, order: &[usize]) -> Result<()> {
        let n = self.free_cores.len();
        let mut seen = vec![false; n];
        let valid = order.len() == n
            && order
                .iter()
                .all(|&i| i < n && !std::mem::replace(&mut seen[i], true))
            && order
                .windows(2)
                .all(|w| self.free_cores[w[0]] >= self.free_cores[w[1]]);
        if !valid {
            return Err(Error::Engine(
                "restore_span_order: not a descending-free permutation of the nodes"
                    .into(),
            ));
        }
        self.span_order = order.to_vec();
        self.span_order_stale = false;
        Ok(())
    }

    /// Return a placement's resources to the pool. Slices on draining
    /// nodes leave the allocation instead (graceful shrink: the cores
    /// disappear only after the work on them finished).
    pub fn release(&mut self, p: &Placement) {
        self.span_order_stale = true;
        for &(i, cores, gpus) in &p.slots {
            self.busy_cores[i] -= cores;
            self.busy_gpus[i] -= gpus;
            self.total_busy_cores -= cores as u64;
            self.total_busy_gpus -= gpus as u64;
            if self.draining[i] {
                continue;
            }
            self.free_cores[i] += cores;
            self.free_gpus[i] += gpus;
            debug_assert!(self.free_cores[i] + self.busy_cores[i] <= self.spec.nodes[i].cores);
            debug_assert!(self.free_gpus[i] + self.busy_gpus[i] <= self.spec.nodes[i].gpus);
            self.total_free_cores += cores as u64;
            self.total_free_gpus += gpus as u64;
        }
    }

    /// Invariant check used by tests: per-node free/busy counts within
    /// bounds and totals consistent (free + busy == spec on schedulable
    /// nodes, free == 0 on draining ones); a non-stale span index must
    /// be a permutation in descending free-cores order.
    pub fn check_invariants(&self) -> bool {
        let sum_c: u64 = self.free_cores.iter().map(|&c| c as u64).sum();
        let sum_g: u64 = self.free_gpus.iter().map(|&g| g as u64).sum();
        let sum_bc: u64 = self.busy_cores.iter().map(|&c| c as u64).sum();
        let sum_bg: u64 = self.busy_gpus.iter().map(|&g| g as u64).sum();
        let cap_c: u64 = self
            .spec
            .nodes
            .iter()
            .zip(&self.draining)
            .filter(|(_, &d)| !d)
            .map(|(n, _)| n.cores as u64)
            .sum();
        let cap_g: u64 = self
            .spec
            .nodes
            .iter()
            .zip(&self.draining)
            .filter(|(_, &d)| !d)
            .map(|(n, _)| n.gpus as u64)
            .sum();
        let span_ok = self.span_order_stale || {
            let mut seen = vec![false; self.free_cores.len()];
            self.span_order.len() == self.free_cores.len()
                && self.span_order.iter().all(|&i| {
                    i < seen.len() && !std::mem::replace(&mut seen[i], true)
                })
                && self
                    .span_order
                    .windows(2)
                    .all(|w| self.free_cores[w[0]] >= self.free_cores[w[1]])
        };
        let nodes_ok = (0..self.spec.nodes.len()).all(|i| {
            let n = &self.spec.nodes[i];
            if self.draining[i] {
                self.free_cores[i] == 0
                    && self.free_gpus[i] == 0
                    && self.busy_cores[i] <= n.cores
                    && self.busy_gpus[i] <= n.gpus
            } else {
                self.free_cores[i] + self.busy_cores[i] == n.cores
                    && self.free_gpus[i] + self.busy_gpus[i] == n.gpus
            }
        });
        span_ok
            && nodes_ok
            && sum_c == self.total_free_cores
            && sum_g == self.total_free_gpus
            && sum_bc == self.total_busy_cores
            && sum_bg == self.total_busy_gpus
            && cap_c == self.cap_cores
            && cap_g == self.cap_gpus
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_bool;
    use crate::util::rng::Rng;

    fn cluster() -> ClusterSpec {
        ClusterSpec::uniform("t", 4, 8, 2)
    }

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = Allocator::new(&cluster());
        let p = a.try_alloc(&ResourceRequest::new(4, 1)).unwrap();
        assert_eq!(a.used_cores(), 4);
        assert_eq!(a.used_gpus(), 1);
        a.release(&p);
        assert_eq!(a.used_cores(), 0);
        assert_eq!(a.used_gpus(), 0);
        assert!(a.check_invariants());
    }

    #[test]
    fn gpu_task_is_node_local() {
        // 2 GPUs per node; a 2-GPU task must land on one node.
        let mut a = Allocator::new(&cluster());
        let p = a.try_alloc(&ResourceRequest::new(2, 2)).unwrap();
        assert_eq!(p.slots.len(), 1);
    }

    #[test]
    fn gpu_exhaustion_blocks() {
        let mut a = Allocator::new(&cluster()); // 8 GPUs total
        let mut placements = vec![];
        for _ in 0..8 {
            placements.push(a.try_alloc(&ResourceRequest::new(1, 1)).unwrap());
        }
        assert!(a.try_alloc(&ResourceRequest::new(1, 1)).is_none());
        a.release(&placements.pop().unwrap());
        assert!(a.try_alloc(&ResourceRequest::new(1, 1)).is_some());
    }

    #[test]
    fn cpu_task_spans_nodes() {
        let mut a = Allocator::new(&cluster()); // 32 cores over 4 nodes
        let p = a.try_alloc(&ResourceRequest::new(20, 0)).unwrap();
        assert!(p.slots.len() >= 3, "20 cores must span >= 3 of 8-core nodes");
        assert_eq!(p.total_cores(), 20);
        assert_eq!(a.free_cores(), 12);
        a.release(&p);
        assert!(a.check_invariants());
    }

    #[test]
    fn fragmentation_can_block_node_local() {
        // Fill 1 core + 1 gpu on each node; a (8-core,1-gpu) task then
        // fails even though 28 cores are free allocation-wide.
        let mut a = Allocator::new(&cluster());
        for _ in 0..4 {
            a.try_alloc(&ResourceRequest::new(1, 1)).unwrap();
        }
        assert!(a.try_alloc(&ResourceRequest::new(8, 1)).is_none());
        // ... but a CPU-only 8-core task still fits by spanning.
        assert!(a.try_alloc(&ResourceRequest::new(8, 0)).is_some());
    }

    #[test]
    fn span_order_stays_sorted_across_alloc_bursts() {
        // Bursts of spanning allocations repair the index in place; the
        // invariant checker verifies descending order + permutation.
        let mut a = Allocator::new(&ClusterSpec::uniform("t", 6, 10, 1));
        let mut live = vec![];
        for cores in [7, 7, 9, 4, 12, 3, 11] {
            live.push(a.try_alloc(&ResourceRequest::new(cores, 0)).unwrap());
            assert!(a.check_invariants(), "after spanning alloc of {cores}");
        }
        // Interleave node-local + release (stale paths) with more bursts.
        let g = a.try_alloc(&ResourceRequest::new(1, 1)).unwrap();
        a.release(&live.pop().unwrap());
        for cores in [5, 5] {
            live.push(a.try_alloc(&ResourceRequest::new(cores, 0)).unwrap());
            assert!(a.check_invariants(), "after re-sort + alloc of {cores}");
        }
        a.release(&g);
        for p in &live {
            a.release(p);
        }
        assert!(a.check_invariants());
        assert_eq!(a.used_cores(), 0);
    }

    #[test]
    fn add_node_grows_schedulable_capacity() {
        let mut a = Allocator::new(&ClusterSpec::uniform("t", 1, 4, 1));
        assert!(a.try_alloc(&ResourceRequest::new(6, 0)).is_none());
        let i = a.add_node(NodeSpec { cores: 4, gpus: 1 });
        assert_eq!(i, 1);
        assert_eq!(a.capacity_cores(), 8);
        assert_eq!(a.capacity_gpus(), 2);
        assert!(a.check_invariants());
        // A 6-core spanning task now fits across both nodes.
        let p = a.try_alloc(&ResourceRequest::new(6, 0)).unwrap();
        assert_eq!(p.total_cores(), 6);
        assert!(a.check_invariants());
        a.release(&p);
        assert_eq!(a.free_cores(), 8);
    }

    #[test]
    fn drain_is_graceful_and_never_double_grants() {
        let mut a = Allocator::new(&ClusterSpec::uniform("t", 2, 4, 1));
        // Pin a task to a node via the GPU (node-local).
        let p = a.try_alloc(&ResourceRequest::new(2, 1)).unwrap();
        let node = p.slots[0].0;
        a.drain_node(node).unwrap();
        assert!(a.check_invariants());
        assert!(a.is_draining(node));
        assert_eq!(a.capacity_cores(), 4, "only the surviving node counts");
        assert!(!a.node_idle(node), "task still running on the draining node");
        // Nothing new lands on the draining node.
        for _ in 0..4 {
            if let Some(q) = a.try_alloc(&ResourceRequest::new(1, 0)) {
                assert!(q.slots.iter().all(|&(i, _, _)| i != node));
            }
        }
        // Free capacity is exactly the other node's (minus what we took).
        assert!(a.free_cores() <= 4);
        // The running task finishes: its cores vanish, node goes idle.
        a.release(&p);
        assert!(a.node_idle(node));
        assert_eq!(a.node_free(node), (0, 0), "drained capacity never returns");
        assert!(a.check_invariants());
        // Double drain errors; undrain restores full capacity.
        assert!(a.drain_node(node).is_err());
        a.undrain_node(node).unwrap();
        assert_eq!(a.capacity_cores(), 8);
        assert!(a.check_invariants());
        assert!(a.undrain_node(node).is_err());
    }

    #[test]
    fn undrain_while_busy_restores_only_unused_capacity() {
        let mut a = Allocator::new(&ClusterSpec::uniform("t", 1, 8, 2));
        let p = a.try_alloc(&ResourceRequest::new(3, 1)).unwrap();
        a.drain_node(0).unwrap();
        assert_eq!(a.free_cores(), 0);
        a.undrain_node(0).unwrap();
        assert_eq!(a.free_cores(), 5);
        assert_eq!(a.free_gpus(), 1);
        assert!(a.check_invariants());
        a.release(&p);
        assert_eq!(a.free_cores(), 8);
        assert!(a.check_invariants());
    }

    #[test]
    fn drain_candidates_prefer_idle_then_newest() {
        let mut a = Allocator::new(&ClusterSpec::uniform("t", 3, 4, 1));
        // Busy up node 0 (cursor starts there for the GPU task).
        let p = a.try_alloc(&ResourceRequest::new(2, 1)).unwrap();
        let busy_node = p.slots[0].0;
        let picks = a.drain_candidates(2);
        assert_eq!(picks.len(), 2);
        assert!(
            !picks.contains(&busy_node),
            "least-busy nodes first: {picks:?} must skip busy node {busy_node}"
        );
        // Idle tie-break: highest index first.
        assert!(picks[0] > picks[1]);
        // Draining nodes are never re-picked.
        a.drain_node(picks[0]).unwrap();
        let again = a.drain_candidates(3);
        assert!(!again.contains(&picks[0]));
        assert_eq!(again.len(), 2);
    }

    #[test]
    fn claim_reapplies_known_placements_exactly() {
        // A fresh allocator fed the running placements of another one
        // (the checkpoint-restore path) reproduces its occupancy.
        let mut a = Allocator::new(&cluster());
        let p1 = a.try_alloc(&ResourceRequest::new(20, 0)).unwrap(); // spans nodes
        let p2 = a.try_alloc(&ResourceRequest::new(2, 2)).unwrap(); // node-local
        let mut b = Allocator::new(&cluster());
        b.claim(&p1).unwrap();
        b.claim(&p2).unwrap();
        b.set_cursor(a.cursor());
        assert!(b.check_invariants());
        assert_eq!(b.free_cores(), a.free_cores());
        assert_eq!(b.free_gpus(), a.free_gpus());
        for i in 0..a.node_count() {
            assert_eq!(b.node_free(i), a.node_free(i), "node {i} free");
            assert_eq!(b.node_busy(i), a.node_busy(i), "node {i} busy");
        }
        assert_eq!(b.cursor(), a.cursor());
        // Releasing the claimed placements drains the occupancy fully.
        b.release(&p1);
        b.release(&p2);
        assert_eq!(b.used_cores(), 0);
        assert!(b.check_invariants());
        // Over-claiming errors and leaves the allocator untouched.
        let mut c = Allocator::new(&ClusterSpec::uniform("t", 1, 2, 0));
        let bad = Placement { slots: vec![(0, 2, 0), (0, 1, 0)] };
        assert!(c.claim(&bad).is_err(), "cumulative over-claim must fail");
        assert_eq!(c.free_cores(), 2);
        assert!(c.claim(&Placement { slots: vec![(5, 1, 0)] }).is_err());
        assert!(c.check_invariants());
    }

    #[test]
    fn span_order_state_round_trips() {
        let mut a = Allocator::new(&ClusterSpec::uniform("t", 3, 4, 0));
        assert!(a.span_order_state().is_none(), "fresh allocator starts stale");
        let p = a.try_alloc(&ResourceRequest::new(6, 0)).unwrap();
        let order = a.span_order_state().expect("spanning alloc builds the index").to_vec();
        // A fresh allocator brought to the same occupancy accepts the
        // captured order and ends up with the identical index.
        let mut b = Allocator::new(&ClusterSpec::uniform("t", 3, 4, 0));
        b.claim(&p).unwrap();
        b.restore_span_order(&order).unwrap();
        assert_eq!(b.span_order_state(), Some(order.as_slice()));
        assert!(b.check_invariants());
        // Invalid orders are rejected: wrong length, duplicate entries,
        // and orderings that violate descending free cores.
        assert!(b.restore_span_order(&order[1..]).is_err());
        let dup: Vec<usize> = vec![order[0]; order.len()];
        assert!(b.restore_span_order(&dup).is_err());
        let mut reversed = order.clone();
        reversed.reverse();
        if reversed != order {
            assert!(b.restore_span_order(&reversed).is_err());
        }
    }

    #[test]
    fn property_no_oversubscription() {
        // Random alloc/release interleavings never violate invariants.
        check_bool(
            0xA110C,
            300,
            |rng: &mut Rng, size| {
                let ops: Vec<(u32, u32, bool)> = (0..size.0 * 4)
                    .map(|_| {
                        (
                            rng.below(10) as u32,
                            rng.below(3) as u32,
                            rng.f64() < 0.4,
                        )
                    })
                    .collect();
                ops
            },
            |ops| {
                let mut a = Allocator::new(&ClusterSpec::uniform("p", 3, 12, 2));
                let mut live: Vec<Placement> = vec![];
                for &(c, g, release_first) in ops {
                    if release_first && !live.is_empty() {
                        let p = live.swap_remove(0);
                        a.release(&p);
                    }
                    if c == 0 && g == 0 {
                        continue;
                    }
                    if let Some(p) = a.try_alloc(&ResourceRequest::new(c, g)) {
                        if p.total_cores() != c as u64 || p.total_gpus() != g as u64 {
                            return false;
                        }
                        live.push(p);
                    }
                    if !a.check_invariants() {
                        return false;
                    }
                }
                for p in &live {
                    a.release(p);
                }
                a.check_invariants() && a.used_cores() == 0 && a.used_gpus() == 0
            },
        );
    }

    #[test]
    fn property_elastic_interleavings_match_fresh_allocator() {
        // Any interleaving of grow/drain/alloc/release must leave the
        // allocator equivalent to one freshly built over the surviving
        // (non-draining) nodes: same per-node free counts, same totals,
        // valid span order, and drained cores are never granted.
        check_bool(
            0xE1A57,
            250,
            |rng: &mut Rng, size| {
                let ops: Vec<(u8, u32, u32)> = (0..size.0 * 5)
                    .map(|_| {
                        (
                            rng.below(5) as u8,
                            rng.below(64) as u32,
                            rng.below(64) as u32,
                        )
                    })
                    .collect();
                ops
            },
            |ops| {
                let mut a = Allocator::new(&ClusterSpec::uniform("p", 3, 8, 2));
                let mut live: Vec<Placement> = vec![];
                for &(op, x, y) in ops {
                    match op {
                        // Weight allocation slightly higher than the rest.
                        0 | 4 => {
                            let (c, g) = (x % 10, y % 3);
                            if c == 0 && g == 0 {
                                continue;
                            }
                            if let Some(p) = a.try_alloc(&ResourceRequest::new(c, g)) {
                                if p.total_cores() != c as u64
                                    || p.total_gpus() != g as u64
                                {
                                    return false;
                                }
                                // No double-grant of drained cores.
                                if p.slots.iter().any(|&(i, _, _)| a.is_draining(i)) {
                                    return false;
                                }
                                live.push(p);
                            }
                        }
                        1 => {
                            if !live.is_empty() {
                                let p = live.swap_remove(x as usize % live.len());
                                a.release(&p);
                            }
                        }
                        2 => {
                            a.add_node(NodeSpec { cores: 2 + x % 8, gpus: y % 3 });
                        }
                        3 => {
                            let i = x as usize % a.node_count();
                            // May legitimately fail on already-draining
                            // nodes; equivalence is what matters.
                            let _ = a.drain_node(i);
                        }
                        _ => unreachable!("op is drawn below 5"),
                    }
                    if !a.check_invariants() {
                        return false;
                    }
                }
                for p in &live {
                    a.release(p);
                }
                if !(a.check_invariants() && a.used_cores() == 0 && a.used_gpus() == 0) {
                    return false;
                }
                // Fresh allocator over the surviving nodes.
                let survivors: Vec<NodeSpec> = (0..a.node_count())
                    .filter(|&i| !a.is_draining(i))
                    .map(|i| a.spec().nodes[i])
                    .collect();
                let fresh = Allocator::new(&ClusterSpec {
                    name: "fresh".into(),
                    nodes: survivors,
                });
                let mut mine: Vec<(u32, u32)> = (0..a.node_count())
                    .filter(|&i| !a.is_draining(i))
                    .map(|i| a.node_free(i))
                    .collect();
                let mut theirs: Vec<(u32, u32)> =
                    (0..fresh.node_count()).map(|i| fresh.node_free(i)).collect();
                mine.sort_unstable();
                theirs.sort_unstable();
                mine == theirs
                    && a.free_cores() == fresh.free_cores()
                    && a.free_gpus() == fresh.free_gpus()
                    && a.capacity_cores() == fresh.capacity_cores()
                    && a.capacity_gpus() == fresh.capacity_gpus()
            },
        );
    }
}
