//! Allocation-wide slot allocator: tracks free cores/GPUs per node and
//! places task requests under the node-locality rules.
//!
//! This is the pilot agent's view of the allocation; all scheduling
//! decisions go through [`Allocator::try_alloc`] / [`Allocator::release`].

use super::{ClusterSpec, ResourceRequest};

/// Where a running task's resources came from: `(node, cores, gpus)`
/// slices, one per node touched.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub slots: Vec<(usize, u32, u32)>,
}

impl Placement {
    pub fn total_cores(&self) -> u64 {
        self.slots.iter().map(|s| s.1 as u64).sum()
    }
    pub fn total_gpus(&self) -> u64 {
        self.slots.iter().map(|s| s.2 as u64).sum()
    }
}

/// Free-resource bookkeeping over a [`ClusterSpec`].
#[derive(Debug, Clone)]
pub struct Allocator {
    spec: ClusterSpec,
    free_cores: Vec<u32>,
    free_gpus: Vec<u32>,
    total_free_cores: u64,
    total_free_gpus: u64,
    /// Rotating start index for first-fit, spreading GPU tasks across
    /// nodes instead of hammering node 0.
    cursor: usize,
    /// Node visit order for spanning allocations, descending by free
    /// cores — a lazily-repaired index. Mutations outside
    /// `alloc_spanning` (node-local allocs, releases) only mark it
    /// stale; `alloc_spanning` repairs its own damage incrementally, so
    /// a burst of spanning allocations (one scheduler drain round
    /// placing a whole CPU task set) sorts once instead of per-task.
    span_order: Vec<usize>,
    span_order_stale: bool,
}

impl Allocator {
    pub fn new(spec: &ClusterSpec) -> Allocator {
        Allocator {
            free_cores: spec.nodes.iter().map(|n| n.cores).collect(),
            free_gpus: spec.nodes.iter().map(|n| n.gpus).collect(),
            total_free_cores: spec.total_cores(),
            total_free_gpus: spec.total_gpus(),
            cursor: 0,
            span_order: Vec::new(),
            span_order_stale: true,
            spec: spec.clone(),
        }
    }

    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    pub fn free_cores(&self) -> u64 {
        self.total_free_cores
    }

    pub fn free_gpus(&self) -> u64 {
        self.total_free_gpus
    }

    pub fn used_cores(&self) -> u64 {
        self.spec.total_cores() - self.total_free_cores
    }

    pub fn used_gpus(&self) -> u64 {
        self.spec.total_gpus() - self.total_free_gpus
    }

    /// Cheap feasibility pre-check (no placement computed).
    pub fn may_fit(&self, req: &ResourceRequest) -> bool {
        req.cpu_cores as u64 <= self.total_free_cores
            && req.gpus as u64 <= self.total_free_gpus
    }

    /// Try to place one task; returns `None` when it doesn't currently fit.
    pub fn try_alloc(&mut self, req: &ResourceRequest) -> Option<Placement> {
        if !self.may_fit(req) {
            return None;
        }
        if req.node_local() {
            self.alloc_node_local(req)
        } else {
            self.alloc_spanning(req)
        }
    }

    fn alloc_node_local(&mut self, req: &ResourceRequest) -> Option<Placement> {
        let n = self.free_cores.len();
        for off in 0..n {
            let i = (self.cursor + off) % n;
            if self.free_cores[i] >= req.cpu_cores && self.free_gpus[i] >= req.gpus {
                self.free_cores[i] -= req.cpu_cores;
                self.free_gpus[i] -= req.gpus;
                self.total_free_cores -= req.cpu_cores as u64;
                self.total_free_gpus -= req.gpus as u64;
                self.cursor = (i + 1) % n;
                if req.cpu_cores > 0 {
                    self.span_order_stale = true;
                }
                return Some(Placement { slots: vec![(i, req.cpu_cores, req.gpus)] });
            }
        }
        None
    }

    fn alloc_spanning(&mut self, req: &ResourceRequest) -> Option<Placement> {
        // total_free_cores >= cpu_cores was pre-checked; greedily take
        // cores from the fullest-free nodes to limit fragmentation.
        if self.span_order_stale {
            self.span_order = (0..self.free_cores.len()).collect();
            self.span_order
                .sort_by_key(|&i| std::cmp::Reverse(self.free_cores[i]));
            self.span_order_stale = false;
        }
        let mut remaining = req.cpu_cores;
        let mut slots = Vec::new();
        // Visit nodes in cached descending-free-cores order.
        let mut consumed = 0usize;
        for &i in &self.span_order {
            if remaining == 0 {
                break;
            }
            let take = self.free_cores[i].min(remaining);
            consumed += 1;
            if take > 0 {
                slots.push((i, take, 0));
                remaining -= take;
            }
        }
        debug_assert_eq!(remaining, 0);
        for &(i, c, _) in &slots {
            self.free_cores[i] -= c;
        }
        self.total_free_cores -= req.cpu_cores as u64;
        self.repair_span_order(consumed);
        Some(Placement { slots })
    }

    /// Restore `span_order`'s descending-free-cores invariant after a
    /// spanning allocation that consumed its first `consumed` entries:
    /// all but the last are drained to zero free cores and belong at
    /// the back; the last — possibly only partially drained — is
    /// re-positioned by binary search. In place, via rotates: no
    /// comparison sort, no allocations.
    fn repair_span_order(&mut self, consumed: usize) {
        if consumed == 0 {
            return;
        }
        let n = self.span_order.len();
        // [drained.., partial, rest..] -> [partial, rest.., drained..].
        self.span_order.rotate_left(consumed - 1);
        // Slot the partial node (now at index 0) into the still-sorted
        // rest.
        let rest_len = n - consumed;
        let free = self.free_cores[self.span_order[0]];
        let pos = self.span_order[1..1 + rest_len]
            .partition_point(|&i| self.free_cores[i] >= free);
        self.span_order[..=pos].rotate_left(1);
    }

    /// Return a placement's resources to the pool.
    pub fn release(&mut self, p: &Placement) {
        self.span_order_stale = true;
        for &(i, cores, gpus) in &p.slots {
            self.free_cores[i] += cores;
            self.free_gpus[i] += gpus;
            debug_assert!(self.free_cores[i] <= self.spec.nodes[i].cores);
            debug_assert!(self.free_gpus[i] <= self.spec.nodes[i].gpus);
            self.total_free_cores += cores as u64;
            self.total_free_gpus += gpus as u64;
        }
    }

    /// Invariant check used by tests: per-node free counts within bounds
    /// and totals consistent; a non-stale span index must be a
    /// permutation in descending free-cores order.
    pub fn check_invariants(&self) -> bool {
        let sum_c: u64 = self.free_cores.iter().map(|&c| c as u64).sum();
        let sum_g: u64 = self.free_gpus.iter().map(|&g| g as u64).sum();
        let span_ok = self.span_order_stale || {
            let mut seen = vec![false; self.free_cores.len()];
            self.span_order.len() == self.free_cores.len()
                && self.span_order.iter().all(|&i| {
                    i < seen.len() && !std::mem::replace(&mut seen[i], true)
                })
                && self
                    .span_order
                    .windows(2)
                    .all(|w| self.free_cores[w[0]] >= self.free_cores[w[1]])
        };
        span_ok
            && sum_c == self.total_free_cores
            && sum_g == self.total_free_gpus
            && self
                .free_cores
                .iter()
                .zip(&self.spec.nodes)
                .all(|(&f, n)| f <= n.cores)
            && self
                .free_gpus
                .iter()
                .zip(&self.spec.nodes)
                .all(|(&f, n)| f <= n.gpus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_bool;
    use crate::util::rng::Rng;

    fn cluster() -> ClusterSpec {
        ClusterSpec::uniform("t", 4, 8, 2)
    }

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = Allocator::new(&cluster());
        let p = a.try_alloc(&ResourceRequest::new(4, 1)).unwrap();
        assert_eq!(a.used_cores(), 4);
        assert_eq!(a.used_gpus(), 1);
        a.release(&p);
        assert_eq!(a.used_cores(), 0);
        assert_eq!(a.used_gpus(), 0);
        assert!(a.check_invariants());
    }

    #[test]
    fn gpu_task_is_node_local() {
        // 2 GPUs per node; a 2-GPU task must land on one node.
        let mut a = Allocator::new(&cluster());
        let p = a.try_alloc(&ResourceRequest::new(2, 2)).unwrap();
        assert_eq!(p.slots.len(), 1);
    }

    #[test]
    fn gpu_exhaustion_blocks() {
        let mut a = Allocator::new(&cluster()); // 8 GPUs total
        let mut placements = vec![];
        for _ in 0..8 {
            placements.push(a.try_alloc(&ResourceRequest::new(1, 1)).unwrap());
        }
        assert!(a.try_alloc(&ResourceRequest::new(1, 1)).is_none());
        a.release(&placements.pop().unwrap());
        assert!(a.try_alloc(&ResourceRequest::new(1, 1)).is_some());
    }

    #[test]
    fn cpu_task_spans_nodes() {
        let mut a = Allocator::new(&cluster()); // 32 cores over 4 nodes
        let p = a.try_alloc(&ResourceRequest::new(20, 0)).unwrap();
        assert!(p.slots.len() >= 3, "20 cores must span >= 3 of 8-core nodes");
        assert_eq!(p.total_cores(), 20);
        assert_eq!(a.free_cores(), 12);
        a.release(&p);
        assert!(a.check_invariants());
    }

    #[test]
    fn fragmentation_can_block_node_local() {
        // Fill 1 core + 1 gpu on each node; a (8-core,1-gpu) task then
        // fails even though 28 cores are free allocation-wide.
        let mut a = Allocator::new(&cluster());
        for _ in 0..4 {
            a.try_alloc(&ResourceRequest::new(1, 1)).unwrap();
        }
        assert!(a.try_alloc(&ResourceRequest::new(8, 1)).is_none());
        // ... but a CPU-only 8-core task still fits by spanning.
        assert!(a.try_alloc(&ResourceRequest::new(8, 0)).is_some());
    }

    #[test]
    fn span_order_stays_sorted_across_alloc_bursts() {
        // Bursts of spanning allocations repair the index in place; the
        // invariant checker verifies descending order + permutation.
        let mut a = Allocator::new(&ClusterSpec::uniform("t", 6, 10, 1));
        let mut live = vec![];
        for cores in [7, 7, 9, 4, 12, 3, 11] {
            live.push(a.try_alloc(&ResourceRequest::new(cores, 0)).unwrap());
            assert!(a.check_invariants(), "after spanning alloc of {cores}");
        }
        // Interleave node-local + release (stale paths) with more bursts.
        let g = a.try_alloc(&ResourceRequest::new(1, 1)).unwrap();
        a.release(&live.pop().unwrap());
        for cores in [5, 5] {
            live.push(a.try_alloc(&ResourceRequest::new(cores, 0)).unwrap());
            assert!(a.check_invariants(), "after re-sort + alloc of {cores}");
        }
        a.release(&g);
        for p in &live {
            a.release(p);
        }
        assert!(a.check_invariants());
        assert_eq!(a.used_cores(), 0);
    }

    #[test]
    fn property_no_oversubscription() {
        // Random alloc/release interleavings never violate invariants.
        check_bool(
            0xA110C,
            300,
            |rng: &mut Rng, size| {
                let ops: Vec<(u32, u32, bool)> = (0..size.0 * 4)
                    .map(|_| {
                        (
                            rng.below(10) as u32,
                            rng.below(3) as u32,
                            rng.f64() < 0.4,
                        )
                    })
                    .collect();
                ops
            },
            |ops| {
                let mut a = Allocator::new(&ClusterSpec::uniform("p", 3, 12, 2));
                let mut live: Vec<Placement> = vec![];
                for &(c, g, release_first) in ops {
                    if release_first && !live.is_empty() {
                        let p = live.swap_remove(0);
                        a.release(&p);
                    }
                    if c == 0 && g == 0 {
                        continue;
                    }
                    if let Some(p) = a.try_alloc(&ResourceRequest::new(c, g)) {
                        if p.total_cores() != c as u64 || p.total_gpus() != g as u64 {
                            return false;
                        }
                        live.push(p);
                    }
                    if !a.check_invariants() {
                        return false;
                    }
                }
                for p in &live {
                    a.release(p);
                }
                a.check_invariants() && a.used_cores() == 0 && a.used_gpus() == 0
            },
        );
    }
}
