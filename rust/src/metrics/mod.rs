//! Metrics (substrate S17): per-task records, utilization timelines
//! (the data behind Figures 4–6), throughput, and measured DOA_res.

mod chrome;
mod plot;
mod report;

pub use chrome::{chrome_trace, chrome_trace_records};
pub use plot::ascii_timeline;
pub use report::{per_set_summaries, report_to_json, SetSummary};

use crate::error::{Error, Result};
use crate::resources::ClusterSpec;
use crate::util::json::{f64_or_nan, from_f64_nan, obj, FromJson, Json, ToJson};

/// One executed task's lifecycle record.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub uid: usize,
    pub set_idx: usize,
    pub set_name: String,
    pub pipeline: usize,
    pub branch: usize,
    pub submitted: f64,
    pub started: f64,
    pub finished: f64,
    pub cores: u64,
    pub gpus: u64,
    pub failed: bool,
}

impl TaskRecord {
    pub fn wait_time(&self) -> f64 {
        self.started - self.submitted
    }
    pub fn runtime(&self) -> f64 {
        self.finished - self.started
    }
}

impl ToJson for TaskRecord {
    fn to_json(&self) -> Json {
        obj([
            ("uid", Json::from(self.uid)),
            ("set_idx", Json::from(self.set_idx)),
            ("set_name", Json::from(self.set_name.clone())),
            ("pipeline", Json::from(self.pipeline)),
            ("branch", Json::from(self.branch)),
            // Not-yet-started/finished tasks hold NaN -> null.
            ("submitted", from_f64_nan(self.submitted)),
            ("started", from_f64_nan(self.started)),
            ("finished", from_f64_nan(self.finished)),
            ("cores", Json::from(self.cores as usize)),
            ("gpus", Json::from(self.gpus as usize)),
            ("failed", Json::from(self.failed)),
        ])
    }
}

impl FromJson for TaskRecord {
    fn from_json(v: &Json) -> Result<TaskRecord> {
        Ok(TaskRecord {
            uid: v.req_u64("uid")? as usize,
            set_idx: v.req_u64("set_idx")? as usize,
            set_name: v.req_str("set_name")?.to_string(),
            pipeline: v.req_u64("pipeline")? as usize,
            branch: v.req_u64("branch")? as usize,
            submitted: f64_or_nan(v.get("submitted"))?,
            started: f64_or_nan(v.get("started"))?,
            finished: f64_or_nan(v.get("finished"))?,
            cores: v.req_u64("cores")?,
            gpus: v.req_u64("gpus")?,
            failed: v.req_bool("failed")?,
        })
    }
}

/// Step-function *offered capacity* over time: `(t, cores, gpus)`
/// change points, non-decreasing in time. Fixed allocations have a
/// single point at t = 0; elastic runs append a point whenever the
/// offered capacity moves — grows at the instant they apply, graceful
/// drains as a node's free cores leave immediately and its busy cores
/// leave when the work on them releases. Because resources in use are
/// always part of the offered capacity, utilization integrated against
/// this timeline stays in [0, 1]; a shrink that removes idle nodes
/// *raises* reported utilization instead of silently diluting it
/// against capacity that no longer exists.
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityTimeline {
    /// `(time, offered cores, offered gpus)`; the first point carries
    /// the initial capacity (t = 0 in practice).
    pub points: Vec<(f64, u64, u64)>,
}

impl CapacityTimeline {
    /// A capacity that never changes.
    pub fn constant(cores: u64, gpus: u64) -> CapacityTimeline {
        CapacityTimeline { points: vec![(0.0, cores, gpus)] }
    }

    /// The (constant) capacity of a fixed allocation.
    pub fn of_cluster(cluster: &ClusterSpec) -> CapacityTimeline {
        CapacityTimeline::constant(cluster.total_cores(), cluster.total_gpus())
    }

    /// Append a change point at `t` (monotone); a point at the exact
    /// same instant overwrites the previous one (e.g. two resize events
    /// applied in the same engine step).
    pub fn record(&mut self, t: f64, cores: u64, gpus: u64) {
        match self.points.last_mut() {
            Some(last) if last.0 == t => {
                last.1 = cores;
                last.2 = gpus;
            }
            Some(last) => {
                debug_assert!(t > last.0, "capacity points must be monotone in time");
                self.points.push((t, cores, gpus));
            }
            None => self.points.push((t, cores, gpus)),
        }
    }

    /// Capacity in effect at time `t` (0 before the first point).
    pub fn at(&self, t: f64) -> (u64, u64) {
        let mut cur = (0, 0);
        for &(pt, c, g) in &self.points {
            if pt <= t {
                cur = (c, g);
            } else {
                break;
            }
        }
        cur
    }

    /// True when the capacity never changes over the timeline.
    pub fn is_constant(&self) -> bool {
        self.points
            .windows(2)
            .all(|w| w[0].1 == w[1].1 && w[0].2 == w[1].2)
    }

    /// Offered `(core-seconds, gpu-seconds)` over `[t0, t1]` — the
    /// utilization denominator for a window.
    pub fn integrate(&self, t0: f64, t1: f64) -> (f64, f64) {
        if !(t1 > t0) {
            return (0.0, 0.0);
        }
        let (mut cs, mut gs) = (0.0, 0.0);
        for (k, &(pt, c, g)) in self.points.iter().enumerate() {
            let end = self.points.get(k + 1).map_or(f64::INFINITY, |p| p.0);
            let (s, e) = (pt.max(t0), end.min(t1));
            if e > s {
                cs += c as f64 * (e - s);
                gs += g as f64 * (e - s);
            }
        }
        (cs, gs)
    }

    /// Per-dimension maximum capacity over the timeline.
    pub fn peak(&self) -> (u64, u64) {
        self.points
            .iter()
            .fold((0, 0), |(c, g), &(_, pc, pg)| (c.max(pc), g.max(pg)))
    }

    /// Capacity after the last change point.
    pub fn final_capacity(&self) -> (u64, u64) {
        self.points.last().map_or((0, 0), |&(_, c, g)| (c, g))
    }

    /// CSV rendering: `time_s,capacity_cores,capacity_gpus`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("time_s,capacity_cores,capacity_gpus\n");
        for &(t, c, g) in &self.points {
            s.push_str(&format!("{t:.3},{c},{g}\n"));
        }
        s
    }
}

impl ToJson for CapacityTimeline {
    fn to_json(&self) -> Json {
        Json::Arr(
            self.points
                .iter()
                .map(|&(t, c, g)| {
                    Json::Arr(vec![
                        Json::from(t),
                        Json::from(c as usize),
                        Json::from(g as usize),
                    ])
                })
                .collect(),
        )
    }
}

impl FromJson for CapacityTimeline {
    fn from_json(v: &Json) -> Result<CapacityTimeline> {
        let arr = v
            .as_arr()
            .ok_or_else(|| Error::Config("capacity timeline: expected an array".into()))?;
        let mut points = Vec::with_capacity(arr.len());
        for p in arr {
            let triple = p.as_arr().filter(|t| t.len() == 3).ok_or_else(|| {
                Error::Config("capacity timeline: each point must be [t, cores, gpus]".into())
            })?;
            let t = triple[0]
                .as_f64()
                .ok_or_else(|| Error::Config("capacity timeline: bad time".into()))?;
            let c = triple[1]
                .as_u64()
                .ok_or_else(|| Error::Config("capacity timeline: bad cores".into()))?;
            let g = triple[2]
                .as_u64()
                .ok_or_else(|| Error::Config("capacity timeline: bad gpus".into()))?;
            points.push((t, c, g));
        }
        Ok(CapacityTimeline { points })
    }
}

/// Step-function utilization over time, rebuilt from task records —
/// exactly what Figs. 4–6 plot (cores/GPUs in use vs. TTX).
#[derive(Debug, Clone)]
pub struct UtilizationTrace {
    /// (time, cores_in_use, gpus_in_use) at each change point.
    pub points: Vec<(f64, u64, u64)>,
    /// Peak schedulable capacity over the run (fraction denominators in
    /// [`sampled`](Self::sampled) fall back to the per-instant capacity,
    /// not these).
    pub total_cores: u64,
    pub total_gpus: u64,
    /// Capacity timeline the utilization integrates against; constant
    /// for fixed allocations.
    pub capacity: CapacityTimeline,
    pub makespan: f64,
}

impl UtilizationTrace {
    pub fn from_records(records: &[TaskRecord], cluster: &ClusterSpec) -> UtilizationTrace {
        UtilizationTrace::from_records_capacity(records, CapacityTimeline::of_cluster(cluster))
    }

    /// [`from_records`](Self::from_records) against a time-varying
    /// capacity (elastic allocations).
    pub fn from_records_capacity(
        records: &[TaskRecord],
        capacity: CapacityTimeline,
    ) -> UtilizationTrace {
        // Change points: every start (+) and finish (-).
        let mut deltas: Vec<(f64, i64, i64)> = Vec::with_capacity(records.len() * 2);
        let mut makespan = 0.0f64;
        for r in records {
            deltas.push((r.started, r.cores as i64, r.gpus as i64));
            deltas.push((r.finished, -(r.cores as i64), -(r.gpus as i64)));
            makespan = makespan.max(r.finished);
        }
        deltas.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut points = Vec::with_capacity(deltas.len() + 1);
        let (mut c, mut g) = (0i64, 0i64);
        points.push((0.0, 0, 0));
        let mut i = 0;
        while i < deltas.len() {
            let t = deltas[i].0;
            // Fold all deltas at identical timestamps.
            while i < deltas.len() && deltas[i].0 == t {
                c += deltas[i].1;
                g += deltas[i].2;
                i += 1;
            }
            debug_assert!(c >= 0 && g >= 0);
            points.push((t, c.max(0) as u64, g.max(0) as u64));
        }
        let (total_cores, total_gpus) = capacity.peak();
        UtilizationTrace { points, total_cores, total_gpus, capacity, makespan }
    }

    /// Time-integrated utilization in [0,1] for cores / GPUs: used
    /// core/GPU-seconds over core/GPU-seconds *offered by the capacity
    /// timeline* across the makespan. On a fixed allocation this is the
    /// classic `used / (total x makespan)`; on an elastic one, capacity
    /// that was never offered (drained idle nodes) no longer dilutes
    /// the ratio — and since busy cores stay on the timeline until
    /// released, the ratio cannot exceed 1 either.
    pub fn mean_utilization(&self) -> (f64, f64) {
        if self.makespan <= 0.0 {
            return (0.0, 0.0);
        }
        let (mut core_s, mut gpu_s) = (0.0, 0.0);
        for w in self.points.windows(2) {
            let dt = w[1].0 - w[0].0;
            core_s += w[0].1 as f64 * dt;
            gpu_s += w[0].2 as f64 * dt;
        }
        // Tail after the last change point is all-zero by construction.
        // Zero offered capacity (GPU-only / CPU-only specs) yields 0,
        // not NaN.
        let (cap_core_s, cap_gpu_s) = self.capacity.integrate(0.0, self.makespan);
        (
            if cap_core_s > 0.0 { core_s / cap_core_s } else { 0.0 },
            if cap_gpu_s > 0.0 { gpu_s / cap_gpu_s } else { 0.0 },
        )
    }

    /// Utilization sampled on a uniform grid (CSV/figure output);
    /// fractions are against the capacity in effect at each sample.
    pub fn sampled(&self, samples: usize) -> Vec<(f64, f64, f64)> {
        assert!(samples >= 2);
        let mut out = Vec::with_capacity(samples);
        let mut seg = 0usize;
        for k in 0..samples {
            let t = self.makespan * k as f64 / (samples - 1) as f64;
            while seg + 1 < self.points.len() && self.points[seg + 1].0 <= t {
                seg += 1;
            }
            let (_, c, g) = self.points[seg];
            let (cap_c, cap_g) = self.capacity.at(t);
            out.push((
                t,
                c as f64 / cap_c.max(1) as f64,
                g as f64 / cap_g.max(1) as f64,
            ));
        }
        out
    }

    /// CSV rendering: `time,cores_used,gpus_used,core_frac,gpu_frac`;
    /// fractions are against the capacity in effect at each change
    /// point.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("time_s,cores_used,gpus_used,core_frac,gpu_frac\n");
        for &(t, c, g) in &self.points {
            let (cap_c, cap_g) = self.capacity.at(t);
            s.push_str(&format!(
                "{:.3},{},{},{:.4},{:.4}\n",
                t,
                c,
                g,
                c as f64 / cap_c.max(1) as f64,
                g as f64 / cap_g.max(1) as f64
            ));
        }
        s
    }
}

/// Step-function *allocation backlog* over time: how many tasks (and
/// how many cores / GPUs they request) are queued — submitted but not
/// yet placed — at each instant. The companion of [`UtilizationTrace`]
/// for streaming-traffic analysis: a backlog that keeps growing over
/// the arrival window means the workload exceeds the allocation's
/// service capacity (the saturation knee).
#[derive(Debug, Clone, PartialEq)]
pub struct BacklogTrace {
    /// (time, queued tasks, queued cores, queued gpus) at each change
    /// point; starts at (0, 0, 0, 0).
    pub points: Vec<(f64, u64, u64, u64)>,
    /// Last task finish time (the observation horizon).
    pub horizon: f64,
}

impl BacklogTrace {
    pub fn from_records(records: &[TaskRecord]) -> BacklogTrace {
        // Change points: +req at submission, -req at placement (start).
        let mut deltas: Vec<(f64, i64, i64, i64)> = Vec::with_capacity(records.len() * 2);
        let mut horizon = 0.0f64;
        for r in records {
            if r.finished.is_finite() {
                horizon = horizon.max(r.finished);
            }
            if !r.submitted.is_finite() || !r.started.is_finite() {
                continue; // never-placed task (aborted run); skip
            }
            deltas.push((r.submitted, 1, r.cores as i64, r.gpus as i64));
            deltas.push((r.started, -1, -(r.cores as i64), -(r.gpus as i64)));
        }
        deltas.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut points = Vec::with_capacity(deltas.len() + 1);
        points.push((0.0, 0, 0, 0));
        let (mut n, mut c, mut g) = (0i64, 0i64, 0i64);
        let mut i = 0;
        while i < deltas.len() {
            let t = deltas[i].0;
            // Fold all deltas at identical timestamps.
            while i < deltas.len() && deltas[i].0 == t {
                n += deltas[i].1;
                c += deltas[i].2;
                g += deltas[i].3;
                i += 1;
            }
            debug_assert!(n >= 0 && c >= 0 && g >= 0);
            points.push((t, n.max(0) as u64, c.max(0) as u64, g.max(0) as u64));
        }
        BacklogTrace { points, horizon }
    }

    /// Peak backlog as (tasks, cores, gpus) — each dimension's own
    /// maximum (they need not occur at the same instant).
    pub fn peak(&self) -> (u64, u64, u64) {
        let mut p = (0, 0, 0);
        for &(_, n, c, g) in &self.points {
            p.0 = p.0.max(n);
            p.1 = p.1.max(c);
            p.2 = p.2.max(g);
        }
        p
    }

    /// Time-averaged queued-task count over `[t0, t1]`.
    pub fn mean_tasks_between(&self, t0: f64, t1: f64) -> f64 {
        if !(t1 > t0) {
            return 0.0;
        }
        let mut acc = 0.0;
        for w in self.points.windows(2) {
            let (s, e) = (w[0].0.max(t0), w[1].0.min(t1));
            if e > s {
                acc += w[0].1 as f64 * (e - s);
            }
        }
        // After the last change point the backlog holds its last value.
        if let Some(&(last_t, last_n, _, _)) = self.points.last() {
            let s = last_t.max(t0);
            if t1 > s {
                acc += last_n as f64 * (t1 - s);
            }
        }
        acc / (t1 - t0)
    }

    /// Time-averaged queued-task count over the whole horizon.
    pub fn mean_tasks(&self) -> f64 {
        self.mean_tasks_between(0.0, self.horizon)
    }

    /// Backlog at the end of the horizon (nonzero only for aborted or
    /// truncated runs; complete runs always drain to zero).
    pub fn final_tasks(&self) -> u64 {
        self.points.last().map_or(0, |&(_, n, _, _)| n)
    }

    /// CSV rendering: `time_s,queued_tasks,queued_cores,queued_gpus`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("time_s,queued_tasks,queued_cores,queued_gpus\n");
        for &(t, n, c, g) in &self.points {
            s.push_str(&format!("{t:.3},{n},{c},{g}\n"));
        }
        s
    }
}

/// Measured DOA_res (§5.2): the maximum number of *distinct independent
/// branches* with at least one task running at the same instant, minus 1.
pub fn measured_doa_res(records: &[TaskRecord]) -> usize {
    // Sweep-line over (time, +branch) / (time, -branch) events.
    #[derive(PartialEq)]
    enum Ev {
        End,
        Start,
    }
    let mut evs: Vec<(f64, Ev, usize)> = Vec::with_capacity(records.len() * 2);
    for r in records {
        evs.push((r.started, Ev::Start, r.branch));
        evs.push((r.finished, Ev::End, r.branch));
    }
    // Ends before starts at equal time (half-open intervals).
    evs.sort_by(|a, b| {
        a.0.total_cmp(&b.0).then_with(|| match (&a.1, &b.1) {
            (Ev::End, Ev::Start) => std::cmp::Ordering::Less,
            (Ev::Start, Ev::End) => std::cmp::Ordering::Greater,
            _ => std::cmp::Ordering::Equal,
        })
    });
    let max_branch = records.iter().map(|r| r.branch).max().unwrap_or(0);
    let mut live = vec![0usize; max_branch + 1];
    let mut distinct = 0usize;
    let mut best = 0usize;
    for (_, ev, b) in evs {
        match ev {
            Ev::Start => {
                live[b] += 1;
                if live[b] == 1 {
                    distinct += 1;
                    best = best.max(distinct);
                }
            }
            Ev::End => {
                live[b] -= 1;
                if live[b] == 0 {
                    distinct -= 1;
                }
            }
        }
    }
    best.saturating_sub(1)
}

/// Jain's fairness index over a sample: `(Σx)² / (n · Σx²)`, in
/// `(0, 1]` — 1 when every value is equal, `1/n` when one value holds
/// everything. The traffic report applies it to per-workflow waits to
/// quantify scheduler starvation: FIFO under a greedy member drives it
/// toward `1/n`, weighted fair sharing holds it near 1.
///
/// Degenerate samples (empty, or all-zero — nobody waited) are
/// perfectly fair by definition: 1.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sum_sq: f64 = xs.iter().map(|x| x * x).sum();
    if sum_sq <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sum_sq)
}

/// Task throughput: completed tasks per second over the makespan.
pub fn throughput(records: &[TaskRecord]) -> f64 {
    let makespan = records.iter().map(|r| r.finished).fold(0.0, f64::max);
    if makespan <= 0.0 {
        0.0
    } else {
        records.len() as f64 / makespan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(uid: usize, branch: usize, start: f64, end: f64, cores: u64, gpus: u64) -> TaskRecord {
        TaskRecord {
            uid,
            set_idx: 0,
            set_name: "S".into(),
            pipeline: 0,
            branch,
            submitted: start,
            started: start,
            finished: end,
            cores,
            gpus,
            failed: false,
        }
    }

    fn cluster() -> ClusterSpec {
        ClusterSpec::uniform("t", 1, 10, 2)
    }

    #[test]
    fn utilization_integrates_correctly() {
        // One task: 5 cores for 10s of a 10s makespan on 10 cores = 50%.
        let recs = vec![rec(0, 0, 0.0, 10.0, 5, 0)];
        let tr = UtilizationTrace::from_records(&recs, &cluster());
        let (cu, gu) = tr.mean_utilization();
        assert!((cu - 0.5).abs() < 1e-9);
        assert_eq!(gu, 0.0);
        assert_eq!(tr.makespan, 10.0);
    }

    #[test]
    fn utilization_overlapping_tasks() {
        let recs = vec![
            rec(0, 0, 0.0, 10.0, 4, 1),
            rec(1, 0, 5.0, 10.0, 4, 1),
        ];
        let tr = UtilizationTrace::from_records(&recs, &cluster());
        // cores: 4*10 + 4*5 = 60 core-s over 100 -> 0.6
        let (cu, gu) = tr.mean_utilization();
        assert!((cu - 0.6).abs() < 1e-9);
        // gpus: 1*10 + 1*5 = 15 gpu-s over 20 -> 0.75
        assert!((gu - 0.75).abs() < 1e-9);
    }

    #[test]
    fn sampled_grid_is_uniform() {
        let recs = vec![rec(0, 0, 0.0, 10.0, 10, 2)];
        let tr = UtilizationTrace::from_records(&recs, &cluster());
        let s = tr.sampled(11);
        assert_eq!(s.len(), 11);
        assert!((s[0].0 - 0.0).abs() < 1e-9);
        assert!((s[10].0 - 10.0).abs() < 1e-9);
        assert!((s[5].1 - 1.0).abs() < 1e-9, "full core usage mid-run");
    }

    #[test]
    fn csv_has_header_and_rows() {
        let recs = vec![rec(0, 0, 0.0, 1.0, 1, 0)];
        let tr = UtilizationTrace::from_records(&recs, &cluster());
        let csv = tr.to_csv();
        assert!(csv.starts_with("time_s,"));
        assert!(csv.lines().count() >= 3);
    }

    #[test]
    fn doa_res_counts_distinct_branches() {
        // Branch 0 and 1 overlap; branch 2 runs alone afterwards.
        let recs = vec![
            rec(0, 0, 0.0, 10.0, 1, 0),
            rec(1, 1, 5.0, 15.0, 1, 0),
            rec(2, 2, 20.0, 30.0, 1, 0),
        ];
        assert_eq!(measured_doa_res(&recs), 1);
    }

    #[test]
    fn doa_res_sequential_is_zero() {
        let recs = vec![
            rec(0, 0, 0.0, 10.0, 1, 0),
            rec(1, 1, 10.0, 20.0, 1, 0), // half-open: no overlap at t=10
        ];
        assert_eq!(measured_doa_res(&recs), 0);
    }

    #[test]
    fn doa_res_same_branch_does_not_count_twice() {
        let recs = vec![
            rec(0, 0, 0.0, 10.0, 1, 0),
            rec(1, 0, 0.0, 10.0, 1, 0),
        ];
        assert_eq!(measured_doa_res(&recs), 0);
    }

    #[test]
    fn jain_index_ranges() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0, "nobody waited: perfectly fair");
        assert!((jain_index(&[5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        // One value holds everything: 1/n.
        assert!((jain_index(&[10.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        // Moderate skew lands strictly between.
        let j = jain_index(&[1.0, 2.0, 3.0]);
        assert!(j > 1.0 / 3.0 && j < 1.0, "got {j}");
    }

    #[test]
    fn throughput_simple() {
        let recs = vec![rec(0, 0, 0.0, 5.0, 1, 0), rec(1, 0, 0.0, 10.0, 1, 0)];
        assert!((throughput(&recs) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn zero_core_cluster_yields_finite_utilization() {
        // Regression: a GPU-only ClusterSpec (0 cores) used to divide by
        // zero in mean_utilization/sampled and poison reports with NaN.
        let recs = vec![rec(0, 0, 0.0, 10.0, 0, 1)];
        let gpu_only = ClusterSpec::uniform("gpu-only", 1, 0, 2);
        let tr = UtilizationTrace::from_records(&recs, &gpu_only);
        let (cu, gu) = tr.mean_utilization();
        assert!(cu.is_finite() && gu.is_finite());
        assert_eq!(cu, 0.0, "no cores in use, no cores in the cluster");
        assert!((gu - 0.5).abs() < 1e-9, "1 of 2 GPUs busy the whole run");
        for (t, c, g) in tr.sampled(5) {
            assert!(t.is_finite() && c.is_finite() && g.is_finite());
        }
        assert!(!tr.to_csv().contains("NaN"));
    }

    fn queued(uid: usize, sub: f64, start: f64, end: f64, cores: u64, gpus: u64) -> TaskRecord {
        let mut r = rec(uid, 0, start, end, cores, gpus);
        r.submitted = sub;
        r
    }

    #[test]
    fn backlog_trace_integrates_queue_time() {
        // Task 0 queued [0, 4), task 1 queued [2, 8): overlap in [2, 4).
        let recs = vec![
            queued(0, 0.0, 4.0, 10.0, 2, 0),
            queued(1, 2.0, 8.0, 10.0, 1, 1),
        ];
        let tr = BacklogTrace::from_records(&recs);
        assert_eq!(tr.horizon, 10.0);
        assert_eq!(tr.peak(), (2, 3, 1));
        assert_eq!(tr.final_tasks(), 0);
        // Queued-task integral: 1*2 + 2*2 + 1*4 = 10 task-seconds.
        assert!((tr.mean_tasks() - 1.0).abs() < 1e-9);
        assert!((tr.mean_tasks_between(0.0, 4.0) - 1.5).abs() < 1e-9);
        assert!((tr.mean_tasks_between(8.0, 10.0) - 0.0).abs() < 1e-9);
        assert!(tr.to_csv().starts_with("time_s,queued_tasks"));
    }

    #[test]
    fn backlog_zero_wait_tasks_cancel_out() {
        // submitted == started: the +/- deltas fold to a flat zero line.
        let recs = vec![queued(0, 1.0, 1.0, 5.0, 4, 1)];
        let tr = BacklogTrace::from_records(&recs);
        assert_eq!(tr.peak(), (0, 0, 0));
        assert_eq!(tr.mean_tasks(), 0.0);
    }

    #[test]
    fn capacity_timeline_records_and_integrates() {
        let mut cap = CapacityTimeline::constant(10, 2);
        assert!(cap.is_constant());
        cap.record(5.0, 5, 1);
        cap.record(8.0, 15, 3);
        assert!(!cap.is_constant());
        assert_eq!(cap.at(0.0), (10, 2));
        assert_eq!(cap.at(4.999), (10, 2));
        assert_eq!(cap.at(5.0), (5, 1));
        assert_eq!(cap.at(100.0), (15, 3));
        assert_eq!(cap.peak(), (15, 3));
        assert_eq!(cap.final_capacity(), (15, 3));
        // 10*5 + 5*3 + 15*2 = 95 core-s; 2*5 + 1*3 + 3*2 = 19 gpu-s.
        let (cs, gs) = cap.integrate(0.0, 10.0);
        assert!((cs - 95.0).abs() < 1e-9);
        assert!((gs - 19.0).abs() < 1e-9);
        // Sub-window spanning one change point: 10*1 + 5*1 = 15.
        assert!((cap.integrate(4.0, 6.0).0 - 15.0).abs() < 1e-9);
        // Same-instant record overwrites instead of duplicating.
        cap.record(8.0, 20, 4);
        assert_eq!(cap.points.last(), Some(&(8.0, 20, 4)));
        assert!(cap.to_csv().starts_with("time_s,capacity_cores"));
    }

    #[test]
    fn shrink_with_idle_nodes_raises_utilization() {
        // Regression for the elastic fix: one task using 4 of 10 cores
        // for the whole 10 s run. Against the constant capacity that is
        // 40%; if half the (idle) capacity is drained at t = 5 the
        // offered core-seconds shrink to 10*5 + 5*5 = 75, so the same
        // work reads as 40/75 ≈ 53%.
        let recs = vec![rec(0, 0, 0.0, 10.0, 4, 0)];
        let fixed = UtilizationTrace::from_records(&recs, &cluster());
        let mut cap = CapacityTimeline::constant(10, 2);
        cap.record(5.0, 5, 1);
        let elastic = UtilizationTrace::from_records_capacity(&recs, cap);
        let (cu_fixed, _) = fixed.mean_utilization();
        let (cu_elastic, _) = elastic.mean_utilization();
        assert!((cu_fixed - 0.4).abs() < 1e-9);
        assert!((cu_elastic - 40.0 / 75.0).abs() < 1e-9);
        assert!(
            cu_elastic > cu_fixed,
            "shrinking idle capacity must raise utilization ({cu_elastic} vs {cu_fixed})"
        );
        // Peak capacity feeds the public totals.
        assert_eq!(elastic.total_cores, 10);
        // Sampled fractions use the capacity in effect at each instant:
        // 4/10 before the shrink, 4/5 after.
        let s = elastic.sampled(11);
        assert!((s[2].1 - 0.4).abs() < 1e-9);
        assert!((s[8].1 - 0.8).abs() < 1e-9);
    }
}
