//! Metrics (substrate S17): per-task records, utilization timelines
//! (the data behind Figures 4–6), throughput, and measured DOA_res.

mod chrome;
mod plot;
mod report;

pub use chrome::chrome_trace;
pub use plot::ascii_timeline;
pub use report::{per_set_summaries, report_to_json, SetSummary};

use crate::resources::ClusterSpec;

/// One executed task's lifecycle record.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub uid: usize,
    pub set_idx: usize,
    pub set_name: String,
    pub pipeline: usize,
    pub branch: usize,
    pub submitted: f64,
    pub started: f64,
    pub finished: f64,
    pub cores: u64,
    pub gpus: u64,
    pub failed: bool,
}

impl TaskRecord {
    pub fn wait_time(&self) -> f64 {
        self.started - self.submitted
    }
    pub fn runtime(&self) -> f64 {
        self.finished - self.started
    }
}

/// Step-function utilization over time, rebuilt from task records —
/// exactly what Figs. 4–6 plot (cores/GPUs in use vs. TTX).
#[derive(Debug, Clone)]
pub struct UtilizationTrace {
    /// (time, cores_in_use, gpus_in_use) at each change point.
    pub points: Vec<(f64, u64, u64)>,
    pub total_cores: u64,
    pub total_gpus: u64,
    pub makespan: f64,
}

impl UtilizationTrace {
    pub fn from_records(records: &[TaskRecord], cluster: &ClusterSpec) -> UtilizationTrace {
        // Change points: every start (+) and finish (-).
        let mut deltas: Vec<(f64, i64, i64)> = Vec::with_capacity(records.len() * 2);
        let mut makespan = 0.0f64;
        for r in records {
            deltas.push((r.started, r.cores as i64, r.gpus as i64));
            deltas.push((r.finished, -(r.cores as i64), -(r.gpus as i64)));
            makespan = makespan.max(r.finished);
        }
        deltas.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut points = Vec::with_capacity(deltas.len() + 1);
        let (mut c, mut g) = (0i64, 0i64);
        points.push((0.0, 0, 0));
        let mut i = 0;
        while i < deltas.len() {
            let t = deltas[i].0;
            // Fold all deltas at identical timestamps.
            while i < deltas.len() && deltas[i].0 == t {
                c += deltas[i].1;
                g += deltas[i].2;
                i += 1;
            }
            debug_assert!(c >= 0 && g >= 0);
            points.push((t, c.max(0) as u64, g.max(0) as u64));
        }
        UtilizationTrace {
            points,
            total_cores: cluster.total_cores(),
            total_gpus: cluster.total_gpus(),
            makespan,
        }
    }

    /// Time-integrated utilization in [0,1] for cores / GPUs.
    pub fn mean_utilization(&self) -> (f64, f64) {
        if self.makespan <= 0.0 {
            return (0.0, 0.0);
        }
        let (mut core_s, mut gpu_s) = (0.0, 0.0);
        for w in self.points.windows(2) {
            let dt = w[1].0 - w[0].0;
            core_s += w[0].1 as f64 * dt;
            gpu_s += w[0].2 as f64 * dt;
        }
        // Tail after the last change point is all-zero by construction.
        // `.max(1)` guards GPU-only / CPU-only cluster specs (a zero
        // denominator would silently poison reports with NaN).
        (
            core_s / (self.total_cores.max(1) as f64 * self.makespan),
            gpu_s / (self.total_gpus.max(1) as f64 * self.makespan),
        )
    }

    /// Utilization sampled on a uniform grid (CSV/figure output).
    pub fn sampled(&self, samples: usize) -> Vec<(f64, f64, f64)> {
        assert!(samples >= 2);
        let mut out = Vec::with_capacity(samples);
        let mut seg = 0usize;
        for k in 0..samples {
            let t = self.makespan * k as f64 / (samples - 1) as f64;
            while seg + 1 < self.points.len() && self.points[seg + 1].0 <= t {
                seg += 1;
            }
            let (_, c, g) = self.points[seg];
            out.push((
                t,
                c as f64 / self.total_cores.max(1) as f64,
                g as f64 / self.total_gpus.max(1) as f64,
            ));
        }
        out
    }

    /// CSV rendering: `time,cores_used,gpus_used,core_frac,gpu_frac`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("time_s,cores_used,gpus_used,core_frac,gpu_frac\n");
        for &(t, c, g) in &self.points {
            s.push_str(&format!(
                "{:.3},{},{},{:.4},{:.4}\n",
                t,
                c,
                g,
                c as f64 / self.total_cores.max(1) as f64,
                g as f64 / self.total_gpus.max(1) as f64
            ));
        }
        s
    }
}

/// Step-function *allocation backlog* over time: how many tasks (and
/// how many cores / GPUs they request) are queued — submitted but not
/// yet placed — at each instant. The companion of [`UtilizationTrace`]
/// for streaming-traffic analysis: a backlog that keeps growing over
/// the arrival window means the workload exceeds the allocation's
/// service capacity (the saturation knee).
#[derive(Debug, Clone, PartialEq)]
pub struct BacklogTrace {
    /// (time, queued tasks, queued cores, queued gpus) at each change
    /// point; starts at (0, 0, 0, 0).
    pub points: Vec<(f64, u64, u64, u64)>,
    /// Last task finish time (the observation horizon).
    pub horizon: f64,
}

impl BacklogTrace {
    pub fn from_records(records: &[TaskRecord]) -> BacklogTrace {
        // Change points: +req at submission, -req at placement (start).
        let mut deltas: Vec<(f64, i64, i64, i64)> = Vec::with_capacity(records.len() * 2);
        let mut horizon = 0.0f64;
        for r in records {
            if r.finished.is_finite() {
                horizon = horizon.max(r.finished);
            }
            if !r.submitted.is_finite() || !r.started.is_finite() {
                continue; // never-placed task (aborted run); skip
            }
            deltas.push((r.submitted, 1, r.cores as i64, r.gpus as i64));
            deltas.push((r.started, -1, -(r.cores as i64), -(r.gpus as i64)));
        }
        deltas.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut points = Vec::with_capacity(deltas.len() + 1);
        points.push((0.0, 0, 0, 0));
        let (mut n, mut c, mut g) = (0i64, 0i64, 0i64);
        let mut i = 0;
        while i < deltas.len() {
            let t = deltas[i].0;
            // Fold all deltas at identical timestamps.
            while i < deltas.len() && deltas[i].0 == t {
                n += deltas[i].1;
                c += deltas[i].2;
                g += deltas[i].3;
                i += 1;
            }
            debug_assert!(n >= 0 && c >= 0 && g >= 0);
            points.push((t, n.max(0) as u64, c.max(0) as u64, g.max(0) as u64));
        }
        BacklogTrace { points, horizon }
    }

    /// Peak backlog as (tasks, cores, gpus) — each dimension's own
    /// maximum (they need not occur at the same instant).
    pub fn peak(&self) -> (u64, u64, u64) {
        let mut p = (0, 0, 0);
        for &(_, n, c, g) in &self.points {
            p.0 = p.0.max(n);
            p.1 = p.1.max(c);
            p.2 = p.2.max(g);
        }
        p
    }

    /// Time-averaged queued-task count over `[t0, t1]`.
    pub fn mean_tasks_between(&self, t0: f64, t1: f64) -> f64 {
        if !(t1 > t0) {
            return 0.0;
        }
        let mut acc = 0.0;
        for w in self.points.windows(2) {
            let (s, e) = (w[0].0.max(t0), w[1].0.min(t1));
            if e > s {
                acc += w[0].1 as f64 * (e - s);
            }
        }
        // After the last change point the backlog holds its last value.
        if let Some(&(last_t, last_n, _, _)) = self.points.last() {
            let s = last_t.max(t0);
            if t1 > s {
                acc += last_n as f64 * (t1 - s);
            }
        }
        acc / (t1 - t0)
    }

    /// Time-averaged queued-task count over the whole horizon.
    pub fn mean_tasks(&self) -> f64 {
        self.mean_tasks_between(0.0, self.horizon)
    }

    /// Backlog at the end of the horizon (nonzero only for aborted or
    /// truncated runs; complete runs always drain to zero).
    pub fn final_tasks(&self) -> u64 {
        self.points.last().map_or(0, |&(_, n, _, _)| n)
    }

    /// CSV rendering: `time_s,queued_tasks,queued_cores,queued_gpus`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("time_s,queued_tasks,queued_cores,queued_gpus\n");
        for &(t, n, c, g) in &self.points {
            s.push_str(&format!("{t:.3},{n},{c},{g}\n"));
        }
        s
    }
}

/// Measured DOA_res (§5.2): the maximum number of *distinct independent
/// branches* with at least one task running at the same instant, minus 1.
pub fn measured_doa_res(records: &[TaskRecord]) -> usize {
    // Sweep-line over (time, +branch) / (time, -branch) events.
    #[derive(PartialEq)]
    enum Ev {
        End,
        Start,
    }
    let mut evs: Vec<(f64, Ev, usize)> = Vec::with_capacity(records.len() * 2);
    for r in records {
        evs.push((r.started, Ev::Start, r.branch));
        evs.push((r.finished, Ev::End, r.branch));
    }
    // Ends before starts at equal time (half-open intervals).
    evs.sort_by(|a, b| {
        a.0.total_cmp(&b.0).then_with(|| match (&a.1, &b.1) {
            (Ev::End, Ev::Start) => std::cmp::Ordering::Less,
            (Ev::Start, Ev::End) => std::cmp::Ordering::Greater,
            _ => std::cmp::Ordering::Equal,
        })
    });
    let max_branch = records.iter().map(|r| r.branch).max().unwrap_or(0);
    let mut live = vec![0usize; max_branch + 1];
    let mut distinct = 0usize;
    let mut best = 0usize;
    for (_, ev, b) in evs {
        match ev {
            Ev::Start => {
                live[b] += 1;
                if live[b] == 1 {
                    distinct += 1;
                    best = best.max(distinct);
                }
            }
            Ev::End => {
                live[b] -= 1;
                if live[b] == 0 {
                    distinct -= 1;
                }
            }
        }
    }
    best.saturating_sub(1)
}

/// Task throughput: completed tasks per second over the makespan.
pub fn throughput(records: &[TaskRecord]) -> f64 {
    let makespan = records.iter().map(|r| r.finished).fold(0.0, f64::max);
    if makespan <= 0.0 {
        0.0
    } else {
        records.len() as f64 / makespan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(uid: usize, branch: usize, start: f64, end: f64, cores: u64, gpus: u64) -> TaskRecord {
        TaskRecord {
            uid,
            set_idx: 0,
            set_name: "S".into(),
            pipeline: 0,
            branch,
            submitted: start,
            started: start,
            finished: end,
            cores,
            gpus,
            failed: false,
        }
    }

    fn cluster() -> ClusterSpec {
        ClusterSpec::uniform("t", 1, 10, 2)
    }

    #[test]
    fn utilization_integrates_correctly() {
        // One task: 5 cores for 10s of a 10s makespan on 10 cores = 50%.
        let recs = vec![rec(0, 0, 0.0, 10.0, 5, 0)];
        let tr = UtilizationTrace::from_records(&recs, &cluster());
        let (cu, gu) = tr.mean_utilization();
        assert!((cu - 0.5).abs() < 1e-9);
        assert_eq!(gu, 0.0);
        assert_eq!(tr.makespan, 10.0);
    }

    #[test]
    fn utilization_overlapping_tasks() {
        let recs = vec![
            rec(0, 0, 0.0, 10.0, 4, 1),
            rec(1, 0, 5.0, 10.0, 4, 1),
        ];
        let tr = UtilizationTrace::from_records(&recs, &cluster());
        // cores: 4*10 + 4*5 = 60 core-s over 100 -> 0.6
        let (cu, gu) = tr.mean_utilization();
        assert!((cu - 0.6).abs() < 1e-9);
        // gpus: 1*10 + 1*5 = 15 gpu-s over 20 -> 0.75
        assert!((gu - 0.75).abs() < 1e-9);
    }

    #[test]
    fn sampled_grid_is_uniform() {
        let recs = vec![rec(0, 0, 0.0, 10.0, 10, 2)];
        let tr = UtilizationTrace::from_records(&recs, &cluster());
        let s = tr.sampled(11);
        assert_eq!(s.len(), 11);
        assert!((s[0].0 - 0.0).abs() < 1e-9);
        assert!((s[10].0 - 10.0).abs() < 1e-9);
        assert!((s[5].1 - 1.0).abs() < 1e-9, "full core usage mid-run");
    }

    #[test]
    fn csv_has_header_and_rows() {
        let recs = vec![rec(0, 0, 0.0, 1.0, 1, 0)];
        let tr = UtilizationTrace::from_records(&recs, &cluster());
        let csv = tr.to_csv();
        assert!(csv.starts_with("time_s,"));
        assert!(csv.lines().count() >= 3);
    }

    #[test]
    fn doa_res_counts_distinct_branches() {
        // Branch 0 and 1 overlap; branch 2 runs alone afterwards.
        let recs = vec![
            rec(0, 0, 0.0, 10.0, 1, 0),
            rec(1, 1, 5.0, 15.0, 1, 0),
            rec(2, 2, 20.0, 30.0, 1, 0),
        ];
        assert_eq!(measured_doa_res(&recs), 1);
    }

    #[test]
    fn doa_res_sequential_is_zero() {
        let recs = vec![
            rec(0, 0, 0.0, 10.0, 1, 0),
            rec(1, 1, 10.0, 20.0, 1, 0), // half-open: no overlap at t=10
        ];
        assert_eq!(measured_doa_res(&recs), 0);
    }

    #[test]
    fn doa_res_same_branch_does_not_count_twice() {
        let recs = vec![
            rec(0, 0, 0.0, 10.0, 1, 0),
            rec(1, 0, 0.0, 10.0, 1, 0),
        ];
        assert_eq!(measured_doa_res(&recs), 0);
    }

    #[test]
    fn throughput_simple() {
        let recs = vec![rec(0, 0, 0.0, 5.0, 1, 0), rec(1, 0, 0.0, 10.0, 1, 0)];
        assert!((throughput(&recs) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn zero_core_cluster_yields_finite_utilization() {
        // Regression: a GPU-only ClusterSpec (0 cores) used to divide by
        // zero in mean_utilization/sampled and poison reports with NaN.
        let recs = vec![rec(0, 0, 0.0, 10.0, 0, 1)];
        let gpu_only = ClusterSpec::uniform("gpu-only", 1, 0, 2);
        let tr = UtilizationTrace::from_records(&recs, &gpu_only);
        let (cu, gu) = tr.mean_utilization();
        assert!(cu.is_finite() && gu.is_finite());
        assert_eq!(cu, 0.0, "no cores in use, no cores in the cluster");
        assert!((gu - 0.5).abs() < 1e-9, "1 of 2 GPUs busy the whole run");
        for (t, c, g) in tr.sampled(5) {
            assert!(t.is_finite() && c.is_finite() && g.is_finite());
        }
        assert!(!tr.to_csv().contains("NaN"));
    }

    fn queued(uid: usize, sub: f64, start: f64, end: f64, cores: u64, gpus: u64) -> TaskRecord {
        let mut r = rec(uid, 0, start, end, cores, gpus);
        r.submitted = sub;
        r
    }

    #[test]
    fn backlog_trace_integrates_queue_time() {
        // Task 0 queued [0, 4), task 1 queued [2, 8): overlap in [2, 4).
        let recs = vec![
            queued(0, 0.0, 4.0, 10.0, 2, 0),
            queued(1, 2.0, 8.0, 10.0, 1, 1),
        ];
        let tr = BacklogTrace::from_records(&recs);
        assert_eq!(tr.horizon, 10.0);
        assert_eq!(tr.peak(), (2, 3, 1));
        assert_eq!(tr.final_tasks(), 0);
        // Queued-task integral: 1*2 + 2*2 + 1*4 = 10 task-seconds.
        assert!((tr.mean_tasks() - 1.0).abs() < 1e-9);
        assert!((tr.mean_tasks_between(0.0, 4.0) - 1.5).abs() < 1e-9);
        assert!((tr.mean_tasks_between(8.0, 10.0) - 0.0).abs() < 1e-9);
        assert!(tr.to_csv().starts_with("time_s,queued_tasks"));
    }

    #[test]
    fn backlog_zero_wait_tasks_cancel_out() {
        // submitted == started: the +/- deltas fold to a flat zero line.
        let recs = vec![queued(0, 1.0, 1.0, 5.0, 4, 1)];
        let tr = BacklogTrace::from_records(&recs);
        assert_eq!(tr.peak(), (0, 0, 0));
        assert_eq!(tr.mean_tasks(), 0.0);
    }
}
