//! Chrome-trace export: task Gantt charts viewable in Perfetto /
//! chrome://tracing. Each pipeline becomes a "thread", each task a
//! complete event — the interactive equivalent of the paper's Figs 4–6.

use crate::engine::RunReport;
use crate::metrics::TaskRecord;
use crate::util::json::{obj, Json};

/// Serialize a run as a Chrome trace (JSON array format).
///
/// Times are exported in microseconds (trace-viewer convention) with
/// 1 paper-second = 1 us so makespans stay readable.
pub fn chrome_trace(rep: &RunReport) -> String {
    chrome_trace_records(&rep.records, "pipeline")
}

/// [`chrome_trace`] over bare records: the live report's records and a
/// replayed stream's (`obs::trace::replay`) export identically, so a
/// Chrome trace can be produced from any recorded NDJSON stream —
/// `lane_label` names what `tid` groups by (`"pipeline"` live,
/// `"slot"` replayed).
pub fn chrome_trace_records(records: &[TaskRecord], lane_label: &str) -> String {
    let mut events = Vec::with_capacity(records.len() + 8);
    for r in records {
        events.push(obj([
            ("name", Json::from(format!("{}[{}]", r.set_name, r.uid))),
            ("cat", Json::from(r.set_name.clone())),
            ("ph", Json::from("X")),
            ("ts", Json::from(r.started * 1e0)),
            ("dur", Json::from((r.finished - r.started).max(0.0))),
            ("pid", Json::from(0usize)),
            ("tid", Json::from(r.pipeline)),
            (
                "args",
                obj([
                    ("cores", Json::from(r.cores as usize)),
                    ("gpus", Json::from(r.gpus as usize)),
                    ("branch", Json::from(r.branch)),
                    ("wait_s", Json::from(r.wait_time())),
                ]),
            ),
        ]));
    }
    // Thread name metadata per lane.
    let max_pipe = records.iter().map(|r| r.pipeline).max().unwrap_or(0);
    for p in 0..=max_pipe {
        events.push(obj([
            ("name", Json::from("thread_name")),
            ("ph", Json::from("M")),
            ("pid", Json::from(0usize)),
            ("tid", Json::from(p)),
            ("args", obj([("name", Json::from(format!("{lane_label} {p}")))])),
        ]));
    }
    Json::Arr(events).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddmd::{ddmd_workflow, DdmdConfig};
    use crate::engine::{simulate, ExecutionMode};
    use crate::resources::ClusterSpec;
    use crate::util::json::Json;

    #[test]
    fn trace_is_valid_json_with_all_tasks() {
        let wf = ddmd_workflow(&DdmdConfig::paper());
        let rep = simulate(&wf, &ClusterSpec::summit_paper(), ExecutionMode::Asynchronous);
        let text = chrome_trace(&rep);
        let v = Json::parse(&text).unwrap();
        let arr = v.as_arr().unwrap();
        let complete_events = arr
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .count();
        assert_eq!(complete_events, rep.records.len());
        // Metadata events name all 3 pipelines.
        let meta = arr.iter().filter(|e| e.get("ph").as_str() == Some("M")).count();
        assert_eq!(meta, 3);
        // Events carry resource args.
        let first = arr.iter().find(|e| e.get("ph").as_str() == Some("X")).unwrap();
        assert!(first.get("args").get("cores").as_u64().is_some());
    }
}
