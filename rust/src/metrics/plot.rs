//! ASCII timeline rendering of utilization traces — terminal versions
//! of the paper's Figures 4–6.

use super::UtilizationTrace;

/// Render CPU and GPU utilization as two stacked ASCII strips.
///
/// Each column is a time bucket; glyph height encodes the fraction of
/// the allocation in use (mirrors the colored regions of Figs. 4–6).
pub fn ascii_timeline(trace: &UtilizationTrace, width: usize, height: usize) -> String {
    assert!(width >= 10 && height >= 2);
    let samples = trace.sampled(width);
    let mut out = String::new();
    for (label, pick) in [
        ("CPU", 1usize), // index into (t, core_frac, gpu_frac)
        ("GPU", 2usize),
    ] {
        out.push_str(&format!(
            "{label} utilization (peak capacity = {}):\n",
            if pick == 1 { trace.total_cores } else { trace.total_gpus }
        ));
        for row in (0..height).rev() {
            let threshold = (row as f64 + 0.5) / height as f64;
            let mut line = String::with_capacity(width + 8);
            line.push_str(&format!("{:>4.0}% |", threshold * 100.0));
            for s in &samples {
                let frac = if pick == 1 { s.1 } else { s.2 };
                line.push(if frac >= threshold { '█' } else { ' ' });
            }
            line.push('\n');
            out.push_str(&line);
        }
        out.push_str(&format!(
            "      +{}\n       0 s {:>w$.0} s\n",
            "-".repeat(width),
            trace.makespan,
            w = width.saturating_sub(8)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::TaskRecord;
    use crate::resources::ClusterSpec;

    #[test]
    fn renders_full_and_empty_regions() {
        let recs = vec![TaskRecord {
            uid: 0,
            set_idx: 0,
            set_name: "S".into(),
            pipeline: 0,
            branch: 0,
            submitted: 0.0,
            started: 0.0,
            finished: 5.0,
            cores: 10,
            gpus: 0,
            failed: false,
        }, TaskRecord {
            uid: 1,
            set_idx: 0,
            set_name: "S".into(),
            pipeline: 0,
            branch: 0,
            submitted: 0.0,
            started: 5.0,
            finished: 10.0,
            cores: 0,
            gpus: 2,
            failed: false,
        }];
        let tr = UtilizationTrace::from_records(&recs, &ClusterSpec::uniform("t", 1, 10, 2));
        let art = ascii_timeline(&tr, 40, 4);
        assert!(art.contains("CPU utilization"));
        assert!(art.contains("GPU utilization"));
        assert!(art.contains('█'));
        assert!(art.lines().count() > 8);
    }
}
