//! Structured report export: RunReport -> JSON (for downstream
//! analysis/plotting) and per-set summary tables.

use std::collections::BTreeMap;

use crate::engine::RunReport;
use crate::util::json::{obj, Json};
use crate::util::stats::Summary;

/// Per-task-set aggregate statistics from a run.
#[derive(Debug, Clone)]
pub struct SetSummary {
    pub set_name: String,
    pub tasks: usize,
    pub wait: Summary,
    pub runtime: Summary,
    pub first_start: f64,
    pub last_finish: f64,
}

/// Aggregate task records by set.
pub fn per_set_summaries(rep: &RunReport) -> Vec<SetSummary> {
    let mut groups: BTreeMap<&str, Vec<&crate::metrics::TaskRecord>> = BTreeMap::new();
    for r in &rep.records {
        groups.entry(r.set_name.as_str()).or_default().push(r);
    }
    groups
        .into_iter()
        .map(|(name, rs)| {
            let waits: Vec<f64> = rs.iter().map(|r| r.wait_time()).collect();
            let runtimes: Vec<f64> = rs.iter().map(|r| r.runtime()).collect();
            SetSummary {
                set_name: name.to_string(),
                tasks: rs.len(),
                wait: Summary::of(&waits),
                runtime: Summary::of(&runtimes),
                first_start: rs.iter().map(|r| r.started).fold(f64::INFINITY, f64::min),
                last_finish: rs.iter().map(|r| r.finished).fold(0.0, f64::max),
            }
        })
        .collect()
}

/// Full JSON export of a run (metrics + per-set summaries + trace).
pub fn report_to_json(rep: &RunReport) -> Json {
    let sets = per_set_summaries(rep)
        .into_iter()
        .map(|s| {
            obj([
                ("set", Json::from(s.set_name)),
                ("tasks", Json::from(s.tasks)),
                ("wait_mean", Json::from(s.wait.mean)),
                ("wait_p95", Json::from(s.wait.p95)),
                ("runtime_mean", Json::from(s.runtime.mean)),
                ("first_start", Json::from(s.first_start)),
                ("last_finish", Json::from(s.last_finish)),
            ])
        })
        .collect();
    obj([
        ("workflow", Json::from(rep.workflow.clone())),
        ("mode", Json::from(rep.mode.label())),
        ("makespan", Json::from(rep.makespan)),
        ("cpu_utilization", Json::from(rep.cpu_utilization)),
        ("gpu_utilization", Json::from(rep.gpu_utilization)),
        ("throughput", Json::from(rep.throughput)),
        ("doa_res_measured", Json::from(rep.doa_res)),
        ("tasks", Json::from(rep.records.len())),
        ("failed_tasks", Json::from(rep.failed_tasks)),
        ("sched_rounds", Json::from(rep.sched_rounds)),
        ("peak_live_tasks", Json::from(rep.peak_live_tasks)),
        ("sets", Json::Arr(sets)),
        (
            "trace",
            Json::Arr(
                rep.trace
                    .points
                    .iter()
                    .map(|&(t, c, g)| {
                        Json::Arr(vec![
                            Json::from(t),
                            Json::from(c as usize),
                            Json::from(g as usize),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Dag;
    use crate::engine::{simulate_cfg, EngineConfig, ExecutionMode};
    use crate::entk::{Pipeline, Workflow};
    use crate::resources::{ClusterSpec, ResourceRequest};
    use crate::task::TaskSetSpec;

    fn run() -> RunReport {
        let mut dag = Dag::new();
        dag.add_node("A");
        dag.add_node("B");
        dag.add_edge(0, 1).unwrap();
        let wf = Workflow {
            name: "r".into(),
            sets: vec![
                TaskSetSpec::new("A", 3, ResourceRequest::new(1, 0), 5.0).with_sigma(0.0),
                TaskSetSpec::new("B", 2, ResourceRequest::new(1, 0), 2.0).with_sigma(0.0),
            ],
            dag,
            sequential: vec![Pipeline::new("s").stage(&[0]).stage(&[1])],
            asynchronous: vec![Pipeline::new("a").stage(&[0]).stage(&[1])],
        };
        simulate_cfg(
            &wf,
            &ClusterSpec::uniform("t", 1, 4, 0),
            ExecutionMode::Sequential,
            &EngineConfig::ideal(),
        )
    }

    #[test]
    fn per_set_summaries_aggregate() {
        let rep = run();
        let sums = per_set_summaries(&rep);
        assert_eq!(sums.len(), 2);
        let a = sums.iter().find(|s| s.set_name == "A").unwrap();
        assert_eq!(a.tasks, 3);
        assert!((a.runtime.mean - 5.0).abs() < 1e-9);
        assert_eq!(a.first_start, 0.0);
    }

    #[test]
    fn json_export_parses_back() {
        let rep = run();
        let j = report_to_json(&rep);
        let text = j.to_string_pretty();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back.get("workflow").as_str(), Some("r"));
        assert_eq!(back.get("tasks").as_u64(), Some(5));
        assert!(back.get("trace").as_arr().unwrap().len() >= 3);
        assert_eq!(back.get("mode").as_str(), Some("sequential"));
    }
}
