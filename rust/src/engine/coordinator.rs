//! Multi-workflow coordinator: multiplexes N [`WorkflowDriver`]s over
//! one shared pilot [`Agent`] and one [`Executor`].
//!
//! The coordinator owns the three global resources the drivers must
//! share — the allocation (via the agent), the clock (via the
//! executor), and the task-uid namespace — and runs the event loop:
//!
//! 1. materialize the driver of every registered workflow whose arrival
//!    time has been reached (workflows are *streamed*: a member that
//!    arrives at t = 10⁴ costs one pending spec until then, not live
//!    driver state);
//! 2. feed `ClockAdvanced` to every live driver and submit whatever
//!    became ready;
//! 3. invoke the continuous scheduler once per state change;
//! 4. launch placements, then drain the executor's next completion
//!    batch (all completions sharing one instant are handed back in a
//!    single call) and route each back to its owning driver; drivers
//!    that finish are folded into their [`RunReport`] immediately and
//!    dropped.
//!
//! ## Bounded live state
//!
//! Global task uids are recycled through a free list the moment their
//! completion is processed, so the `specs` / `route` slabs (and the
//! agent's placement table) are bounded by **in-flight + queued** tasks
//! — not by the total number of tasks ever streamed. A traffic run of
//! thousands of workflows holds per-task engine state only for the work
//! that is actually outstanding; the high-water mark is reported as
//! [`RunReport::peak_live_tasks`].
//!
//! `engine::run` is a coordinator with exactly one driver, so the
//! single-workflow path and the concurrent-campaign path are the same
//! code.

use std::time::{Duration, Instant};

use super::driver::{EngineEvent, WorkflowDriver};
use super::{EngineConfig, ExecutionMode, RunReport};
use crate::entk::Workflow;
use crate::error::{Error, Result};
use crate::exec::{Executor, RunningTask};
use crate::metrics::CapacityTimeline;
use crate::pilot::{Agent, AutoscalePolicy, ResizeEvent, ResourcePlan};
use crate::resources::{ClusterSpec, NodeSpec};
use crate::task::TaskSpec;

/// A registered workflow whose driver has not been materialized yet:
/// until the engine clock reaches `arrival` it costs one workflow spec,
/// no per-task state.
#[derive(Debug)]
struct PendingArrival {
    wf: Workflow,
    mode: ExecutionMode,
    arrival: f64,
    /// Member slot (index of its report in [`Coordinator::run`]'s
    /// result, i.e. registration order).
    slot: usize,
    /// TX-stream base (cumulative set count — the merged-DAG node
    /// offset).
    set_stream: u64,
    /// Priority base (cumulative pipeline count).
    pipeline_base: u64,
}

/// Shared-pilot multiplexer over any number of workflow drivers.
pub struct Coordinator {
    cluster: ClusterSpec,
    cfg: EngineConfig,
    /// Registered workflows, materialized lazily during [`run`](Self::run).
    pending: Vec<PendingArrival>,
    next_set_stream: u64,
    next_pipeline: u64,
    /// Elastic allocation plan (timed resizes + autoscaler), applied
    /// inside the event loop.
    plan: Option<ResourcePlan>,
}

impl Coordinator {
    pub fn new(cluster: &ClusterSpec, cfg: &EngineConfig) -> Coordinator {
        Coordinator {
            cluster: cluster.clone(),
            cfg: cfg.clone(),
            pending: Vec::new(),
            next_set_stream: 0,
            next_pipeline: 0,
            plan: None,
        }
    }

    /// Attach an elastic [`ResourcePlan`]: timed grow/drain events and
    /// an optional backlog-driven autoscaler, applied to the shared
    /// pilot while drivers run. Every change to the *offered* capacity
    /// is recorded on the run's [`CapacityTimeline`] (see
    /// [`RunReport::capacity`]), which utilization metrics integrate
    /// against: grows appear at the instant they apply; a graceful
    /// drain sheds a node's free cores immediately and its busy cores
    /// as the running work releases them. Workflow feasibility
    /// ([`ClusterSpec::check`]) is still validated against the *initial*
    /// cluster at registration time.
    pub fn set_resource_plan(&mut self, plan: ResourcePlan) -> Result<()> {
        plan.validate()?;
        self.plan = Some(plan);
        Ok(())
    }

    /// Register a workflow whose roots become schedulable at `arrival`
    /// (engine seconds). Returns the index of its report in
    /// [`Coordinator::run`]'s result. The driver itself is only built
    /// when the clock reaches `arrival` (streamed registration).
    pub fn add_workflow(
        &mut self,
        wf: Workflow,
        mode: ExecutionMode,
        arrival: f64,
    ) -> Result<usize> {
        if !arrival.is_finite() || arrival < 0.0 {
            return Err(Error::Config(format!(
                "workflow '{}': invalid arrival time {arrival}",
                wf.name
            )));
        }
        for s in &wf.sets {
            self.cluster.check(&s.req)?;
        }
        // Validate now so registration errors surface at add time, not
        // mid-run when the driver is materialized.
        wf.validate()?;
        let n_sets = wf.sets.len() as u64;
        let n_pipes = WorkflowDriver::pipeline_count_of(&wf, mode) as u64;
        let slot = self.pending.len();
        self.pending.push(PendingArrival {
            wf,
            mode,
            arrival,
            slot,
            set_stream: self.next_set_stream,
            pipeline_base: self.next_pipeline,
        });
        self.next_set_stream += n_sets;
        self.next_pipeline += n_pipes;
        Ok(slot)
    }

    /// Number of registered workflows (pending or live).
    pub fn driver_count(&self) -> usize {
        self.pending.len()
    }

    /// Drive every registered workflow to completion over `executor`;
    /// returns one [`RunReport`] per workflow, in registration order.
    /// Scheduler accounting (rounds / wall time) and the live-task
    /// high-water mark are global and repeated on every report.
    pub fn run(mut self, executor: &mut dyn Executor) -> Result<Vec<RunReport>> {
        let mut agent = Agent::new(&self.cluster, self.cfg.policy);
        let mut capacity = CapacityTimeline::of_cluster(&self.cluster);
        // Elastic plan state: timed events in time order, the autoscaler
        // and its next evaluation time, and the node shape growth uses.
        let plan = self.plan.take();
        let (resize_events, autoscale, grow_node): (
            Vec<ResizeEvent>,
            Option<AutoscalePolicy>,
            Option<NodeSpec>,
        ) = match &plan {
            Some(p) => {
                let mut evs = p.events.clone();
                evs.sort_by(|a, b| a.at.total_cmp(&b.at));
                let node = p.node.or_else(|| self.cluster.nodes.first().copied());
                if node.is_none()
                    && (p.autoscale.is_some() || evs.iter().any(|e| e.delta > 0))
                {
                    return Err(Error::Config(
                        "resource plan: no node shape to grow by \
                         (empty cluster and no plan.node)"
                            .into(),
                    ));
                }
                (evs, p.autoscale.clone(), node)
            }
            None => (Vec::new(), None, None),
        };
        let mut next_resize = 0usize;
        let mut next_check: Option<f64> = autoscale.as_ref().map(|p| p.interval);
        // Consecutive no-op autoscaler evaluations with nothing running:
        // past a small bound the tick stops being scheduled, so a queue
        // the autoscaler cannot help (max_nodes reached, unfit shape)
        // surfaces as the deadlock error instead of ticking forever.
        let mut stalled_checks = 0u32;
        let n_members = self.pending.len();
        // Per-slot live drivers / finished reports.
        let mut drivers: Vec<Option<WorkflowDriver>> = Vec::new();
        drivers.resize_with(n_members, || None);
        let mut done: Vec<Option<RunReport>> = Vec::new();
        done.resize_with(n_members, || None);
        // Arrival-ordered stream of registrations, consumed as the
        // clock reaches each arrival (ties resolve in registration
        // order, matching merged-DAG set ordering).
        let mut pending_list = std::mem::take(&mut self.pending);
        pending_list.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.slot.cmp(&b.slot)));
        let mut pending = pending_list.into_iter().peekable();
        // Slots with a live driver, kept sorted by slot: the event loop
        // walks only live members, so per-event cost tracks live state
        // (like memory), not the total stream length.
        let mut live_slots: Vec<usize> = Vec::new();

        // Global uid slab: uid -> (driver slot, driver-local uid) and
        // the launchable spec. Completed uids are recycled via the free
        // list, bounding live entries by in-flight + queued tasks.
        let mut route: Vec<(usize, usize)> = Vec::new();
        let mut specs: Vec<TaskSpec> = Vec::new();
        let mut free_uids: Vec<usize> = Vec::new();
        let mut live_uids = 0usize;
        let mut peak_live = 0usize;

        let mut in_flight = 0usize;
        let mut sched_rounds = 0usize;
        let mut sched_wall = Duration::ZERO;
        // Only invoke the scheduler when the system state changed (new
        // submissions or freed resources) — avoids O(queue) rescans on
        // clock-advance iterations.
        let mut sched_dirty = true;

        loop {
            let now = executor.now();

            // 0. Elasticity: apply every timed resize that is due, then
            // at most one (catch-up) autoscaler evaluation. The timeline
            // records *offered* capacity (free + busy): a grow shows up
            // at the instant it applies, a graceful drain sheds a node's
            // free cores now and its busy cores only as the work on them
            // releases (step 4) — so cores in use never exceed the
            // recorded capacity. Growth can unblock queued work, so it
            // re-arms the scheduler.
            while next_resize < resize_events.len()
                && resize_events[next_resize].at <= now + 1e-12
            {
                let ev = resize_events[next_resize];
                next_resize += 1;
                if ev.delta > 0 {
                    agent.grow(ev.delta as usize, grow_node.expect("validated above"));
                    sched_dirty = true;
                } else {
                    agent.drain(ev.delta.unsigned_abs() as usize);
                }
                record_offered(&mut capacity, &agent, now);
            }
            if let (Some(p), Some(t)) = (&autoscale, next_check) {
                if t <= now + 1e-12 {
                    // One evaluation per wakeup; the next check lands on
                    // the first interval multiple strictly after `now`.
                    let missed = ((now - t) / p.interval).floor().max(0.0) + 1.0;
                    next_check = Some(t + missed * p.interval);
                    let delta = autoscale_delta(p, &agent, in_flight);
                    let acted = if delta > 0 {
                        agent.grow(delta as usize, grow_node.expect("validated above"));
                        sched_dirty = true;
                        true
                    } else if delta < 0 {
                        agent.drain(delta.unsigned_abs() as usize) > 0
                    } else {
                        false
                    };
                    if acted {
                        record_offered(&mut capacity, &agent, now);
                    }
                    if acted || in_flight > 0 {
                        stalled_checks = 0;
                    } else {
                        stalled_checks += 1;
                    }
                }
            }

            // 1. Materialize every registered workflow whose arrival is
            // due; its roots release in step 2 below.
            while pending.peek().is_some_and(|p| p.arrival <= now + 1e-12) {
                let p = pending.next().expect("peeked pending arrival");
                // Validated at registration; compile only.
                let d = WorkflowDriver::compile_prevalidated(
                    p.wf,
                    p.mode,
                    &self.cfg,
                    p.arrival,
                    p.set_stream,
                    p.pipeline_base,
                );
                drivers[p.slot] = Some(d);
                if let Err(pos) = live_slots.binary_search(&p.slot) {
                    live_slots.insert(pos, p.slot);
                }
            }

            // 2. Release activations that are due, in slot order (this
            // matches merged-DAG set ordering: member k's sets precede
            // member k+1's).
            for li in 0..live_slots.len() {
                let di = live_slots[li];
                let subs = drivers[di]
                    .as_mut()
                    .expect("live slot holds a driver")
                    .step(EngineEvent::ClockAdvanced { now });
                for sub in subs {
                    let local = sub.spec.uid;
                    let mut spec = sub.spec;
                    let gid = match free_uids.pop() {
                        Some(g) => {
                            spec.uid = g;
                            specs[g] = spec;
                            route[g] = (di, local);
                            g
                        }
                        None => {
                            let g = specs.len();
                            spec.uid = g;
                            specs.push(spec);
                            route.push((di, local));
                            g
                        }
                    };
                    agent.submit(&specs[gid], sub.priority, now);
                    live_uids += 1;
                    peak_live = peak_live.max(live_uids);
                    sched_dirty = true;
                    // Fresh work re-arms a parked autoscaler: the rescue
                    // path (grow when tasks queue with nothing running)
                    // must get its chance before the deadlock check.
                    stalled_checks = 0;
                }
            }

            // 3. Schedule everything that fits.
            let placed = if sched_dirty {
                let t0 = Instant::now();
                let placed = agent.schedule();
                sched_wall += t0.elapsed();
                sched_rounds += 1;
                sched_dirty = false;
                placed
            } else {
                Vec::new()
            };
            for s in &placed {
                let spec = &specs[s.uid];
                let (di, local) = route[s.uid];
                drivers[di]
                    .as_mut()
                    .expect("placed task belongs to a live driver")
                    .on_started(local, now);
                executor.launch(&RunningTask {
                    uid: s.uid,
                    tx: spec.tx + self.cfg.task_overhead,
                    started_at: now,
                    kind: Some(spec.kind.clone()),
                });
                in_flight += 1;
            }

            // 4. Wait for progress.
            let mut next_deferred = live_slots
                .iter()
                .filter_map(|&di| {
                    drivers[di]
                        .as_ref()
                        .expect("live slot holds a driver")
                        .next_activation()
                })
                .fold(f64::INFINITY, f64::min);
            if let Some(p) = pending.peek() {
                next_deferred = next_deferred.min(p.arrival);
            }
            // Unapplied timed resizes are wake-ups too (a future grow
            // may be the only thing that can serve a starved queue).
            if next_resize < resize_events.len() {
                next_deferred = next_deferred.min(resize_events[next_resize].at);
            }
            // The autoscaler only ticks while there is work its decision
            // could affect, and parks after repeated no-op evaluations
            // with nothing running (see `stalled_checks`).
            if let Some(t) = next_check {
                if (in_flight > 0 || agent.queue_len() > 0) && stalled_checks < 3 {
                    next_deferred = next_deferred.min(t);
                }
            }
            if in_flight > 0 {
                match executor.peek_next_completion() {
                    // An activation is due before the next completion:
                    // fast-forward to it (virtual time).
                    Some(peek) if next_deferred < peek => {
                        executor.advance_to(next_deferred);
                        continue;
                    }
                    Some(_) => {}
                    // Real executor: wait no longer than the next due
                    // activation; wake early if a completion lands.
                    None => {
                        if next_deferred.is_finite() && next_deferred > now + 1e-12 {
                            if !executor.wait_until(next_deferred) {
                                continue; // deadline hit; release at loop top
                            }
                        }
                    }
                }
                let completions = executor.drain_ready();
                if completions.is_empty() {
                    return Err(Error::Engine("executor lost in-flight tasks".into()));
                }
                for c in completions {
                    in_flight -= 1;
                    agent.complete(c.uid);
                    sched_dirty = true; // resources were freed
                    let (di, local) = route[c.uid];
                    // Recycle the global uid: its spec/route slot (and
                    // the agent's placement entry) are now reusable.
                    free_uids.push(c.uid);
                    live_uids -= 1;
                    {
                        let d = drivers[di]
                            .as_mut()
                            .expect("completion routed to a live driver");
                        let _ = d.step(EngineEvent::TaskCompleted {
                            uid: local,
                            finished_at: c.finished_at,
                            failed: c.failed,
                        });
                        if c.failed && self.cfg.abort_on_failure {
                            // Report the driver-local uid: that is the
                            // uid visible in the member's RunReport
                            // records.
                            return Err(Error::Engine(format!(
                                "task {} ({}) of workflow '{}' failed",
                                local,
                                d.record(local).set_name,
                                d.workflow_name()
                            )));
                        }
                    }
                    // Fold finished drivers into their report right
                    // away: streamed runs never accumulate dead driver
                    // state.
                    if drivers[di].as_ref().is_some_and(|d| d.is_done()) {
                        let d = drivers[di].take().expect("checked is_some");
                        done[di] = Some(d.into_report(&capacity));
                        if let Ok(pos) = live_slots.binary_search(&di) {
                            live_slots.remove(pos);
                        }
                    }
                }
                // Graceful shrink: resources this batch released on
                // draining nodes left the allocation at this instant —
                // a no-op compare for ordinary completions.
                record_offered(&mut capacity, &agent, executor.now());
            } else if next_deferred.is_finite() {
                // Nothing running; sleep (real) or fast-forward (virtual)
                // to the next activation — e.g. a workflow yet to arrive.
                executor.wait_until(next_deferred);
            } else if agent.queue_len() > 0 {
                return Err(Error::Engine(
                    "deadlock: tasks queued but nothing running (unsatisfiable request?)"
                        .into(),
                ));
            } else {
                break; // every driver drained
            }
        }

        // Degenerate members (zero-task workflows) never see a
        // completion; finalize whatever is left.
        for di in 0..drivers.len() {
            if let Some(d) = drivers[di].take() {
                debug_assert!(d.is_done());
                done[di] = Some(d.into_report(&capacity));
            }
        }
        let mut reports: Vec<RunReport> = Vec::with_capacity(n_members);
        for slot in done {
            reports.push(slot.expect("every registered workflow produces a report"));
        }
        for r in &mut reports {
            r.sched_rounds = sched_rounds;
            r.sched_wall = sched_wall;
            r.peak_live_tasks = peak_live;
            // The full (final) timeline replaces each member's
            // fold-time snapshot: member utilization was already
            // integrated over the member's own window, for which the
            // snapshot was complete, and downstream merges (campaign /
            // traffic reports) need the whole run's capacity history.
            r.capacity = capacity.clone();
        }
        Ok(reports)
    }
}

/// Append a point to the offered-capacity timeline iff the agent's
/// offered capacity (free + busy; see [`Agent::offered`]) moved since
/// the last recorded point.
fn record_offered(capacity: &mut CapacityTimeline, agent: &Agent, now: f64) {
    let (c, g) = agent.offered();
    if (c, g) != capacity.final_capacity() {
        capacity.record(now, c, g);
    }
}

/// One autoscaler evaluation: positive = nodes to add, negative = nodes
/// to drain, 0 = leave the allocation alone. Pure decision logic —
/// deterministic given the agent state.
fn autoscale_delta(p: &AutoscalePolicy, agent: &Agent, in_flight: usize) -> i64 {
    let (cap_c, cap_g) = agent.capacity();
    let nodes = agent.schedulable_nodes();
    let queued = agent.queue_len();
    let (q_c, q_g) = agent.queued_demand();
    // Backlog pressure: queued demand exceeds the threshold fraction of
    // capacity — or tasks are queued with nothing running at all (the
    // rescue case after a deep shrink left the queue unservable).
    let pressured = q_c as f64 > p.up_backlog * cap_c as f64
        || q_g as f64 > p.up_backlog * cap_g as f64
        || (queued > 0 && in_flight == 0);
    if pressured {
        if nodes < p.max_nodes {
            return p.step.min(p.max_nodes - nodes) as i64;
        }
        return 0;
    }
    if queued == 0 && nodes > p.min_nodes {
        let (free_c, free_g) = agent.free();
        if free_c as f64 >= p.down_idle * cap_c as f64
            && free_g as f64 >= p.down_idle * cap_g as f64
        {
            return -(p.step.min(nodes - p.min_nodes) as i64);
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Dag;
    use crate::entk::{Pipeline, Workflow};
    use crate::resources::ResourceRequest;
    use crate::sim::VirtualExecutor;
    use crate::task::TaskSetSpec;

    fn solo(tx: f64) -> Workflow {
        let mut dag = Dag::new();
        dag.add_node("A");
        Workflow {
            name: "solo".into(),
            sets: vec![TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), tx).with_sigma(0.0)],
            dag,
            sequential: vec![Pipeline::new("s").stage(&[0])],
            asynchronous: vec![Pipeline::new("a").stage(&[0])],
        }
    }

    #[test]
    fn two_drivers_share_one_agent() {
        let cluster = ClusterSpec::uniform("t", 1, 2, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(20.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert_eq!(reports.len(), 2);
        assert!((reports[0].makespan - 10.0).abs() < 1e-9);
        assert!((reports[1].makespan - 20.0).abs() < 1e-9);
    }

    #[test]
    fn late_arrival_shifts_the_member_timeline() {
        let cluster = ClusterSpec::uniform("t", 1, 2, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 100.0).unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert!((reports[0].makespan - 10.0).abs() < 1e-9);
        assert!((reports[1].records[0].submitted - 100.0).abs() < 1e-9);
        assert!((reports[1].makespan - 110.0).abs() < 1e-9);
    }

    #[test]
    fn contention_serializes_across_drivers() {
        // One core: two single-task workflows arriving together must run
        // back to back on the shared allocation.
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert!((reports[0].makespan - 10.0).abs() < 1e-9);
        assert!((reports[1].makespan - 20.0).abs() < 1e-9, "second waits for the core");
    }

    #[test]
    fn streamed_arrivals_recycle_task_state() {
        // 50 workflows arriving one after another: live per-task state
        // must stay bounded by in-flight + queued, not grow with the
        // total stream length.
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        for k in 0..50 {
            coord
                .add_workflow(solo(1.0), ExecutionMode::Asynchronous, 2.0 * k as f64)
                .unwrap();
        }
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert_eq!(reports.len(), 50);
        assert!((reports[49].makespan - 99.0).abs() < 1e-9, "arrival 98 s + 1 s run");
        assert!(
            reports[0].peak_live_tasks <= 2,
            "peak live task state {} for a 50-task stream",
            reports[0].peak_live_tasks
        );
    }

    #[test]
    fn out_of_order_registration_reports_in_registration_order() {
        let cluster = ClusterSpec::uniform("t", 1, 2, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 100.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert!((reports[0].records[0].submitted - 100.0).abs() < 1e-9);
        assert!((reports[1].records[0].submitted - 0.0).abs() < 1e-9);
        assert!((reports[0].makespan - 110.0).abs() < 1e-9);
        assert!((reports[1].makespan - 10.0).abs() < 1e-9);
    }

    #[test]
    fn timed_grow_unblocks_a_starved_queue() {
        // One 1-core node, two 10 s tasks at t = 0: the second is
        // queued. A +1-node grow at t = 5 lets it start right then.
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord
            .set_resource_plan(crate::pilot::ResourcePlan::new().resize(5.0, 1))
            .unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert!((reports[0].makespan - 10.0).abs() < 1e-9);
        assert!(
            (reports[1].makespan - 15.0).abs() < 1e-9,
            "queued task must start on the grown node at t = 5, got {}",
            reports[1].makespan
        );
        // The capacity timeline carries the grow.
        assert_eq!(reports[0].capacity.points, vec![(0.0, 1, 0), (5.0, 2, 0)]);
        assert_eq!(reports[1].capacity, reports[0].capacity);
    }

    #[test]
    fn shrink_is_graceful_and_future_work_avoids_drained_nodes() {
        // Two 1-core nodes, two tasks running from t = 0; a drain at
        // t = 2 marks one node (both equally busy -> the newest). Both
        // tasks still finish at 10; a third workflow arriving at t = 3
        // must wait for the *surviving* core and finish at 20.
        let cluster = ClusterSpec::uniform("t", 2, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 3.0).unwrap();
        coord
            .set_resource_plan(crate::pilot::ResourcePlan::new().resize(2.0, -1))
            .unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert!((reports[0].makespan - 10.0).abs() < 1e-9, "running work finishes");
        assert!((reports[1].makespan - 10.0).abs() < 1e-9, "running work finishes");
        assert!(
            (reports[2].makespan - 20.0).abs() < 1e-9,
            "late arrival waits for the surviving core, got {}",
            reports[2].makespan
        );
        // Offered capacity: the drained node was fully busy at t = 2,
        // so its core leaves the timeline when its task releases it at
        // t = 10 — never before the work that occupied it finished.
        assert_eq!(reports[0].capacity.points, vec![(0.0, 2, 0), (10.0, 1, 0)]);
        // Utilization stays a true fraction even though both initial
        // tasks keep running past the drain: offered core-seconds over
        // [0, 10] are 2x10 (the busy drained core still counts until
        // released), so in-use never exceeds offered.
        for r in &reports {
            let (cu, _) = r.trace.mean_utilization();
            assert!(cu <= 1.0 + 1e-9, "utilization must stay in [0,1], got {cu}");
        }
    }

    #[test]
    fn draining_everything_with_queued_work_is_a_deadlock() {
        // One node, one running + one queued task; draining the only
        // node at t = 1 leaves the queued task unservable forever.
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord
            .set_resource_plan(crate::pilot::ResourcePlan::new().resize(1.0, -1))
            .unwrap();
        let mut ex = VirtualExecutor::new();
        let err = coord.run(&mut ex);
        assert!(err.is_err(), "shrink below queued demand must surface as an error");
    }

    #[test]
    fn autoscaler_rescues_a_starved_queue_and_records_capacity() {
        // One 1-core node, three 10 s tasks at t = 0. The autoscaler
        // (interval 5, step 1, max 3) sees the backlog and grows; the
        // campaign finishes far earlier than the serial 30 s.
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        for _ in 0..3 {
            coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        }
        coord
            .set_resource_plan(crate::pilot::ResourcePlan::new().with_autoscale(
                crate::pilot::AutoscalePolicy {
                    interval: 5.0,
                    min_nodes: 1,
                    max_nodes: 3,
                    step: 1,
                    ..Default::default()
                },
            ))
            .unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        let last = reports.iter().map(|r| r.makespan).fold(0.0f64, f64::max);
        assert!(
            last < 30.0 - 1e-9,
            "autoscaler must relieve the 1-core serialization, got {last}"
        );
        assert!(!reports[0].capacity.is_constant(), "growth must be recorded");
        assert!(reports[0].capacity.peak().0 >= 2);
    }

    #[test]
    fn rejects_bad_arrivals_and_unsatisfiable_requests() {
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        assert!(coord
            .add_workflow(solo(1.0), ExecutionMode::Asynchronous, -1.0)
            .is_err());
        let mut wf = solo(1.0);
        wf.sets[0].req = ResourceRequest::new(0, 3); // no GPUs exist
        assert!(coord.add_workflow(wf, ExecutionMode::Asynchronous, 0.0).is_err());
        assert_eq!(coord.driver_count(), 0);
    }
}
