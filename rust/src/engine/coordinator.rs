//! Multi-workflow coordinator: multiplexes N [`WorkflowDriver`]s over
//! one shared pilot [`Agent`] and one [`Executor`].
//!
//! The coordinator owns the three global resources the drivers must
//! share — the allocation (via the agent), the clock (via the
//! executor), and the task-uid namespace — and runs the event loop:
//!
//! 1. materialize the driver of every registered workflow whose arrival
//!    time has been reached (workflows are *streamed*: a member that
//!    arrives at t = 10⁴ costs one pending spec until then, not live
//!    driver state);
//! 2. feed `ClockAdvanced` to every driver *due* at the current clock
//!    — the event [`Calendar`] tracks each live driver's next
//!    activation, so idle drivers cost nothing — and submit whatever
//!    became ready;
//! 3. invoke the continuous scheduler once per state change;
//! 4. launch placements, then drain the executor's next completion
//!    batch (all completions sharing one instant are handed back in a
//!    single call) and route each back to its owning driver; drivers
//!    that finish are folded into their [`RunReport`] immediately and
//!    dropped.
//!
//! ## Bounded live state
//!
//! Global task uids are recycled through a free list the moment their
//! completion is processed, so the `specs` / `route` slabs (and the
//! agent's placement table) are bounded by **in-flight + queued** tasks
//! — not by the total number of tasks ever streamed. A traffic run of
//! thousands of workflows holds per-task engine state only for the work
//! that is actually outstanding; the high-water mark is reported as
//! [`RunReport::peak_live_tasks`].
//!
//! ## Checkpoint / resume
//!
//! The whole event loop is snapshottable: [`Coordinator::run_until`]
//! stops the loop the moment the engine clock reaches a checkpoint
//! time and returns a [`SimSnapshot`] capturing every piece of live
//! state — pending arrivals, driver countdowns and records, the uid
//! slab and free list, the allocator's per-node occupancy and drain
//! flags, the scheduler queue, in-flight tasks, the capacity timeline
//! and the resource-plan position. [`Coordinator::restore`] rebuilds a
//! runnable coordinator from the snapshot; in-flight tasks are
//! re-launched into the fresh executor with their original start time
//! and sampled duration, and their placements are re-claimed on the
//! restored allocator, so the resumed run continues **bit-identically**
//! to the uninterrupted one (see `tests/checkpoint.rs`). A resume may
//! attach a *different* [`ResourcePlan`] — the preemptible /
//! queue-backfill scenario where the follow-up allocation has another
//! shape.
//!
//! `engine::run` is a coordinator with exactly one driver, so the
//! single-workflow path and the concurrent-campaign path are the same
//! code.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;
use std::time::Duration;

use super::calendar::{Calendar, Lane, WakePolicy};
use super::driver::{EngineEvent, Submission, WorkflowDriver};
use super::{EngineConfig, ExecutionMode, RunReport, EPS};
use crate::checkpoint::{
    DriverEntry, FinishedMember, LiveTask, PendingMember, RunningEntry, SimSnapshot,
};
use crate::entk::Workflow;
use crate::error::{Error, Result};
use crate::exec::{Completion, Executor, RunningTask};
use crate::failure::{FailureProcess, FailureSpec, RetryEntry};
use crate::metrics::CapacityTimeline;
use crate::obs::profile::EngineProfile;
use crate::obs::{EventSink, NullSink, ObsEvent};
use crate::pilot::{Agent, AutoscalePolicy, ResizeEvent, ResourcePlan, RunningMeta, Scheduler};
use crate::resources::{Allocator, ClusterSpec, NodeSpec, ResourceRequest};
use crate::task::{TaskKind, TaskSpec};
use crate::util::bench::Stopwatch;

/// How a (possibly checkpointed) coordinator run ended.
#[derive(Debug)]
pub enum RunOutcome {
    /// Every workflow drained; one report per member, in registration
    /// order.
    Completed(Vec<RunReport>),
    /// The engine clock reached the checkpoint time first; the boxed
    /// snapshot restores via [`Coordinator::restore`].
    Checkpointed(Box<SimSnapshot>),
}

/// Shared-pilot multiplexer over any number of workflow drivers.
pub struct Coordinator {
    cluster: ClusterSpec,
    cfg: EngineConfig,
    /// Registered workflows, materialized lazily during [`run`](Self::run).
    /// Stored directly as the checkpoint schema's [`PendingMember`] —
    /// a not-yet-arrived workflow costs one spec, no per-task state.
    pending: Vec<PendingMember>,
    next_set_stream: u64,
    next_pipeline: u64,
    /// Elastic allocation plan (timed resizes + autoscaler), applied
    /// inside the event loop.
    plan: Option<ResourcePlan>,
    /// Failure-injection spec (MTBF process / preemption trace +
    /// retry policy), applied inside the event loop. On a restored
    /// coordinator the snapshot's failure-process state wins; setting
    /// a spec there is rejected.
    failure: Option<FailureSpec>,
    /// Snapshot to resume from (set by [`Coordinator::restore`]).
    resume: Option<Box<SimSnapshot>>,
    /// Event-loop strategy (calendar vs legacy full scan). Execution
    /// strategy, not simulation state: it is never serialized, and
    /// either policy resumes any snapshot bit-identically.
    wake: WakePolicy,
    /// Event sink for the next run (`--emit-events`). Like the wake
    /// policy this is observation strategy, not simulation state: it is
    /// never serialized, and a restored coordinator accepts a fresh
    /// sink to continue the stream.
    sink: Option<Box<dyn EventSink>>,
    /// Self-profiling handle (`--profile`), shared with the caller so
    /// the counters stay readable after the run consumes `self`.
    profile: Option<Rc<RefCell<EngineProfile>>>,
}

impl Coordinator {
    pub fn new(cluster: &ClusterSpec, cfg: &EngineConfig) -> Coordinator {
        Coordinator {
            cluster: cluster.clone(),
            cfg: cfg.clone(),
            pending: Vec::new(),
            next_set_stream: 0,
            next_pipeline: 0,
            plan: None,
            failure: None,
            resume: None,
            wake: WakePolicy::default(),
            sink: None,
            profile: None,
        }
    }

    /// Select the event-loop strategy (default [`WakePolicy::Calendar`]).
    /// [`WakePolicy::FullScan`] keeps the legacy O(live drivers)-per-
    /// iteration loop: the equivalence-test baseline
    /// (`tests/loop_equiv.rs`) and the scale bench's before/after
    /// comparison (`benches/bench_scale.rs`).
    pub fn set_wake_policy(&mut self, wake: WakePolicy) {
        self.wake = wake;
    }

    /// Rebuild a runnable coordinator from a [`SimSnapshot`]. The next
    /// [`run`](Self::run) (or [`run_until`](Self::run_until)) continues
    /// the interrupted simulation exactly where the checkpoint stopped
    /// it: same clock, same queue, same in-flight work. Attach a
    /// [`ResourcePlan`] via [`set_resource_plan`](Self::set_resource_plan)
    /// to resume on a *different-shaped* pilot (the plan replaces any
    /// remnant of the checkpointed run's plan; its event times are
    /// absolute engine times, so `0:-2` shrinks at the resume instant).
    pub fn restore(snapshot: SimSnapshot) -> Result<Coordinator> {
        snapshot.validate()?;
        Ok(Coordinator {
            cluster: snapshot.cluster.clone(),
            cfg: snapshot.cfg.clone(),
            pending: Vec::new(),
            next_set_stream: snapshot.next_set_stream,
            next_pipeline: snapshot.next_pipeline,
            plan: None,
            failure: None,
            resume: Some(Box::new(snapshot)),
            wake: WakePolicy::default(),
            sink: None,
            profile: None,
        })
    }

    /// Attach an [`EventSink`]: every engine occurrence of the next run
    /// is emitted to it as a typed [`ObsEvent`] (see [`crate::obs`]).
    /// The stream is a pure function of the deterministic simulation —
    /// bit-identical per seed and across wake policies — and is *not*
    /// part of a checkpoint: attach a fresh sink after
    /// [`restore`](Self::restore) and the resumed run's stream,
    /// concatenated after the pre-checkpoint prefix, equals the
    /// uninterrupted run's stream.
    pub fn set_event_sink(&mut self, sink: Box<dyn EventSink>) {
        self.sink = Some(sink);
    }

    /// Enable wall-clock self-profiling for the next run and return the
    /// shared handle the counters accumulate into (see
    /// [`EngineProfile`]). Profiling observes host time only — it never
    /// changes the simulation trajectory or the event stream.
    pub fn enable_profiling(&mut self) -> Rc<RefCell<EngineProfile>> {
        let p = Rc::new(RefCell::new(EngineProfile::new()));
        self.profile = Some(Rc::clone(&p));
        p
    }

    /// Attach an *existing* profiling handle instead of a fresh one, so
    /// counters accumulate across chained runs (checkpoint/resume
    /// legs). See [`enable_profiling`](Self::enable_profiling).
    pub fn set_profile_handle(&mut self, profile: Rc<RefCell<EngineProfile>>) {
        self.profile = Some(profile);
    }

    /// Attach an elastic [`ResourcePlan`]: timed grow/drain events and
    /// an optional backlog-driven autoscaler, applied to the shared
    /// pilot while drivers run. Every change to the *offered* capacity
    /// is recorded on the run's [`CapacityTimeline`] (see
    /// [`RunReport::capacity`]), which utilization metrics integrate
    /// against: grows appear at the instant they apply; a graceful
    /// drain sheds a node's free cores immediately and its busy cores
    /// as the running work releases them. Workflow feasibility
    /// ([`ClusterSpec::check`]) is still validated against the *initial*
    /// cluster at registration time. On a restored coordinator the plan
    /// replaces the checkpointed run's remaining plan.
    pub fn set_resource_plan(&mut self, plan: ResourcePlan) -> Result<()> {
        plan.validate()?;
        self.plan = Some(plan);
        Ok(())
    }

    /// Attach a failure-injection spec: a seed-driven MTBF process
    /// and/or a trace of timed node preemptions, plus the retry policy
    /// applied to tasks the resulting node kills take down. A node
    /// failure is a *hard kill* — in-flight work on the node is lost
    /// (unlike a graceful drain, which lets running tasks finish) and
    /// each victim re-enters the scheduler after its per-attempt
    /// backoff. Rejected on a restored coordinator: the failure
    /// process' state (RNG position, pending retries, attempt counts)
    /// is part of the checkpoint and resumes from there.
    pub fn set_failure_spec(&mut self, spec: FailureSpec) -> Result<()> {
        if self.resume.is_some() {
            return Err(Error::Config(
                "failure: cannot attach a failure spec to a restored \
                 coordinator (the failure process is part of the checkpoint)"
                    .into(),
            ));
        }
        spec.validate()?;
        self.failure = Some(spec);
        Ok(())
    }

    /// Register a workflow whose roots become schedulable at `arrival`
    /// (engine seconds). Returns the index of its report in
    /// [`Coordinator::run`]'s result. The driver itself is only built
    /// when the clock reaches `arrival` (streamed registration).
    pub fn add_workflow(
        &mut self,
        wf: Workflow,
        mode: ExecutionMode,
        arrival: f64,
    ) -> Result<usize> {
        if self.resume.is_some() {
            return Err(Error::Config(format!(
                "workflow '{}': cannot register new workflows on a restored \
                 coordinator (the member set is part of the checkpoint)",
                wf.name
            )));
        }
        if !arrival.is_finite() || arrival < 0.0 {
            return Err(Error::Config(format!(
                "workflow '{}': invalid arrival time {arrival}",
                wf.name
            )));
        }
        for s in &wf.sets {
            self.cluster.check(&s.req)?;
        }
        // Validate now so registration errors surface at add time, not
        // mid-run when the driver is materialized.
        wf.validate()?;
        let n_sets = wf.sets.len() as u64;
        let n_pipes = WorkflowDriver::pipeline_count_of(&wf, mode) as u64;
        let slot = self.pending.len();
        self.pending.push(PendingMember {
            wf,
            mode,
            arrival,
            slot,
            set_stream: self.next_set_stream,
            pipeline_base: self.next_pipeline,
        });
        self.next_set_stream += n_sets;
        self.next_pipeline += n_pipes;
        Ok(slot)
    }

    /// Number of registered workflows (pending or live).
    pub fn driver_count(&self) -> usize {
        self.pending.len()
    }

    /// Drive every registered workflow to completion over `executor`;
    /// returns one [`RunReport`] per workflow, in registration order.
    /// Scheduler accounting (rounds / wall time) and the live-task
    /// high-water mark are global and repeated on every report.
    pub fn run(self, executor: &mut dyn Executor) -> Result<Vec<RunReport>> {
        match self.run_until(executor, None)? {
            RunOutcome::Completed(reports) => Ok(reports),
            RunOutcome::Checkpointed(_) => {
                unreachable!("run_until(None) never checkpoints")
            }
        }
    }

    /// [`run`](Self::run) with an optional preemption point: when the
    /// engine clock reaches `checkpoint_at` before the last workflow
    /// drains, the loop stops and returns
    /// [`RunOutcome::Checkpointed`] with the full simulation state.
    /// A run that finishes earlier returns
    /// [`RunOutcome::Completed`] as usual.
    pub fn run_until(
        mut self,
        executor: &mut dyn Executor,
        checkpoint_at: Option<f64>,
    ) -> Result<RunOutcome> {
        // NaN/inf would silently disable the requested preemption (every
        // clock comparison against them is false); refuse up front.
        if let Some(t) = checkpoint_at {
            if !t.is_finite() {
                return Err(Error::Config(format!(
                    "checkpoint: invalid checkpoint time {t}"
                )));
            }
        }
        let plan = self.plan.take();
        let wake = self.wake;
        let sink = self.sink.take().unwrap_or_else(|| Box::new(NullSink));
        let profile = self.profile.take();
        let state = match self.resume.take() {
            Some(snap) => {
                EngineLoop::from_snapshot(*snap, plan, executor, wake, sink, profile)?
            }
            None => EngineLoop::fresh(self, plan, wake, sink, profile)?,
        };
        state.drive(executor, checkpoint_at)
    }

    /// Convenience wrapper: run with a mandatory preemption point (the
    /// checkpoint entry point named in the architecture docs).
    pub fn checkpoint(
        self,
        executor: &mut dyn Executor,
        at: f64,
    ) -> Result<RunOutcome> {
        self.run_until(executor, Some(at))
    }
}

/// The event loop's complete live state. One instance per
/// [`Coordinator::run_until`] call, built either fresh from the
/// registered workflows or from a [`SimSnapshot`]; snapshotting is the
/// inverse of construction.
struct EngineLoop {
    cfg: EngineConfig,
    cluster: ClusterSpec,
    next_set_stream: u64,
    next_pipeline: u64,
    agent: Agent,
    capacity: CapacityTimeline,
    /// Timed resize events in time order; `next_resize` indexes the
    /// first unapplied one.
    resize_events: Vec<ResizeEvent>,
    next_resize: usize,
    autoscale: Option<AutoscalePolicy>,
    next_check: Option<f64>,
    /// Consecutive no-op autoscaler evaluations with nothing running:
    /// past a small bound the tick stops being scheduled, so a queue
    /// the autoscaler cannot help (max_nodes reached, unfit shape)
    /// surfaces as the deadlock error instead of ticking forever.
    stalled_checks: u32,
    grow_node: Option<NodeSpec>,
    /// Arrival-ordered stream of registrations, consumed from the
    /// front as the clock reaches each arrival (ties resolve in
    /// registration order, matching merged-DAG set ordering).
    pending: VecDeque<PendingMember>,
    /// Per-slot live drivers / finished reports.
    drivers: Vec<Option<WorkflowDriver>>,
    done: Vec<Option<RunReport>>,
    /// Slots with a live driver, kept sorted by slot: the event loop
    /// walks only live members, so per-event cost tracks live state
    /// (like memory), not the total stream length.
    live_slots: Vec<usize>,
    /// Global uid slab: uid -> (driver slot, driver-local uid) and the
    /// launchable spec. Completed uids are recycled via the free list,
    /// bounding live entries by in-flight + queued tasks.
    route: Vec<(usize, usize)>,
    specs: Vec<TaskSpec>,
    free_uids: Vec<usize>,
    live_uids: usize,
    peak_live: usize,
    in_flight: usize,
    sched_rounds: usize,
    sched_wall: Duration,
    /// Only invoke the scheduler when the system state changed (new
    /// submissions or freed resources) — avoids O(queue) rescans on
    /// clock-advance iterations.
    sched_dirty: bool,
    /// Event-loop strategy: calendar (step only due drivers) vs the
    /// legacy full scan. See [`WakePolicy`].
    wake: WakePolicy,
    /// Failure-injection process (MTBF draws + trace replay + resilience
    /// stats). `None` when failure injection is off — the loop then
    /// pays nothing for the feature.
    failure: Option<FailureProcess>,
    /// Killed tasks waiting out their retry backoff. Small (bounded by
    /// tasks killed and not yet resubmitted), scanned for the min due
    /// time; entries re-enter the scheduler through the ordinary
    /// submission path when due.
    retries: Vec<RetryEntry>,
    /// Per-uid attempt counts (uid-indexed, sparse in practice):
    /// `attempts[uid]` = times the task was killed so far. Reset when
    /// the uid completes and is recycled.
    attempts: Vec<u32>,
    /// Per-driver wake times + singleton event lanes (calendar mode).
    /// Never snapshotted: rebuilt from the drivers' deferred sets on
    /// restore (see [`EngineLoop::from_snapshot`]).
    calendar: Calendar,
    /// `WorkflowDriver::step` invocations (perf accounting — the
    /// scan-vs-calendar figure of merit; see `RunReport::driver_steps`).
    driver_steps: u64,
    /// Where engine events go (see [`crate::obs`]). `obs` caches
    /// `sink.enabled()` so a disabled sink costs one branch per
    /// emission site and no event construction.
    sink: Box<dyn EventSink>,
    obs: bool,
    /// Wall-clock self-profiling (shared handle; see [`EngineProfile`]).
    profile: Option<Rc<RefCell<EngineProfile>>>,
}

/// Normalize an attached [`ResourcePlan`] into loop state: events
/// sorted by time, the autoscaler, and the grow-node shape (defaulting
/// to the cluster's first node; its absence is an error whenever
/// anything could grow). One code path for fresh runs and resumes.
fn normalize_plan(
    plan: ResourcePlan,
    cluster: &ClusterSpec,
) -> Result<(Vec<ResizeEvent>, Option<AutoscalePolicy>, Option<NodeSpec>)> {
    let mut evs = plan.events;
    evs.sort_by(|a, b| a.at.total_cmp(&b.at));
    let node = plan.node.or_else(|| cluster.nodes.first().copied());
    if node.is_none() && (plan.autoscale.is_some() || evs.iter().any(|e| e.delta > 0)) {
        return Err(Error::Config(
            "resource plan: no node shape to grow by \
             (empty cluster and no plan.node)"
                .into(),
        ));
    }
    Ok((evs, plan.autoscale, node))
}

impl EngineLoop {
    /// Fresh loop state over the coordinator's registered workflows.
    fn fresh(
        coord: Coordinator,
        plan: Option<ResourcePlan>,
        wake: WakePolicy,
        sink: Box<dyn EventSink>,
        profile: Option<Rc<RefCell<EngineProfile>>>,
    ) -> Result<EngineLoop> {
        let agent = Agent::new(&coord.cluster, coord.cfg.policy, coord.cfg.task_overhead);
        let capacity = CapacityTimeline::of_cluster(&coord.cluster);
        let (resize_events, autoscale, grow_node) = match plan {
            Some(p) => normalize_plan(p, &coord.cluster)?,
            None => (Vec::new(), None, None),
        };
        let next_check = autoscale.as_ref().map(|p| p.interval);
        // Arm the stochastic fault process at t = 0 against the initial
        // capacity (validated in `set_failure_spec`). Trace events need
        // no arming — they replay from the sorted list.
        let failure = coord.failure.map(|spec| {
            let mut fp = FailureProcess::new(spec, coord.cfg.seed);
            let mut weights = Vec::new();
            fault_weights(&agent, &fp.spec, &mut weights);
            let rate: f64 = weights.iter().map(|&(_, w)| w).sum();
            fp.draw_next(0.0, rate);
            fp
        });
        let n_members = coord.pending.len();
        let mut drivers: Vec<Option<WorkflowDriver>> = Vec::new();
        drivers.resize_with(n_members, || None);
        let mut done: Vec<Option<RunReport>> = Vec::new();
        done.resize_with(n_members, || None);
        let mut pending_list = coord.pending;
        pending_list
            .sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.slot.cmp(&b.slot)));
        let obs = sink.enabled();
        let mut el = EngineLoop {
            cfg: coord.cfg,
            cluster: coord.cluster,
            next_set_stream: coord.next_set_stream,
            next_pipeline: coord.next_pipeline,
            agent,
            capacity,
            resize_events,
            next_resize: 0,
            autoscale,
            next_check,
            stalled_checks: 0,
            grow_node,
            pending: pending_list.into(),
            drivers,
            done,
            live_slots: Vec::new(),
            route: Vec::new(),
            specs: Vec::new(),
            free_uids: Vec::new(),
            live_uids: 0,
            peak_live: 0,
            in_flight: 0,
            sched_rounds: 0,
            sched_wall: Duration::ZERO,
            sched_dirty: true,
            wake,
            failure,
            retries: Vec::new(),
            attempts: Vec::new(),
            // Drivers register their wakes as they materialize.
            calendar: Calendar::new(),
            driver_steps: 0,
            sink,
            obs,
            profile,
        };
        // The stream opens with the initial offered capacity so a
        // replay can seed its timeline exactly; a *resumed* run emits
        // no such point (the pre-checkpoint prefix already carries it).
        if el.obs {
            let (c, g) = el.capacity.final_capacity();
            el.sink
                .emit(&ObsEvent::CapacityOffered { t: 0.0, cores: c, gpus: g });
        }
        Ok(el)
    }

    /// Rebuild loop state from a checkpoint. Re-launches every
    /// in-flight task into `executor` with its original start time and
    /// sampled duration (so completions land at the original instants)
    /// and fast-forwards the clock to the snapshot time. A `plan` given
    /// here (via [`Coordinator::set_resource_plan`] after restore)
    /// replaces the snapshot's remaining plan.
    fn from_snapshot(
        s: SimSnapshot,
        plan: Option<ResourcePlan>,
        executor: &mut dyn Executor,
        wake: WakePolicy,
        sink: Box<dyn EventSink>,
        profile: Option<Rc<RefCell<EngineProfile>>>,
    ) -> Result<EngineLoop> {
        let SimSnapshot {
            now,
            cfg,
            cluster,
            n_members,
            next_set_stream,
            next_pipeline,
            pending,
            drivers: driver_entries,
            finished,
            slab_len,
            live_tasks,
            free_uids,
            peak_live,
            nodes,
            draining,
            cursor,
            span_order,
            running,
            queue,
            tenant_weights,
            capacity,
            resize_events,
            autoscale,
            next_check,
            stalled_checks,
            grow_node,
            sched_rounds,
            sched_dirty,
            failure,
            retries,
            attempts,
        } = s;

        // Members: live drivers, finished reports, not-yet-arrived.
        let mut drivers: Vec<Option<WorkflowDriver>> = Vec::new();
        drivers.resize_with(n_members, || None);
        for e in driver_entries {
            let slot = e.slot;
            drivers[slot] = Some(WorkflowDriver::from_state(e.state, &cfg)?);
        }
        let mut done: Vec<Option<RunReport>> = Vec::new();
        done.resize_with(n_members, || None);
        for m in finished {
            // Rebuild against the member's *fold-time* capacity (not
            // the checkpoint-time one) so its utilization trace is
            // bit-identical to the uninterrupted run's.
            done[m.slot] = Some(RunReport::from_records_capacity(
                m.workflow,
                m.mode,
                m.records,
                m.capacity,
                m.failed_tasks,
            ));
        }
        let live_slots: Vec<usize> = drivers
            .iter()
            .enumerate()
            .filter_map(|(i, d)| d.as_ref().map(|_| i))
            .collect();
        let mut pending_list = pending;
        pending_list
            .sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.slot.cmp(&b.slot)));

        // Uid slab: free slots hold inert placeholders.
        let placeholder = TaskSpec {
            uid: 0,
            set_idx: 0,
            ordinal: 0,
            tx: 0.0,
            req: ResourceRequest::new(0, 0),
            kind: TaskKind::Stress,
        };
        let mut specs: Vec<TaskSpec> = vec![placeholder; slab_len];
        let mut route: Vec<(usize, usize)> = vec![(0, 0); slab_len];
        for lt in &live_tasks {
            route[lt.uid] = (lt.slot, lt.local);
            let mut spec = lt.spec.clone();
            spec.uid = lt.uid;
            specs[lt.uid] = spec;
        }
        let live_uids = live_tasks.len();

        // Agent: allocator with the snapshot occupancy re-claimed
        // (claims precede drains — a draining node's still-busy slices
        // need its free capacity on the books to be claimable), the
        // scheduler queue re-pushed in insertion order, and the
        // uid -> running bookkeeping of in-flight work.
        let mut alloc = Allocator::new(&ClusterSpec { name: cluster.name.clone(), nodes });
        for r in &running {
            alloc.claim(&r.placement)?;
        }
        for (i, d) in draining.iter().enumerate() {
            if *d {
                alloc.drain_node(i)?;
            }
        }
        alloc.set_cursor(cursor);
        // A valid-at-checkpoint spanning order is restored verbatim:
        // its equal-free tie-breaks are repair-history dependent and a
        // fresh sort could pick different nodes for the next spanning
        // placement.
        if let Some(order) = &span_order {
            alloc.restore_span_order(order)?;
        }
        let mut sched = Scheduler::new(cfg.policy);
        for &(t, w) in &tenant_weights {
            sched.set_weight(t, w);
        }
        for q in &queue {
            sched.push(*q);
        }
        let in_flight = running.len();

        // Re-launch in-flight work into the fresh executor — original
        // start time + original total duration, so every completion
        // lands at exactly the instant the uninterrupted run saw — and
        // rebuild the per-task running bookkeeping the scheduler
        // disciplines consume: owning tenant (fair-share ledger) and
        // projected completion (conservative-backfill reservation).
        let mut running_table: Vec<Option<RunningMeta>> = vec![None; slab_len];
        for r in &running {
            let (slot, local) = route[r.uid];
            let d = drivers[slot].as_ref().ok_or_else(|| {
                Error::Config(format!(
                    "snapshot: running task {} routed to slot {slot} with no live driver",
                    r.uid
                ))
            })?;
            if local >= d.record_count() {
                return Err(Error::Config(format!(
                    "snapshot: running task {} has no task record",
                    r.uid
                )));
            }
            let started = d.record(local).started;
            if !started.is_finite() {
                return Err(Error::Config(format!(
                    "snapshot: running task {} has no start time",
                    r.uid
                )));
            }
            let tx = specs[r.uid].tx + cfg.task_overhead;
            executor.launch(&RunningTask {
                uid: r.uid,
                tx,
                started_at: started,
                kind: Some(specs[r.uid].kind),
            });
            sched.note_started(slot, &specs[r.uid].req);
            running_table[r.uid] = Some(RunningMeta {
                placement: r.placement.clone(),
                tenant: slot,
                req: specs[r.uid].req,
                end: started + tx,
            });
        }
        executor.advance_to(now);
        let agent = Agent::from_parts(alloc, sched, running_table, cfg.task_overhead);

        // Plan: an explicit plan attached after restore replaces the
        // checkpointed run's remnant (events are absolute engine times;
        // anything at or before `now` applies at the resume instant).
        let (resize_events, autoscale, next_check, stalled_checks, grow_node) =
            match plan {
                Some(p) => {
                    let (evs, auto, node) = normalize_plan(p, &cluster)?;
                    let nc = auto.as_ref().map(|a| a.interval);
                    (evs, auto, nc, 0, node)
                }
                None => (resize_events, autoscale, next_check, stalled_checks, grow_node),
            };

        // The calendar is never captured in the snapshot: every wake
        // is a pure function of its driver's deferred set, so restore
        // rebuilds it exactly — the calendar-mode resume is
        // bit-identical to the uninterrupted run (tests/loop_equiv.rs,
        // tests/checkpoint.rs).
        let mut calendar = Calendar::new();
        for &slot in &live_slots {
            let d = drivers[slot].as_ref().expect("live slot holds a driver");
            calendar.set_wake(slot, d.next_activation());
        }

        // Failure process: RNG position, pending fault, trace cursor and
        // cumulative stats restore verbatim — the resumed fault sequence
        // is bit-identical to the uninterrupted one. Attempt counts
        // rebuild from their sparse form.
        let failure = failure.as_ref().map(FailureProcess::from_state);
        let mut attempt_counts = vec![0u32; slab_len];
        for &(uid, n) in &attempts {
            if uid >= slab_len {
                return Err(Error::Config(format!(
                    "snapshot: attempt count for uid {uid} outside the slab"
                )));
            }
            attempt_counts[uid] = n;
        }

        Ok(EngineLoop {
            cfg,
            cluster,
            next_set_stream,
            next_pipeline,
            agent,
            capacity,
            resize_events,
            next_resize: 0,
            autoscale,
            next_check,
            stalled_checks,
            grow_node,
            pending: pending_list.into(),
            drivers,
            done,
            live_slots,
            route,
            specs,
            free_uids,
            live_uids,
            peak_live,
            in_flight,
            sched_rounds,
            sched_wall: Duration::ZERO,
            sched_dirty,
            wake,
            failure,
            retries,
            attempts: attempt_counts,
            calendar,
            driver_steps: 0,
            // Restore emits nothing — not even re-launches of in-flight
            // work (their original `task_started` events are in the
            // pre-checkpoint prefix). The first resumed event is the
            // first *new* state transition, which is exactly what makes
            // prefix + resumed stream equal the uninterrupted stream.
            obs: sink.enabled(),
            sink,
            profile,
        })
    }

    /// Capture the complete loop state at engine time `now` — always a
    /// loop top: completions at exactly `now` have been drained (they
    /// advanced the clock), while arrivals/activations/resizes due at
    /// `now` are still pending. Restore re-enters the loop at the same
    /// point.
    fn into_snapshot(self, now: f64) -> SimSnapshot {
        let mut driver_entries = Vec::new();
        for (slot, d) in self.drivers.iter().enumerate() {
            if let Some(d) = d {
                driver_entries.push(DriverEntry { slot, state: d.snapshot_state() });
            }
        }
        let mut finished = Vec::new();
        for (slot, r) in self.done.iter().enumerate() {
            if let Some(r) = r {
                finished.push(FinishedMember {
                    slot,
                    workflow: r.workflow.clone(),
                    mode: r.mode,
                    records: r.records.clone(),
                    // `r.capacity` still holds the fold-time timeline
                    // here (the end-of-run overwrite with the final
                    // timeline only happens when the run completes).
                    capacity: r.capacity.clone(),
                    failed_tasks: r.failed_tasks,
                });
            }
        }
        let pending: Vec<PendingMember> = self.pending.into_iter().collect();
        let free: std::collections::BTreeSet<usize> =
            self.free_uids.iter().copied().collect();
        let mut live_tasks = Vec::new();
        for uid in 0..self.specs.len() {
            if free.contains(&uid) {
                continue;
            }
            let (slot, local) = self.route[uid];
            live_tasks.push(LiveTask { uid, slot, local, spec: self.specs[uid].clone() });
        }
        let running: Vec<RunningEntry> = self
            .agent
            .running_placements()
            .into_iter()
            .map(|(uid, placement)| RunningEntry { uid, placement })
            .collect();
        let queue = self.agent.queued_tasks();
        let tenant_weights = self.agent.tenant_weights();
        let alloc = self.agent.allocator();
        let nodes = alloc.spec().nodes.clone();
        let draining: Vec<bool> =
            (0..alloc.node_count()).map(|i| alloc.is_draining(i)).collect();
        let cursor = alloc.cursor();
        let span_order = alloc.span_order_state().map(|o| o.to_vec());
        // Attempt counts serialize sparsely: only uids that were
        // actually killed carry a nonzero count.
        let attempts: Vec<(usize, u32)> = self
            .attempts
            .iter()
            .enumerate()
            .filter(|&(_, &n)| n > 0)
            .map(|(uid, &n)| (uid, n))
            .collect();
        SimSnapshot {
            now,
            cfg: self.cfg,
            cluster: self.cluster,
            n_members: self.done.len(),
            next_set_stream: self.next_set_stream,
            next_pipeline: self.next_pipeline,
            pending,
            drivers: driver_entries,
            finished,
            slab_len: self.route.len(),
            live_tasks,
            free_uids: self.free_uids,
            peak_live: self.peak_live,
            nodes,
            draining,
            cursor,
            span_order,
            running,
            queue,
            tenant_weights,
            capacity: self.capacity,
            resize_events: self.resize_events[self.next_resize..].to_vec(),
            autoscale: self.autoscale,
            next_check: self.next_check,
            stalled_checks: self.stalled_checks,
            grow_node: self.grow_node,
            sched_rounds: self.sched_rounds,
            sched_dirty: self.sched_dirty,
            failure: self.failure.as_ref().map(FailureProcess::state),
            retries: self.retries,
            attempts,
        }
    }

    /// The event loop (see the module docs for the step numbering).
    fn drive(
        mut self,
        executor: &mut dyn Executor,
        checkpoint_at: Option<f64>,
    ) -> Result<RunOutcome> {
        // Hot-path scratch, reused across iterations: driver
        // submissions, the due-slot working set, and the completion
        // drain all borrow these instead of allocating per iteration.
        let mut subs: Vec<Submission> = Vec::new();
        let mut due_slots: Vec<usize> = Vec::new();
        let mut completions: Vec<Completion> = Vec::new();
        loop {
            let now = executor.now();

            // Preemption point: snapshot at the loop top. Completions
            // at exactly `now` were drained on the way here; pending
            // arrivals/activations/resizes due at `now` are captured
            // unprocessed — restore re-enters here, so the resumed run
            // replays the same iteration the uninterrupted run would
            // have executed next.
            if let Some(t_ck) = checkpoint_at {
                if now + EPS >= t_ck {
                    if self.obs {
                        self.sink.emit(&ObsEvent::CheckpointTaken { t: now });
                        // The sink outlives the snapshot (it is derived
                        // state, never captured); push the prefix to its
                        // destination before the loop state is consumed.
                        // Best-effort: file sinks keep the error latched
                        // for the CLI's final flush, and a stream write
                        // failure must not abort the snapshot itself.
                        let _ = self.sink.flush();
                    }
                    if let Some(p) = &self.profile {
                        p.borrow_mut().checkpoints += 1;
                    }
                    return Ok(RunOutcome::Checkpointed(Box::new(
                        self.into_snapshot(now),
                    )));
                }
            }
            if let Some(p) = &self.profile {
                p.borrow_mut().loop_iterations += 1;
            }

            // 0. Elasticity: apply every timed resize that is due, then
            // at most one (catch-up) autoscaler evaluation. The timeline
            // records *offered* capacity (free + busy): a grow shows up
            // at the instant it applies, a graceful drain sheds a node's
            // free cores now and its busy cores only as the work on them
            // releases (step 4) — so cores in use never exceed the
            // recorded capacity. Growth can unblock queued work, so it
            // re-arms the scheduler.
            let mut resized = false;
            while self.next_resize < self.resize_events.len()
                && self.resize_events[self.next_resize].at <= now + EPS
            {
                let ev = self.resize_events[self.next_resize];
                self.next_resize += 1;
                if ev.delta > 0 {
                    self.agent
                        .grow(ev.delta as usize, self.grow_node.expect("validated above"));
                    self.sched_dirty = true;
                } else {
                    self.agent.drain(ev.delta.unsigned_abs() as usize);
                }
                resized = true;
                if self.obs {
                    self.sink
                        .emit(&ObsEvent::PilotResized { t: now, delta: ev.delta });
                }
                if let Some(p) = &self.profile {
                    p.borrow_mut().resizes += 1;
                }
            }
            // Record once after the burst: N same-instant resizes yield
            // one timeline point carrying their net effect, not N.
            if resized {
                self.note_offered(now);
            }
            // Clone the policy only on iterations where a check is
            // actually due (this is the event loop's hot path).
            if self.next_check.is_some_and(|t| t <= now + EPS) {
                if let (Some(p), Some(t)) = (self.autoscale.clone(), self.next_check) {
                    // One evaluation per wakeup; the next check lands on
                    // the first interval multiple strictly after `now`.
                    let missed = ((now - t) / p.interval).floor().max(0.0) + 1.0;
                    self.next_check = Some(t + missed * p.interval);
                    let delta = autoscale_delta(&p, &self.agent, self.in_flight);
                    let acted = if delta > 0 {
                        self.agent
                            .grow(delta as usize, self.grow_node.expect("validated above"));
                        self.sched_dirty = true;
                        true
                    } else if delta < 0 {
                        self.agent.drain(delta.unsigned_abs() as usize) > 0
                    } else {
                        false
                    };
                    if self.obs {
                        self.sink.emit(&ObsEvent::AutoscaleDecision {
                            t: now,
                            delta,
                            acted,
                        });
                    }
                    if let Some(p) = &self.profile {
                        p.borrow_mut().autoscale_evals += 1;
                    }
                    if acted {
                        self.note_offered(now);
                    }
                    if acted || self.in_flight > 0 {
                        self.stalled_checks = 0;
                    } else {
                        self.stalled_checks += 1;
                    }
                }
            }

            // 1. Materialize every registered workflow whose arrival is
            // due; its roots release in step 2 below.
            while self
                .pending
                .front()
                .is_some_and(|p| p.arrival <= now + EPS)
            {
                let p = self.pending.pop_front().expect("peeked pending arrival");
                // Validated at registration; compile only.
                let slot = p.slot;
                if self.obs {
                    self.sink.emit(&ObsEvent::WorkflowArrived {
                        t: now,
                        slot,
                        workflow: p.wf.name.clone(),
                        arrival: p.arrival,
                    });
                }
                if let Some(prof) = &self.profile {
                    prof.borrow_mut().arrivals += 1;
                }
                let d = WorkflowDriver::compile_prevalidated(
                    p.wf,
                    p.mode,
                    &self.cfg,
                    p.arrival,
                    p.set_stream,
                    p.pipeline_base,
                );
                self.drivers[slot] = Some(d);
                if let Err(pos) = self.live_slots.binary_search(&slot) {
                    self.live_slots.insert(pos, slot);
                }
                // A fresh driver's roots are deferred to its arrival
                // time (i.e. due now): register its wake so the
                // calendar releases them this iteration.
                if self.wake == WakePolicy::Calendar {
                    let t = self.drivers[slot]
                        .as_ref()
                        .expect("just materialized")
                        .next_activation();
                    self.calendar.set_wake(slot, t);
                }
            }

            // 1.5. Failure injection: fire every due node fault — trace
            // replays first, then the stochastic MTBF process — and
            // resubmit every killed task whose retry backoff has
            // elapsed. Ordering matters at a shared instant: kills
            // precede the scheduler round (step 3), so a task placed at
            // the same instant a node dies is never a victim, and
            // completions at exactly the fault time were drained on the
            // way here (the task finished; the fault just missed it).
            if let Some(mut fp) = self.failure.take() {
                while let Some(ev) = fp.trace_due(now, EPS) {
                    self.process_kill(ev.node, now, executor, &mut fp)?;
                }
                while !fp.next_fault.is_nan() && fp.next_fault <= now + EPS {
                    // One victim-pick draw per fire (consumed even when
                    // nothing is schedulable, so the RNG stream is a
                    // pure function of the fault count), then re-arm
                    // against the post-kill capacity.
                    let mut weights = Vec::new();
                    fault_weights(&self.agent, &fp.spec, &mut weights);
                    match fp.pick_victim(&weights) {
                        Some(node) => {
                            self.process_kill(node, now, executor, &mut fp)?
                        }
                        None => fp.stats.failures_injected += 1,
                    }
                    fault_weights(&self.agent, &fp.spec, &mut weights);
                    let rate: f64 = weights.iter().map(|&(_, w)| w).sum();
                    fp.draw_next(now, rate);
                }
                self.failure = Some(fp);
            }
            if !self.retries.is_empty() {
                // Deterministic resubmission order at a shared instant:
                // (due, uid). Retries re-enter the scheduler as ordinary
                // submissions — fair-share and backfill disciplines see
                // them exactly like fresh work.
                self.retries.sort_by(|a, b| {
                    a.due.total_cmp(&b.due).then(a.uid.cmp(&b.uid))
                });
                let due = self
                    .retries
                    .iter()
                    .take_while(|r| r.due <= now + EPS)
                    .count();
                for r in self.retries.drain(..due) {
                    let (di, local) = self.route[r.uid];
                    let prio = match self.drivers[di].as_ref() {
                        Some(d) => d.priority_of(local),
                        None => {
                            return Err(Error::Engine(format!(
                                "retry for task {} routed to slot {di} with no \
                                 live driver",
                                r.uid
                            )))
                        }
                    };
                    self.agent.submit(&self.specs[r.uid], prio, di, now);
                    self.sched_dirty = true;
                    self.stalled_checks = 0;
                    if self.obs {
                        let spec = &self.specs[r.uid];
                        self.sink.emit(&ObsEvent::TaskSubmitted {
                            t: now,
                            uid: r.uid,
                            slot: di,
                            local,
                            kind: spec.kind.label().to_string(),
                            cores: spec.req.cpu_cores as u64,
                            gpus: spec.req.gpus as u64,
                            tx: spec.tx,
                            attempt: r.attempt,
                        });
                    }
                    if let Some(p) = &self.profile {
                        p.borrow_mut().retries_resubmitted += 1;
                    }
                }
            }

            // 2. Release activations that are due, in slot order (this
            // matches merged-DAG set ordering: member k's sets precede
            // member k+1's). The calendar hands back exactly the slots
            // whose wake is due; the legacy scan clocks everyone.
            match self.wake {
                WakePolicy::FullScan => {
                    due_slots.clear();
                    due_slots.extend(self.live_slots.iter().copied());
                }
                WakePolicy::Calendar => self.calendar.due_wakes(now, &mut due_slots),
            }
            for &di in &due_slots {
                subs.clear();
                self.driver_steps += 1;
                if let Some(p) = &self.profile {
                    p.borrow_mut().driver_wakes += 1;
                }
                self.drivers[di]
                    .as_mut()
                    .expect("due slot holds a driver")
                    .step_into(EngineEvent::ClockAdvanced { now }, &mut subs);
                for sub in subs.drain(..) {
                    let local = sub.spec.uid;
                    let mut spec = sub.spec;
                    let gid = match self.free_uids.pop() {
                        Some(g) => {
                            spec.uid = g;
                            self.specs[g] = spec;
                            self.route[g] = (di, local);
                            g
                        }
                        None => {
                            let g = self.specs.len();
                            spec.uid = g;
                            self.specs.push(spec);
                            self.route.push((di, local));
                            g
                        }
                    };
                    self.agent.submit(&self.specs[gid], sub.priority, di, now);
                    if self.obs {
                        let spec = &self.specs[gid];
                        self.sink.emit(&ObsEvent::TaskSubmitted {
                            t: now,
                            uid: gid,
                            slot: di,
                            local,
                            kind: spec.kind.label().to_string(),
                            cores: spec.req.cpu_cores as u64,
                            gpus: spec.req.gpus as u64,
                            tx: spec.tx,
                            attempt: 0,
                        });
                    }
                    if let Some(p) = &self.profile {
                        p.borrow_mut().submissions += 1;
                    }
                    self.live_uids += 1;
                    self.peak_live = self.peak_live.max(self.live_uids);
                    self.sched_dirty = true;
                    // Fresh work re-arms a parked autoscaler: the rescue
                    // path (grow when tasks queue with nothing running)
                    // must get its chance before the deadlock check.
                    self.stalled_checks = 0;
                }
                // The step consumed this driver's wake; re-register its
                // new horizon (or nothing, if its deferred set drained).
                if self.wake == WakePolicy::Calendar {
                    let t = self.drivers[di]
                        .as_ref()
                        .expect("due slot holds a driver")
                        .next_activation();
                    self.calendar.set_wake(di, t);
                }
            }

            // 3. Schedule everything that fits.
            let placed = if self.sched_dirty {
                let t0 = Stopwatch::start();
                let placed = self.agent.schedule(now);
                let dt = t0.elapsed();
                self.sched_wall += dt;
                self.sched_rounds += 1;
                self.sched_dirty = false;
                if let Some(p) = &self.profile {
                    p.borrow_mut().sched_rounds.record(dt);
                }
                placed
            } else {
                Vec::new()
            };
            for s in &placed {
                let spec = &self.specs[s.uid];
                let (di, local) = self.route[s.uid];
                self.drivers[di]
                    .as_mut()
                    .expect("placed task belongs to a live driver")
                    .on_started(local, now);
                executor.launch(&RunningTask {
                    uid: s.uid,
                    tx: spec.tx + self.cfg.task_overhead,
                    started_at: now,
                    kind: Some(spec.kind),
                });
                self.in_flight += 1;
                if self.obs {
                    self.sink.emit(&ObsEvent::TaskStarted {
                        t: now,
                        uid: s.uid,
                        slot: di,
                        local,
                        node: s.placement.slots.first().map_or(0, |&(n, _, _)| n),
                        cores: s.placement.total_cores(),
                        gpus: s.placement.total_gpus(),
                    });
                }
                if let Some(p) = &self.profile {
                    p.borrow_mut().tasks_started += 1;
                }
            }

            // 4. Wait for progress. The next wake-up horizon is the
            // earliest of: a driver's deferred activation, the next
            // pending arrival, the next unapplied timed resize (a
            // future grow may be the only thing that can serve a
            // starved queue), the next autoscaler tick (only while
            // there is work its decision could affect, parked after
            // repeated no-op evaluations with nothing running — see
            // `stalled_checks`), and the checkpoint deadline (the clock
            // must land on it exactly so the snapshot's `now` is the
            // requested one — but only while the simulation is still
            // active: a run that drains before the checkpoint must
            // complete normally, not idle forward to t_ck and snapshot
            // a finished sim).
            let autoscale_tick = self.next_check.filter(|_| {
                (self.in_flight > 0 || self.agent.queue_len() > 0)
                    && self.stalled_checks < 3
            });
            let next_deferred = match self.wake {
                WakePolicy::FullScan => {
                    let mut nd = self
                        .live_slots
                        .iter()
                        .filter_map(|&di| {
                            self.drivers[di]
                                .as_ref()
                                .expect("live slot holds a driver")
                                .next_activation()
                        })
                        .fold(f64::INFINITY, f64::min);
                    if let Some(p) = self.pending.front() {
                        nd = nd.min(p.arrival);
                    }
                    if self.next_resize < self.resize_events.len() {
                        nd = nd.min(self.resize_events[self.next_resize].at);
                    }
                    if let Some(t) = autoscale_tick {
                        nd = nd.min(t);
                    }
                    // A pending retry is real future work: it keeps the
                    // sim active (and prevents the deadlock error /
                    // premature drain below) until it resubmits.
                    if let Some(t) = self.next_retry() {
                        nd = nd.min(t);
                    }
                    let sim_active = self.in_flight > 0
                        || nd.is_finite()
                        || self.agent.queue_len() > 0;
                    // The next injected fault only matters while the
                    // sim is active — like the checkpoint deadline, it
                    // must not keep a drained run idling forward.
                    if sim_active {
                        if let Some(fp) = &self.failure {
                            let t = fp.next_event();
                            if !t.is_nan() {
                                nd = nd.min(t);
                            }
                        }
                    }
                    if let Some(t_ck) = checkpoint_at {
                        if sim_active {
                            nd = nd.min(t_ck);
                        }
                    }
                    nd
                }
                WakePolicy::Calendar => {
                    // Driver wakes are already registered; refresh the
                    // four singleton lanes and peek. O(1) per lane, one
                    // (amortized) heap peek for the wakes.
                    self.calendar
                        .set_lane(Lane::Arrival, self.pending.front().map(|p| p.arrival));
                    self.calendar.set_lane(
                        Lane::Resize,
                        self.resize_events.get(self.next_resize).map(|e| e.at),
                    );
                    self.calendar.set_lane(Lane::Autoscale, autoscale_tick);
                    // Retries count toward activity (pending future
                    // work); the fault and checkpoint lanes are cleared
                    // first so a stale value never inflates the
                    // activity check, then re-set only while active —
                    // a drained run must complete, not idle forward to
                    // the next would-be fault.
                    self.calendar.set_lane(Lane::Retry, self.next_retry());
                    self.calendar.set_lane(Lane::Failure, None);
                    self.calendar.set_lane(Lane::Checkpoint, None);
                    let horizon = self.calendar.next_event();
                    let sim_active = self.in_flight > 0
                        || horizon.is_finite()
                        || self.agent.queue_len() > 0;
                    if sim_active {
                        self.calendar.set_lane(
                            Lane::Failure,
                            self.failure
                                .as_ref()
                                .map(|fp| fp.next_event())
                                .filter(|t| !t.is_nan()),
                        );
                    }
                    self.calendar
                        .set_lane(Lane::Checkpoint, checkpoint_at.filter(|_| sim_active));
                    self.calendar.next_event()
                }
            };
            if self.in_flight > 0 {
                match executor.peek_next_completion() {
                    // An activation is due before the next completion:
                    // fast-forward to it (virtual time).
                    Some(peek) if next_deferred < peek => {
                        executor.advance_to(next_deferred);
                        continue;
                    }
                    Some(_) => {}
                    // Real executor: wait no longer than the next due
                    // activation; wake early if a completion lands.
                    None => {
                        if next_deferred.is_finite()
                            && next_deferred > now + EPS
                            && !executor.wait_until(next_deferred)
                        {
                            continue; // deadline hit; release at loop top
                        }
                    }
                }
                let drain_t0 = self.profile.as_ref().map(|_| Stopwatch::start());
                executor.drain_ready_into(&mut completions);
                if completions.is_empty() {
                    return Err(Error::Engine("executor lost in-flight tasks".into()));
                }
                for &c in &completions {
                    self.in_flight -= 1;
                    self.agent.complete(c.uid);
                    self.sched_dirty = true; // resources were freed
                    let (di, local) = self.route[c.uid];
                    if self.obs {
                        self.sink.emit(&ObsEvent::TaskCompleted {
                            t: c.finished_at,
                            uid: c.uid,
                            slot: di,
                            local,
                            failed: c.failed,
                        });
                    }
                    // Goodput: a completion's full residency is work
                    // that *counted* — unlike the lost core-hours a
                    // kill discards (see `process_kill`).
                    if let Some(fp) = self.failure.as_mut() {
                        if let Some(d) = self.drivers[di].as_ref() {
                            let dt = c.finished_at - d.record(local).started;
                            let req = &self.specs[c.uid].req;
                            fp.stats.goodput_core_s += dt * req.cpu_cores as f64;
                            fp.stats.goodput_gpu_s += dt * req.gpus as f64;
                        }
                    }
                    // Recycle the global uid: its spec/route slot (and
                    // the agent's placement entry) are now reusable.
                    self.free_uids.push(c.uid);
                    self.live_uids -= 1;
                    if c.uid < self.attempts.len() {
                        self.attempts[c.uid] = 0;
                    }
                    {
                        let d = self.drivers[di]
                            .as_mut()
                            .expect("completion routed to a live driver");
                        // A completion never produces submissions
                        // directly — it only defers children, released
                        // by the next ClockAdvanced.
                        subs.clear();
                        d.step_into(
                            EngineEvent::TaskCompleted {
                                uid: local,
                                finished_at: c.finished_at,
                                failed: c.failed,
                            },
                            &mut subs,
                        );
                        debug_assert!(subs.is_empty());
                        if c.failed && self.cfg.abort_on_failure {
                            // Report the driver-local uid: that is the
                            // uid visible in the member's RunReport
                            // records.
                            return Err(Error::Engine(format!(
                                "task {} ({}) of workflow '{}' failed",
                                local,
                                d.record(local).set_name,
                                d.workflow_name()
                            )));
                        }
                    }
                    // Fold finished drivers into their report right
                    // away: streamed runs never accumulate dead driver
                    // state.
                    if self.drivers[di].as_ref().is_some_and(|d| d.is_done()) {
                        let d = self.drivers[di].take().expect("checked is_some");
                        if self.obs {
                            self.sink.emit(&ObsEvent::WorkflowCompleted {
                                t: c.finished_at,
                                slot: di,
                                workflow: d.workflow_name().to_string(),
                            });
                        }
                        self.done[di] = Some(d.into_report(&self.capacity));
                        if let Ok(pos) = self.live_slots.binary_search(&di) {
                            self.live_slots.remove(pos);
                        }
                        self.calendar.cancel_wake(di);
                    } else if self.wake == WakePolicy::Calendar {
                        // The completion may have deferred children
                        // (possibly earlier than the registered wake):
                        // refresh this driver's horizon.
                        let t = self.drivers[di]
                            .as_ref()
                            .expect("not folded")
                            .next_activation();
                        self.calendar.set_wake(di, t);
                    }
                }
                // Graceful shrink: resources this batch released on
                // draining nodes left the allocation at this instant —
                // a no-op compare for ordinary completions.
                self.note_offered(executor.now());
                if let (Some(p), Some(t0)) = (&self.profile, drain_t0) {
                    let mut p = p.borrow_mut();
                    p.drain_rounds.record(t0.elapsed());
                    p.completions += completions.len() as u64;
                }
            } else if next_deferred.is_finite() {
                // Nothing running; sleep (real) or fast-forward (virtual)
                // to the next activation — e.g. a workflow yet to arrive.
                executor.wait_until(next_deferred);
            } else if self.agent.queue_len() > 0 {
                return Err(Error::Engine(
                    "deadlock: tasks queued but nothing running (unsatisfiable request?)"
                        .into(),
                ));
            } else {
                break; // every driver drained
            }
        }

        // Degenerate members (zero-task workflows) never see a
        // completion; finalize whatever is left.
        let drained: Vec<Option<WorkflowDriver>> = std::mem::take(&mut self.drivers);
        for (di, slot) in drained.into_iter().enumerate() {
            if let Some(d) = slot {
                debug_assert!(d.is_done());
                if self.obs {
                    self.sink.emit(&ObsEvent::WorkflowCompleted {
                        t: executor.now(),
                        slot: di,
                        workflow: d.workflow_name().to_string(),
                    });
                }
                self.done[di] = Some(d.into_report(&self.capacity));
            }
        }
        // Best-effort: a completed simulation must still fold into its
        // reports when the stream destination failed. File sinks latch
        // the error; the CLI's final flush surfaces it (warning +
        // nonzero exit) after the report prints.
        let _ = self.sink.flush();
        let n_members = self.done.len();
        let mut reports: Vec<RunReport> = Vec::with_capacity(n_members);
        for slot in self.done {
            reports.push(slot.expect("every registered workflow produces a report"));
        }
        for r in &mut reports {
            r.sched_rounds = self.sched_rounds;
            r.sched_wall = self.sched_wall;
            r.driver_steps = self.driver_steps;
            r.peak_live_tasks = self.peak_live;
            // Resilience stats are coordinator-global (the failure
            // process spans members), repeated on every report like
            // the scheduler accounting above.
            r.resilience = self.failure.as_ref().map(|fp| fp.stats);
            // The full (final) timeline replaces each member's
            // fold-time snapshot: member utilization was already
            // integrated over the member's own window, for which the
            // snapshot was complete, and downstream merges (campaign /
            // traffic reports) need the whole run's capacity history.
            r.capacity = self.capacity.clone();
        }
        Ok(RunOutcome::Completed(reports))
    }

    /// Earliest pending retry due time (linear scan — the retry set is
    /// bounded by killed-and-not-yet-resubmitted tasks, typically tiny).
    fn next_retry(&self) -> Option<f64> {
        self.retries.iter().map(|r| r.due).reduce(f64::min)
    }

    /// Append a point to the offered-capacity timeline iff the agent's
    /// offered capacity (free + busy; see [`Agent::offered`]) moved
    /// since the last recorded point — and mirror every appended point
    /// onto the event stream, so a replay rebuilds the timeline
    /// point-for-point.
    fn note_offered(&mut self, now: f64) {
        let (c, g) = self.agent.offered();
        if (c, g) != self.capacity.final_capacity() {
            self.capacity.record(now, c, g);
            if self.obs {
                self.sink
                    .emit(&ObsEvent::CapacityOffered { t: now, cores: c, gpus: g });
            }
        }
    }

    /// Hard-kill node `node` at `now`: every placement touching it is
    /// torn down ([`Agent::kill_node`] — capacity released, fair-share
    /// ledger retired), its in-flight completion is cancelled in the
    /// executor, the partial work is booked as lost core/GPU-seconds,
    /// and each victim either enters retry backoff or — with the
    /// attempt budget exhausted — fails the run with the typed
    /// [`Error::RetriesExhausted`]. The victim's uid stays live across
    /// the backoff (its spec and route must survive until the retry
    /// resubmits), and the driver is *not* stepped: the task did not
    /// complete, so its countdowns must not move.
    fn process_kill(
        &mut self,
        node: usize,
        now: f64,
        executor: &mut dyn Executor,
        fp: &mut FailureProcess,
    ) -> Result<()> {
        fp.stats.failures_injected += 1;
        if let Some(p) = &self.profile {
            p.borrow_mut().faults += 1;
        }
        let victims = self.agent.kill_node(node);
        // Every injected fault that reached a node appears on the
        // stream — victimless ones too, so a replay's ledger counts
        // `failures_injected` exactly as the live run does.
        if self.obs {
            self.sink.emit(&ObsEvent::NodeFault {
                t: now,
                node,
                victims: victims.len(),
            });
        }
        if victims.is_empty() {
            return Ok(());
        }
        self.sched_dirty = true; // capacity returned / queue changed
        for (uid, meta) in victims {
            executor.cancel(uid);
            self.in_flight -= 1;
            let (di, local) = self.route[uid];
            let d = self.drivers[di].as_ref().ok_or_else(|| {
                Error::Engine(format!(
                    "killed task {uid} routed to slot {di} with no live driver"
                ))
            })?;
            let dt = (now - d.record(local).started).max(0.0);
            fp.stats.lost_core_s += dt * meta.req.cpu_cores as f64;
            fp.stats.lost_gpu_s += dt * meta.req.gpus as f64;
            fp.stats.tasks_killed += 1;
            if self.attempts.len() <= uid {
                self.attempts.resize(uid + 1, 0);
            }
            self.attempts[uid] += 1;
            let attempt = self.attempts[uid];
            if self.obs {
                self.sink.emit(&ObsEvent::TaskKilled {
                    t: now,
                    uid,
                    slot: di,
                    local,
                    node,
                    attempt,
                    lost_core_s: dt * meta.req.cpu_cores as f64,
                });
            }
            if fp.spec.retry.allows(attempt) {
                let delay = fp.spec.retry.delay(self.cfg.seed, uid, attempt);
                let due = now + delay;
                self.retries.push(RetryEntry { uid, due, attempt });
                fp.stats.retries_scheduled += 1;
                if self.obs {
                    self.sink
                        .emit(&ObsEvent::RetryScheduled { t: now, uid, due, attempt });
                }
            } else {
                fp.stats.retries_exhausted += 1;
                if self.obs {
                    self.sink.emit(&ObsEvent::RetriesExhausted {
                        t: now,
                        uid,
                        slot: di,
                        attempts: attempt,
                    });
                    // Best-effort: the run is about to abort with the
                    // typed error; keep the stream's tail on disk.
                    let _ = self.sink.flush();
                }
                return Err(Error::RetriesExhausted {
                    workflow: d.workflow_name().to_string(),
                    uid,
                    attempts: attempt,
                });
            }
        }
        // Kills on a draining node shed offered capacity at this
        // instant; a no-op compare otherwise.
        self.note_offered(now);
        Ok(())
    }
}

/// Per-node fault weights (failures per second) for the stochastic
/// process: every schedulable node fails at rate `1/mtbf`, scaled by
/// `gpu_factor` on GPU nodes (accelerator hardware fails more often in
/// practice). Draining nodes are excluded — they are already leaving.
fn fault_weights(agent: &Agent, spec: &FailureSpec, out: &mut Vec<(usize, f64)>) {
    out.clear();
    let Some(mtbf) = spec.mtbf else { return };
    let alloc = agent.allocator();
    let nodes = &alloc.spec().nodes;
    for (i, n) in nodes.iter().enumerate() {
        if alloc.is_draining(i) {
            continue;
        }
        let w = (1.0 / mtbf) * if n.gpus > 0 { spec.gpu_factor } else { 1.0 };
        out.push((i, w));
    }
}

/// One autoscaler evaluation: positive = nodes to add, negative = nodes
/// to drain, 0 = leave the allocation alone. Pure decision logic —
/// deterministic given the agent state.
fn autoscale_delta(p: &AutoscalePolicy, agent: &Agent, in_flight: usize) -> i64 {
    let (cap_c, cap_g) = agent.capacity();
    let nodes = agent.schedulable_nodes();
    let queued = agent.queue_len();
    let (q_c, q_g) = agent.queued_demand();
    // Backlog pressure: queued demand exceeds the threshold fraction of
    // capacity — or tasks are queued with nothing running at all (the
    // rescue case after a deep shrink left the queue unservable).
    let pressured = q_c as f64 > p.up_backlog * cap_c as f64
        || q_g as f64 > p.up_backlog * cap_g as f64
        || (queued > 0 && in_flight == 0);
    if pressured {
        if nodes < p.max_nodes {
            return p.step.min(p.max_nodes - nodes) as i64;
        }
        return 0;
    }
    if queued == 0 && nodes > p.min_nodes {
        let (free_c, free_g) = agent.free();
        if free_c as f64 >= p.down_idle * cap_c as f64
            && free_g as f64 >= p.down_idle * cap_g as f64
        {
            return -(p.step.min(nodes - p.min_nodes) as i64);
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Dag;
    use crate::entk::{Pipeline, Workflow};
    use crate::resources::ResourceRequest;
    use crate::sim::VirtualExecutor;
    use crate::task::TaskSetSpec;

    fn solo(tx: f64) -> Workflow {
        let mut dag = Dag::new();
        dag.add_node("A");
        Workflow {
            name: "solo".into(),
            sets: vec![TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), tx).with_sigma(0.0)],
            dag,
            sequential: vec![Pipeline::new("s").stage(&[0])],
            asynchronous: vec![Pipeline::new("a").stage(&[0])],
        }
    }

    #[test]
    fn two_drivers_share_one_agent() {
        let cluster = ClusterSpec::uniform("t", 1, 2, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(20.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert_eq!(reports.len(), 2);
        assert!((reports[0].makespan - 10.0).abs() < 1e-9);
        assert!((reports[1].makespan - 20.0).abs() < 1e-9);
    }

    #[test]
    fn late_arrival_shifts_the_member_timeline() {
        let cluster = ClusterSpec::uniform("t", 1, 2, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 100.0).unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert!((reports[0].makespan - 10.0).abs() < 1e-9);
        assert!((reports[1].records[0].submitted - 100.0).abs() < 1e-9);
        assert!((reports[1].makespan - 110.0).abs() < 1e-9);
    }

    #[test]
    fn contention_serializes_across_drivers() {
        // One core: two single-task workflows arriving together must run
        // back to back on the shared allocation.
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert!((reports[0].makespan - 10.0).abs() < 1e-9);
        assert!((reports[1].makespan - 20.0).abs() < 1e-9, "second waits for the core");
    }

    #[test]
    fn streamed_arrivals_recycle_task_state() {
        // 50 workflows arriving one after another: live per-task state
        // must stay bounded by in-flight + queued, not grow with the
        // total stream length.
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        for k in 0..50 {
            coord
                .add_workflow(solo(1.0), ExecutionMode::Asynchronous, 2.0 * k as f64)
                .unwrap();
        }
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert_eq!(reports.len(), 50);
        assert!((reports[49].makespan - 99.0).abs() < 1e-9, "arrival 98 s + 1 s run");
        assert!(
            reports[0].peak_live_tasks <= 2,
            "peak live task state {} for a 50-task stream",
            reports[0].peak_live_tasks
        );
    }

    #[test]
    fn out_of_order_registration_reports_in_registration_order() {
        let cluster = ClusterSpec::uniform("t", 1, 2, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 100.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert!((reports[0].records[0].submitted - 100.0).abs() < 1e-9);
        assert!((reports[1].records[0].submitted - 0.0).abs() < 1e-9);
        assert!((reports[0].makespan - 110.0).abs() < 1e-9);
        assert!((reports[1].makespan - 10.0).abs() < 1e-9);
    }

    #[test]
    fn timed_grow_unblocks_a_starved_queue() {
        // One 1-core node, two 10 s tasks at t = 0: the second is
        // queued. A +1-node grow at t = 5 lets it start right then.
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord
            .set_resource_plan(crate::pilot::ResourcePlan::new().resize(5.0, 1))
            .unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert!((reports[0].makespan - 10.0).abs() < 1e-9);
        assert!(
            (reports[1].makespan - 15.0).abs() < 1e-9,
            "queued task must start on the grown node at t = 5, got {}",
            reports[1].makespan
        );
        // The capacity timeline carries the grow.
        assert_eq!(reports[0].capacity.points, vec![(0.0, 1, 0), (5.0, 2, 0)]);
        assert_eq!(reports[1].capacity, reports[0].capacity);
    }

    #[test]
    fn shrink_is_graceful_and_future_work_avoids_drained_nodes() {
        // Two 1-core nodes, two tasks running from t = 0; a drain at
        // t = 2 marks one node (both equally busy -> the newest). Both
        // tasks still finish at 10; a third workflow arriving at t = 3
        // must wait for the *surviving* core and finish at 20.
        let cluster = ClusterSpec::uniform("t", 2, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 3.0).unwrap();
        coord
            .set_resource_plan(crate::pilot::ResourcePlan::new().resize(2.0, -1))
            .unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert!((reports[0].makespan - 10.0).abs() < 1e-9, "running work finishes");
        assert!((reports[1].makespan - 10.0).abs() < 1e-9, "running work finishes");
        assert!(
            (reports[2].makespan - 20.0).abs() < 1e-9,
            "late arrival waits for the surviving core, got {}",
            reports[2].makespan
        );
        // Offered capacity: the drained node was fully busy at t = 2,
        // so its core leaves the timeline when its task releases it at
        // t = 10 — never before the work that occupied it finished.
        assert_eq!(reports[0].capacity.points, vec![(0.0, 2, 0), (10.0, 1, 0)]);
        // Utilization stays a true fraction even though both initial
        // tasks keep running past the drain: offered core-seconds over
        // [0, 10] are 2x10 (the busy drained core still counts until
        // released), so in-use never exceeds offered.
        for r in &reports {
            let (cu, _) = r.trace.mean_utilization();
            assert!(cu <= 1.0 + 1e-9, "utilization must stay in [0,1], got {cu}");
        }
    }

    #[test]
    fn draining_everything_with_queued_work_is_a_deadlock() {
        // One node, one running + one queued task; draining the only
        // node at t = 1 leaves the queued task unservable forever.
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord
            .set_resource_plan(crate::pilot::ResourcePlan::new().resize(1.0, -1))
            .unwrap();
        let mut ex = VirtualExecutor::new();
        let err = coord.run(&mut ex);
        assert!(err.is_err(), "shrink below queued demand must surface as an error");
    }

    #[test]
    fn autoscaler_rescues_a_starved_queue_and_records_capacity() {
        // One 1-core node, three 10 s tasks at t = 0. The autoscaler
        // (interval 5, step 1, max 3) sees the backlog and grows; the
        // campaign finishes far earlier than the serial 30 s.
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        for _ in 0..3 {
            coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        }
        coord
            .set_resource_plan(crate::pilot::ResourcePlan::new().with_autoscale(
                crate::pilot::AutoscalePolicy {
                    interval: 5.0,
                    min_nodes: 1,
                    max_nodes: 3,
                    step: 1,
                    ..Default::default()
                },
            ))
            .unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        let last = reports.iter().map(|r| r.makespan).fold(0.0f64, f64::max);
        assert!(
            last < 30.0 - 1e-9,
            "autoscaler must relieve the 1-core serialization, got {last}"
        );
        assert!(!reports[0].capacity.is_constant(), "growth must be recorded");
        assert!(reports[0].capacity.peak().0 >= 2);
    }

    #[test]
    fn rejects_bad_arrivals_and_unsatisfiable_requests() {
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        assert!(coord
            .add_workflow(solo(1.0), ExecutionMode::Asynchronous, -1.0)
            .is_err());
        let mut wf = solo(1.0);
        wf.sets[0].req = ResourceRequest::new(0, 3); // no GPUs exist
        assert!(coord.add_workflow(wf, ExecutionMode::Asynchronous, 0.0).is_err());
        assert_eq!(coord.driver_count(), 0);
    }

    // ----- checkpoint / resume ----------------------------------------

    fn contended_coord() -> Coordinator {
        // 1 core, three 10 s workflows (t = 0, 0, 12): at t = 5 one
        // task is running, one queued, one pending arrival — every
        // member population of the snapshot is non-empty.
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 12.0).unwrap();
        coord
    }

    #[test]
    fn checkpoint_then_restore_is_bit_identical() {
        let mut ex = VirtualExecutor::new();
        let straight = contended_coord().run(&mut ex).unwrap();

        let mut ex1 = VirtualExecutor::new();
        let outcome = contended_coord().checkpoint(&mut ex1, 5.0).unwrap();
        let RunOutcome::Checkpointed(snap) = outcome else {
            panic!("run must reach the t = 5 checkpoint before finishing")
        };
        assert_eq!(snap.now, 5.0);
        assert_eq!(snap.running.len(), 1, "one task in flight at t = 5");
        assert_eq!(snap.queue.len(), 1, "one task queued at t = 5");
        assert_eq!(snap.pending.len(), 1, "one arrival still pending at t = 5");
        let mut ex2 = VirtualExecutor::new();
        let resumed = Coordinator::restore(*snap).unwrap().run(&mut ex2).unwrap();

        assert_eq!(resumed.len(), straight.len());
        for (a, b) in straight.iter().zip(&resumed) {
            assert_eq!(a.makespan, b.makespan, "exact f64 equality required");
            assert_eq!(a.records.len(), b.records.len());
            for (ra, rb) in a.records.iter().zip(&b.records) {
                assert_eq!(ra.submitted.to_bits(), rb.submitted.to_bits());
                assert_eq!(ra.started.to_bits(), rb.started.to_bits());
                assert_eq!(ra.finished.to_bits(), rb.finished.to_bits());
            }
            assert_eq!(a.capacity, b.capacity);
            assert_eq!(a.peak_live_tasks, b.peak_live_tasks);
        }
    }

    #[test]
    fn checkpoint_at_zero_and_past_completion() {
        // t = 0: nothing has happened yet; resume reproduces the run.
        let mut ex = VirtualExecutor::new();
        let outcome = contended_coord().run_until(&mut ex, Some(0.0)).unwrap();
        let RunOutcome::Checkpointed(snap) = outcome else {
            panic!("t = 0 checkpoint must fire before any work")
        };
        assert!(snap.running.is_empty());
        let mut ex2 = VirtualExecutor::new();
        let resumed = Coordinator::restore(*snap).unwrap().run(&mut ex2).unwrap();
        assert!((resumed[2].makespan - 30.0).abs() < 1e-9);

        // A checkpoint beyond the last finish: the run just completes.
        let mut ex3 = VirtualExecutor::new();
        match contended_coord().run_until(&mut ex3, Some(1e9)).unwrap() {
            RunOutcome::Completed(reports) => assert_eq!(reports.len(), 3),
            RunOutcome::Checkpointed(_) => panic!("run finishes before t = 1e9"),
        }
    }

    #[test]
    fn restored_coordinator_rejects_new_registrations() {
        let mut ex = VirtualExecutor::new();
        let RunOutcome::Checkpointed(snap) =
            contended_coord().run_until(&mut ex, Some(5.0)).unwrap()
        else {
            panic!("must checkpoint")
        };
        let mut coord = Coordinator::restore(*snap).unwrap();
        assert!(coord
            .add_workflow(solo(1.0), ExecutionMode::Asynchronous, 0.0)
            .is_err());
    }

    #[test]
    fn resume_with_plan_replaces_the_remnant_plan() {
        // Checkpoint mid-run, then resume with an immediate +1-node
        // grow: the queued task starts at the resume instant instead of
        // waiting for the busy core.
        let mut ex = VirtualExecutor::new();
        let RunOutcome::Checkpointed(snap) =
            contended_coord().run_until(&mut ex, Some(5.0)).unwrap()
        else {
            panic!("must checkpoint")
        };
        let mut coord = Coordinator::restore(*snap).unwrap();
        coord
            .set_resource_plan(crate::pilot::ResourcePlan::new().resize(0.0, 1))
            .unwrap();
        let mut ex2 = VirtualExecutor::new();
        let reports = coord.run(&mut ex2).unwrap();
        assert!((reports[0].makespan - 10.0).abs() < 1e-9);
        assert!(
            (reports[1].makespan - 15.0).abs() < 1e-9,
            "queued task must start on the grown node at the t = 5 resume, got {}",
            reports[1].makespan
        );
    }
}
