//! Multi-workflow coordinator: multiplexes N [`WorkflowDriver`]s over
//! one shared pilot [`Agent`] and one [`Executor`].
//!
//! The coordinator owns the three global resources the drivers must
//! share — the allocation (via the agent), the clock (via the
//! executor), and the task-uid namespace — and runs the event loop:
//!
//! 1. feed `ClockAdvanced` to every driver and submit whatever became
//!    ready (a late-arriving workflow's roots are just deferred
//!    activations that come due);
//! 2. invoke the continuous scheduler once per state change;
//! 3. launch placements, then drain the executor's next completion
//!    batch (all completions sharing one instant are handed back in a
//!    single call) and route each back to its owning driver.
//!
//! `engine::run` is a coordinator with exactly one driver, so the
//! single-workflow path and the concurrent-campaign path are the same
//! code.

use std::time::{Duration, Instant};

use super::driver::{EngineEvent, Submission, WorkflowDriver};
use super::{EngineConfig, ExecutionMode, RunReport};
use crate::entk::Workflow;
use crate::error::{Error, Result};
use crate::exec::{Executor, RunningTask};
use crate::pilot::Agent;
use crate::resources::ClusterSpec;
use crate::task::TaskSpec;

/// Shared-pilot multiplexer over any number of workflow drivers.
pub struct Coordinator {
    cluster: ClusterSpec,
    cfg: EngineConfig,
    drivers: Vec<WorkflowDriver>,
    /// Next driver's TX-stream base (cumulative set count, i.e. the
    /// merged-DAG node offset).
    next_set_stream: u64,
    /// Next driver's priority base (cumulative pipeline count).
    next_pipeline: u64,
}

impl Coordinator {
    pub fn new(cluster: &ClusterSpec, cfg: &EngineConfig) -> Coordinator {
        Coordinator {
            cluster: cluster.clone(),
            cfg: cfg.clone(),
            drivers: Vec::new(),
            next_set_stream: 0,
            next_pipeline: 0,
        }
    }

    /// Register a workflow whose roots become schedulable at `arrival`
    /// (engine seconds). Returns the index of its report in
    /// [`Coordinator::run`]'s result.
    pub fn add_workflow(
        &mut self,
        wf: Workflow,
        mode: ExecutionMode,
        arrival: f64,
    ) -> Result<usize> {
        if !arrival.is_finite() || arrival < 0.0 {
            return Err(Error::Config(format!(
                "workflow '{}': invalid arrival time {arrival}",
                wf.name
            )));
        }
        for s in &wf.sets {
            self.cluster.check(&s.req)?;
        }
        let n_sets = wf.sets.len() as u64;
        let d = WorkflowDriver::new(
            wf,
            mode,
            &self.cfg,
            arrival,
            self.next_set_stream,
            self.next_pipeline,
        )?;
        self.next_set_stream += n_sets;
        self.next_pipeline += d.pipeline_count() as u64;
        self.drivers.push(d);
        Ok(self.drivers.len() - 1)
    }

    pub fn driver_count(&self) -> usize {
        self.drivers.len()
    }

    /// Drive every registered workflow to completion over `executor`;
    /// returns one [`RunReport`] per driver, in registration order.
    /// Scheduler accounting (rounds / wall time) is global and repeated
    /// on every report.
    pub fn run(mut self, executor: &mut dyn Executor) -> Result<Vec<RunReport>> {
        let mut agent = Agent::new(&self.cluster, self.cfg.policy);
        // Global uid -> (driver index, driver-local uid).
        let mut route: Vec<(usize, usize)> = Vec::new();
        // Global-uid-indexed specs (what the executor launches).
        let mut specs: Vec<TaskSpec> = Vec::new();
        let mut in_flight = 0usize;
        let mut sched_rounds = 0usize;
        let mut sched_wall = Duration::ZERO;
        // Only invoke the scheduler when the system state changed (new
        // submissions or freed resources) — avoids O(queue) rescans on
        // clock-advance iterations.
        let mut sched_dirty = true;

        loop {
            let now = executor.now();

            // 1. Release activations that are due, in driver order (this
            // matches merged-DAG set ordering: member k's sets precede
            // member k+1's).
            for di in 0..self.drivers.len() {
                let subs = self.drivers[di].step(EngineEvent::ClockAdvanced { now });
                for sub in subs {
                    Self::submit(&mut agent, &mut route, &mut specs, di, sub, now);
                    sched_dirty = true;
                }
            }

            // 2. Schedule everything that fits.
            let placed = if sched_dirty {
                let t0 = Instant::now();
                let placed = agent.schedule();
                sched_wall += t0.elapsed();
                sched_rounds += 1;
                sched_dirty = false;
                placed
            } else {
                Vec::new()
            };
            for s in &placed {
                let spec = &specs[s.uid];
                let (di, local) = route[s.uid];
                self.drivers[di].on_started(local, now);
                executor.launch(&RunningTask {
                    uid: s.uid,
                    tx: spec.tx + self.cfg.task_overhead,
                    started_at: now,
                    kind: Some(spec.kind.clone()),
                });
                in_flight += 1;
            }

            // 3. Wait for progress.
            let next_deferred = self
                .drivers
                .iter()
                .filter_map(|d| d.next_activation())
                .fold(f64::INFINITY, f64::min);
            if in_flight > 0 {
                match executor.peek_next_completion() {
                    // An activation is due before the next completion:
                    // fast-forward to it (virtual time).
                    Some(peek) if next_deferred < peek => {
                        executor.advance_to(next_deferred);
                        continue;
                    }
                    Some(_) => {}
                    // Real executor: wait no longer than the next due
                    // activation; wake early if a completion lands.
                    None => {
                        if next_deferred.is_finite() && next_deferred > now + 1e-12 {
                            if !executor.wait_until(next_deferred) {
                                continue; // deadline hit; release at loop top
                            }
                        }
                    }
                }
                let completions = executor.drain_ready();
                if completions.is_empty() {
                    return Err(Error::Engine("executor lost in-flight tasks".into()));
                }
                for c in completions {
                    in_flight -= 1;
                    agent.complete(c.uid);
                    sched_dirty = true; // resources were freed
                    let (di, local) = route[c.uid];
                    let _ = self.drivers[di].step(EngineEvent::TaskCompleted {
                        uid: local,
                        finished_at: c.finished_at,
                        failed: c.failed,
                    });
                    if c.failed && self.cfg.abort_on_failure {
                        // Report the driver-local uid: that is the uid
                        // visible in the member's RunReport records.
                        return Err(Error::Engine(format!(
                            "task {} ({}) of workflow '{}' failed",
                            local,
                            self.drivers[di].record(local).set_name,
                            self.drivers[di].workflow_name()
                        )));
                    }
                }
            } else if next_deferred.is_finite() {
                // Nothing running; sleep (real) or fast-forward (virtual)
                // to the next activation — e.g. a workflow yet to arrive.
                executor.wait_until(next_deferred);
            } else if agent.queue_len() > 0 {
                return Err(Error::Engine(
                    "deadlock: tasks queued but nothing running (unsatisfiable request?)"
                        .into(),
                ));
            } else {
                break; // every driver drained
            }
        }

        debug_assert!(self.drivers.iter().all(|d| d.is_done()));
        let cluster = self.cluster;
        let mut reports: Vec<RunReport> = self
            .drivers
            .into_iter()
            .map(|d| d.into_report(&cluster))
            .collect();
        for r in &mut reports {
            r.sched_rounds = sched_rounds;
            r.sched_wall = sched_wall;
        }
        Ok(reports)
    }

    /// Move a driver submission into the global namespace and enqueue it.
    fn submit(
        agent: &mut Agent,
        route: &mut Vec<(usize, usize)>,
        specs: &mut Vec<TaskSpec>,
        driver_idx: usize,
        sub: Submission,
        now: f64,
    ) {
        let local = sub.spec.uid;
        let mut spec = sub.spec;
        spec.uid = specs.len();
        agent.submit(&spec, sub.priority, now);
        route.push((driver_idx, local));
        specs.push(spec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Dag;
    use crate::entk::{Pipeline, Workflow};
    use crate::resources::ResourceRequest;
    use crate::sim::VirtualExecutor;
    use crate::task::TaskSetSpec;

    fn solo(tx: f64) -> Workflow {
        let mut dag = Dag::new();
        dag.add_node("A");
        Workflow {
            name: "solo".into(),
            sets: vec![TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), tx).with_sigma(0.0)],
            dag,
            sequential: vec![Pipeline::new("s").stage(&[0])],
            asynchronous: vec![Pipeline::new("a").stage(&[0])],
        }
    }

    #[test]
    fn two_drivers_share_one_agent() {
        let cluster = ClusterSpec::uniform("t", 1, 2, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(20.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert_eq!(reports.len(), 2);
        assert!((reports[0].makespan - 10.0).abs() < 1e-9);
        assert!((reports[1].makespan - 20.0).abs() < 1e-9);
    }

    #[test]
    fn late_arrival_shifts_the_member_timeline() {
        let cluster = ClusterSpec::uniform("t", 1, 2, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 100.0).unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert!((reports[0].makespan - 10.0).abs() < 1e-9);
        assert!((reports[1].records[0].submitted - 100.0).abs() < 1e-9);
        assert!((reports[1].makespan - 110.0).abs() < 1e-9);
    }

    #[test]
    fn contention_serializes_across_drivers() {
        // One core: two single-task workflows arriving together must run
        // back to back on the shared allocation.
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        coord.add_workflow(solo(10.0), ExecutionMode::Asynchronous, 0.0).unwrap();
        let mut ex = VirtualExecutor::new();
        let reports = coord.run(&mut ex).unwrap();
        assert!((reports[0].makespan - 10.0).abs() < 1e-9);
        assert!((reports[1].makespan - 20.0).abs() < 1e-9, "second waits for the core");
    }

    #[test]
    fn rejects_bad_arrivals_and_unsatisfiable_requests() {
        let cluster = ClusterSpec::uniform("t", 1, 1, 0);
        let cfg = EngineConfig::ideal();
        let mut coord = Coordinator::new(&cluster, &cfg);
        assert!(coord
            .add_workflow(solo(1.0), ExecutionMode::Asynchronous, -1.0)
            .is_err());
        let mut wf = solo(1.0);
        wf.sets[0].req = ResourceRequest::new(0, 3); // no GPUs exist
        assert!(coord.add_workflow(wf, ExecutionMode::Asynchronous, 0.0).is_err());
        assert_eq!(coord.driver_count(), 0);
    }
}
