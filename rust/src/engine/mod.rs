//! The execution engine: compiles a [`Workflow`] realization into a
//! set-level plan and drives it to completion over any [`Executor`].
//!
//! One engine serves three execution modes (§4–§6 of the paper):
//!
//! - [`ExecutionMode::Sequential`] — the baseline: one pipeline, stage
//!   barriers between ranks;
//! - [`ExecutionMode::Asynchronous`] — the paper's contribution:
//!   several concurrently-progressing pipelines multiplexed onto one
//!   pilot allocation (stage barriers *within* each pipeline);
//! - [`ExecutionMode::Adaptive`] — the paper's future-work mode: pure
//!   task-set-level dependencies, no stage barriers at all.
//!
//! and two time domains: virtual (discrete-event, paper scale) and real
//! (threads + wall clock, scaled).

mod plan;

pub use plan::{compile, ExecutionMode, JobSet};

use std::time::{Duration, Instant};

use crate::entk::Workflow;
use crate::error::{Error, Result};
use crate::exec::{Executor, RunningTask};
use crate::metrics::{measured_doa_res, throughput, TaskRecord, UtilizationTrace};
use crate::pilot::{Agent, Policy};
use crate::resources::ClusterSpec;
use crate::sim::VirtualExecutor;
use crate::task::TaskSpec;
use crate::util::rng::Rng;

/// Engine tunables.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Seed for TX sampling (deterministic runs).
    pub seed: u64,
    /// Per-task launch overhead in paper-seconds, added to every task's
    /// occupancy (models EnTK/RP launch latency; the paper measured ~4%
    /// total framework overhead).
    pub task_overhead: f64,
    /// Latency between dependency satisfaction and task submission
    /// (stage-transition overhead; the paper attributes ~2% extra to
    /// enabling asynchronicity — more pipelines, more transitions).
    pub stage_overhead: f64,
    /// Scheduler policy.
    pub policy: Policy,
    /// Abort the run on the first failed task (default: record & go on).
    pub abort_on_failure: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            seed: 42,
            task_overhead: 2.0,
            stage_overhead: 3.0,
            policy: Policy::FifoBackfill,
            abort_on_failure: false,
        }
    }
}

impl EngineConfig {
    /// Zero-overhead config (model-validation tests).
    pub fn ideal() -> Self {
        EngineConfig { task_overhead: 0.0, stage_overhead: 0.0, ..Default::default() }
    }
}

/// Everything measured about one run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub workflow: String,
    pub mode: ExecutionMode,
    /// Total time to execution (the paper's TTX), paper-seconds.
    pub makespan: f64,
    pub records: Vec<TaskRecord>,
    pub trace: UtilizationTrace,
    pub cpu_utilization: f64,
    pub gpu_utilization: f64,
    /// Completed tasks per paper-second.
    pub throughput: f64,
    /// Measured DOA_res (§5.2): max concurrent distinct branches - 1.
    pub doa_res: usize,
    pub failed_tasks: usize,
    /// Scheduler invocations (perf accounting).
    pub sched_rounds: usize,
    /// Wall-clock spent inside the scheduler (perf accounting).
    pub sched_wall: Duration,
}

impl RunReport {
    /// Relative improvement I = 1 - tAsync/tSeq (Eqn. 5) against a
    /// baseline report.
    pub fn improvement_over(&self, seq: &RunReport) -> f64 {
        1.0 - self.makespan / seq.makespan
    }
}

/// Simulate a workflow on a virtual cluster (discrete-event, exact).
pub fn simulate(wf: &Workflow, cluster: &ClusterSpec, mode: ExecutionMode) -> RunReport {
    simulate_cfg(wf, cluster, mode, &EngineConfig::default())
}

pub fn simulate_cfg(
    wf: &Workflow,
    cluster: &ClusterSpec,
    mode: ExecutionMode,
    cfg: &EngineConfig,
) -> RunReport {
    let mut ex = VirtualExecutor::new();
    run(wf, cluster, mode, cfg, &mut ex).expect("virtual simulation cannot fail")
}

/// Drive a workflow to completion over an arbitrary executor.
pub fn run(
    wf: &Workflow,
    cluster: &ClusterSpec,
    mode: ExecutionMode,
    cfg: &EngineConfig,
    executor: &mut dyn Executor,
) -> Result<RunReport> {
    wf.validate()?;
    for s in &wf.sets {
        cluster.check(&s.req)?;
    }
    let jobsets = compile(wf, mode);
    let analysis = wf.analysis();
    let branch_of = &analysis.branches.branch_of;

    let mut rng = Rng::new(cfg.seed);
    let mut agent = Agent::new(cluster, cfg.policy);

    // Per-jobset countdowns.
    let n_js = jobsets.len();
    let mut deps_left: Vec<usize> = jobsets.iter().map(|j| j.deps.len()).collect();
    let mut tasks_left: Vec<usize> = jobsets.iter().map(|j| wf.sets[j.set_idx].tasks as usize).collect();
    let mut children: Vec<Vec<usize>> = vec![vec![]; n_js];
    for (i, j) in jobsets.iter().enumerate() {
        for &d in &j.deps {
            children[d].push(i);
        }
    }

    // Task bookkeeping (uid-indexed).
    let mut specs: Vec<TaskSpec> = Vec::new();
    let mut jobset_of: Vec<usize> = Vec::new();
    let mut records: Vec<TaskRecord> = Vec::new();

    // Deferred jobset activations: (ready_at, jobset).
    let mut deferred: Vec<(f64, usize)> = Vec::new();
    let mut in_flight = 0usize;
    let mut failed_tasks = 0usize;
    let mut sched_rounds = 0usize;
    let mut sched_wall = Duration::ZERO;

    // Activate roots at t=0 (no stage_overhead on initial submission).
    for (i, j) in jobsets.iter().enumerate() {
        if j.deps.is_empty() {
            deferred.push((0.0, i));
        }
        let _ = j;
    }

    let activate =
        |js: usize,
         now: f64,
         rng: &mut Rng,
         specs: &mut Vec<TaskSpec>,
         jobset_of: &mut Vec<usize>,
         records: &mut Vec<TaskRecord>,
         agent: &mut Agent| {
            let j = &jobsets[js];
            let set = &wf.sets[j.set_idx];
            let mut set_rng = rng.fork(j.set_idx as u64);
            for ordinal in 0..set.tasks {
                let uid = specs.len();
                let tx = set.sample_tx(&mut set_rng);
                let spec = TaskSpec {
                    uid,
                    set_idx: j.set_idx,
                    ordinal,
                    tx,
                    req: set.req,
                    kind: set.kind.clone(),
                };
                agent.submit(&spec, j.pipeline as u64, now);
                records.push(TaskRecord {
                    uid,
                    set_idx: j.set_idx,
                    set_name: set.name.clone(),
                    pipeline: j.pipeline,
                    branch: branch_of[j.set_idx],
                    submitted: now,
                    started: f64::NAN,
                    finished: f64::NAN,
                    cores: set.req.cpu_cores as u64,
                    gpus: set.req.gpus as u64,
                    failed: false,
                });
                specs.push(spec);
                jobset_of.push(js);
            }
        };

    // Only invoke the scheduler when the system state changed (new
    // submissions or freed resources) — avoids O(queue) rescans on
    // clock-advance iterations.
    let mut sched_dirty = true;
    loop {
        let now = executor.now();

        // 1. Release deferred activations that are due.
        let mut i = 0;
        while i < deferred.len() {
            if deferred[i].0 <= now + 1e-12 {
                let (_, js) = deferred.swap_remove(i);
                activate(js, now, &mut rng, &mut specs, &mut jobset_of, &mut records, &mut agent);
                sched_dirty = true;
            } else {
                i += 1;
            }
        }

        // 2. Schedule everything that fits.
        let placed = if sched_dirty {
            let t0 = Instant::now();
            let placed = agent.schedule();
            sched_wall += t0.elapsed();
            sched_rounds += 1;
            sched_dirty = false;
            placed
        } else {
            Vec::new()
        };
        for s in &placed {
            let spec = &specs[s.uid];
            records[s.uid].started = now;
            executor.launch(&RunningTask {
                uid: s.uid,
                tx: spec.tx + cfg.task_overhead,
                started_at: now,
                kind: Some(spec.kind.clone()),
            });
            in_flight += 1;
        }

        // 3. Wait for progress.
        if in_flight > 0 {
            // If a deferred activation is due before the next completion,
            // fast-forward to it instead (virtual time only).
            let next_deferred = deferred
                .iter()
                .map(|d| d.0)
                .fold(f64::INFINITY, f64::min);
            if let Some(peek) = executor_peek(executor) {
                if next_deferred < peek {
                    executor_advance(executor, next_deferred);
                    continue;
                }
            }
            let c = executor
                .wait_next()
                .ok_or_else(|| Error::Engine("executor lost in-flight tasks".into()))?;
            in_flight -= 1;
            agent.complete(c.uid);
            sched_dirty = true; // resources were freed
            records[c.uid].finished = c.finished_at;
            records[c.uid].failed = c.failed;
            if c.failed {
                failed_tasks += 1;
                if cfg.abort_on_failure {
                    return Err(Error::Engine(format!(
                        "task {} ({}) failed",
                        c.uid, records[c.uid].set_name
                    )));
                }
            }
            // Jobset completion -> unlock children.
            let js = jobset_of[c.uid];
            tasks_left[js] -= 1;
            if tasks_left[js] == 0 {
                for &child in &children[js] {
                    deps_left[child] -= 1;
                    if deps_left[child] == 0 {
                        deferred.push((c.finished_at + cfg.stage_overhead, child));
                    }
                }
            }
        } else if !deferred.is_empty() {
            let t = deferred.iter().map(|d| d.0).fold(f64::INFINITY, f64::min);
            executor_advance(executor, t);
            if executor_peek(executor).is_none() && executor.now() < t {
                // Real executor cannot time-travel; busy-wait briefly.
                std::thread::sleep(Duration::from_millis(1));
            }
        } else if agent.queue_len() > 0 {
            return Err(Error::Engine(
                "deadlock: tasks queued but nothing running (unsatisfiable request?)".into(),
            ));
        } else {
            break; // all done
        }
    }

    let makespan = records.iter().map(|r| r.finished).fold(0.0, f64::max);
    let trace = UtilizationTrace::from_records(&records, cluster);
    let (cpu_u, gpu_u) = trace.mean_utilization();
    Ok(RunReport {
        workflow: wf.name.clone(),
        mode,
        makespan,
        throughput: throughput(&records),
        doa_res: measured_doa_res(&records),
        cpu_utilization: cpu_u,
        gpu_utilization: gpu_u,
        failed_tasks,
        sched_rounds,
        sched_wall,
        records,
        trace,
    })
}

// --- virtual-time helpers (dynamic dispatch workaround) ---------------
// The Executor trait keeps a minimal object-safe surface; virtual-time
// peeking/advancing is engine-internal and implemented via downcasting.

fn executor_peek(ex: &dyn Executor) -> Option<f64> {
    ex.peek_next_completion()
}

fn executor_advance(ex: &mut dyn Executor, t: f64) {
    ex.advance_to(t);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Dag;
    use crate::entk::{Pipeline, Workflow};
    use crate::resources::ResourceRequest;
    use crate::task::TaskSetSpec;

    /// T0 -> {T1, T2}: T1 and T2 independent, 10s each, single-task sets.
    fn fork_workflow(cores_each: u32) -> Workflow {
        let mut dag = Dag::new();
        let a = dag.add_node("A");
        let b = dag.add_node("B");
        let c = dag.add_node("C");
        dag.add_edge(a, b).unwrap();
        dag.add_edge(a, c).unwrap();
        Workflow {
            name: "fork".into(),
            sets: vec![
                TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), 10.0).with_sigma(0.0),
                TaskSetSpec::new("B", 1, ResourceRequest::new(cores_each, 0), 10.0).with_sigma(0.0),
                TaskSetSpec::new("C", 1, ResourceRequest::new(cores_each, 0), 10.0).with_sigma(0.0),
            ],
            dag,
            sequential: vec![Pipeline::new("seq").stage(&[0]).stage(&[1]).stage(&[2])],
            asynchronous: vec![
                Pipeline::new("p0").stage(&[0]).stage(&[1]),
                Pipeline::new("p1").stage(&[2]),
            ],
        }
    }

    fn small_cluster() -> ClusterSpec {
        ClusterSpec::uniform("t", 1, 4, 0)
    }

    #[test]
    fn sequential_sums_async_overlaps() {
        let wf = fork_workflow(1);
        let cfg = EngineConfig::ideal();
        let seq = simulate_cfg(&wf, &small_cluster(), ExecutionMode::Sequential, &cfg);
        let asy = simulate_cfg(&wf, &small_cluster(), ExecutionMode::Asynchronous, &cfg);
        assert!((seq.makespan - 30.0).abs() < 1e-9, "seq {}", seq.makespan);
        assert!((asy.makespan - 20.0).abs() < 1e-9, "async {}", asy.makespan);
        assert!((asy.improvement_over(&seq) - (1.0 - 20.0 / 30.0)).abs() < 1e-9);
    }

    #[test]
    fn async_equals_sequential_when_resources_bind() {
        // B and C each need all 4 cores: DOA_res = 0, async collapses
        // to a chain (§5.2's "collapse" scenario).
        let wf = fork_workflow(4);
        let cfg = EngineConfig::ideal();
        let seq = simulate_cfg(&wf, &small_cluster(), ExecutionMode::Sequential, &cfg);
        let asy = simulate_cfg(&wf, &small_cluster(), ExecutionMode::Asynchronous, &cfg);
        assert!((seq.makespan - asy.makespan).abs() < 1e-9);
        assert_eq!(asy.doa_res, 0);
    }

    #[test]
    fn doa_res_measured_on_fork() {
        let wf = fork_workflow(1);
        let asy = simulate(&wf, &small_cluster(), ExecutionMode::Asynchronous);
        assert_eq!(asy.doa_res, 1, "B and C overlap -> 2 branches - 1");
    }

    #[test]
    fn overheads_extend_makespan() {
        let wf = fork_workflow(1);
        let ideal = simulate_cfg(
            &wf,
            &small_cluster(),
            ExecutionMode::Sequential,
            &EngineConfig::ideal(),
        );
        let lossy = simulate_cfg(
            &wf,
            &small_cluster(),
            ExecutionMode::Sequential,
            &EngineConfig { task_overhead: 1.0, stage_overhead: 2.0, ..Default::default() },
        );
        // 3 tasks x 1s + 2 stage transitions x 2s = +7s.
        assert!((lossy.makespan - ideal.makespan - 7.0).abs() < 1e-9);
    }

    #[test]
    fn adaptive_matches_async_on_simple_fork() {
        let wf = fork_workflow(1);
        let cfg = EngineConfig::ideal();
        let a1 = simulate_cfg(&wf, &small_cluster(), ExecutionMode::Asynchronous, &cfg);
        let a2 = simulate_cfg(&wf, &small_cluster(), ExecutionMode::Adaptive, &cfg);
        assert!((a1.makespan - a2.makespan).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let wf = fork_workflow(1);
        let r1 = simulate(&wf, &small_cluster(), ExecutionMode::Asynchronous);
        let r2 = simulate(&wf, &small_cluster(), ExecutionMode::Asynchronous);
        assert_eq!(r1.makespan, r2.makespan);
        assert_eq!(r1.records.len(), r2.records.len());
    }

    #[test]
    fn unsatisfiable_request_errors() {
        let mut wf = fork_workflow(1);
        wf.sets[1].req = ResourceRequest::new(0, 5); // no GPUs in cluster
        let mut ex = VirtualExecutor::new();
        let err = run(
            &wf,
            &small_cluster(),
            ExecutionMode::Sequential,
            &EngineConfig::ideal(),
            &mut ex,
        );
        assert!(err.is_err());
    }

    #[test]
    fn utilization_accounts_all_core_seconds() {
        let wf = fork_workflow(1);
        let r = simulate_cfg(
            &wf,
            &small_cluster(),
            ExecutionMode::Sequential,
            &EngineConfig::ideal(),
        );
        // 3 tasks x 1 core x 10 s = 30 core-s over (4 cores x 30 s).
        assert!((r.cpu_utilization - 30.0 / 120.0).abs() < 1e-9);
    }
}
