//! The execution engine: compiles a [`Workflow`] realization into a
//! set-level plan and drives it to completion over any [`Executor`].
//!
//! One engine serves three execution modes (§4–§6 of the paper):
//!
//! - [`ExecutionMode::Sequential`] — the baseline: one pipeline, stage
//!   barriers between ranks;
//! - [`ExecutionMode::Asynchronous`] — the paper's contribution:
//!   several concurrently-progressing pipelines multiplexed onto one
//!   pilot allocation (stage barriers *within* each pipeline);
//! - [`ExecutionMode::Adaptive`] — the paper's future-work mode: pure
//!   task-set-level dependencies, no stage barriers at all.
//!
//! and two time domains: virtual (discrete-event, paper scale) and real
//! (threads + wall clock, scaled).
//!
//! The engine core is event-driven and composable: a [`WorkflowDriver`]
//! is one workflow's state machine, and a [`Coordinator`] multiplexes
//! any number of drivers — including workflows arriving mid-run — over
//! one shared pilot agent. [`run`] is the single-workflow convenience
//! wrapper (one coordinator, one driver). See `docs/ARCHITECTURE.md`
//! for the full event flow.
//!
//! # Examples
//!
//! Simulate the paper's DeepDriveMD workflow in both modes and measure
//! the improvement asynchronous execution buys (Eqn. 5):
//!
//! ```
//! use asyncflow::ddmd::{ddmd_workflow, DdmdConfig};
//! use asyncflow::engine::{simulate, ExecutionMode};
//! use asyncflow::resources::ClusterSpec;
//!
//! let wf = ddmd_workflow(&DdmdConfig::paper());
//! let cluster = ClusterSpec::summit_paper();
//! let seq = simulate(&wf, &cluster, ExecutionMode::Sequential);
//! let asy = simulate(&wf, &cluster, ExecutionMode::Asynchronous);
//! assert!(asy.makespan < seq.makespan);
//! assert!(asy.improvement_over(&seq) > 0.0);
//! ```

mod calendar;
mod coordinator;
mod driver;
mod plan;

/// Clock-comparison slack shared by every due-time test in the stack:
/// the event loop's arrival/resize/autoscale/checkpoint gates, driver
/// activation release, the event calendar's due-wake test, executor
/// deadline waits and the simulator's fast-forward assertion all
/// compare the clock through this single epsilon. One constant means
/// one rounding contract — a driver deemed due by the calendar is also
/// due by the loop, bit-for-bit, which the checkpoint/resume and
/// calendar-vs-scan equivalence tests rely on. `asyncflow lint`
/// (DET001) rejects raw `1e-12` literals anywhere else in the
/// clock-handling modules.
pub const EPS: f64 = 1e-12;

pub use calendar::{Calendar, Lane, WakePolicy};
pub use coordinator::{Coordinator, RunOutcome};
pub use driver::{DriverState, EngineEvent, Submission, WorkflowDriver};
pub use plan::{compile, ExecutionMode, JobSet};

use std::time::Duration;

use crate::entk::Workflow;
use crate::error::Result;
use crate::exec::Executor;
use crate::metrics::{
    measured_doa_res, throughput, CapacityTimeline, TaskRecord, UtilizationTrace,
};
use crate::pilot::Policy;
use crate::resources::ClusterSpec;
use crate::sim::VirtualExecutor;
use crate::util::json::{from_u64, obj, FromJson, Json, ToJson};

/// Engine tunables.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Seed for TX sampling (deterministic runs).
    pub seed: u64,
    /// Per-task launch overhead in paper-seconds, added to every task's
    /// occupancy (models EnTK/RP launch latency; the paper measured ~4%
    /// total framework overhead).
    pub task_overhead: f64,
    /// Latency between dependency satisfaction and task submission
    /// (stage-transition overhead; the paper attributes ~2% extra to
    /// enabling asynchronicity — more pipelines, more transitions).
    pub stage_overhead: f64,
    /// Scheduler policy.
    pub policy: Policy,
    /// Abort the run on the first failed task (default: record & go on).
    pub abort_on_failure: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            seed: 42,
            task_overhead: 2.0,
            stage_overhead: 3.0,
            policy: Policy::FifoBackfill,
            abort_on_failure: false,
        }
    }
}

impl EngineConfig {
    /// Zero-overhead config (model-validation tests).
    pub fn ideal() -> Self {
        EngineConfig { task_overhead: 0.0, stage_overhead: 0.0, ..Default::default() }
    }
}

impl ToJson for EngineConfig {
    fn to_json(&self) -> Json {
        obj([
            ("seed", from_u64(self.seed)),
            ("task_overhead", Json::from(self.task_overhead)),
            ("stage_overhead", Json::from(self.stage_overhead)),
            ("policy", Json::from(self.policy.label())),
            ("abort_on_failure", Json::from(self.abort_on_failure)),
        ])
    }
}

impl FromJson for EngineConfig {
    fn from_json(v: &Json) -> Result<EngineConfig> {
        Ok(EngineConfig {
            seed: v.req_u64("seed")?,
            task_overhead: v.req_f64("task_overhead")?,
            stage_overhead: v.req_f64("stage_overhead")?,
            policy: v.req_str("policy")?.parse()?,
            abort_on_failure: v.req_bool("abort_on_failure")?,
        })
    }
}

/// Everything measured about one run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub workflow: String,
    pub mode: ExecutionMode,
    /// Total time to execution (the paper's TTX), paper-seconds.
    pub makespan: f64,
    pub records: Vec<TaskRecord>,
    pub trace: UtilizationTrace,
    pub cpu_utilization: f64,
    pub gpu_utilization: f64,
    /// Completed tasks per paper-second.
    pub throughput: f64,
    /// Measured DOA_res (§5.2): max concurrent distinct branches - 1.
    pub doa_res: usize,
    pub failed_tasks: usize,
    /// Scheduler invocations (perf accounting).
    pub sched_rounds: usize,
    /// Wall-clock spent inside the scheduler (perf accounting).
    pub sched_wall: Duration,
    /// `WorkflowDriver::step` invocations the event loop performed
    /// (perf accounting, coordinator-global like `sched_rounds`). The
    /// calendar loop touches only *due* drivers, so this is the
    /// scan-vs-calendar figure of merit (`benches/bench_scale.rs`).
    /// Like `sched_wall` it measures the execution strategy, not the
    /// simulation: it is not part of snapshots or serialized reports,
    /// and a resumed run counts only its post-restore steps.
    pub driver_steps: u64,
    /// High-water mark of live per-task engine state (in-flight +
    /// queued) during the run. Coordinator-global (repeated on every
    /// member report, like `sched_rounds`); streamed campaigns keep
    /// this far below the total task count.
    pub peak_live_tasks: usize,
    /// Offered-capacity timeline of the run (free + in-use resources).
    /// Constant for fixed allocations; elastic runs (a
    /// [`ResourcePlan`](crate::pilot::ResourcePlan) was active) carry
    /// one point per change — grows when applied, drained cores when
    /// the work occupying them released. Like `sched_rounds`, this is
    /// coordinator-global and repeated on every member report;
    /// utilization figures integrate against it.
    pub capacity: CapacityTimeline,
    /// Resilience accounting when failure injection was active
    /// (`None` otherwise): faults fired, tasks killed, retries, and
    /// the goodput / lost-work core-second split. Coordinator-global
    /// (the failure process spans members), repeated on every report
    /// like `sched_rounds`.
    pub resilience: Option<crate::failure::ResilienceStats>,
}

impl RunReport {
    /// Relative improvement I = 1 - tAsync/tSeq (Eqn. 5) against a
    /// baseline report.
    pub fn improvement_over(&self, seq: &RunReport) -> f64 {
        1.0 - self.makespan / seq.makespan
    }

    /// Derive a report from finished task records: makespan,
    /// utilization trace, throughput and measured DOA_res. Scheduler
    /// accounting starts zeroed (it is coordinator-global). Single
    /// source of the metric derivations for per-workflow and merged
    /// campaign reports alike.
    pub fn from_records(
        workflow: impl Into<String>,
        mode: ExecutionMode,
        records: Vec<TaskRecord>,
        cluster: &ClusterSpec,
        failed_tasks: usize,
    ) -> RunReport {
        Self::from_records_capacity(
            workflow,
            mode,
            records,
            CapacityTimeline::of_cluster(cluster),
            failed_tasks,
        )
    }

    /// [`from_records`](Self::from_records) against a time-varying
    /// capacity (elastic allocations): utilization integrates against
    /// the timeline, not a constant core/GPU count.
    pub fn from_records_capacity(
        workflow: impl Into<String>,
        mode: ExecutionMode,
        records: Vec<TaskRecord>,
        capacity: CapacityTimeline,
        failed_tasks: usize,
    ) -> RunReport {
        let makespan = records.iter().map(|r| r.finished).fold(0.0, f64::max);
        let trace = UtilizationTrace::from_records_capacity(&records, capacity.clone());
        let (cpu_u, gpu_u) = trace.mean_utilization();
        RunReport {
            workflow: workflow.into(),
            mode,
            makespan,
            throughput: throughput(&records),
            doa_res: measured_doa_res(&records),
            cpu_utilization: cpu_u,
            gpu_utilization: gpu_u,
            failed_tasks,
            sched_rounds: 0,
            sched_wall: Duration::ZERO,
            driver_steps: 0,
            peak_live_tasks: 0,
            capacity,
            resilience: None,
            records,
            trace,
        }
    }
}

/// Simulate a workflow on a virtual cluster (discrete-event, exact).
pub fn simulate(wf: &Workflow, cluster: &ClusterSpec, mode: ExecutionMode) -> RunReport {
    simulate_cfg(wf, cluster, mode, &EngineConfig::default())
}

pub fn simulate_cfg(
    wf: &Workflow,
    cluster: &ClusterSpec,
    mode: ExecutionMode,
    cfg: &EngineConfig,
) -> RunReport {
    let mut ex = VirtualExecutor::new();
    run(wf, cluster, mode, cfg, &mut ex).expect("virtual simulation cannot fail")
}

/// Drive a workflow to completion over an arbitrary executor.
///
/// Thin wrapper: one [`Coordinator`] multiplexing a single
/// [`WorkflowDriver`] arriving at t = 0. Concurrent / late-arriving
/// workflows use the coordinator directly (or
/// [`Campaign::simulate_online`](crate::campaign::Campaign::simulate_online)).
pub fn run(
    wf: &Workflow,
    cluster: &ClusterSpec,
    mode: ExecutionMode,
    cfg: &EngineConfig,
    executor: &mut dyn Executor,
) -> Result<RunReport> {
    let mut coord = Coordinator::new(cluster, cfg);
    coord.add_workflow(wf.clone(), mode, 0.0)?;
    let mut reports = coord.run(executor)?;
    Ok(reports.pop().expect("one driver yields one report"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Dag;
    use crate::entk::{Pipeline, Workflow};
    use crate::resources::ResourceRequest;
    use crate::task::TaskSetSpec;

    /// T0 -> {T1, T2}: T1 and T2 independent, 10s each, single-task sets.
    fn fork_workflow(cores_each: u32) -> Workflow {
        let mut dag = Dag::new();
        let a = dag.add_node("A");
        let b = dag.add_node("B");
        let c = dag.add_node("C");
        dag.add_edge(a, b).unwrap();
        dag.add_edge(a, c).unwrap();
        Workflow {
            name: "fork".into(),
            sets: vec![
                TaskSetSpec::new("A", 1, ResourceRequest::new(1, 0), 10.0).with_sigma(0.0),
                TaskSetSpec::new("B", 1, ResourceRequest::new(cores_each, 0), 10.0).with_sigma(0.0),
                TaskSetSpec::new("C", 1, ResourceRequest::new(cores_each, 0), 10.0).with_sigma(0.0),
            ],
            dag,
            sequential: vec![Pipeline::new("seq").stage(&[0]).stage(&[1]).stage(&[2])],
            asynchronous: vec![
                Pipeline::new("p0").stage(&[0]).stage(&[1]),
                Pipeline::new("p1").stage(&[2]),
            ],
        }
    }

    fn small_cluster() -> ClusterSpec {
        ClusterSpec::uniform("t", 1, 4, 0)
    }

    #[test]
    fn sequential_sums_async_overlaps() {
        let wf = fork_workflow(1);
        let cfg = EngineConfig::ideal();
        let seq = simulate_cfg(&wf, &small_cluster(), ExecutionMode::Sequential, &cfg);
        let asy = simulate_cfg(&wf, &small_cluster(), ExecutionMode::Asynchronous, &cfg);
        assert!((seq.makespan - 30.0).abs() < 1e-9, "seq {}", seq.makespan);
        assert!((asy.makespan - 20.0).abs() < 1e-9, "async {}", asy.makespan);
        assert!((asy.improvement_over(&seq) - (1.0 - 20.0 / 30.0)).abs() < 1e-9);
    }

    #[test]
    fn async_equals_sequential_when_resources_bind() {
        // B and C each need all 4 cores: DOA_res = 0, async collapses
        // to a chain (§5.2's "collapse" scenario).
        let wf = fork_workflow(4);
        let cfg = EngineConfig::ideal();
        let seq = simulate_cfg(&wf, &small_cluster(), ExecutionMode::Sequential, &cfg);
        let asy = simulate_cfg(&wf, &small_cluster(), ExecutionMode::Asynchronous, &cfg);
        assert!((seq.makespan - asy.makespan).abs() < 1e-9);
        assert_eq!(asy.doa_res, 0);
    }

    #[test]
    fn doa_res_measured_on_fork() {
        let wf = fork_workflow(1);
        let asy = simulate(&wf, &small_cluster(), ExecutionMode::Asynchronous);
        assert_eq!(asy.doa_res, 1, "B and C overlap -> 2 branches - 1");
    }

    #[test]
    fn overheads_extend_makespan() {
        let wf = fork_workflow(1);
        let ideal = simulate_cfg(
            &wf,
            &small_cluster(),
            ExecutionMode::Sequential,
            &EngineConfig::ideal(),
        );
        let lossy = simulate_cfg(
            &wf,
            &small_cluster(),
            ExecutionMode::Sequential,
            &EngineConfig { task_overhead: 1.0, stage_overhead: 2.0, ..Default::default() },
        );
        // 3 tasks x 1s + 2 stage transitions x 2s = +7s.
        assert!((lossy.makespan - ideal.makespan - 7.0).abs() < 1e-9);
    }

    #[test]
    fn adaptive_matches_async_on_simple_fork() {
        let wf = fork_workflow(1);
        let cfg = EngineConfig::ideal();
        let a1 = simulate_cfg(&wf, &small_cluster(), ExecutionMode::Asynchronous, &cfg);
        let a2 = simulate_cfg(&wf, &small_cluster(), ExecutionMode::Adaptive, &cfg);
        assert!((a1.makespan - a2.makespan).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let wf = fork_workflow(1);
        let r1 = simulate(&wf, &small_cluster(), ExecutionMode::Asynchronous);
        let r2 = simulate(&wf, &small_cluster(), ExecutionMode::Asynchronous);
        assert_eq!(r1.makespan, r2.makespan);
        assert_eq!(r1.records.len(), r2.records.len());
    }

    #[test]
    fn unsatisfiable_request_errors() {
        let mut wf = fork_workflow(1);
        wf.sets[1].req = ResourceRequest::new(0, 5); // no GPUs in cluster
        let mut ex = VirtualExecutor::new();
        let err = run(
            &wf,
            &small_cluster(),
            ExecutionMode::Sequential,
            &EngineConfig::ideal(),
            &mut ex,
        );
        assert!(err.is_err());
    }

    #[test]
    fn utilization_accounts_all_core_seconds() {
        let wf = fork_workflow(1);
        let r = simulate_cfg(
            &wf,
            &small_cluster(),
            ExecutionMode::Sequential,
            &EngineConfig::ideal(),
        );
        // 3 tasks x 1 core x 10 s = 30 core-s over (4 cores x 30 s).
        assert!((r.cpu_utilization - 30.0 / 120.0).abs() < 1e-9);
    }
}
