//! Event calendar: the engine loop's indexed priority structure.
//!
//! The coordinator's legacy loop paid O(live drivers) on **every**
//! iteration — step 2 clocked every live driver (a no-op for all but
//! the due ones) and step 4 re-folded every driver's
//! `next_activation()` from scratch. The calendar replaces both scans:
//! it holds each live driver's next activation as a *wake*, plus the
//! loop's singleton timed events (next pending arrival, next timed
//! resize, next autoscaler tick, the checkpoint deadline, the next
//! injected node failure, the earliest due retry) as *lanes*, so an
//! iteration touches only drivers whose wakes are due and the
//! next-event horizon is a heap peek.
//!
//! ## Wakes: binary heap with lazy invalidation
//!
//! Wakes live in a binary min-heap of `(time, slot)` ordered by
//! [`f64::total_cmp`] with ties broken toward the lower slot. A
//! re-registration does not search the heap: it overwrites
//! `registered[slot]` and pushes a fresh entry, leaving the old entry
//! *stale*. An entry is authoritative iff its time equals
//! `registered[slot]` **bit-for-bit**; stale entries are discarded
//! whenever they surface at the top. Amortized cost per registration
//! is O(log n); the heap never holds more entries than wake
//! registrations performed, and pops reclaim the garbage.
//!
//! Invalidation rules (who re-registers, and when — see
//! `EngineLoop::drive`):
//! - a driver's wake is (re)registered whenever its deferred set can
//!   have changed: at materialization (arrival), after it is stepped
//!   with `ClockAdvanced`, and after each `TaskCompleted` routed to it;
//! - a wake is cancelled when its driver finishes and is folded into
//!   its report;
//! - re-registering the *same* time is a no-op (no heap push), so
//!   steady-state completions that do not move a driver's horizon cost
//!   nothing.
//!
//! ## Lanes: singleton scalars
//!
//! Arrival / resize / autoscale / checkpoint are one-per-loop values
//! that the coordinator already tracks as sorted cursors; the calendar
//! carries them as plain scalars (set every iteration, O(1)) so
//! [`next_event`](Calendar::next_event) is the single source of the
//! loop's wake-up horizon. Gating (the autoscaler only ticks while
//! work exists, the checkpoint only while the sim is active) stays in
//! the coordinator — the lane holds the *effective* time or nothing.
//!
//! ## Snapshots
//!
//! The calendar is **not** captured in [`SimSnapshot`]: every wake is
//! a pure function of its driver's deferred set
//! (`WorkflowDriver::next_activation`), and every lane of loop state
//! that *is* captured. Restore rebuilds it exactly — see
//! `EngineLoop::from_snapshot` and the equivalence tests in
//! `tests/loop_equiv.rs`.
//!
//! [`SimSnapshot`]: crate::checkpoint::SimSnapshot

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::EPS;

/// Which event-loop path computes due drivers and the wake-up horizon.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WakePolicy {
    /// Event-calendar loop: step only drivers whose wake is due;
    /// `next_deferred` is a heap peek. The default.
    #[default]
    Calendar,
    /// Legacy loop: clock every live driver every iteration and fold
    /// every `next_activation()`. Kept as the equivalence baseline and
    /// for the scale bench's before/after comparison.
    FullScan,
}

/// Singleton timed events owned by the loop itself (not by a driver).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Next pending workflow arrival.
    Arrival,
    /// Next unapplied timed resize event.
    Resize,
    /// Next autoscaler evaluation (already gated by the caller).
    Autoscale,
    /// Checkpoint deadline (already gated on sim activity).
    Checkpoint,
    /// Next injected node failure — MTBF fire or trace replay (already
    /// gated on sim activity).
    Failure,
    /// Earliest due retry of a killed task waiting out its backoff.
    Retry,
}

const N_LANES: usize = 6;

/// Min-heap entry; `BinaryHeap` is a max-heap, so the `Ord` impl is
/// reversed. Ties break toward the lower slot so due wakes surface in
/// the same slot order the legacy full scan used.
#[derive(Debug, Clone, Copy)]
struct Entry {
    time: f64,
    slot: usize,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time) == Ordering::Equal && self.slot == other.slot
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: the heap's max is the earliest (time, slot).
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.slot.cmp(&self.slot))
    }
}

/// Indexed priority structure over per-slot wakes + singleton lanes.
#[derive(Debug, Default)]
pub struct Calendar {
    heap: BinaryHeap<Entry>,
    /// `registered[slot]` is the slot's authoritative wake time; NaN
    /// means no wake. Heap entries whose time is not bit-identical to
    /// this are stale and skipped on pop.
    registered: Vec<f64>,
    /// Lane times (NaN = lane empty), indexed by `Lane as usize`.
    lanes: [f64; N_LANES],
}

impl Calendar {
    pub fn new() -> Calendar {
        Calendar { heap: BinaryHeap::new(), registered: Vec::new(), lanes: [f64::NAN; N_LANES] }
    }

    /// Register (or move) slot's wake to time `t`. Re-registering the
    /// current time is a no-op.
    pub fn schedule_wake(&mut self, slot: usize, t: f64) {
        debug_assert!(!t.is_nan(), "NaN wake time for slot {slot}");
        if self.registered.len() <= slot {
            self.registered.resize(slot + 1, f64::NAN);
        }
        if self.registered[slot].to_bits() == t.to_bits() {
            return; // already registered at exactly this time
        }
        self.registered[slot] = t;
        self.heap.push(Entry { time: t, slot });
    }

    /// Drop slot's wake (driver finished or has nothing deferred). The
    /// heap entry, if any, becomes stale and is reclaimed lazily.
    pub fn cancel_wake(&mut self, slot: usize) {
        if let Some(r) = self.registered.get_mut(slot) {
            *r = f64::NAN;
        }
    }

    /// Convenience: wake at `Some(t)`, cancel at `None` (the shape of
    /// `WorkflowDriver::next_activation`).
    pub fn set_wake(&mut self, slot: usize, t: Option<f64>) {
        match t {
            Some(t) => self.schedule_wake(slot, t),
            None => self.cancel_wake(slot),
        }
    }

    /// Pop every wake due at `now` into `out` (slot order, matching the
    /// legacy scan's iteration order) and consume their registrations.
    /// `out` is cleared first; the caller re-registers after stepping.
    pub fn due_wakes(&mut self, now: f64, out: &mut Vec<usize>) {
        out.clear();
        while let Some(top) = self.heap.peek() {
            let Entry { time, slot } = *top;
            if self.registered.get(slot).is_some_and(|r| r.to_bits() == time.to_bits()) {
                if time > now + EPS {
                    break; // earliest live wake is in the future
                }
                self.heap.pop();
                self.registered[slot] = f64::NAN;
                out.push(slot);
            } else {
                self.heap.pop(); // stale (re-registered or cancelled)
            }
        }
        // (time, slot) heap order interleaves slots of different due
        // times; the engine steps due drivers in slot order.
        out.sort_unstable();
    }

    /// Earliest live wake, ignoring lanes (infinity when none).
    /// Reclaims stale heap tops on the way.
    pub fn next_wake(&mut self) -> f64 {
        while let Some(top) = self.heap.peek() {
            let Entry { time, slot } = *top;
            if self.registered.get(slot).is_some_and(|r| r.to_bits() == time.to_bits()) {
                return time;
            }
            self.heap.pop();
        }
        f64::INFINITY
    }

    /// Set (Some) or clear (None) a lane's next event time.
    pub fn set_lane(&mut self, lane: Lane, t: Option<f64>) {
        self.lanes[lane as usize] = t.unwrap_or(f64::NAN);
    }

    /// The loop's wake-up horizon: earliest of every live wake and
    /// every set lane (infinity when nothing is pending anywhere).
    pub fn next_event(&mut self) -> f64 {
        let mut t = self.next_wake();
        for &l in &self.lanes {
            if !l.is_nan() {
                t = t.min(l);
            }
        }
        t
    }

    /// Number of live (registered) wakes — test/debug visibility.
    pub fn live_wakes(&self) -> usize {
        self.registered.iter().filter(|r| !r.is_nan()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn due(cal: &mut Calendar, now: f64) -> Vec<usize> {
        let mut out = Vec::new();
        cal.due_wakes(now, &mut out);
        out
    }

    #[test]
    fn wakes_surface_in_slot_order() {
        let mut cal = Calendar::new();
        cal.schedule_wake(7, 5.0);
        cal.schedule_wake(2, 3.0);
        cal.schedule_wake(4, 5.0);
        assert_eq!(cal.next_wake(), 3.0);
        assert_eq!(due(&mut cal, 5.0), vec![2, 4, 7]);
        assert_eq!(cal.next_wake(), f64::INFINITY);
        assert_eq!(cal.live_wakes(), 0);
    }

    #[test]
    fn due_respects_epsilon_like_the_loop() {
        let mut cal = Calendar::new();
        cal.schedule_wake(0, 10.0);
        assert!(due(&mut cal, 10.0 - 1e-9).is_empty());
        // Within the loop's 1e-12 slack counts as due.
        assert_eq!(due(&mut cal, 10.0 - 1e-13), vec![0]);
    }

    #[test]
    fn reregistration_invalidates_the_old_entry() {
        let mut cal = Calendar::new();
        cal.schedule_wake(3, 8.0);
        cal.schedule_wake(3, 2.0); // moved earlier
        assert_eq!(cal.next_wake(), 2.0);
        assert_eq!(due(&mut cal, 2.0), vec![3]);
        // The stale 8.0 entry must not resurface.
        assert!(due(&mut cal, 100.0).is_empty());
    }

    #[test]
    fn moving_a_wake_later_works_via_staleness() {
        let mut cal = Calendar::new();
        cal.schedule_wake(1, 2.0);
        cal.schedule_wake(1, 9.0);
        assert!(due(&mut cal, 5.0).is_empty());
        assert_eq!(cal.next_wake(), 9.0);
        assert_eq!(due(&mut cal, 9.0), vec![1]);
    }

    #[test]
    fn cancel_then_reschedule() {
        let mut cal = Calendar::new();
        cal.schedule_wake(0, 4.0);
        cal.cancel_wake(0);
        assert_eq!(cal.next_wake(), f64::INFINITY);
        cal.schedule_wake(0, 4.0);
        assert_eq!(due(&mut cal, 4.0), vec![0]);
    }

    #[test]
    fn same_time_reregistration_is_a_noop() {
        let mut cal = Calendar::new();
        cal.schedule_wake(0, 4.0);
        for _ in 0..100 {
            cal.schedule_wake(0, 4.0);
        }
        assert_eq!(cal.heap.len(), 1, "bit-equal re-registrations must not grow the heap");
    }

    #[test]
    fn lanes_fold_into_the_horizon() {
        let mut cal = Calendar::new();
        cal.schedule_wake(0, 12.0);
        cal.set_lane(Lane::Arrival, Some(7.0));
        cal.set_lane(Lane::Resize, Some(30.0));
        cal.set_lane(Lane::Autoscale, None);
        cal.set_lane(Lane::Checkpoint, Some(5.5));
        assert_eq!(cal.next_event(), 5.5);
        cal.set_lane(Lane::Checkpoint, None);
        assert_eq!(cal.next_event(), 7.0);
        cal.set_lane(Lane::Arrival, None);
        assert_eq!(cal.next_event(), 12.0);
        assert_eq!(cal.next_wake(), 12.0, "lanes must not disturb wakes");
    }

    #[test]
    fn empty_calendar_horizon_is_infinite() {
        let mut cal = Calendar::new();
        assert_eq!(cal.next_event(), f64::INFINITY);
        assert!(due(&mut cal, 1e18).is_empty());
    }

    #[test]
    fn interleaved_register_step_register_stream() {
        // Simulates the loop's steady state: wakes move forward as
        // drivers are stepped; the heap stays consistent throughout.
        let mut cal = Calendar::new();
        for slot in 0..50 {
            cal.schedule_wake(slot, slot as f64);
        }
        let mut seen = Vec::new();
        let mut now = 0.0;
        while cal.next_wake().is_finite() {
            now = cal.next_wake();
            let mut batch = Vec::new();
            cal.due_wakes(now, &mut batch);
            for &s in &batch {
                seen.push(s);
                // Every third slot defers again, 10 times each (its
                // wakes land at s, s+10, …, s+100).
                if s % 3 == 0 && now < s as f64 + 100.0 {
                    cal.schedule_wake(s, now + 10.0);
                }
            }
        }
        assert!(now >= 100.0);
        assert_eq!(seen.len(), 50 + 17 * 10); // 0,3,..,48 re-woken 10x
    }
}
