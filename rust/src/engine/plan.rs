//! Realization compiler: turns a workflow + execution mode into the
//! set-level execution plan (jobsets with dependencies) the driver runs.

use crate::entk::Workflow;

/// The three execution modes the paper discusses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutionMode {
    /// Baseline: the `sequential` PST realization.
    Sequential,
    /// The paper's contribution: the `asynchronous` PST realization
    /// (stage barriers within pipelines, pipelines independent).
    Asynchronous,
    /// The paper's future-work mode: pure DAG dependencies, no stage
    /// barriers — every task set becomes eligible the instant its DAG
    /// parents complete (§6.1).
    Adaptive,
}

impl ExecutionMode {
    pub fn label(&self) -> &'static str {
        match self {
            ExecutionMode::Sequential => "sequential",
            ExecutionMode::Asynchronous => "asynchronous",
            ExecutionMode::Adaptive => "adaptive",
        }
    }
}

impl std::str::FromStr for ExecutionMode {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "seq" | "sequential" => Ok(ExecutionMode::Sequential),
            "async" | "asynchronous" => Ok(ExecutionMode::Asynchronous),
            "adaptive" => Ok(ExecutionMode::Adaptive),
            _ => Err(crate::error::Error::Config(format!("unknown mode '{s}'"))),
        }
    }
}

/// One schedulable unit: a task set plus the jobsets that must fully
/// complete before it may start.
#[derive(Debug, Clone)]
pub struct JobSet {
    /// Index into `Workflow::sets`.
    pub set_idx: usize,
    /// Jobset indices that must complete first.
    pub deps: Vec<usize>,
    /// Pipeline this set executes under (scheduling priority; for
    /// adaptive mode this is the DAG branch id).
    pub pipeline: usize,
}

/// Compile a workflow realization into jobsets.
///
/// PST modes (sequential/asynchronous) produce, for a set `s` in stage
/// `k` of pipeline `p`, dependencies =
/// - every set of stage `k-1` of `p` (stage ordering barrier), plus
/// - the DAG parents of **every** member of stage `k` (stage *entry*
///   barrier: all sets of a stage become eligible together — this is
///   precisely the cross-branch coupling the paper's §6.1 future-work
///   paragraph wants to remove, and `Adaptive` removes).
pub fn compile(wf: &Workflow, mode: ExecutionMode) -> Vec<JobSet> {
    match mode {
        ExecutionMode::Sequential => compile_pst(wf, &wf.sequential),
        ExecutionMode::Asynchronous => compile_pst(wf, &wf.asynchronous),
        ExecutionMode::Adaptive => compile_adaptive(wf),
    }
}

fn compile_pst(wf: &Workflow, pipelines: &[crate::entk::Pipeline]) -> Vec<JobSet> {
    // jobset index == set index (each set is one jobset; validate()
    // guarantees the realization covers every set exactly once).
    let n = wf.sets.len();
    let mut jobsets: Vec<JobSet> =
        (0..n).map(|s| JobSet { set_idx: s, deps: vec![], pipeline: 0 }).collect();

    for (p_idx, p) in pipelines.iter().enumerate() {
        for (k, stage) in p.stages.iter().enumerate() {
            // Stage-entry barrier: union of DAG parents of all members.
            let mut entry: Vec<usize> = stage
                .sets
                .iter()
                .flat_map(|&s| wf.dag.parents(s).iter().copied())
                .collect();
            // Stage-order barrier: all sets of the previous stage.
            if k > 0 {
                entry.extend(p.stages[k - 1].sets.iter().copied());
            }
            entry.sort_unstable();
            entry.dedup();
            for &s in &stage.sets {
                jobsets[s].pipeline = p_idx;
                jobsets[s].deps = entry.clone();
                // A set never depends on itself (possible when a stage
                // member is also a parent of another member).
                jobsets[s].deps.retain(|&d| d != s);
            }
        }
    }
    jobsets
}

fn compile_adaptive(wf: &Workflow) -> Vec<JobSet> {
    let analysis = wf.analysis();
    (0..wf.sets.len())
        .map(|s| JobSet {
            set_idx: s,
            deps: wf.dag.parents(s).to_vec(),
            pipeline: analysis.branches.branch_of[s],
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Dag;
    use crate::entk::{Pipeline, Workflow};
    use crate::resources::ResourceRequest;
    use crate::task::TaskSetSpec;

    /// c-DG-like shape: T0 -> {T1,T2}; T1->T3; T2->T4.
    fn wf() -> Workflow {
        let mut dag = Dag::new();
        for name in ["T0", "T1", "T2", "T3", "T4"] {
            dag.add_node(name);
        }
        dag.add_edge(0, 1).unwrap();
        dag.add_edge(0, 2).unwrap();
        dag.add_edge(1, 3).unwrap();
        dag.add_edge(2, 4).unwrap();
        let set = |n: &str| TaskSetSpec::new(n, 1, ResourceRequest::new(1, 0), 1.0);
        Workflow {
            name: "t".into(),
            sets: ["T0", "T1", "T2", "T3", "T4"].iter().map(|n| set(n)).collect(),
            dag,
            sequential: vec![Pipeline::new("seq").stage(&[0]).stage(&[1, 2]).stage(&[3, 4])],
            asynchronous: vec![
                Pipeline::new("p0").stage(&[0]),
                Pipeline::new("p1").stage(&[1]).stage(&[3]),
                Pipeline::new("p2").stage(&[2]).stage(&[4]),
            ],
        }
    }

    #[test]
    fn sequential_imposes_rank_barriers() {
        let js = compile(&wf(), ExecutionMode::Sequential);
        // T3's deps include BOTH T1 and T2 (stage barrier), not just T1.
        assert_eq!(js[3].deps, vec![1, 2]);
        assert_eq!(js[4].deps, vec![1, 2]);
        assert!(js[0].deps.is_empty());
    }

    #[test]
    fn async_keeps_pipelines_independent() {
        let js = compile(&wf(), ExecutionMode::Asynchronous);
        // T3 only waits on its own pipeline's T1.
        assert_eq!(js[3].deps, vec![1]);
        assert_eq!(js[4].deps, vec![2]);
        assert_eq!(js[1].deps, vec![0], "cross-pipeline DAG parent preserved");
        assert_eq!(js[3].pipeline, 1);
        assert_eq!(js[4].pipeline, 2);
    }

    #[test]
    fn adaptive_uses_dag_parents_only() {
        let js = compile(&wf(), ExecutionMode::Adaptive);
        for (i, j) in js.iter().enumerate() {
            assert_eq!(j.deps, wf().dag.parents(i).to_vec());
        }
    }

    #[test]
    fn stage_entry_barrier_couples_stage_members() {
        // Async realization where one stage holds sets with different
        // parents: both wait for the union.
        let mut w = wf();
        w.asynchronous = vec![
            Pipeline::new("p0").stage(&[0]),
            Pipeline::new("p1").stage(&[1, 2]).stage(&[3, 4]),
        ];
        let js = compile(&w, ExecutionMode::Asynchronous);
        // Stage {T3,T4}: entry barrier = parents(T3) u parents(T4) u prev
        // stage {T1,T2} = {1,2}.
        assert_eq!(js[3].deps, vec![1, 2]);
        assert_eq!(js[4].deps, vec![1, 2]);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!("async".parse::<ExecutionMode>().unwrap(), ExecutionMode::Asynchronous);
        assert_eq!("seq".parse::<ExecutionMode>().unwrap(), ExecutionMode::Sequential);
        assert_eq!("adaptive".parse::<ExecutionMode>().unwrap(), ExecutionMode::Adaptive);
        assert!("xyz".parse::<ExecutionMode>().is_err());
        assert_eq!(ExecutionMode::Sequential.label(), "sequential");
    }
}
