//! Per-workflow execution state machine.
//!
//! A [`WorkflowDriver`] owns everything one workflow needs to make
//! progress — compiled jobsets, dependency countdowns, deferred
//! activations, task specs and lifecycle records — but owns **no**
//! resources and **no** clock. It is driven purely by typed
//! [`EngineEvent`]s fed to [`WorkflowDriver::step`], and answers with
//! the task [`Submission`]s those events made ready.
//!
//! This inversion is what lets the [`Coordinator`](super::Coordinator)
//! multiplex N drivers — including workflows that *arrive while others
//! are running* — over one shared pilot [`Agent`](crate::pilot::Agent)
//! and one executor, the way RADICAL-Pilot serves concurrent workflow
//! sessions on a single allocation.
//!
//! ## Uid spaces
//!
//! Drivers speak their own *local* task-uid space (`0..n_tasks`); the
//! coordinator re-uids submissions into the shared global namespace and
//! routes completions back through the mapping. A driver never sees
//! another driver's tasks.
//!
//! ## Determinism
//!
//! Task execution times are drawn from a per-set stream seeded only by
//! `(seed, set_stream_offset + set_idx)`, never from a shared mutable
//! RNG. Activation order therefore cannot perturb TX draws, which is
//! what makes "N workflows arriving at t=0 over one agent" reproduce a
//! statically merged-DAG campaign *exactly* (see `tests/coordinator.rs`).

use super::plan::{compile, ExecutionMode, JobSet};
use super::{EngineConfig, RunReport, EPS};
use crate::entk::Workflow;
use crate::error::{Error, Result};
use crate::metrics::{CapacityTimeline, TaskRecord};
use crate::task::TaskSpec;
use crate::util::rng::Rng;

/// A typed event consumed by [`WorkflowDriver::step`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EngineEvent {
    /// The shared engine clock reached `now`; deferred jobset
    /// activations (stage transitions, the workflow's own arrival) may
    /// have become due.
    ClockAdvanced { now: f64 },
    /// One of this driver's tasks completed (driver-local uid).
    TaskCompleted { uid: usize, finished_at: f64, failed: bool },
}

/// A ready task the driver wants submitted to the shared pilot agent.
#[derive(Debug, Clone)]
pub struct Submission {
    /// Task spec in the driver's *local* uid space; the coordinator
    /// re-uids it into the global namespace before submission.
    pub spec: TaskSpec,
    /// Scheduling priority, already globally namespaced (the driver's
    /// pipeline offset + the jobset's pipeline index).
    pub priority: u64,
}

/// Serializable mid-run driver state (the checkpoint subsystem's view
/// of one live workflow). Only the *evolving* state is captured: the
/// compiled jobsets, branch decomposition and children lists are pure
/// functions of `(wf, mode)` and are recompiled on restore, so the
/// snapshot stays schema-stable as compilation internals change.
#[derive(Debug, Clone)]
pub struct DriverState {
    pub wf: Workflow,
    pub mode: ExecutionMode,
    pub arrival: f64,
    pub set_stream_offset: u64,
    pub pipeline_offset: u64,
    pub deps_left: Vec<usize>,
    pub tasks_left: Vec<usize>,
    pub jobset_of: Vec<usize>,
    pub records: Vec<TaskRecord>,
    pub deferred: Vec<(f64, usize)>,
    pub tasks_remaining: u64,
    pub failed_tasks: usize,
}

/// One workflow's complete execution state, progressed via [`step`].
///
/// [`step`]: WorkflowDriver::step
#[derive(Debug)]
pub struct WorkflowDriver {
    wf: Workflow,
    mode: ExecutionMode,
    jobsets: Vec<JobSet>,
    branch_of: Vec<usize>,
    n_branches: usize,
    /// Unmet dependency count per jobset.
    deps_left: Vec<usize>,
    /// Uncompleted task count per jobset.
    tasks_left: Vec<usize>,
    /// Jobsets unlocked by each jobset's completion.
    children: Vec<Vec<usize>>,
    /// Owning jobset per local uid (grows as jobsets activate; specs
    /// themselves move out in `Submission`s — the coordinator keeps the
    /// launchable copy).
    jobset_of: Vec<usize>,
    records: Vec<TaskRecord>,
    /// Pending jobset activations: (due time, jobset index).
    deferred: Vec<(f64, usize)>,
    seed: u64,
    stage_overhead: f64,
    /// Global base for this driver's per-set TX streams (the merged-DAG
    /// set-index offset when part of a campaign).
    set_stream_offset: u64,
    /// Global base for this driver's pipeline priorities.
    pipeline_offset: u64,
    /// When the workflow arrives at the shared agent (engine seconds).
    arrival: f64,
    tasks_remaining: u64,
    failed_tasks: usize,
}

impl WorkflowDriver {
    /// Compile `wf` under `mode` into a driver whose root jobsets become
    /// due at `arrival`. `set_stream_offset` / `pipeline_offset`
    /// namespace this driver's TX streams and priorities among its
    /// coordinator siblings.
    pub fn new(
        wf: Workflow,
        mode: ExecutionMode,
        cfg: &EngineConfig,
        arrival: f64,
        set_stream_offset: u64,
        pipeline_offset: u64,
    ) -> Result<WorkflowDriver> {
        wf.validate()?;
        Ok(Self::compile_prevalidated(
            wf,
            mode,
            cfg,
            arrival,
            set_stream_offset,
            pipeline_offset,
        ))
    }

    /// [`new`](Self::new) minus the validation pass, for callers that
    /// already validated the workflow (the coordinator validates at
    /// registration time and materializes the driver much later —
    /// re-validating every streamed member would double the cost).
    pub(crate) fn compile_prevalidated(
        wf: Workflow,
        mode: ExecutionMode,
        cfg: &EngineConfig,
        arrival: f64,
        set_stream_offset: u64,
        pipeline_offset: u64,
    ) -> WorkflowDriver {
        let jobsets = compile(&wf, mode);
        let analysis = wf.analysis();
        let branch_of = analysis.branches.branch_of.clone();
        let n_branches = analysis.branches.count();
        let n_js = jobsets.len();
        let deps_left: Vec<usize> = jobsets.iter().map(|j| j.deps.len()).collect();
        let tasks_left: Vec<usize> =
            jobsets.iter().map(|j| wf.sets[j.set_idx].tasks as usize).collect();
        let mut children: Vec<Vec<usize>> = vec![vec![]; n_js];
        for (i, j) in jobsets.iter().enumerate() {
            for &d in &j.deps {
                children[d].push(i);
            }
        }
        // Root jobsets are "deferred to the arrival time": a workflow
        // arriving mid-campaign is just one whose roots are due later.
        let deferred: Vec<(f64, usize)> = jobsets
            .iter()
            .enumerate()
            .filter(|(_, j)| j.deps.is_empty())
            .map(|(i, _)| (arrival, i))
            .collect();
        let tasks_remaining = wf.total_tasks();
        WorkflowDriver {
            jobsets,
            branch_of,
            n_branches,
            deps_left,
            tasks_left,
            children,
            jobset_of: Vec::new(),
            records: Vec::new(),
            deferred,
            seed: cfg.seed,
            stage_overhead: cfg.stage_overhead,
            set_stream_offset,
            pipeline_offset,
            arrival,
            tasks_remaining,
            failed_tasks: 0,
            wf,
            mode,
        }
    }

    /// Capture the evolving state for a checkpoint (see [`DriverState`]).
    pub fn snapshot_state(&self) -> DriverState {
        DriverState {
            wf: self.wf.clone(),
            mode: self.mode,
            arrival: self.arrival,
            set_stream_offset: self.set_stream_offset,
            pipeline_offset: self.pipeline_offset,
            deps_left: self.deps_left.clone(),
            tasks_left: self.tasks_left.clone(),
            jobset_of: self.jobset_of.clone(),
            records: self.records.clone(),
            deferred: self.deferred.clone(),
            tasks_remaining: self.tasks_remaining,
            failed_tasks: self.failed_tasks,
        }
    }

    /// Rebuild a live driver from a checkpointed [`DriverState`]:
    /// recompiles the jobsets from `(wf, mode)` and overlays the
    /// captured countdowns, records and deferred activations. Errors
    /// when the state is inconsistent with the recompiled plan.
    pub fn from_state(s: DriverState, cfg: &EngineConfig) -> Result<WorkflowDriver> {
        let mut d = Self::new(
            s.wf,
            s.mode,
            cfg,
            s.arrival,
            s.set_stream_offset,
            s.pipeline_offset,
        )?;
        let n_js = d.jobsets.len();
        if s.deps_left.len() != n_js || s.tasks_left.len() != n_js {
            return Err(Error::Config(format!(
                "driver state: {} countdown entries for {} jobsets",
                s.deps_left.len(),
                n_js
            )));
        }
        if s.jobset_of.len() != s.records.len() {
            return Err(Error::Config(format!(
                "driver state: {} task records but {} jobset owners",
                s.records.len(),
                s.jobset_of.len()
            )));
        }
        if s.jobset_of.iter().any(|&js| js >= n_js)
            || s.deferred.iter().any(|&(_, js)| js >= n_js)
        {
            return Err(Error::Config(
                "driver state: jobset index out of range".into(),
            ));
        }
        d.deps_left = s.deps_left;
        d.tasks_left = s.tasks_left;
        d.jobset_of = s.jobset_of;
        d.records = s.records;
        d.deferred = s.deferred;
        d.tasks_remaining = s.tasks_remaining;
        d.failed_tasks = s.failed_tasks;
        Ok(d)
    }

    /// Consume one event; return the submissions it made ready.
    /// Convenience wrapper over [`step_into`](Self::step_into).
    pub fn step(&mut self, ev: EngineEvent) -> Vec<Submission> {
        let mut out = Vec::new();
        self.step_into(ev, &mut out);
        out
    }

    /// Consume one event, appending the submissions it made ready to
    /// `out` (not cleared). The coordinator's hot path reuses one
    /// buffer across iterations instead of allocating per step.
    pub fn step_into(&mut self, ev: EngineEvent, out: &mut Vec<Submission>) {
        match ev {
            EngineEvent::ClockAdvanced { now } => self.release_due(now, out),
            EngineEvent::TaskCompleted { uid, finished_at, failed } => {
                self.records[uid].finished = finished_at;
                self.records[uid].failed = failed;
                if failed {
                    self.failed_tasks += 1;
                }
                self.tasks_remaining -= 1;
                let js = self.jobset_of[uid];
                self.tasks_left[js] -= 1;
                if self.tasks_left[js] == 0 {
                    // Jobset fully complete -> count down its children;
                    // those reaching zero become due after the stage
                    // transition overhead.
                    for &child in &self.children[js] {
                        self.deps_left[child] -= 1;
                        if self.deps_left[child] == 0 {
                            self.deferred.push((finished_at + self.stage_overhead, child));
                        }
                    }
                }
            }
        }
    }

    /// Release every deferred activation due at `now`, in deterministic
    /// (time, jobset index) order, expanding each into task submissions.
    fn release_due(&mut self, now: f64, out: &mut Vec<Submission>) {
        // Fast path: the legacy full-scan loop clocks every driver on
        // every iteration; skip the sort when nothing is due.
        if self.deferred.iter().all(|d| d.0 > now + EPS) {
            return;
        }
        self.deferred
            .sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut k = 0;
        while k < self.deferred.len() && self.deferred[k].0 <= now + EPS {
            k += 1;
        }
        // Activate by index (the tuples are Copy) so the due prefix
        // never needs collecting into a temporary.
        for i in 0..k {
            let (_, js) = self.deferred[i];
            self.activate(js, now, out);
        }
        self.deferred.drain(..k);
    }

    /// Expand one jobset into its task specs/records/submissions.
    fn activate(&mut self, js: usize, now: f64, out: &mut Vec<Submission>) {
        let j = &self.jobsets[js];
        let set = &self.wf.sets[j.set_idx];
        // Per-set TX stream keyed by (seed, global set index) only:
        // order-independent, so concurrent and late-arriving siblings
        // draw exactly what a merged-DAG run would.
        let mut set_rng =
            Rng::new(self.seed).fork(self.set_stream_offset + j.set_idx as u64);
        for ordinal in 0..set.tasks {
            let uid = self.records.len();
            let tx = set.sample_tx(&mut set_rng);
            let spec = TaskSpec {
                uid,
                set_idx: j.set_idx,
                ordinal,
                tx,
                req: set.req,
                kind: set.kind,
            };
            self.records.push(TaskRecord {
                uid,
                set_idx: j.set_idx,
                set_name: set.name.clone(),
                pipeline: j.pipeline,
                branch: self.branch_of[j.set_idx],
                submitted: now,
                started: f64::NAN,
                finished: f64::NAN,
                cores: set.req.cpu_cores as u64,
                gpus: set.req.gpus as u64,
                failed: false,
            });
            self.jobset_of.push(js);
            out.push(Submission {
                spec,
                priority: self.pipeline_offset + j.pipeline as u64,
            });
        }
    }

    /// Record that a (local-uid) task was placed and started at `now`.
    pub fn on_started(&mut self, uid: usize, now: f64) {
        self.records[uid].started = now;
    }

    /// Scheduling priority of an already-activated task (local uid): a
    /// pure function of driver state, recomputed when failure injection
    /// resubmits a killed task — the retry enters the scheduler with
    /// the same priority an ordinary submission would carry.
    pub fn priority_of(&self, uid: usize) -> u64 {
        self.pipeline_offset + self.jobsets[self.jobset_of[uid]].pipeline as u64
    }

    /// Earliest pending deferred activation, if any.
    pub fn next_activation(&self) -> Option<f64> {
        self.deferred.iter().map(|d| d.0).reduce(f64::min)
    }

    /// Lifecycle record of an activated task (local uid).
    pub fn record(&self, uid: usize) -> &TaskRecord {
        &self.records[uid]
    }

    /// Number of activated task records so far (bounds-check for
    /// restore paths before calling [`record`](Self::record)).
    pub fn record_count(&self) -> usize {
        self.records.len()
    }

    /// True once every task of the workflow has completed.
    pub fn is_done(&self) -> bool {
        self.tasks_remaining == 0
    }

    pub fn workflow_name(&self) -> &str {
        &self.wf.name
    }

    pub fn mode(&self) -> ExecutionMode {
        self.mode
    }

    pub fn arrival(&self) -> f64 {
        self.arrival
    }

    /// Number of independent DAG branches (for campaign-level branch
    /// namespacing).
    pub fn branch_count(&self) -> usize {
        self.n_branches
    }

    /// Number of pipelines in the compiled realization (for priority
    /// namespacing; matches merged-DAG pipeline numbering).
    pub fn pipeline_count(&self) -> usize {
        match self.mode {
            // Cached at compile time; pipeline_count_of recomputes the
            // same branch analysis.
            ExecutionMode::Adaptive => self.n_branches,
            mode => Self::pipeline_count_of(&self.wf, mode),
        }
    }

    /// [`pipeline_count`](Self::pipeline_count) without building the
    /// driver — the coordinator reserves priority bases at registration
    /// time, long before the driver is materialized, and the two
    /// computations must never diverge.
    pub fn pipeline_count_of(wf: &Workflow, mode: ExecutionMode) -> usize {
        match mode {
            ExecutionMode::Sequential => wf.sequential.len(),
            ExecutionMode::Asynchronous => wf.asynchronous.len(),
            ExecutionMode::Adaptive => wf.analysis().branches.count(),
        }
    }

    /// Finalize into a per-workflow [`RunReport`] against the capacity
    /// timeline observed so far (complete up to this driver's last
    /// finish, which is all its utilization integrates over).
    /// Scheduler accounting is coordinator-global and filled in by the
    /// caller.
    pub fn into_report(self, capacity: &CapacityTimeline) -> RunReport {
        RunReport::from_records_capacity(
            self.wf.name.clone(),
            self.mode,
            self.records,
            capacity.clone(),
            self.failed_tasks,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Dag;
    use crate::entk::{Pipeline, Workflow};
    use crate::resources::ResourceRequest;
    use crate::task::TaskSetSpec;

    fn chain_wf() -> Workflow {
        let mut dag = Dag::new();
        let a = dag.add_node("A");
        let b = dag.add_node("B");
        dag.add_edge(a, b).unwrap();
        Workflow {
            name: "chain".into(),
            sets: vec![
                TaskSetSpec::new("A", 2, ResourceRequest::new(1, 0), 10.0).with_sigma(0.0),
                TaskSetSpec::new("B", 1, ResourceRequest::new(1, 0), 5.0).with_sigma(0.0),
            ],
            dag,
            sequential: vec![Pipeline::new("s").stage(&[0]).stage(&[1])],
            asynchronous: vec![Pipeline::new("p").stage(&[0]).stage(&[1])],
        }
    }

    fn driver_at(arrival: f64) -> WorkflowDriver {
        WorkflowDriver::new(
            chain_wf(),
            ExecutionMode::Sequential,
            &EngineConfig::ideal(),
            arrival,
            0,
            0,
        )
        .unwrap()
    }

    #[test]
    fn roots_release_at_arrival_not_before() {
        let mut d = driver_at(50.0);
        assert_eq!(d.next_activation(), Some(50.0));
        assert!(d.step(EngineEvent::ClockAdvanced { now: 10.0 }).is_empty());
        let subs = d.step(EngineEvent::ClockAdvanced { now: 50.0 });
        assert_eq!(subs.len(), 2, "set A has two tasks");
        assert_eq!(subs[0].spec.uid, 0);
        assert_eq!(subs[1].spec.uid, 1);
        assert_eq!(d.next_activation(), None);
    }

    #[test]
    fn completion_unlocks_children_after_all_set_tasks() {
        let mut d = driver_at(0.0);
        let subs = d.step(EngineEvent::ClockAdvanced { now: 0.0 });
        assert_eq!(subs.len(), 2);
        d.on_started(0, 0.0);
        d.on_started(1, 0.0);
        // First A task completing does not unlock B.
        d.step(EngineEvent::TaskCompleted { uid: 0, finished_at: 10.0, failed: false });
        assert_eq!(d.next_activation(), None);
        // Second one does.
        d.step(EngineEvent::TaskCompleted { uid: 1, finished_at: 10.0, failed: false });
        assert_eq!(d.next_activation(), Some(10.0));
        let subs = d.step(EngineEvent::ClockAdvanced { now: 10.0 });
        assert_eq!(subs.len(), 1);
        assert_eq!(subs[0].spec.set_idx, 1);
        assert!(!d.is_done());
        d.on_started(2, 10.0);
        d.step(EngineEvent::TaskCompleted { uid: 2, finished_at: 15.0, failed: false });
        assert!(d.is_done());
    }

    #[test]
    fn tx_streams_are_activation_order_independent() {
        // Same seed, different arrival offsets: identical TX draws.
        let mut sigma_wf = chain_wf();
        sigma_wf.sets[0].tx_sigma_frac = 0.2;
        let cfg = EngineConfig { seed: 9, ..EngineConfig::ideal() };
        let draws = |arrival: f64| {
            let mut d = WorkflowDriver::new(
                sigma_wf.clone(),
                ExecutionMode::Sequential,
                &cfg,
                arrival,
                0,
                0,
            )
            .unwrap();
            d.step(EngineEvent::ClockAdvanced { now: arrival })
                .iter()
                .map(|s| s.spec.tx)
                .collect::<Vec<_>>()
        };
        assert_eq!(draws(0.0), draws(123.0));
    }

    #[test]
    fn priorities_carry_pipeline_offset() {
        let d = WorkflowDriver::new(
            chain_wf(),
            ExecutionMode::Asynchronous,
            &EngineConfig::ideal(),
            0.0,
            0,
            7,
        );
        let mut d = d.unwrap();
        let subs = d.step(EngineEvent::ClockAdvanced { now: 0.0 });
        assert!(subs.iter().all(|s| s.priority == 7));
        assert_eq!(d.pipeline_count(), 1);
    }
}
