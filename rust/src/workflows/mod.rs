//! Abstract-DG workflows (substrate S16): the paper's Fig. 3b graph and
//! its two concrete parameterizations c-DG1 / c-DG2 (Table 2), plus a
//! random-workflow generator for property tests and benches.
//!
//! ### Fig. 3b reconstruction
//!
//! The paper gives the figure only as an image; the edge set used here
//! is reconstructed from every textual constraint:
//! - 8 task sets T0..T7 with DOA_dep = 2 (Table 3);
//! - T7 executes only after *both* T4 and T5 (§6.1);
//! - (T1,T4), (T2,T5) and (T1,T5) are pairwise independent (§6.1/§6.2);
//! - the async realization co-schedules {T3,T6} against {{T4,T5},T7}
//!   (§7.2), with Fig. 6 noting t(T3,T6) ~ t(T4,T5)+t(T7) — so T3 and
//!   T6 must share a stage (same rank), and the sequential stage sums
//!   must land near the paper's ~1860/1856 s measurements;
//!
//! Satisfying edge set: `T0->{T1,T2,T5}; T1->T3; T2->{T4,T6};
//! {T4,T5}->T7`. Forks at T0 (+2) and T2 (+1) open four path segments,
//! the T7 join merges one (-1): three independent branches, DOA_dep = 2
//! exactly as Table 3 reports. (Strict breadth-first *indexing* of the
//! figure is sacrificed for these semantic constraints: T5 sits at
//! rank 1.)
//!
//! ### Table 2 interpretation
//!
//! "# Task" rows with braced set pairs ({T1,T2} etc.) are read as the
//! brace-group **total**, split evenly (e.g. c-DG2 {T3,T6}: 96 -> 48
//! each). The per-set reading would demand 192 concurrent GPUs against
//! the allocation's 96 and contradict the paper's own Eqn. 3 prediction
//! of 1300 s; see DESIGN.md §Substitutions and EXPERIMENTS.md E3.

use crate::dag::Dag;
use crate::entk::{Pipeline, Workflow};
use crate::resources::ResourceRequest;
use crate::task::TaskSetSpec;
use crate::util::rng::Rng;

/// Fig. 3b's dependency graph.
pub fn fig3b_dag() -> Dag {
    let mut d = Dag::new();
    for i in 0..8 {
        d.add_node(format!("T{i}"));
    }
    d.add_edge(0, 1).unwrap();
    d.add_edge(0, 2).unwrap();
    d.add_edge(0, 5).unwrap();
    d.add_edge(1, 3).unwrap();
    d.add_edge(2, 4).unwrap();
    d.add_edge(2, 6).unwrap();
    d.add_edge(4, 7).unwrap();
    d.add_edge(5, 7).unwrap();
    d
}

/// Per-set parameters for a concrete DG (one column of Table 2).
#[derive(Debug, Clone, Copy)]
pub struct CdgSetParams {
    pub tasks: u32,
    pub cores: u32,
    pub gpus: u32,
    /// Mean TTX fraction of the ~2000 s budget.
    pub ttx_fraction: f64,
}

/// Build a concrete workflow over Fig. 3b.
///
/// `params[i]` parameterizes task set Ti. TX mean = fraction x 2000 s,
/// sigma = 0.05 (Table 2's N(mu, 0.05)).
pub fn cdg_workflow(name: &str, params: [CdgSetParams; 8]) -> Workflow {
    let dag = fig3b_dag();
    let sets: Vec<TaskSetSpec> = params
        .iter()
        .enumerate()
        .map(|(i, p)| {
            TaskSetSpec::new(
                format!("T{i}"),
                p.tasks,
                ResourceRequest::new(p.cores, p.gpus),
                p.ttx_fraction * 2000.0,
            )
            .with_sigma(0.05)
        })
        .collect();

    // Sequential: one pipeline, stages by figure rank ({T0}, {T1,T2},
    // {T3..T6}, {T7}); T5's only parent is T0, so placing it in stage 3
    // is dependency-valid.
    let sequential = vec![Pipeline::new(format!("{name}-seq"))
        .stage(&[0])
        .stage(&[1, 2])
        .stage(&[3, 4, 5, 6])
        .stage(&[7])];

    // Asynchronous (§7.2): a prefix pipeline [T0; {T1,T2}], then
    // {T3,T6} against {{T4,T5}; T7}.
    let asynchronous = vec![
        Pipeline::new(format!("{name}-p0")).stage(&[0]).stage(&[1, 2]),
        Pipeline::new(format!("{name}-p1")).stage(&[3, 6]),
        Pipeline::new(format!("{name}-p2")).stage(&[4, 5]).stage(&[7]),
    ];

    let wf = Workflow {
        name: name.to_string(),
        sets,
        dag,
        sequential,
        asynchronous,
    };
    wf.validate().expect("cdg builder produces valid workflows");
    wf
}

/// Table 2, column c-DG1: asynchronicity's *negative* case (I ~ -0.015).
pub fn cdg1() -> Workflow {
    let p = |tasks, cores, gpus, f| CdgSetParams { tasks, cores, gpus, ttx_fraction: f };
    cdg_workflow(
        "c-DG1",
        [
            p(96, 16, 1, 0.38), // T0
            p(16, 40, 0, 0.11), // T1 ({T1,T2}: 32 total)
            p(16, 40, 0, 0.11), // T2
            p(8, 4, 0, 0.06),   // T3 ({T3,T6}: 16 total)
            p(8, 32, 1, 0.08),  // T4 ({T4,T5}: 16 total)
            p(8, 32, 1, 0.08),  // T5
            p(8, 4, 0, 0.06),   // T6
            p(96, 4, 1, 0.36),  // T7
        ],
    )
}

/// Table 2, column c-DG2: asynchronicity's strong win (I ~ 0.26).
pub fn cdg2() -> Workflow {
    let p = |tasks, cores, gpus, f| CdgSetParams { tasks, cores, gpus, ttx_fraction: f };
    cdg_workflow(
        "c-DG2",
        [
            p(96, 16, 1, 0.19), // T0
            p(16, 40, 0, 0.08), // T1
            p(16, 40, 0, 0.08), // T2
            p(48, 4, 1, 0.38),  // T3 ({T3,T6}: 96 total)
            p(8, 32, 1, 0.12),  // T4
            p(8, 32, 1, 0.12),  // T5
            p(48, 4, 1, 0.38),  // T6
            p(16, 4, 0, 0.23),  // T7
        ],
    )
}

/// Random layered workflow generator (benches / property tests): up to
/// `max_ranks` ranks, random fan-out, random resources bounded by the
/// cluster's node size.
pub fn random_workflow(rng: &mut Rng, max_ranks: usize, max_sets_per_rank: usize) -> Workflow {
    let ranks = 2 + rng.below(max_ranks.max(1) as u64) as usize;
    let mut dag = Dag::new();
    let mut sets = Vec::new();
    let mut by_rank: Vec<Vec<usize>> = Vec::new();
    for r in 0..ranks {
        let width = 1 + rng.below(max_sets_per_rank.max(1) as u64) as usize;
        let mut level = Vec::new();
        for _ in 0..width {
            let id = dag.add_node(format!("S{}", sets.len()));
            let gpus = if rng.f64() < 0.4 { 1 } else { 0 };
            sets.push(
                TaskSetSpec::new(
                    format!("S{}", sets.len()),
                    1 + rng.below(12) as u32,
                    ResourceRequest::new(1 + rng.below(8) as u32, gpus),
                    10.0 + rng.f64() * 90.0,
                )
                .with_sigma(0.05),
            );
            level.push(id);
        }
        if r > 0 {
            for &v in &level {
                // Each node gets >= 1 parent from the previous rank.
                let prev = &by_rank[r - 1];
                let p = prev[rng.below(prev.len() as u64) as usize];
                dag.add_edge(p, v).unwrap();
                if prev.len() > 1 && rng.f64() < 0.25 {
                    let p2 = prev[rng.below(prev.len() as u64) as usize];
                    if p2 != p {
                        let _ = dag.add_edge(p2, v);
                    }
                }
            }
        }
        by_rank.push(level);
    }
    // Sequential: rank stages. Async: one pipeline per branch chain —
    // derived simply as rank-stage pipelines per branch id.
    let analysis = crate::dag::DagAnalysis::of(&dag);
    let mut seq = Pipeline::new("seq");
    for level in &by_rank {
        seq = seq.stage(level);
    }
    let nbranches = analysis.branches.count();
    let mut async_pipes: Vec<Pipeline> = (0..nbranches)
        .map(|b| Pipeline::new(format!("p{b}")))
        .collect();
    for level in &by_rank {
        // group this rank's sets by branch
        let mut per_branch: Vec<Vec<usize>> = vec![vec![]; nbranches];
        for &v in level {
            per_branch[analysis.branches.branch_of[v]].push(v);
        }
        for (b, group) in per_branch.into_iter().enumerate() {
            if !group.is_empty() {
                async_pipes[b].stages.push(crate::entk::Stage::of(&group));
            }
        }
    }
    async_pipes.retain(|p| !p.stages.is_empty());
    let wf = Workflow {
        name: "random".into(),
        sets,
        dag,
        sequential: vec![seq],
        asynchronous: async_pipes,
    };
    wf.validate().expect("random builder produces valid workflows");
    wf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::DagAnalysis;
    use crate::util::prop::check_bool;

    #[test]
    fn fig3b_satisfies_textual_constraints() {
        let d = fig3b_dag();
        let a = DagAnalysis::of(&d);
        assert_eq!(a.doa_dep, 2, "Table 3: DOA_dep = 2");
        // T7 after both T4 and T5.
        assert_eq!(d.parents(7), &[4, 5]);
        // §6.1/§6.2 independence pairs.
        assert!(d.independent(1, 4));
        assert!(d.independent(2, 5));
        assert!(d.independent(1, 5));
        // {T3,T6} share a rank (they co-run in Fig. 6's async stage).
        assert_eq!(a.ranks[3], a.ranks[6]);
        assert_eq!(a.ranks, vec![0, 1, 1, 2, 2, 1, 2, 3]);
    }

    #[test]
    fn cdg1_and_cdg2_validate() {
        cdg1().validate().unwrap();
        cdg2().validate().unwrap();
        // Sequential TTX budget ~2000 s (paper: "about 2000 s for both").
        let c = crate::resources::ClusterSpec::summit_paper();
        let t1 = crate::model::t_seq(&cdg1(), &c, 0.0);
        let t2 = crate::model::t_seq(&cdg2(), &c, 0.0);
        assert!((1700.0..=2100.0).contains(&t1), "c-DG1 tSeq={t1}");
        assert!((1700.0..=2100.0).contains(&t2), "c-DG2 tSeq={t2}");
    }

    #[test]
    fn property_random_workflows_always_valid() {
        check_bool(
            0xF00D,
            60,
            |rng: &mut Rng, size| {
                let mut r = rng.fork(size.0 as u64);
                random_workflow(&mut r, 4, 3)
            },
            |wf| wf.validate().is_ok() && wf.analysis().doa_dep + 1 >= 1,
        );
    }
}
