//! Executors (substrate S13): the engine's pluggable execution backends.
//!
//! - [`crate::sim::VirtualExecutor`] — discrete-event, virtual time
//!   (paper-scale experiments);
//! - [`StressExecutor`] — real threads + wall clock, tasks sleep or spin
//!   for their (scaled) TX: validates the coordinator under true
//!   concurrency, like the paper's `stress` executable;
//! - the ML executor in `crate::ddmd::mlexec` (behind the `pjrt`
//!   feature) — real threads whose task bodies call the PJRT runtime
//!   (DeepDriveMD task semantics).

mod stress;

pub use stress::{StressExecutor, StressMode};

use std::time::Duration;

use crate::task::TaskKind;

/// A task handed to an executor by the engine after scheduling.
#[derive(Debug, Clone)]
pub struct RunningTask {
    pub uid: usize,
    /// Execution time in paper-scale seconds (virtual executors honor it
    /// exactly; real executors scale it).
    pub tx: f64,
    /// Engine time at launch.
    pub started_at: f64,
    /// Body for real executors (None for virtual).
    pub kind: Option<TaskKind>,
}

/// Completion report from an executor.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub uid: usize,
    pub finished_at: f64,
    pub failed: bool,
}

/// The engine's execution backend.
pub trait Executor {
    /// Begin executing a scheduled task.
    fn launch(&mut self, task: &RunningTask);

    /// Block until some running task completes; `None` when nothing is
    /// in flight.
    fn wait_next(&mut self) -> Option<Completion>;

    /// Current engine time (virtual seconds, or scaled wall-clock).
    fn now(&self) -> f64;

    /// Abort a running task: its completion must never be delivered.
    /// Virtual executors drop the pending completion event; the
    /// default is a no-op for executors that cannot revoke work
    /// already handed to a real thread (the engine then ignores the
    /// stale completion by uid).
    fn cancel(&mut self, _uid: usize) {}

    /// Earliest pending completion time, when the executor can know it
    /// (virtual time). Real executors return `None`.
    fn peek_next_completion(&self) -> Option<f64> {
        None
    }

    /// Fast-forward the clock to `t` (virtual time only; no-op for real
    /// executors, which can't time-travel).
    fn advance_to(&mut self, _t: f64) {}

    /// Batched completion draining: block until at least one running
    /// task completes, then hand back *every* completion sharing that
    /// instant (virtual time) or already waiting (real executors) in
    /// one call, instead of one-by-one wakeups. Returns an empty batch
    /// only when nothing is in flight. Convenience wrapper over
    /// [`drain_ready_into`](Self::drain_ready_into).
    fn drain_ready(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        self.drain_ready_into(&mut out);
        out
    }

    /// [`drain_ready`](Self::drain_ready) into a caller-owned buffer
    /// (cleared first): the engine loop drains once per wakeup and
    /// reuses one buffer for the run instead of allocating a fresh
    /// `Vec` every iteration.
    fn drain_ready_into(&mut self, out: &mut Vec<Completion>) {
        out.clear();
        out.extend(self.wait_next());
    }

    /// Block until engine time reaches `t` or a completion becomes
    /// available, whichever happens first; returns `true` when a
    /// completion may be ready to drain. Virtual executors fast-forward
    /// instantly. The default naps briefly (no busy-spin) and then
    /// reports `true`: a real executor without a timed-wait primitive
    /// cannot rule out a pending completion, and the caller's blocking
    /// drain must not be starved until the deadline.
    fn wait_until(&mut self, t: f64) -> bool {
        self.advance_to(t);
        if self.now() + crate::engine::EPS < t {
            std::thread::sleep(Duration::from_millis(1));
            return true;
        }
        false
    }
}
