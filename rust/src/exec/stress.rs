//! Real-concurrency executor: one thread per running task, sleeping
//! (or spinning) for TX × scale wall-clock seconds — the moral
//! equivalent of the paper's `stress` synthetic executable.

use std::collections::{BTreeSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use super::{Completion, Executor, RunningTask};

/// How a stress task occupies its time slice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StressMode {
    /// Sleep for the scaled TX (default: no CPU contention, so scaled
    /// durations stay faithful even on a small host).
    #[default]
    Sleep,
    /// Busy-spin for the scaled TX (exercises genuine CPU pressure).
    Spin,
}

/// Wall-clock executor. `scale` maps paper seconds to wall seconds
/// (e.g. 0.01 ⇒ a 340 s Simulation takes 3.4 s).
pub struct StressExecutor {
    scale: f64,
    mode: StressMode,
    epoch: Instant,
    tx_chan: Sender<(usize, bool)>,
    rx_chan: Receiver<(usize, bool)>,
    in_flight: usize,
    /// Completions received while waiting on a deadline, not yet handed
    /// to the engine.
    pending: VecDeque<(usize, bool)>,
    /// Injected failures: 0-based *launch ordinals* that should report
    /// failure (tests). Keyed on launch order, not uid: the engine
    /// recycles global uids, so a uid no longer names one task.
    fail_launches: BTreeSet<usize>,
    /// Tasks launched so far (the next launch's ordinal).
    launches: usize,
}

impl StressExecutor {
    pub fn new(scale: f64, mode: StressMode) -> StressExecutor {
        let (tx_chan, rx_chan) = channel();
        StressExecutor {
            scale,
            mode,
            epoch: Instant::now(),
            tx_chan,
            rx_chan,
            in_flight: 0,
            pending: VecDeque::new(),
            fail_launches: BTreeSet::new(),
            launches: 0,
        }
    }

    /// Mark the `n`-th launched task (0-based launch order) to complete
    /// as failed (failure-injection testing).
    pub fn inject_failure(&mut self, n: usize) {
        self.fail_launches.insert(n);
    }

    fn completion(&self, (uid, failed): (usize, bool)) -> Completion {
        Completion { uid, finished_at: self.now(), failed }
    }
}

impl Executor for StressExecutor {
    fn launch(&mut self, task: &RunningTask) {
        let wall = (task.tx * self.scale).max(0.0);
        let uid = task.uid;
        let fail = self.fail_launches.contains(&self.launches);
        self.launches += 1;
        let chan = self.tx_chan.clone();
        let mode = self.mode;
        self.in_flight += 1;
        std::thread::spawn(move || {
            match mode {
                StressMode::Sleep => std::thread::sleep(std::time::Duration::from_secs_f64(wall)),
                StressMode::Spin => {
                    let t0 = Instant::now();
                    while t0.elapsed().as_secs_f64() < wall {
                        std::hint::black_box(0u64);
                    }
                }
            }
            // Receiver may be gone if the engine aborted; ignore.
            let _ = chan.send((uid, fail));
        });
    }

    fn wait_next(&mut self) -> Option<Completion> {
        if let Some(msg) = self.pending.pop_front() {
            self.in_flight -= 1;
            return Some(self.completion(msg));
        }
        if self.in_flight == 0 {
            return None;
        }
        let msg = self.rx_chan.recv().ok()?;
        self.in_flight -= 1;
        Some(self.completion(msg))
    }

    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() / self.scale
    }

    fn drain_ready_into(&mut self, out: &mut Vec<Completion>) {
        out.clear();
        // Anything buffered by wait_until drains without blocking ...
        while let Some(msg) = self.pending.pop_front() {
            self.in_flight -= 1;
            out.push(self.completion(msg));
        }
        // ... otherwise block for the first completion ...
        if out.is_empty() {
            match self.wait_next() {
                Some(c) => out.push(c),
                None => return,
            }
        }
        // ... then sweep up everything else that already landed.
        while self.in_flight > 0 {
            match self.rx_chan.try_recv() {
                Ok(msg) => {
                    self.in_flight -= 1;
                    out.push(self.completion(msg));
                }
                Err(_) => break,
            }
        }
    }

    fn wait_until(&mut self, t: f64) -> bool {
        if !self.pending.is_empty() {
            return true;
        }
        // Clamp: non-finite deadlines (infinity = "any completion") and
        // absurd horizons must not panic Duration::from_secs_f64; cap
        // each wait at an hour and let the caller loop. f64::min maps
        // NaN to the cap too.
        let wall = ((t - self.now()) * self.scale).min(3600.0);
        if wall <= 0.0 {
            return false;
        }
        // Timed wait (no busy-spinning): wakes early when a completion
        // lands, which we buffer for the next drain.
        match self.rx_chan.recv_timeout(Duration::from_secs_f64(wall)) {
            Ok(msg) => {
                self.pending.push_back(msg);
                true
            }
            Err(_) => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completes_tasks_roughly_in_tx_order() {
        let mut ex = StressExecutor::new(0.01, StressMode::Sleep);
        // Paper-seconds: 20 and 5 -> wall 0.2s and 0.05s.
        ex.launch(&RunningTask { uid: 0, tx: 20.0, started_at: 0.0, kind: None });
        ex.launch(&RunningTask { uid: 1, tx: 5.0, started_at: 0.0, kind: None });
        let c1 = ex.wait_next().unwrap();
        assert_eq!(c1.uid, 1);
        let c0 = ex.wait_next().unwrap();
        assert_eq!(c0.uid, 0);
        // Engine time is scaled wall-clock: ~20 paper-seconds elapsed.
        assert!(c0.finished_at >= 18.0 && c0.finished_at < 60.0, "{}", c0.finished_at);
        assert!(ex.wait_next().is_none());
    }

    #[test]
    fn failure_injection_targets_launch_order_not_uid() {
        let mut ex = StressExecutor::new(0.001, StressMode::Sleep);
        ex.inject_failure(0);
        // uid is irrelevant: the first *launch* fails.
        ex.launch(&RunningTask { uid: 7, tx: 1.0, started_at: 0.0, kind: None });
        let c = ex.wait_next().unwrap();
        assert!(c.failed);
        // A later launch reusing the same uid does not fail.
        ex.launch(&RunningTask { uid: 7, tx: 1.0, started_at: 0.0, kind: None });
        let c = ex.wait_next().unwrap();
        assert!(!c.failed);
    }

    #[test]
    fn spin_mode_also_completes() {
        let mut ex = StressExecutor::new(0.001, StressMode::Spin);
        ex.launch(&RunningTask { uid: 0, tx: 10.0, started_at: 0.0, kind: None });
        assert_eq!(ex.wait_next().unwrap().uid, 0);
    }

    #[test]
    fn drain_ready_collects_landed_batch() {
        let mut ex = StressExecutor::new(0.001, StressMode::Sleep);
        for uid in 0..4 {
            ex.launch(&RunningTask { uid, tx: 5.0, started_at: 0.0, kind: None });
        }
        // Let every task land, then drain: one blocking call should
        // sweep (at least the already-arrived subset of) them all.
        std::thread::sleep(Duration::from_millis(50));
        let mut got = 0;
        while got < 4 {
            let batch = ex.drain_ready();
            assert!(!batch.is_empty());
            got += batch.len();
        }
        assert!(ex.drain_ready().is_empty());
    }

    #[test]
    fn wait_until_honors_deadline_and_wakes_on_completion() {
        let mut ex = StressExecutor::new(0.001, StressMode::Sleep);
        // Nothing in flight: waits out the deadline, reports no work.
        let t0 = Instant::now();
        assert!(!ex.wait_until(ex.now() + 20.0)); // 20 paper-ms = 20 wall-ms
        assert!(t0.elapsed() >= Duration::from_millis(15));
        // A completing task interrupts the wait and is buffered.
        ex.launch(&RunningTask { uid: 3, tx: 10.0, started_at: 0.0, kind: None });
        assert!(ex.wait_until(ex.now() + 10_000.0));
        let batch = ex.drain_ready();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].uid, 3);
    }
}
