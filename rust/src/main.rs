//! asyncflow CLI — the workflow launcher.
//!
//! ```text
//! asyncflow experiment table3 [--seed N]
//! asyncflow experiment fig4|fig5|fig6 [--out results/]
//! asyncflow run --workflow ddmd|cdg1|cdg2 --mode seq|async|adaptive
//!               [--cluster summit_paper|summit_706|summit_8gpu]
//!               [--seed N] [--policy pipeline_age|fifo|fifo_strict|smallest_first]
//! asyncflow run --config configs/experiment.json --mode async
//! asyncflow predict --workflow ddmd|cdg1|cdg2 [--cluster ...]
//! asyncflow masking --workflow ddmd|cdg1|cdg2 [--cluster ...]
//! ```

use asyncflow::config;
use asyncflow::ddmd::{ddmd_workflow, DdmdConfig};
use asyncflow::engine::{simulate_cfg, EngineConfig, ExecutionMode};
use asyncflow::entk::Workflow;
use asyncflow::error::{Error, Result};
use asyncflow::experiments;
use asyncflow::metrics::ascii_timeline;
use asyncflow::model;
use asyncflow::obs::profile::EngineProfile;
use asyncflow::obs::{EventSink, FileSink};
use asyncflow::pilot::Policy;
use asyncflow::resources::ClusterSpec;
use asyncflow::traffic::TrafficObs;
use asyncflow::util::cli::Args;
use asyncflow::workflows::{cdg1, cdg2};

use std::cell::RefCell;
use std::rc::Rc;

fn main() {
    let args = match Args::from_env(&[
        "verbose", "ascii", "autoscale", "deny", "profile", "follow", "once",
    ]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("experiment") => cmd_experiment(args),
        Some("run") => cmd_run(args),
        Some("predict") => cmd_predict(args),
        Some("masking") => cmd_masking(args),
        Some("campaign") => cmd_campaign(args),
        Some("traffic") => cmd_traffic(args),
        Some("resilience") => cmd_resilience(args),
        Some("resume") => cmd_resume(args),
        Some("trace") => cmd_trace(args),
        Some("watch") => cmd_watch(args),
        Some("lint") => cmd_lint(args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "asyncflow — asynchronous execution of heterogeneous tasks \
(Pascuzzi et al. 2022 reproduction)

subcommands:
  experiment table3|fig4|fig5|fig6|all   regenerate a paper table/figure
  run      --workflow ddmd|cdg1|cdg2 --mode seq|async|adaptive
  predict  --workflow ...                analytical model only (Eqns 1-7)
  masking  --workflow ...                TX-masking report (Sec 5.3)
  campaign --workflows ddmd,cdg1,cdg2    workflow-level asynchronicity
           [--arrivals 0,300,600]        online mode: members share one
                                         pilot agent and arrive at the
                                         given offsets (seconds)
  traffic  --rate 0.02 --duration 20000  streaming workflow traffic on
           --mix ddmd:2,cdg2:1           one shared pilot: Poisson (or
           [--interval S] [--trace F]    fixed-interval / trace-driven)
           [--sweep 0.005,0.01,0.02]     arrivals drawn from a weighted
           [--jobs N]                    workload mix; reports wait/TTX
           [--max-workflows N]           percentiles, backlog, per-
           [--policy fifo|fair|backfill] workload waits + Jain fairness,
           [--resize T:+N,T:-N]          and the saturation verdict.
           [--autoscale]                 --sweep runs several rates to
           [--autoscale-min N]           find the knee (composes with
           [--autoscale-max N]           --autoscale*: the peak_c column
           [--autoscale-interval S]      shows how far each rate grew);
           [--autoscale-step N]          --jobs N runs the sweep's
                                         independent simulations on N
                                         threads (0 = all cores) with
                                         byte-identical output.
           [--checkpoint-at T]           --resize grows/drains pilot
           [--checkpoint-out F.json]     nodes at the given times
                                         (drains are graceful: running
                                         tasks finish first); --autoscale
                                         sizes the allocation from the
                                         backlog every interval seconds.
                                         --policy fair = per-driver
                                         weighted fair shares (no member
                                         starves late arrivals);
                                         backfill = conservative (never
                                         delays a blocked head).
                                         --checkpoint-at snapshots the
                                         whole simulation at T (a
                                         preemption) to --checkpoint-out.
                                         Catalog: ddmd ddmd-small cdg1
                                         cdg2 cdg1-small cdg2-small
                                         Failure injection (see the
                                         resilience subcommand) composes:
                                         --mtbf/--fail-trace/--retry add
                                         node faults to any traffic run.
  resilience --mtbf 50000               traffic under failure injection:
           [--gpu-factor 2]             each schedulable node fails with
           [--fail-trace 3600:0,7200:5] rate 1/MTBF (GPU nodes scaled by
           [--retry max:3,base:30,      --gpu-factor), --fail-trace
              factor:2,jitter:0.1]      replays explicit t:node
           [--rate/--interval/--trace   preemptions. A failure hard-kills
              /--duration/--mix/...]    the node's running tasks (partial
           [--checkpoint-every T]       work lost, vs the graceful
           [--sweep-cadence 300,1200,   --resize drain); victims retry
              3600]                     through the scheduler after
           [--checkpoint-cost C]        exponential backoff. The report
                                        gains a resilience ledger
                                        (failures, kills, retries,
                                        goodput vs lost core/GPU-time).
                                        --checkpoint-every T snapshots
                                        the whole simulation every T
                                        engine seconds, round-trips each
                                        snapshot through JSON and
                                        resumes it (the crash/resume
                                        soak). --sweep-cadence models
                                        checkpoint intervals against the
                                        failure rate (write cost
                                        --checkpoint-cost, default 60 s)
                                        and locates the optimum next to
                                        the Young/Daly sqrt(2*C*MTBF)
                                        reference.
  lint     [paths...]                    determinism-contract linter over
           [--deny]                      the crate's own sources (default
           [--format human|ndjson]       path: src). --deny exits non-zero
           [--config lint.conf]          on any finding; ndjson emits one
                                         JSON record per finding for CI
                                         artifacts. Rules: DET001 raw
                                         clock epsilons, DET002 hash-
                                         ordered collections, DET003
                                         wall-clock reads, SER001 one-way
                                         To/FromJson, SER002 snapshot
                                         schema fingerprint, PANIC001
                                         unwrap/expect budget. Suppress
                                         one line with
                                         `// lint:allow(RULE): reason`.
  resume   ckpt.json                     resume a preempted traffic run
           [--resize T:+N,T:-N]          from its checkpoint file; the
           [--autoscale ...]             optional plan reshapes the new
           [--out DIR] [--verbose]       pilot (times are absolute, so
                                         0:-4 shrinks at the resume
                                         instant) and the finished run
                                         prints the same report the
                                         uninterrupted one would have
  trace    events.ndjson                 asynchronicity analyzer over a
           [--format human|json]         --emit-events stream: replays
           [--out DIR]                   the typed events into per-kind
           [--render DIR]                concurrency timelines, the
                                         pairwise overlap matrix, the
                                         degree of asynchronicity vs the
                                         sequential-stage baseline, and
                                         utilization + wait/TTX
                                         percentiles reconstructed
                                         purely from the stream. --out
                                         writes trace_analysis.json plus
                                         trace_kinds.csv /
                                         trace_overlap.csv. --render
                                         writes self-contained SVGs
                                         (kind-overlap heatmap, per-kind
                                         concurrency timelines,
                                         utilization/backlog strip) and
                                         a Chrome trace (trace_chrome
                                         .json, open in Perfetto) —
                                         byte-identical per seed.
  watch    events.ndjson                 live terminal dashboard over an
           [--once] [--window S]         --emit-events stream: tails the
           [--interval S] [--follow]     file as the producer appends
                                         (partial trailing lines wait
                                         for their newline), rolling up
                                         arrival/start/completion rates,
                                         backlog + utilization
                                         sparklines, per-kind
                                         concurrency, and windowed
                                         wait/TTX percentiles over a
                                         trailing --window (default
                                         300 s) of *simulation* time.
                                         Repaints every --interval wall
                                         seconds (default 2). --once
                                         renders a single plain frame
                                         plus the exact TrafficReport
                                         headline reconstructed from the
                                         stream, then exits — the CI
                                         form (deterministic bytes).

common options:
  --cluster summit_paper|summit_706|summit_8gpu|local_small
  --seed N
  --policy pipeline_age|fifo|fifo_strict|smallest_first|fair|backfill
  --out DIR (figures)  --ascii (timeline art)
  --emit-events F.ndjson (traffic/resilience/resume: stream typed engine
    events as NDJSON — bit-identical per seed; analyze with trace)
  --profile (traffic/resilience/resume: engine lane counters + drain/
    scheduler wall-time histograms after the report)";

fn pick_workflow(args: &Args) -> Result<Workflow> {
    match args.get_or("workflow", "ddmd") {
        "ddmd" => Ok(ddmd_workflow(&DdmdConfig::paper())),
        "ddmd-small" => Ok(ddmd_workflow(&DdmdConfig::small())),
        "cdg1" => Ok(cdg1()),
        "cdg2" => Ok(cdg2()),
        other => {
            // Treat as a config file path.
            let (wf, _, _) = config::load_experiment(other)?;
            Ok(wf)
        }
    }
}

fn pick_cluster(args: &Args) -> Result<ClusterSpec> {
    match args.get_or("cluster", "summit_paper") {
        "summit_paper" => Ok(ClusterSpec::summit_paper()),
        "summit_706" => Ok(ClusterSpec::summit_706()),
        "summit_8gpu" => Ok(ClusterSpec::summit_8gpu()),
        "local_small" => Ok(ClusterSpec::local_small()),
        other => Err(Error::Config(format!("unknown cluster '{other}'"))),
    }
}

fn pick_engine(args: &Args) -> Result<EngineConfig> {
    let mut cfg = experiments::paper_engine_config(args.get_u64("seed", 42)?);
    cfg.policy = args.get_or("policy", "pipeline_age").parse::<Policy>()?;
    cfg.task_overhead = args.get_f64("task-overhead", cfg.task_overhead)?;
    cfg.stage_overhead = args.get_f64("stage-overhead", cfg.stage_overhead)?;
    Ok(cfg)
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let seed = args.get_u64("seed", 42)?;
    let out_dir = args.get("out").map(std::path::PathBuf::from);

    if which == "table3" || which == "all" {
        println!("# Table 3 (paper values in parentheses / right column)\n");
        let rows = experiments::run_table3(seed);
        println!("{}", experiments::render_table3(&rows));
        let problems = experiments::check_shapes(&rows);
        if problems.is_empty() {
            println!("shape check: OK (signs and magnitudes match the paper)");
        } else {
            println!("shape check: {problems:?}");
        }
    }
    let wfs = experiments::experiment_workflows();
    for (id, idx) in [("fig4", 0usize), ("fig5", 1), ("fig6", 2)] {
        if which == id || which == "all" {
            let (wf, cluster) = &wfs[idx];
            println!("\n# {id}: {} utilization timelines\n", wf.name);
            let art = experiments::run_figure(id, wf, cluster, seed, out_dir.as_deref())?;
            println!("{art}");
        }
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let (wf, cluster, mut cfg) = if let Some(path) = args.get("config") {
        config::load_experiment(path)?
    } else {
        (pick_workflow(args)?, pick_cluster(args)?, pick_engine(args)?)
    };
    if args.get("seed").is_some() {
        cfg.seed = args.get_u64("seed", cfg.seed)?;
    }
    let mode: ExecutionMode = args.get_or("mode", "async").parse()?;
    let rep = simulate_cfg(&wf, &cluster, mode, &cfg);
    println!(
        "workflow={} mode={} cluster={}\n  TTX       = {:.1} s\n  cpu util  = {:.1}%\n  gpu util  = {:.1}%\n  throughput= {:.3} tasks/s\n  DOA_res   = {}\n  tasks     = {} ({} failed)",
        rep.workflow,
        mode.label(),
        cluster.name,
        rep.makespan,
        rep.cpu_utilization * 100.0,
        rep.gpu_utilization * 100.0,
        rep.throughput,
        rep.doa_res,
        rep.records.len(),
        rep.failed_tasks,
    );
    if args.flag("ascii") {
        println!("{}", ascii_timeline(&rep.trace, 72, 6));
    }
    if let Some(dir) = args.get("out") {
        std::fs::create_dir_all(dir)?;
        let base = format!("{}_{}", rep.workflow.replace('/', "_"), mode.label());
        let p = std::path::Path::new(dir).join(format!("{base}.csv"));
        std::fs::write(&p, rep.trace.to_csv())?;
        let gantt = std::path::Path::new(dir).join(format!("{base}.trace.json"));
        std::fs::write(&gantt, asyncflow::metrics::chrome_trace(&rep))?;
        let rj = std::path::Path::new(dir).join(format!("{base}.report.json"));
        std::fs::write(&rj, asyncflow::metrics::report_to_json(&rep).to_string_pretty())?;
        println!("wrote {} (+ .trace.json for Perfetto, + .report.json)", p.display());
    }
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let wf = pick_workflow(args)?;
    let cluster = pick_cluster(args)?;
    let p = model::predict(&wf, &cluster);
    println!(
        "workflow={} cluster={}\n  DOA_dep  = {}\n  DOA_res  = {}\n  WLA      = {} (Eqn 1)\n  tSeq     = {:.0} s (Eqn 2 + overheads)\n  tAsync   = {:.0} s (Eqn 3 + overheads)\n  tAdaptive>= {:.0} s (critical path)\n  I        = {:+.3} (Eqn 5)",
        p.workflow, cluster.name, p.doa_dep, p.doa_res, p.wla, p.t_seq, p.t_async,
        p.t_adaptive_bound, p.improvement
    );
    if p.improvement <= 0.0 {
        println!("  verdict  : asynchronicity is NOT worth it for this workflow (cf. c-DG1)");
    } else {
        println!("  verdict  : asynchronous execution should pay off");
    }
    Ok(())
}

fn cmd_campaign(args: &Args) -> Result<()> {
    let names = args.get_or("workflows", "ddmd,cdg1,cdg2");
    let mut camp = asyncflow::campaign::Campaign::new("campaign");
    for n in names.split(',') {
        camp = camp.add(match n.trim() {
            "ddmd" => ddmd_workflow(&DdmdConfig::paper()),
            "cdg1" => cdg1(),
            "cdg2" => cdg2(),
            other => {
                let (wf, _, _) = config::load_experiment(other)?;
                wf
            }
        });
    }
    let cluster = pick_cluster(args)?;
    let cfg = pick_engine(args)?;

    // Online mode: one shared pilot agent, per-member arrival offsets.
    if let Some(spec) = args.get("arrivals") {
        let arrivals: Vec<f64> = spec
            .split(',')
            .map(|s| {
                s.trim().parse::<f64>().map_err(|_| {
                    Error::Config(format!("--arrivals: expected a number, got '{s}'"))
                })
            })
            .collect::<Result<_>>()?;
        let rep = camp.simulate_online(&arrivals, &cluster, &cfg)?;
        println!(
            "online campaign of {} workflows on {} (shared pilot, asynchronous members)",
            camp.members.len(),
            cluster.name
        );
        for (i, m) in rep.members.iter().enumerate() {
            println!(
                "  {:<16} arrival {:>6.0} s  finish {:>7.0} s  TTX {:>7.0} s  ({} tasks, {} failed)",
                m.workflow,
                rep.arrivals[i],
                m.makespan,
                rep.member_ttx(i),
                m.records.len(),
                m.failed_tasks
            );
        }
        println!(
            "  campaign TTX = {:.0} s (last finish {:.0} s), cpu {:.1}%, gpu {:.1}%, throughput {:.3} tasks/s",
            rep.campaign_ttx(),
            rep.campaign.makespan,
            rep.campaign.cpu_utilization * 100.0,
            rep.campaign.gpu_utilization * 100.0,
            rep.campaign.throughput
        );
        return Ok(());
    }

    let (seq, asy) = camp.simulate(&cluster, &cfg)?;
    println!(
        "campaign of {} workflows on {}\n  sequential (workflow-level BSP): TTX = {:.0} s, cpu {:.1}%, gpu {:.1}%\n  asynchronous (workflow-level):   TTX = {:.0} s, cpu {:.1}%, gpu {:.1}%\n  I = {:+.3}",
        camp.members.len(),
        cluster.name,
        seq.makespan,
        seq.cpu_utilization * 100.0,
        seq.gpu_utilization * 100.0,
        asy.makespan,
        asy.cpu_utilization * 100.0,
        asy.gpu_utilization * 100.0,
        asy.improvement_over(&seq)
    );
    Ok(())
}

/// Elastic-allocation plan from the shared CLI flags: timed `--resize`
/// events and/or the backlog-driven `--autoscale` policy (nodes added
/// have the shape of the cluster's first node). `default_max_nodes`
/// seeds `--autoscale-max` (traffic: 2x the initial cluster; resume:
/// 2x the checkpointed inventory).
fn plan_from_args(
    args: &Args,
    default_max_nodes: usize,
) -> Result<Option<asyncflow::pilot::ResourcePlan>> {
    use asyncflow::pilot::{AutoscalePolicy, ResourcePlan};
    let mut plan: Option<ResourcePlan> = match args.get("resize") {
        Some(spec) => Some(ResourcePlan::parse_resize(spec)?),
        None => None,
    };
    if args.flag("autoscale") {
        let defaults = AutoscalePolicy::default();
        let policy = AutoscalePolicy {
            interval: args.get_f64("autoscale-interval", defaults.interval)?,
            min_nodes: args.get_usize("autoscale-min", 1)?,
            max_nodes: args.get_usize("autoscale-max", default_max_nodes)?,
            step: args.get_usize("autoscale-step", defaults.step)?,
            ..defaults
        };
        plan = Some(plan.unwrap_or_default().with_autoscale(policy));
    }
    Ok(plan)
}

/// Failure-injection spec from the shared CLI flags (`--mtbf`,
/// `--gpu-factor`, `--fail-trace`, `--retry`), shared by `traffic` and
/// `resilience`; `None` when no fault source is configured.
fn failure_from_args(args: &Args) -> Result<Option<asyncflow::failure::FailureSpec>> {
    use asyncflow::failure::{FailureSpec, RetryPolicy};
    let mut spec = FailureSpec::default();
    if let Some(t) = args.get("fail-trace") {
        spec.trace = FailureSpec::parse_trace(t)?.trace;
    }
    if args.get("mtbf").is_some() {
        spec.mtbf = Some(args.get_f64("mtbf", 0.0)?);
    }
    if !spec.is_active() {
        if args.get("retry").is_some() || args.get("gpu-factor").is_some() {
            return Err(Error::Config(
                "--retry/--gpu-factor need a fault source (--mtbf S or --fail-trace t:node,...)"
                    .into(),
            ));
        }
        return Ok(None);
    }
    spec.gpu_factor = args.get_f64("gpu-factor", spec.gpu_factor)?;
    if let Some(r) = args.get("retry") {
        spec.retry = RetryPolicy::parse(r)?;
    }
    spec.validate()?;
    Ok(Some(spec))
}

/// Observability attachments from the shared CLI flags:
/// `--emit-events PATH` streams typed engine events to PATH as NDJSON,
/// `--profile` accumulates lane counters and hot-round wall-time
/// histograms. The handles are shared (`Rc`), so one stream and one
/// profile span every leg of a chained checkpoint/resume run; call
/// [`ObsCli::finish`] once the run ends to flush the stream (surfacing
/// any deferred I/O error) and print the profile.
struct ObsCli {
    path: Option<String>,
    sink: Option<Rc<RefCell<FileSink>>>,
    profile: Option<Rc<RefCell<EngineProfile>>>,
}

impl ObsCli {
    fn from_args(args: &Args) -> Result<ObsCli> {
        let path = args.get("emit-events").map(str::to_string);
        let sink = match &path {
            Some(p) => Some(Rc::new(RefCell::new(FileSink::create(p)?))),
            None => None,
        };
        let profile = args
            .flag("profile")
            .then(|| Rc::new(RefCell::new(EngineProfile::new())));
        Ok(ObsCli { path, sink, profile })
    }

    /// Whether any attachment is active (sweeps reject them: many runs,
    /// one stream/profile would interleave meaninglessly).
    fn active(&self) -> bool {
        self.sink.is_some() || self.profile.is_some()
    }

    /// Fresh per-leg attachments sharing this CLI's handles.
    fn leg(&self) -> TrafficObs {
        TrafficObs {
            sink: self
                .sink
                .as_ref()
                .map(|h| Box::new(Rc::clone(h)) as Box<dyn EventSink>),
            profile: self.profile.as_ref().map(Rc::clone),
        }
    }

    /// Flush the stream and print the profile, after the run. A
    /// latched stream-write error (disk full, deleted directory, ...)
    /// is surfaced *here*, after the report has printed: the run's
    /// numbers are still good, but the exit turns nonzero so CI never
    /// trusts a silently truncated stream.
    fn finish(&self) -> Result<()> {
        let mut stream_err = None;
        if let (Some(h), Some(p)) = (&self.sink, &self.path) {
            match h.borrow_mut().flush() {
                Ok(()) => {
                    println!("wrote {p} (event stream; analyze with: asyncflow trace {p})");
                }
                Err(e) => {
                    eprintln!("warning: event stream '{p}' is incomplete: {e}");
                    stream_err = Some(Error::Config(format!("--emit-events {p}: {e}")));
                }
            }
        }
        if let Some(p) = &self.profile {
            print!("{}", p.borrow().render());
        }
        match stream_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Print a finished traffic report and write the optional `--out`
/// artifacts (shared by `traffic`, `resilience`, and `resume`).
fn emit_traffic_report(args: &Args, rep: &asyncflow::traffic::TrafficReport) -> Result<()> {
    print!("{}", rep.render(args.flag("verbose")));
    if let Some(dir) = args.get("out") {
        std::fs::create_dir_all(dir)?;
        let base = std::path::Path::new(dir);
        let mut wrote = Vec::new();
        let bp = base.join("traffic_backlog.csv");
        std::fs::write(&bp, rep.backlog.to_csv())?;
        wrote.push(bp.display().to_string());
        let wp = base.join("traffic_waits.csv");
        std::fs::write(&wp, rep.waits_csv())?;
        wrote.push(wp.display().to_string());
        let fp = base.join("traffic_fairness.csv");
        std::fs::write(&fp, rep.fairness_csv())?;
        wrote.push(fp.display().to_string());
        let jp = base.join("traffic_report.json");
        std::fs::write(&jp, rep.to_json().to_string_pretty())?;
        wrote.push(jp.display().to_string());
        if rep.resilience.is_some() {
            let rp = base.join("traffic_resilience.csv");
            std::fs::write(&rp, rep.resilience_csv())?;
            wrote.push(rp.display().to_string());
        }
        if !rep.capacity.is_constant() {
            let cp = base.join("traffic_capacity.csv");
            std::fs::write(&cp, rep.capacity.to_csv())?;
            wrote.push(cp.display().to_string());
        }
        println!("wrote {}", wrote.join(", "));
    }
    Ok(())
}

fn cmd_traffic(args: &Args) -> Result<()> {
    use asyncflow::traffic::{
        load_trace_file, run_traffic_resumable_obs, run_traffic_sweep, sweep_csv, sweep_json,
        ArrivalProcess, Catalog, TrafficOutcome, TrafficSpec, WorkloadMix,
    };
    use asyncflow::util::json::ToJson;
    let cluster = pick_cluster(args)?;
    let cfg = pick_engine(args)?;
    let obs = ObsCli::from_args(args)?;
    let seed = args.get_u64("seed", 42)?;
    let duration = args.get_f64("duration", 20000.0)?;
    let mix = WorkloadMix::parse(args.get_or("mix", "ddmd:2,cdg2:1"))?;
    let max_workflows = args.get_usize("max-workflows", 10_000)?;
    let catalog = Catalog::builtin();
    let plan = plan_from_args(args, cluster.nodes.len().max(1) * 2)?;

    // Preemption point: --checkpoint-at T snapshots the simulation at
    // engine time T and writes it to --checkpoint-out (default
    // ckpt.json) instead of finishing the run.
    let checkpoint_at = match args.get("checkpoint-at") {
        Some(_) => Some(args.get_f64("checkpoint-at", 0.0)?),
        None => None,
    };
    if checkpoint_at.is_none() && args.get("checkpoint-out").is_some() {
        return Err(Error::Config(
            "--checkpoint-out requires --checkpoint-at (nothing would be snapshotted)"
                .into(),
        ));
    }

    // The --policy flag is already folded into the engine config by
    // pick_engine; recording it on the spec too makes the spec fully
    // self-describing (and is what the test matrices vary).
    let policy = match args.get("policy") {
        Some(p) => Some(p.parse::<asyncflow::sched::Policy>()?),
        None => None,
    };
    let failure = failure_from_args(args)?;
    let spec_for = |process: ArrivalProcess| TrafficSpec {
        process,
        mix: mix.clone(),
        duration,
        max_workflows,
        seed,
        plan: plan.clone(),
        checkpoint_at,
        policy,
        failure: failure.clone(),
    };

    // Rate sweep: one run per rate, tabulated to expose the saturation
    // knee (bounded wait/backlog below it, growing backlog above it).
    if let Some(rates) = args.get("sweep") {
        if checkpoint_at.is_some() {
            return Err(Error::Config(
                "--checkpoint-at does not combine with --sweep (one checkpoint, one run)"
                    .into(),
            ));
        }
        if obs.active() {
            return Err(Error::Config(
                "--emit-events/--profile do not combine with --sweep (one stream, one run)"
                    .into(),
            ));
        }
        let rates: Vec<f64> = rates
            .split(',')
            .map(|s| {
                s.trim().parse::<f64>().map_err(|_| {
                    Error::Config(format!("--sweep: expected a number, got '{s}'"))
                })
            })
            .collect::<Result<_>>()?;
        // --jobs N shards the independent per-rate simulations across N
        // threads (0 = one per core); the reports — and any CSV/JSON
        // written below — are byte-identical to the serial runner's.
        let jobs = args.get_usize("jobs", 1)?;
        println!(
            "traffic sweep on {} (mix {}, window {:.0} s, seed {seed}, jobs {})\n",
            cluster.name,
            args.get_or("mix", "ddmd:2,cdg2:1"),
            duration,
            if jobs == 0 { "auto".to_string() } else { jobs.to_string() },
        );
        let specs: Vec<_> = rates
            .iter()
            .map(|&rate| spec_for(ArrivalProcess::Poisson { rate }))
            .collect();
        let reports = run_traffic_sweep(&specs, &catalog, &cluster, &cfg, jobs)?;
        println!(
            "{:>9} {:>6} {:>10} {:>10} {:>10} {:>12} {:>8} {:>7}  verdict",
            "rate/s", "wf", "wait_mean", "ttx_p50", "ttx_p95", "backlog_mean", "growth", "peak_c"
        );
        for (rate, rep) in rates.iter().zip(&reports) {
            // peak_c exposes how far an --autoscale'd sweep actually
            // grew at each rate (constant for fixed-pilot sweeps).
            println!(
                "{:>9.4} {:>6} {:>10.1} {:>10.1} {:>10.1} {:>12.1} {:>7.2}x {:>7}  {}",
                rate,
                rep.workflows.len(),
                rep.wait.mean,
                rep.ttx.p50,
                rep.ttx.p95,
                rep.mean_backlog_tasks,
                rep.backlog_growth(),
                rep.capacity.peak().0,
                if rep.is_saturated() { "SATURATED" } else { "bounded" },
            );
        }
        if let Some(dir) = args.get("out") {
            std::fs::create_dir_all(dir)?;
            let base = std::path::Path::new(dir);
            let cp = base.join("traffic_sweep.csv");
            std::fs::write(&cp, sweep_csv(&rates, &reports))?;
            let jp = base.join("traffic_sweep.json");
            std::fs::write(&jp, sweep_json(&rates, &reports).to_string_pretty())?;
            println!("\nwrote {}, {}", cp.display(), jp.display());
        }
        return Ok(());
    }

    let process = if let Some(path) = args.get("trace") {
        load_trace_file(path)?
    } else if args.get("interval").is_some() {
        ArrivalProcess::Deterministic { interval: args.get_f64("interval", 0.0)? }
    } else {
        ArrivalProcess::Poisson { rate: args.get_f64("rate", 0.02)? }
    };
    match run_traffic_resumable_obs(&spec_for(process), &catalog, &cluster, &cfg, obs.leg())? {
        TrafficOutcome::Completed(rep) => {
            if checkpoint_at.is_some() {
                println!(
                    "note: the run finished before the checkpoint time; no snapshot taken"
                );
            }
            emit_traffic_report(args, &rep)?;
        }
        TrafficOutcome::Checkpointed(ck) => {
            let path = args.get_or("checkpoint-out", "ckpt.json");
            std::fs::write(path, ck.to_json().to_string_pretty())?;
            println!(
                "checkpointed at t = {:.1} s: {} live / {} finished / {} pending \
                 workflows, {} running + {} queued tasks",
                ck.sim.now,
                ck.sim.drivers.len(),
                ck.sim.finished.len(),
                ck.sim.pending.len(),
                ck.sim.running.len(),
                ck.sim.queue.len(),
            );
            println!("wrote {path} — resume with: asyncflow resume {path}");
        }
    }
    obs.finish()
}

fn cmd_resilience(args: &Args) -> Result<()> {
    use asyncflow::failure::cadence::{cluster_fault_rate, run_chained_obs, sweep_cadence};
    use asyncflow::traffic::{
        load_trace_file, run_traffic_resumable, run_traffic_resumable_obs, ArrivalProcess,
        Catalog, TrafficOutcome, TrafficSpec, WorkloadMix,
    };
    let cluster = pick_cluster(args)?;
    let cfg = pick_engine(args)?;
    let obs = ObsCli::from_args(args)?;
    let seed = args.get_u64("seed", 42)?;
    let duration = args.get_f64("duration", 20000.0)?;
    let mix = WorkloadMix::parse(args.get_or("mix", "ddmd:2,cdg2:1"))?;
    let max_workflows = args.get_usize("max-workflows", 10_000)?;
    let catalog = Catalog::builtin();
    let plan = plan_from_args(args, cluster.nodes.len().max(1) * 2)?;
    let failure = failure_from_args(args)?.ok_or_else(|| {
        Error::Config(
            "resilience: provide a fault source (--mtbf S and/or --fail-trace t:node,...)"
                .into(),
        )
    })?;
    let policy = match args.get("policy") {
        Some(p) => Some(p.parse::<asyncflow::sched::Policy>()?),
        None => None,
    };
    let process = if let Some(path) = args.get("trace") {
        load_trace_file(path)?
    } else if args.get("interval").is_some() {
        ArrivalProcess::Deterministic { interval: args.get_f64("interval", 0.0)? }
    } else {
        ArrivalProcess::Poisson { rate: args.get_f64("rate", 0.02)? }
    };
    let spec = TrafficSpec {
        process,
        mix,
        duration,
        max_workflows,
        seed,
        plan,
        checkpoint_at: None,
        policy,
        failure: Some(failure.clone()),
    };

    let every = match args.get("checkpoint-every") {
        Some(_) => Some(args.get_f64("checkpoint-every", 0.0)?),
        None => None,
    };
    if every.is_some() && args.get("sweep-cadence").is_some() {
        return Err(Error::Config(
            "--checkpoint-every and --sweep-cadence are exclusive (chain real \
             snapshots, or model the cadence — not both)"
                .into(),
        ));
    }

    // Cadence sweep: a failure-free baseline run supplies the work to
    // protect; the analytic overlay injects the faults per cadence.
    if let Some(list) = args.get("sweep-cadence") {
        if obs.active() {
            return Err(Error::Config(
                "--emit-events/--profile do not combine with --sweep-cadence (the \
                 sweep is analytic; its baseline run is not the observed scenario)"
                    .into(),
            ));
        }
        let cadences: Vec<f64> = list
            .split(',')
            .map(|s| {
                s.trim().parse::<f64>().map_err(|_| {
                    Error::Config(format!("--sweep-cadence: expected a number, got '{s}'"))
                })
            })
            .collect::<Result<_>>()?;
        let rate = cluster_fault_rate(&cluster, &failure);
        if rate <= 0.0 {
            return Err(Error::Config(
                "--sweep-cadence needs the stochastic fault process: set --mtbf".into(),
            ));
        }
        let cost = args.get_f64("checkpoint-cost", 60.0)?;
        let baseline = TrafficSpec { failure: None, ..spec };
        let rep = match run_traffic_resumable(&baseline, &catalog, &cluster, &cfg)? {
            TrafficOutcome::Completed(rep) => rep,
            TrafficOutcome::Checkpointed(_) => {
                return Err(Error::Engine(
                    "resilience sweep: baseline run cannot checkpoint".into(),
                ))
            }
        };
        let sw = sweep_cadence(rep.makespan, rate, cost, &cadences, seed)?;
        print!("{}", sw.render());
        if let Some(dir) = args.get("out") {
            std::fs::create_dir_all(dir)?;
            let base = std::path::Path::new(dir);
            let cp = base.join("resilience_cadence.csv");
            std::fs::write(&cp, sw.csv())?;
            let jp = base.join("resilience_cadence.json");
            std::fs::write(&jp, sw.to_json().to_string_pretty())?;
            println!("wrote {}, {}", cp.display(), jp.display());
        }
        return Ok(());
    }

    if let Some(every) = every {
        // Every leg re-attaches the same shared sink/profile handles,
        // so the emitted stream spans the whole chained run.
        let (rep, legs) =
            run_chained_obs(&spec, &catalog, &cluster, &cfg, every, || obs.leg())?;
        println!(
            "resilience: chained {legs} checkpoint legs (every {every:.0} s, each leg \
             resumed from its JSON snapshot)"
        );
        emit_traffic_report(args, &rep)?;
        return obs.finish();
    }

    match run_traffic_resumable_obs(&spec, &catalog, &cluster, &cfg, obs.leg())? {
        TrafficOutcome::Completed(rep) => {
            emit_traffic_report(args, &rep)?;
            obs.finish()
        }
        TrafficOutcome::Checkpointed(_) => Err(Error::Engine(
            "resilience: run without a checkpoint time cannot checkpoint".into(),
        )),
    }
}

fn cmd_resume(args: &Args) -> Result<()> {
    use asyncflow::traffic::{TrafficCheckpoint, TrafficOutcome};
    use asyncflow::util::json::{FromJson, Json};
    let obs = ObsCli::from_args(args)?;
    let path = args.positional.get(1).map(|s| s.as_str()).ok_or_else(|| {
        Error::Config("resume: expected a checkpoint file (asyncflow resume ckpt.json)".into())
    })?;
    let src = std::fs::read_to_string(path)
        .map_err(|e| Error::Config(format!("resume: cannot read '{path}': {e}")))?;
    let ck = TrafficCheckpoint::from_json(&Json::parse(&src)?)?;
    let nodes = ck.sim.nodes.len().max(1);
    let plan = plan_from_args(args, nodes * 2)?;
    println!(
        "resuming from {path}: t = {:.1} s, {} members ({} live, {} pending), \
         {} running + {} queued tasks{}",
        ck.sim.now,
        ck.sim.n_members,
        ck.sim.drivers.len(),
        ck.sim.pending.len(),
        ck.sim.running.len(),
        ck.sim.queue.len(),
        if plan.is_some() { ", new resource plan attached" } else { "" },
    );
    // Resumed streams intentionally start without a fresh capacity
    // record: the pre-checkpoint stream already carries it, so the
    // concatenation equals the uninterrupted run's stream.
    let rep = match ck.resume_until_obs(plan, None, obs.leg())? {
        TrafficOutcome::Completed(rep) => *rep,
        TrafficOutcome::Checkpointed(_) => {
            return Err(Error::Engine(
                "traffic resume: run without a checkpoint time cannot re-checkpoint".into(),
            ))
        }
    };
    emit_traffic_report(args, &rep)?;
    obs.finish()
}

fn cmd_trace(args: &Args) -> Result<()> {
    use asyncflow::metrics::chrome_trace_records;
    use asyncflow::obs::render::{kind_timeline_svg, overlap_heatmap_svg, util_backlog_svg};
    use asyncflow::obs::trace::{analyze_replayed, parse_stream, replay};
    let path = args.positional.get(1).map(|s| s.as_str()).ok_or_else(|| {
        Error::Config(
            "trace: expected an event stream (asyncflow trace events.ndjson)".into(),
        )
    })?;
    let src = std::fs::read_to_string(path)
        .map_err(|e| Error::Config(format!("trace: cannot read '{path}': {e}")))?;
    let events = parse_stream(&src)?;
    let run = replay(&events)?;
    let analysis = analyze_replayed(&run)?;
    match args.get_or("format", "human") {
        "human" => print!("{}", analysis.render()),
        "json" => println!("{}", analysis.to_json().to_string_pretty()),
        other => {
            return Err(Error::Config(format!(
                "trace: unknown --format '{other}' (human|json)"
            )))
        }
    }
    if let Some(dir) = args.get("out") {
        std::fs::create_dir_all(dir)?;
        let base = std::path::Path::new(dir);
        let jp = base.join("trace_analysis.json");
        std::fs::write(&jp, analysis.to_json().to_string_pretty())?;
        let kp = base.join("trace_kinds.csv");
        std::fs::write(&kp, analysis.kinds_csv())?;
        let op = base.join("trace_overlap.csv");
        std::fs::write(&op, analysis.overlap_csv())?;
        println!("wrote {}, {}, {}", jp.display(), kp.display(), op.display());
    }
    // --render DIR: deterministic SVG figures + a Chrome trace, all
    // reconstructed purely from the stream (byte-identical per seed).
    if let Some(dir) = args.get("render") {
        std::fs::create_dir_all(dir)?;
        let base = std::path::Path::new(dir);
        let hp = base.join("trace_overlap.svg");
        std::fs::write(&hp, overlap_heatmap_svg(&analysis))?;
        let kp = base.join("trace_kinds.svg");
        std::fs::write(&kp, kind_timeline_svg(&run))?;
        let up = base.join("trace_util.svg");
        std::fs::write(&up, util_backlog_svg(&run))?;
        let cp = base.join("trace_chrome.json");
        std::fs::write(&cp, chrome_trace_records(&run.records, "slot"))?;
        println!(
            "wrote {}, {}, {}, {}",
            hp.display(),
            kp.display(),
            up.display(),
            cp.display()
        );
    }
    Ok(())
}

fn cmd_watch(args: &Args) -> Result<()> {
    use asyncflow::obs::tail::TailParser;
    use asyncflow::obs::watch::{follow, watch_once};
    let path = args.positional.get(1).map(|s| s.as_str()).ok_or_else(|| {
        Error::Config(
            "watch: expected an event stream (asyncflow watch events.ndjson [--once])"
                .into(),
        )
    })?;
    let window = args.get_f64("window", 300.0)?;
    if !args.flag("once") {
        // Live mode (the default; --follow spells it out): tail the
        // growing file and repaint every --interval wall seconds.
        let interval = args.get_f64("interval", 2.0)?;
        return follow(std::path::Path::new(path), window, interval, None);
    }
    // --once: one plain frame + headline, then exit — the CI form.
    // Reading through the tail parser tolerates a mid-write trailing
    // line, so `watch --once` is safe against a live stream too.
    let bytes = std::fs::read(path)
        .map_err(|e| Error::Config(format!("watch: cannot read '{path}': {e}")))?;
    let mut events = Vec::new();
    let mut parser = TailParser::new();
    parser.feed(&bytes, &mut events)?;
    if let Err(e) = parser.finish(&mut events) {
        eprintln!("warning: ignoring truncated trailing line: {e}");
    }
    print!("{}", watch_once(&events, path, window));
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    use asyncflow::lint::{lint_paths, LintConfig};
    // Config: --config FILE wins; otherwise ./lint.conf when present
    // (the repo's budgets live there); otherwise built-in defaults.
    let cfg = match args.get("config") {
        Some(p) => LintConfig::load(std::path::Path::new(p))?,
        None => {
            let default = std::path::Path::new("lint.conf");
            if default.exists() {
                LintConfig::load(default)?
            } else {
                LintConfig::default()
            }
        }
    };
    let paths: Vec<String> = if args.positional.len() > 1 {
        args.positional[1..].to_vec()
    } else {
        vec!["src".to_string()]
    };
    let findings = lint_paths(&paths, &cfg)?;
    match args.get_or("format", "human") {
        "ndjson" => {
            for f in &findings {
                println!("{}", f.to_json());
            }
        }
        "human" => {
            for f in &findings {
                println!("{}", f.render_human());
            }
            if findings.is_empty() {
                println!("lint: clean");
            } else {
                println!("lint: {} finding(s)", findings.len());
            }
        }
        other => {
            return Err(Error::Config(format!(
                "lint: unknown --format '{other}' (human|ndjson)"
            )))
        }
    }
    if args.flag("deny") && !findings.is_empty() {
        return Err(Error::Config(format!(
            "lint: {} finding(s) (--deny)",
            findings.len()
        )));
    }
    Ok(())
}

fn cmd_masking(args: &Args) -> Result<()> {
    let wf = pick_workflow(args)?;
    let cluster = pick_cluster(args)?;
    let r = model::masking_report(&wf, &cluster);
    println!(
        "critical path = {:.0} s; masked TX = {:.0} s across {} sets",
        r.critical_path,
        r.masked_seconds,
        r.sets.iter().filter(|s| s.masked).count()
    );
    for s in &r.sets {
        println!(
            "  {:<10} dur={:>7.1}s start={:>7.1} finish={:>7.1} slack={:>7.1} {}",
            s.set_name,
            s.duration,
            s.start,
            s.finish,
            s.slack,
            if s.masked { "MASKED" } else { "critical" }
        );
    }
    Ok(())
}
