//! Pilot-job runtime (substrate S12), modeled on RADICAL-Pilot.
//!
//! The pilot owns the allocation ([`Allocator`]) and runs a *continuous
//! scheduler*: whenever resources change (task completion) or new tasks
//! arrive, it walks the ready queue in policy order and places every
//! task that fits. Backfill (placing a later task past a blocked head)
//! is what lets CPU-only Aggregation tasks slide in beside GPU-saturated
//! Simulation sets — the mechanism behind the paper's TX masking.

mod scheduler;

pub use scheduler::{Policy, QueuedTask, ScheduledTask, Scheduler};

use crate::resources::{Allocator, ClusterSpec, Placement};
use crate::task::TaskSpec;

/// The pilot agent: allocation + scheduler queue.
///
/// The engine drives it: `submit` when dependencies resolve, `schedule`
/// after every state change, `complete` when the executor reports a
/// task done.
#[derive(Debug)]
pub struct Agent {
    alloc: Allocator,
    sched: Scheduler,
    running: Vec<Option<Placement>>, // uid -> placement
}

impl Agent {
    pub fn new(cluster: &ClusterSpec, policy: Policy) -> Agent {
        Agent {
            alloc: Allocator::new(cluster),
            sched: Scheduler::new(policy),
            running: Vec::new(),
        }
    }

    pub fn allocator(&self) -> &Allocator {
        &self.alloc
    }

    pub fn queue_len(&self) -> usize {
        self.sched.queue_len()
    }

    /// Enqueue a ready task (dependencies already satisfied).
    pub fn submit(&mut self, task: &TaskSpec, priority: u64, submitted_at: f64) {
        self.sched.push(QueuedTask {
            uid: task.uid,
            req: task.req,
            priority,
            submitted_at,
        });
    }

    /// Place every queued task that fits, in policy order. Returns the
    /// uids scheduled this round.
    pub fn schedule(&mut self) -> Vec<ScheduledTask> {
        let placed = self.sched.drain_schedulable(&mut self.alloc);
        for s in &placed {
            if self.running.len() <= s.uid {
                self.running.resize(s.uid + 1, None);
            }
            self.running[s.uid] = Some(s.placement.clone());
        }
        placed
    }

    /// Release a completed task's resources.
    pub fn complete(&mut self, uid: usize) {
        let p = self.running[uid]
            .take()
            .expect("complete() for a task that is not running");
        self.alloc.release(&p);
    }

    /// Number of currently running (placed) tasks.
    pub fn running_count(&self) -> usize {
        self.running.iter().filter(|p| p.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::ResourceRequest;
    use crate::task::{TaskKind, TaskSpec};

    fn task(uid: usize, cores: u32, gpus: u32) -> TaskSpec {
        TaskSpec {
            uid,
            set_idx: 0,
            ordinal: 0,
            tx: 1.0,
            req: ResourceRequest::new(cores, gpus),
            kind: TaskKind::Stress,
        }
    }

    #[test]
    fn agent_schedules_and_completes() {
        let cluster = ClusterSpec::uniform("t", 1, 4, 1);
        let mut agent = Agent::new(&cluster, Policy::default());
        agent.submit(&task(0, 2, 0), 0, 0.0);
        agent.submit(&task(1, 2, 0), 0, 0.0);
        agent.submit(&task(2, 2, 0), 0, 0.0); // won't fit yet
        let placed = agent.schedule();
        assert_eq!(placed.len(), 2);
        assert_eq!(agent.queue_len(), 1);
        assert_eq!(agent.running_count(), 2);
        agent.complete(0);
        let placed = agent.schedule();
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 2);
    }

    #[test]
    fn backfill_lets_small_tasks_pass_blocked_head() {
        let cluster = ClusterSpec::uniform("t", 1, 4, 1);
        let mut agent = Agent::new(&cluster, Policy::default());
        // Occupy the GPU.
        agent.submit(&task(0, 1, 1), 0, 0.0);
        assert_eq!(agent.schedule().len(), 1);
        // Head of queue needs the GPU; behind it a CPU-only task.
        agent.submit(&task(1, 1, 1), 1, 1.0);
        agent.submit(&task(2, 1, 0), 2, 2.0);
        let placed = agent.schedule();
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 2, "CPU task backfills past blocked GPU task");
    }

    #[test]
    #[should_panic(expected = "not running")]
    fn double_complete_panics() {
        let cluster = ClusterSpec::uniform("t", 1, 4, 1);
        let mut agent = Agent::new(&cluster, Policy::default());
        agent.submit(&task(0, 1, 0), 0, 0.0);
        agent.schedule();
        agent.complete(0);
        agent.complete(0);
    }
}
