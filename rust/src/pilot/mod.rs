//! Pilot-job runtime (substrate S12), modeled on RADICAL-Pilot.
//!
//! The pilot owns the allocation ([`Allocator`]) and runs a *continuous
//! scheduler*: whenever resources change (task completion) or new tasks
//! arrive, it walks the ready queue in policy order and places every
//! task that fits. Backfill (placing a later task past a blocked head)
//! is what lets CPU-only Aggregation tasks slide in beside GPU-saturated
//! Simulation sets — the mechanism behind the paper's TX masking.

mod elastic;
mod scheduler;

pub use elastic::{AutoscalePolicy, ResizeEvent, ResourcePlan};
pub use scheduler::{Policy, QueuedTask, ScheduledTask, Scheduler};

use crate::resources::{Allocator, ClusterSpec, NodeSpec, Placement};
use crate::task::TaskSpec;

/// The pilot agent: allocation + scheduler queue.
///
/// The engine drives it: `submit` when dependencies resolve, `schedule`
/// after every state change, `complete` when the executor reports a
/// task done.
#[derive(Debug)]
pub struct Agent {
    alloc: Allocator,
    sched: Scheduler,
    running: Vec<Option<Placement>>, // uid -> placement
}

impl Agent {
    pub fn new(cluster: &ClusterSpec, policy: Policy) -> Agent {
        Agent {
            alloc: Allocator::new(cluster),
            sched: Scheduler::new(policy),
            running: Vec::new(),
        }
    }

    /// Rebuild an agent from checkpointed parts: an allocator with the
    /// snapshot occupancy already claimed, a scheduler queue re-pushed
    /// in insertion order, and the uid -> placement table of running
    /// tasks.
    pub(crate) fn from_parts(
        alloc: Allocator,
        sched: Scheduler,
        running: Vec<Option<Placement>>,
    ) -> Agent {
        Agent { alloc, sched, running }
    }

    pub fn allocator(&self) -> &Allocator {
        &self.alloc
    }

    /// Queued (unplaced) tasks in insertion order (checkpointing).
    pub fn queued_tasks(&self) -> &[QueuedTask] {
        self.sched.queued()
    }

    /// `(uid, placement)` of every running task, ascending by uid
    /// (checkpointing).
    pub fn running_placements(&self) -> Vec<(usize, Placement)> {
        self.running
            .iter()
            .enumerate()
            .filter_map(|(uid, p)| p.as_ref().map(|p| (uid, p.clone())))
            .collect()
    }

    pub fn queue_len(&self) -> usize {
        self.sched.queue_len()
    }

    /// Enqueue a ready task (dependencies already satisfied).
    pub fn submit(&mut self, task: &TaskSpec, priority: u64, submitted_at: f64) {
        self.sched.push(QueuedTask {
            uid: task.uid,
            req: task.req,
            priority,
            submitted_at,
        });
    }

    /// Place every queued task that fits, in policy order. Returns the
    /// uids scheduled this round.
    pub fn schedule(&mut self) -> Vec<ScheduledTask> {
        let placed = self.sched.drain_schedulable(&mut self.alloc);
        for s in &placed {
            if self.running.len() <= s.uid {
                self.running.resize(s.uid + 1, None);
            }
            self.running[s.uid] = Some(s.placement.clone());
        }
        placed
    }

    /// Release a completed task's resources.
    pub fn complete(&mut self, uid: usize) {
        let p = self.running[uid]
            .take()
            .expect("complete() for a task that is not running");
        self.alloc.release(&p);
    }

    /// Number of currently running (placed) tasks.
    pub fn running_count(&self) -> usize {
        self.running.iter().filter(|p| p.is_some()).count()
    }

    /// Grow the allocation by `n` nodes of the given shape. Draining
    /// nodes of the *same* shape are reclaimed first (newest first) —
    /// an oscillating autoscaler reuses capacity instead of leaking
    /// zombie node slots — and fresh nodes are appended for the rest.
    /// Returns `n`.
    pub fn grow(&mut self, n: usize, node: NodeSpec) -> usize {
        let mut added = 0;
        for i in (0..self.alloc.node_count()).rev() {
            if added == n {
                break;
            }
            if self.alloc.is_draining(i) && self.alloc.spec().nodes[i] == node {
                self.alloc.undrain_node(i).expect("draining node undrains");
                added += 1;
            }
        }
        while added < n {
            self.alloc.add_node(node);
            added += 1;
        }
        added
    }

    /// Gracefully drain up to `n` nodes: the least-busy schedulable
    /// nodes stop accepting work immediately; tasks already on them run
    /// to completion, and their resources then leave the allocation.
    /// Returns how many nodes actually started draining.
    pub fn drain(&mut self, n: usize) -> usize {
        let picks = self.alloc.drain_candidates(n);
        for &i in &picks {
            self.alloc.drain_node(i).expect("candidate is schedulable");
        }
        picks.len()
    }

    /// `(cores, gpus)` of schedulable capacity (draining nodes excluded).
    pub fn capacity(&self) -> (u64, u64) {
        (self.alloc.capacity_cores(), self.alloc.capacity_gpus())
    }

    /// `(cores, gpus)` of *offered* capacity: schedulable capacity plus
    /// resources still occupied on draining nodes (see
    /// [`Allocator::offered_cores`]) — the utilization denominator.
    pub fn offered(&self) -> (u64, u64) {
        (self.alloc.offered_cores(), self.alloc.offered_gpus())
    }

    /// `(cores, gpus)` currently free.
    pub fn free(&self) -> (u64, u64) {
        (self.alloc.free_cores(), self.alloc.free_gpus())
    }

    /// Number of nodes accepting placements.
    pub fn schedulable_nodes(&self) -> usize {
        self.alloc.schedulable_nodes()
    }

    /// `(cores, gpus)` requested by the queued (unplaced) tasks — the
    /// backlog pressure signal the autoscaler scales on.
    pub fn queued_demand(&self) -> (u64, u64) {
        self.sched.queued_demand()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::ResourceRequest;
    use crate::task::{TaskKind, TaskSpec};

    fn task(uid: usize, cores: u32, gpus: u32) -> TaskSpec {
        TaskSpec {
            uid,
            set_idx: 0,
            ordinal: 0,
            tx: 1.0,
            req: ResourceRequest::new(cores, gpus),
            kind: TaskKind::Stress,
        }
    }

    #[test]
    fn agent_schedules_and_completes() {
        let cluster = ClusterSpec::uniform("t", 1, 4, 1);
        let mut agent = Agent::new(&cluster, Policy::default());
        agent.submit(&task(0, 2, 0), 0, 0.0);
        agent.submit(&task(1, 2, 0), 0, 0.0);
        agent.submit(&task(2, 2, 0), 0, 0.0); // won't fit yet
        let placed = agent.schedule();
        assert_eq!(placed.len(), 2);
        assert_eq!(agent.queue_len(), 1);
        assert_eq!(agent.running_count(), 2);
        agent.complete(0);
        let placed = agent.schedule();
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 2);
    }

    #[test]
    fn backfill_lets_small_tasks_pass_blocked_head() {
        let cluster = ClusterSpec::uniform("t", 1, 4, 1);
        let mut agent = Agent::new(&cluster, Policy::default());
        // Occupy the GPU.
        agent.submit(&task(0, 1, 1), 0, 0.0);
        assert_eq!(agent.schedule().len(), 1);
        // Head of queue needs the GPU; behind it a CPU-only task.
        agent.submit(&task(1, 1, 1), 1, 1.0);
        agent.submit(&task(2, 1, 0), 2, 2.0);
        let placed = agent.schedule();
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 2, "CPU task backfills past blocked GPU task");
    }

    #[test]
    #[should_panic(expected = "not running")]
    fn double_complete_panics() {
        let cluster = ClusterSpec::uniform("t", 1, 4, 1);
        let mut agent = Agent::new(&cluster, Policy::default());
        agent.submit(&task(0, 1, 0), 0, 0.0);
        agent.schedule();
        agent.complete(0);
        agent.complete(0);
    }

    #[test]
    fn drain_finishes_running_work_and_blocks_new() {
        let cluster = ClusterSpec::uniform("t", 2, 2, 1);
        let mut agent = Agent::new(&cluster, Policy::default());
        // Fill both nodes with one GPU task each.
        agent.submit(&task(0, 1, 1), 0, 0.0);
        agent.submit(&task(1, 1, 1), 0, 0.0);
        let placed = agent.schedule();
        assert_eq!(placed.len(), 2);
        // Drain one node (both equally busy: newest index drains).
        assert_eq!(agent.drain(1), 1);
        assert_eq!(agent.schedulable_nodes(), 1);
        assert_eq!(agent.capacity(), (2, 1));
        // A new GPU task cannot fit anywhere (survivor's GPU is busy).
        agent.submit(&task(2, 1, 1), 0, 1.0);
        assert!(agent.schedule().is_empty());
        assert_eq!(agent.queued_demand(), (1, 1));
        // The draining node's task completes; its resources vanish, the
        // queued task still waits for the survivor's GPU.
        let drained_node = placed
            .iter()
            .flat_map(|s| s.placement.slots.iter())
            .map(|&(i, _, _)| i)
            .find(|&i| agent.allocator().is_draining(i))
            .expect("one placement sits on the draining node");
        let victim = placed
            .iter()
            .find(|s| s.placement.slots[0].0 == drained_node)
            .unwrap()
            .uid;
        agent.complete(victim);
        assert!(agent.allocator().node_idle(drained_node));
        assert!(agent.schedule().is_empty(), "drained GPU must not be re-granted");
        // The survivor's task completes: now the queued task runs.
        agent.complete(1 - victim);
        let placed = agent.schedule();
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 2);
        assert_ne!(placed[0].placement.slots[0].0, drained_node);
    }

    #[test]
    fn grow_reclaims_draining_nodes_before_appending() {
        let cluster = ClusterSpec::uniform("t", 2, 4, 0);
        let mut agent = Agent::new(&cluster, Policy::default());
        assert_eq!(agent.drain(1), 1);
        assert_eq!(agent.schedulable_nodes(), 1);
        let shape = cluster.nodes[0];
        // Grow by 2: one reclaimed, one appended.
        assert_eq!(agent.grow(2, shape), 2);
        assert_eq!(agent.schedulable_nodes(), 3);
        assert_eq!(agent.allocator().node_count(), 3, "exactly one node appended");
        assert_eq!(agent.capacity(), (12, 0));
        // Different-shape growth never reclaims.
        agent.drain(1);
        agent.grow(1, crate::resources::NodeSpec { cores: 16, gpus: 2 });
        assert_eq!(agent.allocator().node_count(), 4);
        assert_eq!(agent.schedulable_nodes(), 3);
    }
}
