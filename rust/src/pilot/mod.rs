//! Pilot-job runtime (substrate S12), modeled on RADICAL-Pilot.
//!
//! The pilot owns the allocation ([`Allocator`]) and runs a *continuous
//! scheduler*: whenever resources change (task completion) or new tasks
//! arrive, it drains the ready queue in policy order and places every
//! task the active discipline admits. The queue and the disciplines
//! live in the [`sched`](crate::sched) subsystem (shape-bucketed ready
//! queue + pluggable [`SchedPolicy`](crate::sched::SchedPolicy)
//! implementations); the [`Agent`] is the glue that binds one scheduler
//! to one allocation and keeps the running-task bookkeeping — per-task
//! placements, owning driver (fair-share tenant), and projected
//! completion (conservative backfill) — that the disciplines consume.
//!
//! Backfill (placing a later task past a blocked head) is what lets
//! CPU-only Aggregation tasks slide in beside GPU-saturated Simulation
//! sets — the mechanism behind the paper's TX masking.

mod elastic;

pub use crate::sched::{Policy, QueuedTask, ScheduledTask, Scheduler};
pub use elastic::{AutoscalePolicy, ResizeEvent, ResourcePlan};

use crate::resources::{Allocator, ClusterSpec, NodeSpec, Placement, ResourceRequest};
use crate::sched::{DrainCtx, InFlight};
use crate::task::TaskSpec;

/// One running task's bookkeeping: where its resources live, which
/// driver owns it, what it asked for, and when it is expected to
/// finish (start + sampled TX + launch overhead).
#[derive(Debug, Clone)]
pub struct RunningMeta {
    pub placement: Placement,
    pub tenant: usize,
    pub req: ResourceRequest,
    pub end: f64,
}

/// The pilot agent: allocation + scheduler queue.
///
/// The engine drives it: `submit` when dependencies resolve, `schedule`
/// after every state change, `complete` when the executor reports a
/// task done.
#[derive(Debug)]
pub struct Agent {
    alloc: Allocator,
    sched: Scheduler,
    /// Per-task launch overhead added to TX when projecting a running
    /// task's completion (must match what the engine launches with).
    task_overhead: f64,
    running: Vec<Option<RunningMeta>>, // uid -> running bookkeeping
    /// Scratch for the in-flight projection built by [`schedule`]
    /// (projection policies only): reused across rounds so the hot
    /// path does not allocate two fresh `Vec`s per invocation.
    proj_ends: Vec<(f64, usize)>,
    proj_view: Vec<InFlight>,
}

impl Agent {
    pub fn new(cluster: &ClusterSpec, policy: Policy, task_overhead: f64) -> Agent {
        Agent {
            alloc: Allocator::new(cluster),
            sched: Scheduler::new(policy),
            task_overhead,
            running: Vec::new(),
            proj_ends: Vec::new(),
            proj_view: Vec::new(),
        }
    }

    /// Rebuild an agent from checkpointed parts: an allocator with the
    /// snapshot occupancy already claimed, a scheduler with the queue
    /// re-pushed in insertion order and the fair-share ledger replayed,
    /// and the uid -> running bookkeeping of in-flight tasks.
    pub(crate) fn from_parts(
        alloc: Allocator,
        sched: Scheduler,
        running: Vec<Option<RunningMeta>>,
        task_overhead: f64,
    ) -> Agent {
        Agent {
            alloc,
            sched,
            task_overhead,
            running,
            proj_ends: Vec::new(),
            proj_view: Vec::new(),
        }
    }

    pub fn allocator(&self) -> &Allocator {
        &self.alloc
    }

    /// Queued (unplaced) tasks in insertion order (checkpointing).
    pub fn queued_tasks(&self) -> Vec<QueuedTask> {
        self.sched.queued()
    }

    /// `(uid, placement)` of every running task, ascending by uid
    /// (checkpointing).
    pub fn running_placements(&self) -> Vec<(usize, Placement)> {
        self.running
            .iter()
            .enumerate()
            .filter_map(|(uid, m)| m.as_ref().map(|m| (uid, m.placement.clone())))
            .collect()
    }

    pub fn queue_len(&self) -> usize {
        self.sched.queue_len()
    }

    /// The scheduler's drain accounting (probe/scan counters).
    pub fn sched_stats(&self) -> crate::sched::SchedStats {
        self.sched.stats()
    }

    /// Set a driver slot's fair-share weight (meaningful under
    /// [`Policy::WeightedFair`]; a no-op elsewhere). Checkpoints carry
    /// the weights (see [`Agent::tenant_weights`]), so a weighted run
    /// resumes bit-identically.
    pub fn set_tenant_weight(&mut self, tenant: usize, weight: f64) {
        self.sched.set_weight(tenant, weight);
    }

    /// Non-default `(tenant, weight)` fair-share pairs (checkpointing).
    pub fn tenant_weights(&self) -> Vec<(usize, f64)> {
        self.sched.tenant_weights()
    }

    /// Enqueue a ready task (dependencies already satisfied). `tenant`
    /// is the owning driver slot — the fair-share accounting unit.
    pub fn submit(&mut self, task: &TaskSpec, priority: u64, tenant: usize, submitted_at: f64) {
        self.sched.push(QueuedTask {
            uid: task.uid,
            req: task.req,
            priority,
            submitted_at,
            tenant,
            est: task.tx + self.task_overhead,
        });
    }

    /// Place every queued task the active policy admits, in policy
    /// order. `now` is the engine clock (placed tasks are projected to
    /// finish at `now + est`). Returns the placements of this round.
    pub fn schedule(&mut self, now: f64) -> Vec<ScheduledTask> {
        // The in-flight projection is only built for policies that
        // consume it (conservative backfill) — it costs a sort. Both
        // scratch buffers persist on the agent across rounds.
        self.proj_ends.clear();
        self.proj_view.clear();
        if self.sched.needs_projection() {
            self.proj_ends.extend(
                self.running
                    .iter()
                    .enumerate()
                    .filter_map(|(uid, m)| m.as_ref().map(|m| (m.end, uid))),
            );
            self.proj_ends
                .sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            for &(end, uid) in &self.proj_ends {
                let m = self.running[uid].as_ref().expect("collected above");
                let in_flight =
                    InFlight { end, req: self.releasable(&m.placement), tenant: m.tenant };
                self.proj_view.push(in_flight);
            }
        }
        let ctx = DrainCtx { now, running: &self.proj_view };
        let placed = self.sched.drain_schedulable(&mut self.alloc, &ctx);
        for s in &placed {
            if self.running.len() <= s.uid {
                self.running.resize(s.uid + 1, None);
            }
            self.running[s.uid] = Some(RunningMeta {
                placement: s.placement.clone(),
                tenant: s.task.tenant,
                req: s.task.req,
                end: now + s.task.est,
            });
        }
        placed
    }

    /// The portion of a placement that returns to the free pool when it
    /// releases: slices on draining nodes vanish instead, so the
    /// backfill projection must not count them as future capacity.
    fn releasable(&self, p: &Placement) -> ResourceRequest {
        let (mut c, mut g) = (0u32, 0u32);
        for &(node, cores, gpus) in &p.slots {
            if !self.alloc.is_draining(node) {
                c += cores;
                g += gpus;
            }
        }
        ResourceRequest::new(c, g)
    }

    /// Release a completed task's resources.
    pub fn complete(&mut self, uid: usize) {
        let m = self.running[uid]
            .take()
            .expect("complete() for a task that is not running");
        self.alloc.release(&m.placement);
        self.sched.note_finished(m.tenant, &m.req);
    }

    /// Hard-kill a node: every running task with a slice on it dies
    /// *now*, its partial work lost. The graceful-drain contrast
    /// ([`drain`](Self::drain)): a drained node finishes its running
    /// tasks; a killed node does not.
    ///
    /// Resources follow [`Allocator::release`] semantics per victim —
    /// slices on non-draining nodes (the killed node included, which
    /// restarts fail-stop and returns to service immediately) go back
    /// to the free pool; slices a victim held on *draining* nodes
    /// vanish with the drain. Killing a node that is itself mid-drain
    /// therefore drops its busy share from the offered capacity at
    /// once instead of at task completion, and the node stays
    /// draining. Each victim is also retired from the fair-share
    /// ledger (`note_finished`), so started−finished accounting does
    /// not leak.
    ///
    /// Returns the victims as `(uid, meta)`, ascending by uid; the
    /// engine decides their retry fate. Out-of-range or idle nodes
    /// yield no victims.
    pub fn kill_node(&mut self, node: usize) -> Vec<(usize, RunningMeta)> {
        let mut victims = Vec::new();
        for uid in 0..self.running.len() {
            let touches = self.running[uid]
                .as_ref()
                .is_some_and(|m| m.placement.slots.iter().any(|&(n, _, _)| n == node));
            if touches {
                if let Some(m) = self.running[uid].take() {
                    self.alloc.release(&m.placement);
                    self.sched.note_finished(m.tenant, &m.req);
                    victims.push((uid, m));
                }
            }
        }
        victims
    }

    /// Number of currently running (placed) tasks.
    pub fn running_count(&self) -> usize {
        self.running.iter().filter(|m| m.is_some()).count()
    }

    /// Grow the allocation by `n` nodes of the given shape. Draining
    /// nodes of the *same* shape are reclaimed first (newest first) —
    /// an oscillating autoscaler reuses capacity instead of leaking
    /// zombie node slots — and fresh nodes are appended for the rest.
    /// Returns `n`.
    pub fn grow(&mut self, n: usize, node: NodeSpec) -> usize {
        let mut added = 0;
        for i in (0..self.alloc.node_count()).rev() {
            if added == n {
                break;
            }
            if self.alloc.is_draining(i) && self.alloc.spec().nodes[i] == node {
                self.alloc.undrain_node(i).expect("draining node undrains");
                added += 1;
            }
        }
        while added < n {
            self.alloc.add_node(node);
            added += 1;
        }
        added
    }

    /// Gracefully drain up to `n` nodes: the least-busy schedulable
    /// nodes stop accepting work immediately; tasks already on them run
    /// to completion, and their resources then leave the allocation.
    /// Returns how many nodes actually started draining.
    pub fn drain(&mut self, n: usize) -> usize {
        let picks = self.alloc.drain_candidates(n);
        for &i in &picks {
            self.alloc.drain_node(i).expect("candidate is schedulable");
        }
        picks.len()
    }

    /// `(cores, gpus)` of schedulable capacity (draining nodes excluded).
    pub fn capacity(&self) -> (u64, u64) {
        (self.alloc.capacity_cores(), self.alloc.capacity_gpus())
    }

    /// `(cores, gpus)` of *offered* capacity: schedulable capacity plus
    /// resources still occupied on draining nodes (see
    /// [`Allocator::offered_cores`]) — the utilization denominator.
    pub fn offered(&self) -> (u64, u64) {
        (self.alloc.offered_cores(), self.alloc.offered_gpus())
    }

    /// `(cores, gpus)` currently free.
    pub fn free(&self) -> (u64, u64) {
        (self.alloc.free_cores(), self.alloc.free_gpus())
    }

    /// Number of nodes accepting placements.
    pub fn schedulable_nodes(&self) -> usize {
        self.alloc.schedulable_nodes()
    }

    /// `(cores, gpus)` requested by the queued (unplaced) tasks — the
    /// backlog pressure signal the autoscaler scales on. O(1): the
    /// bucketed queue maintains it incrementally.
    pub fn queued_demand(&self) -> (u64, u64) {
        self.sched.queued_demand()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::ResourceRequest;
    use crate::task::{TaskKind, TaskSpec};

    fn task(uid: usize, cores: u32, gpus: u32) -> TaskSpec {
        TaskSpec {
            uid,
            set_idx: 0,
            ordinal: 0,
            tx: 1.0,
            req: ResourceRequest::new(cores, gpus),
            kind: TaskKind::Stress,
        }
    }

    fn agent(cluster: &ClusterSpec) -> Agent {
        Agent::new(cluster, Policy::default(), 0.0)
    }

    #[test]
    fn agent_schedules_and_completes() {
        let cluster = ClusterSpec::uniform("t", 1, 4, 1);
        let mut agent = agent(&cluster);
        agent.submit(&task(0, 2, 0), 0, 0, 0.0);
        agent.submit(&task(1, 2, 0), 0, 0, 0.0);
        agent.submit(&task(2, 2, 0), 0, 0, 0.0); // won't fit yet
        let placed = agent.schedule(0.0);
        assert_eq!(placed.len(), 2);
        assert_eq!(agent.queue_len(), 1);
        assert_eq!(agent.running_count(), 2);
        agent.complete(0);
        let placed = agent.schedule(1.0);
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 2);
    }

    #[test]
    fn backfill_lets_small_tasks_pass_blocked_head() {
        let cluster = ClusterSpec::uniform("t", 1, 4, 1);
        let mut agent = agent(&cluster);
        // Occupy the GPU.
        agent.submit(&task(0, 1, 1), 0, 0, 0.0);
        assert_eq!(agent.schedule(0.0).len(), 1);
        // Head of queue needs the GPU; behind it a CPU-only task.
        agent.submit(&task(1, 1, 1), 1, 0, 1.0);
        agent.submit(&task(2, 1, 0), 2, 0, 2.0);
        let placed = agent.schedule(2.0);
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 2, "CPU task backfills past blocked GPU task");
    }

    #[test]
    fn conservative_backfill_threads_the_projection_through() {
        // 1 node x 4 cores. A 2-core task runs [0, 100); the head needs
        // all 4 cores, so its projected start is t = 100. A long
        // 1-core task would hold a core past that and must wait under
        // Policy::Backfill; a short 2-core task of a different shape
        // finishes well before t = 100 and may jump.
        let cluster = ClusterSpec::uniform("t", 1, 4, 0);
        let mut agent = Agent::new(&cluster, Policy::Backfill, 0.0);
        let mut blocker = task(0, 2, 0);
        blocker.tx = 100.0;
        agent.submit(&blocker, 0, 0, 0.0);
        assert_eq!(agent.schedule(0.0).len(), 1);
        let mut head = task(1, 4, 0);
        head.tx = 10.0;
        agent.submit(&head, 0, 0, 1.0);
        let mut long_small = task(2, 1, 0);
        long_small.tx = 500.0;
        agent.submit(&long_small, 0, 0, 2.0);
        let mut short_small = task(3, 2, 0);
        short_small.tx = 5.0;
        agent.submit(&short_small, 0, 0, 3.0);
        let placed = agent.schedule(3.0);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![3], "only the short task may jump the blocked head");
    }

    #[test]
    #[should_panic(expected = "not running")]
    fn double_complete_panics() {
        let cluster = ClusterSpec::uniform("t", 1, 4, 1);
        let mut agent = agent(&cluster);
        agent.submit(&task(0, 1, 0), 0, 0, 0.0);
        agent.schedule(0.0);
        agent.complete(0);
        agent.complete(0);
    }

    #[test]
    fn drain_finishes_running_work_and_blocks_new() {
        let cluster = ClusterSpec::uniform("t", 2, 2, 1);
        let mut agent = agent(&cluster);
        // Fill both nodes with one GPU task each.
        agent.submit(&task(0, 1, 1), 0, 0, 0.0);
        agent.submit(&task(1, 1, 1), 0, 0, 0.0);
        let placed = agent.schedule(0.0);
        assert_eq!(placed.len(), 2);
        // Drain one node (both equally busy: newest index drains).
        assert_eq!(agent.drain(1), 1);
        assert_eq!(agent.schedulable_nodes(), 1);
        assert_eq!(agent.capacity(), (2, 1));
        // A new GPU task cannot fit anywhere (survivor's GPU is busy).
        agent.submit(&task(2, 1, 1), 0, 0, 1.0);
        assert!(agent.schedule(1.0).is_empty());
        assert_eq!(agent.queued_demand(), (1, 1));
        // The draining node's task completes; its resources vanish, the
        // queued task still waits for the survivor's GPU.
        let drained_node = placed
            .iter()
            .flat_map(|s| s.placement.slots.iter())
            .map(|&(i, _, _)| i)
            .find(|&i| agent.allocator().is_draining(i))
            .expect("one placement sits on the draining node");
        let victim = placed
            .iter()
            .find(|s| s.placement.slots[0].0 == drained_node)
            .unwrap()
            .uid;
        agent.complete(victim);
        assert!(agent.allocator().node_idle(drained_node));
        assert!(agent.schedule(2.0).is_empty(), "drained GPU must not be re-granted");
        // The survivor's task completes: now the queued task runs.
        agent.complete(1 - victim);
        let placed = agent.schedule(3.0);
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 2);
        assert_ne!(placed[0].placement.slots[0].0, drained_node);
    }

    #[test]
    fn grow_reclaims_draining_nodes_before_appending() {
        let cluster = ClusterSpec::uniform("t", 2, 4, 0);
        let mut agent = agent(&cluster);
        assert_eq!(agent.drain(1), 1);
        assert_eq!(agent.schedulable_nodes(), 1);
        let shape = cluster.nodes[0];
        // Grow by 2: one reclaimed, one appended.
        assert_eq!(agent.grow(2, shape), 2);
        assert_eq!(agent.schedulable_nodes(), 3);
        assert_eq!(agent.allocator().node_count(), 3, "exactly one node appended");
        assert_eq!(agent.capacity(), (12, 0));
        // Different-shape growth never reclaims.
        agent.drain(1);
        agent.grow(1, crate::resources::NodeSpec { cores: 16, gpus: 2 });
        assert_eq!(agent.allocator().node_count(), 4);
        assert_eq!(agent.schedulable_nodes(), 3);
    }

    #[test]
    fn kill_frees_resources_and_node_returns_to_service() {
        let cluster = ClusterSpec::uniform("t", 1, 4, 0);
        let mut agent = agent(&cluster);
        agent.submit(&task(0, 2, 0), 0, 0, 0.0);
        agent.submit(&task(1, 2, 0), 0, 0, 0.0);
        assert_eq!(agent.schedule(0.0).len(), 2);
        assert_eq!(agent.free(), (0, 0));
        // Fail-stop: both running tasks die now, resources return.
        let victims = agent.kill_node(0);
        let uids: Vec<usize> = victims.iter().map(|&(uid, _)| uid).collect();
        assert_eq!(uids, vec![0, 1], "victims ascending by uid");
        assert_eq!(agent.running_count(), 0);
        assert_eq!(agent.free(), (4, 0));
        assert_eq!(agent.offered(), (4, 0), "kill on a schedulable node keeps offered capacity");
        assert!(agent.allocator().node_idle(0));
        // The node restarted and takes new work immediately.
        agent.submit(&task(2, 4, 0), 0, 0, 1.0);
        let placed = agent.schedule(1.0);
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].placement.slots[0].0, 0);
        // Idle and out-of-range nodes yield no victims.
        agent.complete(2);
        assert!(agent.kill_node(0).is_empty());
        assert!(agent.kill_node(7).is_empty());
    }

    #[test]
    fn kill_mid_drain_drops_offered_capacity_immediately() {
        let cluster = ClusterSpec::uniform("t", 2, 2, 0);
        let mut agent = agent(&cluster);
        agent.submit(&task(0, 2, 0), 0, 0, 0.0);
        agent.submit(&task(1, 2, 0), 0, 0, 0.0);
        assert_eq!(agent.schedule(0.0).len(), 2);
        assert_eq!(agent.drain(1), 1);
        let dn = (0..2)
            .find(|&i| agent.allocator().is_draining(i))
            .expect("one node is draining");
        // Graceful contract: the draining node's busy share is still
        // offered until its work finishes...
        assert_eq!(agent.capacity(), (2, 0));
        assert_eq!(agent.offered(), (4, 0));
        // ...but a kill pre-empts the graceful hand-back: the share
        // leaves the allocation at the kill instant.
        let victims = agent.kill_node(dn);
        assert_eq!(victims.len(), 1);
        assert!(victims[0].1.placement.slots.iter().all(|&(n, _, _)| n == dn));
        assert_eq!(agent.offered(), (2, 0), "killed drain share vanishes now, not at completion");
        assert_eq!(agent.capacity(), (2, 0));
        assert!(agent.allocator().is_draining(dn), "kill does not cancel the drain");
        assert!(agent.allocator().node_idle(dn));
        // Killing the surviving schedulable node contrasts: its share
        // returns to the free pool and it stays in service.
        let other = 1 - dn;
        assert_eq!(agent.kill_node(other).len(), 1);
        assert_eq!(agent.offered(), (2, 0));
        assert_eq!(agent.free(), (2, 0));
        assert!(!agent.allocator().is_draining(other));
        agent.submit(&task(2, 2, 0), 0, 0, 1.0);
        let placed = agent.schedule(1.0);
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].placement.slots[0].0, other, "drained node must not be re-granted");
    }

    #[test]
    fn kill_rebuilds_backfill_projection() {
        // Same shape as conservative_backfill_threads_the_projection_
        // through, but the node hosting the projected completion dies:
        // the head must unblock at the next round instead of waiting
        // for a completion that will never come.
        let cluster = ClusterSpec::uniform("t", 1, 4, 0);
        let mut agent = Agent::new(&cluster, Policy::Backfill, 0.0);
        let mut blocker = task(0, 2, 0);
        blocker.tx = 100.0;
        agent.submit(&blocker, 0, 0, 0.0);
        assert_eq!(agent.schedule(0.0).len(), 1);
        let mut head = task(1, 4, 0);
        head.tx = 10.0;
        agent.submit(&head, 0, 0, 1.0);
        let mut long_small = task(2, 1, 0);
        long_small.tx = 500.0;
        agent.submit(&long_small, 0, 0, 2.0);
        assert!(agent.schedule(3.0).is_empty(), "head blocked, long task held");
        let victims = agent.kill_node(0);
        assert_eq!(victims.len(), 1);
        assert_eq!(victims[0].0, 0);
        let placed = agent.schedule(4.0);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![1], "head starts once the dead blocker leaves the projection");
        agent.complete(1);
        let placed = agent.schedule(15.0);
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 2);
    }

    #[test]
    fn kill_releases_fair_share_ledger() {
        // A killed task must be retired from the fair-share ledger
        // exactly like a completed one; otherwise the victim tenant
        // carries phantom usage forever.
        let cluster = ClusterSpec::uniform("t", 1, 4, 0);
        let mut agent = Agent::new(&cluster, Policy::WeightedFair, 0.0);
        agent.submit(&task(0, 2, 0), 0, 0, 0.0);
        agent.submit(&task(1, 2, 0), 0, 0, 0.0);
        assert_eq!(agent.schedule(0.0).len(), 2, "tenant 0 fills node 0");
        let shape = cluster.nodes[0];
        agent.grow(1, shape);
        agent.submit(&task(2, 2, 0), 0, 1, 1.0);
        let placed = agent.schedule(1.0);
        assert_eq!(placed.len(), 1, "tenant 1 lands on the grown node");
        // Usage now: tenant 0 -> 4 cores, tenant 1 -> 2 cores. Kill
        // tenant 0's node; its 4 cores must leave the ledger.
        assert_eq!(agent.kill_node(0).len(), 2);
        // Tenant 1's task submitted first: if the drain fell back to
        // FIFO — or if the kill leaked usage (0-vs-4 beats 1-vs-2) —
        // uid 3 would go first. Fair share with a clean ledger picks
        // tenant 0 (usage 0 < 2).
        agent.submit(&task(3, 2, 0), 0, 1, 2.0);
        agent.submit(&task(4, 2, 0), 0, 0, 2.5);
        let placed = agent.schedule(3.0);
        assert_eq!(placed.len(), 2);
        assert_eq!(placed[0].uid, 4, "killed tenant's usage was released, it goes first");
    }
}
