//! Continuous scheduler with pluggable ordering policies and backfill.

use std::collections::HashSet;

use crate::error::{Error, Result};
use crate::resources::{Allocator, Placement, ResourceRequest};
use crate::util::json::{from_u64, obj, FromJson, Json, ToJson};

/// Queue ordering policies (ablated in `benches/bench_ablations.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Policy {
    /// Order by (priority, submit time, uid); the engine sets priority =
    /// pipeline index, so older pipelines always win. Tempting, but it
    /// starves younger pipelines' stragglers (an old pipeline's 96-task
    /// Inference set trickles through GPUs one-by-one ahead of the last
    /// task of a younger Simulation set) — kept as an ablation.
    PipelineAge,
    /// FIFO by submission time with backfill — RADICAL-Pilot-like and
    /// the default: it reproduces the paper's masking behaviour.
    #[default]
    FifoBackfill,
    /// Pure FIFO, **no** backfill: the head of the queue blocks everyone
    /// behind it (worst case for masking; ablation baseline).
    FifoStrict,
    /// Shortest-job-first by requested cores (greedy packing).
    SmallestFirst,
}

impl Policy {
    /// Stable wire name (configs, checkpoints).
    pub fn label(&self) -> &'static str {
        match self {
            Policy::PipelineAge => "pipeline_age",
            Policy::FifoBackfill => "fifo_backfill",
            Policy::FifoStrict => "fifo_strict",
            Policy::SmallestFirst => "smallest_first",
        }
    }
}

impl std::str::FromStr for Policy {
    type Err = Error;
    fn from_str(s: &str) -> Result<Policy> {
        match s {
            "pipeline_age" => Ok(Policy::PipelineAge),
            "fifo" | "fifo_backfill" => Ok(Policy::FifoBackfill),
            "fifo_strict" => Ok(Policy::FifoStrict),
            "smallest_first" => Ok(Policy::SmallestFirst),
            other => Err(Error::Config(format!("unknown scheduler policy '{other}'"))),
        }
    }
}

/// A task waiting for resources.
#[derive(Debug, Clone, Copy)]
pub struct QueuedTask {
    pub uid: usize,
    pub req: ResourceRequest,
    pub priority: u64,
    pub submitted_at: f64,
}

impl ToJson for QueuedTask {
    fn to_json(&self) -> Json {
        obj([
            ("uid", Json::from(self.uid)),
            ("req", self.req.to_json()),
            ("priority", from_u64(self.priority)),
            ("submitted_at", Json::from(self.submitted_at)),
        ])
    }
}

impl FromJson for QueuedTask {
    fn from_json(v: &Json) -> Result<QueuedTask> {
        Ok(QueuedTask {
            uid: v.req_u64("uid")? as usize,
            req: ResourceRequest::from_json(v.get("req"))?,
            priority: v.req_u64("priority")?,
            submitted_at: v.req_f64("submitted_at")?,
        })
    }
}

/// A task the scheduler just placed.
#[derive(Debug, Clone)]
pub struct ScheduledTask {
    pub uid: usize,
    pub placement: Placement,
}

/// Ready-queue + placement loop.
#[derive(Debug)]
pub struct Scheduler {
    policy: Policy,
    queue: Vec<QueuedTask>,
    /// Monotone counter to make ordering total and deterministic.
    arrival_seq: u64,
    arrivals: Vec<u64>,
    /// True while the queue is already in non-decreasing submit-time
    /// order (the engine submits with a monotone clock, so this is the
    /// common case) — lets FIFO policies skip the sort entirely. Reset
    /// whenever the queue drains empty or compaction leaves a sorted
    /// remainder, so one historical out-of-order push does not tax every
    /// later drain forever.
    fifo_sorted: bool,
    /// How many ordering sorts have been performed (perf accounting;
    /// lets tests and benches observe the FIFO fast path).
    sorts: u64,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Scheduler {
        Scheduler {
            policy,
            queue: Vec::new(),
            arrival_seq: 0,
            arrivals: Vec::new(),
            fifo_sorted: true,
            sorts: 0,
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// The queued tasks in insertion order (checkpoint snapshots;
    /// re-pushing them into a fresh scheduler in this order reproduces
    /// the queue, including FIFO tie-breaks).
    pub fn queued(&self) -> &[QueuedTask] {
        &self.queue
    }

    /// Number of ordering sorts performed so far (the FIFO fast path
    /// performs none).
    pub fn sorts_performed(&self) -> u64 {
        self.sorts
    }

    /// Total `(cores, gpus)` requested by the queued tasks. O(queue);
    /// called per autoscaler evaluation, not per scheduling round.
    pub fn queued_demand(&self) -> (u64, u64) {
        self.queue.iter().fold((0, 0), |(c, g), t| {
            (c + t.req.cpu_cores as u64, g + t.req.gpus as u64)
        })
    }

    pub fn push(&mut self, t: QueuedTask) {
        match self.queue.last() {
            Some(last) => {
                if t.submitted_at < last.submitted_at {
                    self.fifo_sorted = false;
                }
            }
            // A single element is trivially sorted, whatever history
            // left `fifo_sorted` at.
            None => self.fifo_sorted = true,
        }
        self.queue.push(t);
        self.arrivals.push(self.arrival_seq);
        self.arrival_seq += 1;
    }

    fn order(&mut self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.queue.len()).collect();
        if self.fifo_sorted
            && matches!(self.policy, Policy::FifoBackfill | Policy::FifoStrict)
        {
            return idx; // insertion order == FIFO order
        }
        self.sorts += 1;
        match self.policy {
            Policy::PipelineAge => idx.sort_by(|&a, &b| {
                let (ta, tb) = (&self.queue[a], &self.queue[b]);
                ta.priority
                    .cmp(&tb.priority)
                    .then(ta.submitted_at.total_cmp(&tb.submitted_at))
                    .then(self.arrivals[a].cmp(&self.arrivals[b]))
            }),
            Policy::FifoBackfill | Policy::FifoStrict => idx.sort_by(|&a, &b| {
                self.queue[a]
                    .submitted_at
                    .total_cmp(&self.queue[b].submitted_at)
                    .then(self.arrivals[a].cmp(&self.arrivals[b]))
            }),
            Policy::SmallestFirst => idx.sort_by(|&a, &b| {
                let (ta, tb) = (&self.queue[a], &self.queue[b]);
                (ta.req.cpu_cores + 100 * ta.req.gpus)
                    .cmp(&(tb.req.cpu_cores + 100 * tb.req.gpus))
                    .then(self.arrivals[a].cmp(&self.arrivals[b]))
            }),
        }
        idx
    }

    /// Walk the queue in policy order placing what fits; remove placed
    /// entries. With `FifoStrict` the walk stops at the first task that
    /// does not fit.
    ///
    /// Perf: within one drain round the allocation only shrinks, so a
    /// request shape that failed once can never succeed later in the
    /// round — identical shapes are memoized in a hash set and skipped
    /// in O(1) (large win for the paper's homogeneous 96-task sets:
    /// 1 placement probe instead of 96 node scans per blocked set, and
    /// no linear memo probe per queued task).
    pub fn drain_schedulable(&mut self, alloc: &mut Allocator) -> Vec<ScheduledTask> {
        let order = self.order();
        let mut placed = Vec::new();
        // Allocated lazily on the first placement: a fully-blocked
        // drain round touches nothing.
        let mut remove: Vec<bool> = Vec::new();
        let mut failed_shapes: HashSet<ResourceRequest> = HashSet::new();
        for &i in &order {
            let t = self.queue[i];
            if failed_shapes.contains(&t.req) {
                if self.policy == Policy::FifoStrict {
                    break;
                }
                continue;
            }
            match alloc.try_alloc(&t.req) {
                Some(placement) => {
                    if remove.is_empty() {
                        remove = vec![false; self.queue.len()];
                    }
                    placed.push(ScheduledTask { uid: t.uid, placement });
                    remove[i] = true;
                }
                None => {
                    if self.policy == Policy::FifoStrict {
                        break;
                    }
                    failed_shapes.insert(t.req);
                }
            }
        }
        // Nothing placed (the common case for a blocked queue under
        // sustained load): the queue is untouched, so skip the
        // compaction copy entirely.
        if placed.is_empty() {
            return placed;
        }
        // Compact queue preserving insertion order.
        let mut q = Vec::with_capacity(self.queue.len() - placed.len());
        let mut a = Vec::with_capacity(q.capacity());
        for (i, t) in self.queue.iter().enumerate() {
            if !remove[i] {
                q.push(*t);
                a.push(self.arrivals[i]);
            }
        }
        self.queue = q;
        self.arrivals = a;
        // Out-of-order pushes are transient; once the disordered entries
        // have drained (fully, or down to a sorted remainder) the FIFO
        // fast path is valid again.
        if !self.fifo_sorted {
            self.fifo_sorted = self
                .queue
                .windows(2)
                .all(|w| w[0].submitted_at <= w[1].submitted_at);
        }
        placed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::ClusterSpec;

    fn qt(uid: usize, cores: u32, gpus: u32, prio: u64, at: f64) -> QueuedTask {
        QueuedTask { uid, req: ResourceRequest::new(cores, gpus), priority: prio, submitted_at: at }
    }

    #[test]
    fn pipeline_age_orders_by_priority() {
        let mut s = Scheduler::new(Policy::PipelineAge);
        s.push(qt(0, 1, 0, 2, 0.0));
        s.push(qt(1, 1, 0, 0, 5.0));
        s.push(qt(2, 1, 0, 1, 1.0));
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 8, 0));
        let placed = s.drain_schedulable(&mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![1, 2, 0]);
    }

    #[test]
    fn fifo_strict_blocks_behind_head() {
        let mut s = Scheduler::new(Policy::FifoStrict);
        s.push(qt(0, 8, 0, 0, 0.0)); // fills the node
        s.push(qt(1, 16, 0, 0, 1.0)); // can never fit now
        s.push(qt(2, 1, 0, 0, 2.0)); // would fit, but strictly blocked
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 2, 8, 0));
        let placed = s.drain_schedulable(&mut alloc);
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 0);
        assert_eq!(s.queue_len(), 2);
    }

    #[test]
    fn fifo_backfill_skips_blocked_head() {
        let mut s = Scheduler::new(Policy::FifoBackfill);
        s.push(qt(0, 8, 0, 0, 0.0));
        s.push(qt(1, 16, 0, 0, 1.0));
        s.push(qt(2, 1, 0, 0, 2.0));
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 2, 8, 0));
        let placed = s.drain_schedulable(&mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![0, 2]);
    }

    #[test]
    fn smallest_first_packs_greedily() {
        let mut s = Scheduler::new(Policy::SmallestFirst);
        s.push(qt(0, 6, 0, 0, 0.0));
        s.push(qt(1, 1, 0, 0, 1.0));
        s.push(qt(2, 3, 0, 0, 2.0));
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 4, 0));
        let placed = s.drain_schedulable(&mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![1, 2]); // 1+3 cores; the 6-core task waits
    }

    #[test]
    fn fifo_out_of_order_pushes_still_sorted() {
        // Regression for the fifo_sorted fast path: pushing an earlier
        // submit time after a later one must disable the shortcut and
        // fall back to the true FIFO order.
        let mut s = Scheduler::new(Policy::FifoBackfill);
        s.push(qt(0, 1, 0, 0, 5.0));
        s.push(qt(1, 1, 0, 0, 1.0)); // earlier, pushed later
        s.push(qt(2, 1, 0, 0, 3.0));
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 3, 0));
        let placed = s.drain_schedulable(&mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![1, 2, 0]);
    }

    #[test]
    fn failed_shape_memo_skips_identical_requests() {
        // 3 identical big tasks that cannot fit plus one small one:
        // the small one still backfills (memo must not block different
        // shapes).
        let mut s = Scheduler::new(Policy::FifoBackfill);
        for uid in 0..3 {
            s.push(qt(uid, 16, 0, 0, uid as f64));
        }
        s.push(qt(9, 1, 0, 0, 9.0));
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 8, 0));
        let placed = s.drain_schedulable(&mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![9]);
        assert_eq!(s.queue_len(), 3);
    }

    #[test]
    fn fifo_fast_path_recovers_after_full_drain() {
        // Regression: one out-of-order push used to flip `fifo_sorted`
        // permanently, so every later FIFO drain paid a sort — even
        // after the queue had fully drained.
        let mut s = Scheduler::new(Policy::FifoBackfill);
        s.push(qt(0, 1, 0, 0, 5.0));
        s.push(qt(1, 1, 0, 0, 1.0)); // earlier submit, pushed later
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 8, 0));
        let placed = s.drain_schedulable(&mut alloc);
        assert_eq!(
            placed.iter().map(|p| p.uid).collect::<Vec<_>>(),
            vec![1, 0],
            "true FIFO order despite out-of-order push"
        );
        assert_eq!(s.queue_len(), 0);
        let sorts_after_disorder = s.sorts_performed();
        assert!(sorts_after_disorder >= 1, "disordered drain must sort");
        // Queue drained: in-order pushes must ride the fast path again.
        s.push(qt(2, 1, 0, 0, 7.0));
        s.push(qt(3, 1, 0, 0, 8.0));
        let placed = s.drain_schedulable(&mut alloc);
        assert_eq!(placed.iter().map(|p| p.uid).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(
            s.sorts_performed(),
            sorts_after_disorder,
            "FIFO fast path must be back after the queue drained empty"
        );
    }

    #[test]
    fn fifo_fast_path_recovers_after_sorted_remainder() {
        // Partial drain that removes the disordered entry: the sorted
        // remainder re-enables the fast path.
        let mut s = Scheduler::new(Policy::FifoBackfill);
        s.push(qt(0, 1, 0, 0, 5.0));
        s.push(qt(1, 1, 0, 0, 1.0)); // out of order; drains first (FIFO)
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 1, 0));
        let placed = s.drain_schedulable(&mut alloc);
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 1, "FIFO places the earliest submit");
        assert_eq!(s.queue_len(), 1, "uid 0 remains queued");
        let sorts = s.sorts_performed();
        alloc.release(&placed[0].placement);
        let placed = s.drain_schedulable(&mut alloc);
        assert_eq!(placed[0].uid, 0);
        assert_eq!(
            s.sorts_performed(),
            sorts,
            "single-element remainder is sorted; no further sorts"
        );
    }

    #[test]
    fn noop_drain_leaves_queue_untouched() {
        // Regression: a drain that places nothing used to rebuild the
        // queue and arrival vectors anyway — the common case for a
        // blocked queue under sustained load.
        let mut s = Scheduler::new(Policy::FifoBackfill);
        for uid in 0..4 {
            s.push(qt(uid, 16, 0, 0, uid as f64)); // none fit on 8 cores
        }
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 8, 0));
        let ptr_before = s.queue.as_ptr();
        let arr_before = s.arrivals.as_ptr();
        let placed = s.drain_schedulable(&mut alloc);
        assert!(placed.is_empty());
        assert_eq!(s.queue_len(), 4);
        assert_eq!(
            s.queue.as_ptr(),
            ptr_before,
            "no-op drain must not reallocate the queue"
        );
        assert_eq!(
            s.arrivals.as_ptr(),
            arr_before,
            "no-op drain must not reallocate the arrival tags"
        );
    }

    #[test]
    fn deterministic_tie_break() {
        // Identical priorities/timestamps: arrival order wins, stably.
        let mut s = Scheduler::new(Policy::PipelineAge);
        for uid in 0..5 {
            s.push(qt(uid, 1, 0, 0, 0.0));
        }
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 5, 0));
        let placed = s.drain_schedulable(&mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![0, 1, 2, 3, 4]);
    }
}
