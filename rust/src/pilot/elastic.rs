//! Elastic resource plans: timed grow/shrink events and a
//! backlog-driven autoscaler policy for the pilot allocation.
//!
//! A [`ResourcePlan`] describes how the allocation should change while
//! workflows run. Two mechanisms compose:
//!
//! - **Timed events** ([`ResizeEvent`]): "at t = 5000 s add 4 nodes, at
//!   t = 12000 s drain 8" — the shape of a queue-backfill or
//!   walltime-limited allocation on a leadership-class machine (CLI:
//!   `asyncflow traffic --resize 5000:+4,12000:-8`).
//! - **Autoscaling** ([`AutoscalePolicy`]): evaluated every
//!   [`interval`](AutoscalePolicy::interval) engine seconds against the
//!   scheduler backlog and idle capacity, growing toward
//!   [`max_nodes`](AutoscalePolicy::max_nodes) under queue pressure and
//!   draining toward [`min_nodes`](AutoscalePolicy::min_nodes) when the
//!   allocation sits idle (CLI: `asyncflow traffic --autoscale`).
//!
//! The [`Coordinator`](crate::engine::Coordinator) applies the plan to
//! the shared pilot [`Agent`](crate::pilot::Agent) inside its event
//! loop and records every change to the *offered* capacity on the
//! run's [`CapacityTimeline`](crate::metrics::CapacityTimeline), which
//! is what utilization metrics integrate against. Shrinks are
//! *graceful*: drained nodes finish their running tasks and never
//! accept new ones (see
//! [`Allocator::drain_node`](crate::resources::Allocator::drain_node));
//! their free cores leave the timeline at the drain, their busy cores
//! when the work on them completes.
//!
//! Plans are plain data (`Clone + PartialEq`) and are part of a traffic
//! scenario's identity: the same seed and the same plan reproduce a
//! bit-identical [`TrafficReport`](crate::traffic::TrafficReport).

use crate::error::{Error, Result};
use crate::resources::NodeSpec;
use crate::util::json::{arr_of, obj, parse_arr, FromJson, Json, ToJson};

/// One timed capacity change: at engine time `at`, add (`delta` > 0) or
/// drain (`delta` < 0) that many nodes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResizeEvent {
    /// Engine time (seconds, >= 0) at which the change applies.
    pub at: f64,
    /// Node count delta: positive grows, negative drains.
    pub delta: i64,
}

impl ToJson for ResizeEvent {
    fn to_json(&self) -> Json {
        obj([("at", Json::from(self.at)), ("delta", Json::Num(self.delta as f64))])
    }
}

impl FromJson for ResizeEvent {
    fn from_json(v: &Json) -> Result<ResizeEvent> {
        Ok(ResizeEvent { at: v.req_f64("at")?, delta: v.req_i64("delta")? })
    }
}

/// Backlog-driven autoscaler: evaluated every `interval` engine
/// seconds while work is outstanding.
///
/// Scale-up triggers when the queued resource demand exceeds
/// `up_backlog` times the current schedulable capacity (or when tasks
/// are queued with nothing running at all — the rescue case after a
/// deep shrink); scale-down triggers when the queue is empty and at
/// least `down_idle` of the capacity sits free. Both move `step` nodes
/// per evaluation and respect the `[min_nodes, max_nodes]` band.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscalePolicy {
    /// Evaluation cadence in engine seconds (> 0).
    pub interval: f64,
    /// Never drain below this many schedulable nodes.
    pub min_nodes: usize,
    /// Never grow above this many schedulable nodes.
    pub max_nodes: usize,
    /// Scale up when queued cores (or GPUs) exceed this fraction of the
    /// schedulable capacity.
    pub up_backlog: f64,
    /// Scale down when the queue is empty and at least this fraction of
    /// the capacity is free.
    pub down_idle: f64,
    /// Nodes added / drained per evaluation (>= 1).
    pub step: usize,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            interval: 300.0,
            min_nodes: 1,
            max_nodes: 64,
            up_backlog: 0.5,
            down_idle: 0.95,
            step: 1,
        }
    }
}

impl AutoscalePolicy {
    fn validate(&self) -> Result<()> {
        if !self.interval.is_finite() || self.interval <= 0.0 {
            return Err(Error::Config(format!(
                "autoscale: interval must be positive, got {}",
                self.interval
            )));
        }
        if self.min_nodes > self.max_nodes {
            return Err(Error::Config(format!(
                "autoscale: min_nodes {} exceeds max_nodes {}",
                self.min_nodes, self.max_nodes
            )));
        }
        if self.step == 0 {
            return Err(Error::Config("autoscale: step must be >= 1".into()));
        }
        if !self.up_backlog.is_finite()
            || self.up_backlog < 0.0
            || !self.down_idle.is_finite()
            || !(0.0..=1.0).contains(&self.down_idle)
        {
            return Err(Error::Config(format!(
                "autoscale: thresholds out of range (up_backlog {}, down_idle {})",
                self.up_backlog, self.down_idle
            )));
        }
        Ok(())
    }
}

impl ToJson for AutoscalePolicy {
    fn to_json(&self) -> Json {
        obj([
            ("interval", Json::from(self.interval)),
            ("min_nodes", Json::from(self.min_nodes)),
            ("max_nodes", Json::from(self.max_nodes)),
            ("up_backlog", Json::from(self.up_backlog)),
            ("down_idle", Json::from(self.down_idle)),
            ("step", Json::from(self.step)),
        ])
    }
}

impl FromJson for AutoscalePolicy {
    fn from_json(v: &Json) -> Result<AutoscalePolicy> {
        let p = AutoscalePolicy {
            interval: v.req_f64("interval")?,
            min_nodes: v.req_u64("min_nodes")? as usize,
            max_nodes: v.req_u64("max_nodes")? as usize,
            up_backlog: v.req_f64("up_backlog")?,
            down_idle: v.req_f64("down_idle")?,
            step: v.req_u64("step")? as usize,
        };
        p.validate()?;
        Ok(p)
    }
}

/// How the pilot allocation changes over a run: timed events, an
/// optional autoscaler, and the node shape used when growing.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ResourcePlan {
    /// Timed grow/drain events (applied in time order).
    pub events: Vec<ResizeEvent>,
    /// Optional backlog-driven autoscaler.
    pub autoscale: Option<AutoscalePolicy>,
    /// Shape of nodes added by grow events / the autoscaler; `None`
    /// clones the initial cluster's first node.
    pub node: Option<NodeSpec>,
}

impl ResourcePlan {
    pub fn new() -> ResourcePlan {
        ResourcePlan::default()
    }

    /// Builder: append one timed resize event.
    pub fn resize(mut self, at: f64, delta: i64) -> ResourcePlan {
        self.events.push(ResizeEvent { at, delta });
        self
    }

    /// Builder: enable the autoscaler.
    pub fn with_autoscale(mut self, policy: AutoscalePolicy) -> ResourcePlan {
        self.autoscale = Some(policy);
        self
    }

    /// Builder: set the node shape used for growth.
    pub fn with_node(mut self, node: NodeSpec) -> ResourcePlan {
        self.node = Some(node);
        self
    }

    /// A plan with neither events nor an autoscaler does nothing.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.autoscale.is_none()
    }

    /// Parse the CLI resize spec `"t:+n,t:-n,..."`.
    ///
    /// ```
    /// use asyncflow::pilot::ResourcePlan;
    ///
    /// let plan = ResourcePlan::parse_resize("5000:+4,12000:-8").unwrap();
    /// assert_eq!(plan.events.len(), 2);
    /// assert_eq!(plan.events[1].delta, -8);
    /// ```
    pub fn parse_resize(spec: &str) -> Result<ResourcePlan> {
        let mut plan = ResourcePlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (t, d) = part.split_once(':').ok_or_else(|| {
                Error::Config(format!("--resize: expected t:+n or t:-n, got '{part}'"))
            })?;
            let at: f64 = t.trim().parse().map_err(|_| {
                Error::Config(format!("--resize: bad time in '{part}'"))
            })?;
            let delta: i64 = d.trim().parse().map_err(|_| {
                Error::Config(format!("--resize: bad node delta in '{part}'"))
            })?;
            plan.events.push(ResizeEvent { at, delta });
        }
        if plan.events.is_empty() {
            return Err(Error::Config(format!("--resize: no events in '{spec}'")));
        }
        plan.validate()?;
        Ok(plan)
    }

    /// Check the plan is well-formed (finite non-negative event times,
    /// nonzero deltas, no duplicate timestamps, sane autoscaler
    /// parameters). Duplicate timestamps are rejected because the
    /// apply order of same-instant resizes would be spec-order
    /// dependent — fold them into one signed delta instead.
    pub fn validate(&self) -> Result<()> {
        for e in &self.events {
            if !e.at.is_finite() || e.at < 0.0 {
                return Err(Error::Config(format!(
                    "resource plan: invalid event time {}",
                    e.at
                )));
            }
            if e.delta == 0 {
                return Err(Error::Config(format!(
                    "resource plan: zero-node resize at t = {}",
                    e.at
                )));
            }
        }
        let mut times: Vec<f64> = self.events.iter().map(|e| e.at).collect();
        times.sort_by(f64::total_cmp);
        if let Some(w) = times.windows(2).find(|w| w[0] == w[1]) {
            return Err(Error::Config(format!(
                "resource plan: duplicate resize timestamp t = {} \
                 (fold same-instant events into one delta)",
                w[0]
            )));
        }
        if let Some(p) = &self.autoscale {
            p.validate()?;
        }
        Ok(())
    }
}

impl ToJson for ResourcePlan {
    fn to_json(&self) -> Json {
        obj([
            ("events", arr_of(&self.events)),
            (
                "autoscale",
                match &self.autoscale {
                    Some(p) => p.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "node",
                match &self.node {
                    Some(n) => n.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }
}

impl FromJson for ResourcePlan {
    fn from_json(v: &Json) -> Result<ResourcePlan> {
        let events = parse_arr(v, "events")?;
        let autoscale = match v.get("autoscale") {
            Json::Null => None,
            p => Some(AutoscalePolicy::from_json(p)?),
        };
        let node = match v.get("node") {
            Json::Null => None,
            n => Some(NodeSpec::from_json(n)?),
        };
        let plan = ResourcePlan { events, autoscale, node };
        plan.validate()?;
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_resize_accepts_signed_deltas() {
        let plan = ResourcePlan::parse_resize("5000:+4, 12000:-8").unwrap();
        assert_eq!(
            plan.events,
            vec![
                ResizeEvent { at: 5000.0, delta: 4 },
                ResizeEvent { at: 12000.0, delta: -8 },
            ]
        );
        assert!(plan.autoscale.is_none());
        assert!(!plan.is_empty());
        // Bare numbers grow too (parse accepts a leading '+' or none).
        let p2 = ResourcePlan::parse_resize("0:2").unwrap();
        assert_eq!(p2.events[0].delta, 2);
    }

    #[test]
    fn parse_resize_rejects_garbage() {
        assert!(ResourcePlan::parse_resize("").is_err());
        assert!(ResourcePlan::parse_resize("5000").is_err());
        assert!(ResourcePlan::parse_resize("x:+4").is_err());
        assert!(ResourcePlan::parse_resize("100:zero").is_err());
        assert!(ResourcePlan::parse_resize("100:+0").is_err());
        assert!(ResourcePlan::parse_resize("-5:+1").is_err());
    }

    #[test]
    fn parse_resize_rejects_malformed_tokens_with_context() {
        // Every malformed-token class names the offending token so CLI
        // users see *which* part of a long spec is broken.
        for (spec, needle) in [
            ("5000:+4,:-2", "':-2'"),          // empty time
            ("5000:", "'5000:'"),              // empty delta
            ("10:+2,20::+1", "'20::+1'"),      // double separator
            ("1e3:+2,nan:-1", "NaN"),          // non-finite time
            ("inf:+1", "inf"),                 // infinite time
            ("10:+1.5", "'10:+1.5'"),          // fractional node delta
            ("10:++2", "'10:++2'"),            // double sign
        ] {
            let err = ResourcePlan::parse_resize(spec).unwrap_err().to_string();
            assert!(
                err.contains(needle),
                "spec {spec:?} must fail mentioning {needle}: got {err:?}"
            );
        }
    }

    #[test]
    fn parse_resize_rejects_duplicate_timestamps_and_negative_times() {
        // Duplicate timestamps are ambiguous (apply order would be
        // spec-order dependent) and rejected by validate().
        let err = ResourcePlan::parse_resize("100:+2,100:-1").unwrap_err().to_string();
        assert!(err.contains("duplicate"), "got {err:?}");
        // ... including duplicates written in different spellings.
        assert!(ResourcePlan::parse_resize("100.0:+2,100:+1").is_err());
        // Negative times are invalid wherever they appear in the spec.
        for spec in ["-1:+2", "10:+1,-3:-1", "-0.5:-2"] {
            let err = ResourcePlan::parse_resize(spec).unwrap_err().to_string();
            assert!(
                err.contains("bad time") || err.contains("invalid event time"),
                "spec {spec:?}: got {err:?}"
            );
        }
        // The builder path hits the same validation.
        let dup = ResourcePlan::new().resize(5.0, 1).resize(5.0, -1);
        assert!(dup.validate().is_err());
    }

    #[test]
    fn plan_round_trips_through_json() {
        let plan = ResourcePlan::new()
            .resize(100.0, 2)
            .resize(900.0, -1)
            .with_autoscale(AutoscalePolicy { step: 3, ..AutoscalePolicy::default() })
            .with_node(NodeSpec { cores: 8, gpus: 2 });
        let wire = plan.to_json().to_string();
        let back =
            ResourcePlan::from_json(&crate::util::json::Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, plan);
        // None fields stay None.
        let bare = ResourcePlan::new().resize(1.0, 1);
        let back = ResourcePlan::from_json(
            &crate::util::json::Json::parse(&bare.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back, bare);
    }

    #[test]
    fn validate_checks_autoscale_band() {
        let bad = ResourcePlan::new().with_autoscale(AutoscalePolicy {
            min_nodes: 8,
            max_nodes: 2,
            ..AutoscalePolicy::default()
        });
        assert!(bad.validate().is_err());
        let bad = ResourcePlan::new().with_autoscale(AutoscalePolicy {
            interval: 0.0,
            ..AutoscalePolicy::default()
        });
        assert!(bad.validate().is_err());
        let bad = ResourcePlan::new().with_autoscale(AutoscalePolicy {
            step: 0,
            ..AutoscalePolicy::default()
        });
        assert!(bad.validate().is_err());
        let ok = ResourcePlan::new()
            .resize(100.0, 2)
            .with_autoscale(AutoscalePolicy::default());
        assert!(ok.validate().is_ok());
    }
}
